"""Observability overhead + batched first-failure attribution (DESIGN.md §12).

Three questions, machine-checked across PRs via
``results/BENCH_observability.json``:

1. **Disarmed instrumentation**: the trace seams and registry-backed
   counters sit on the serving path permanently.  With no tracer armed
   they must cost one module-global ``None`` check per seam -- the
   isolated linked launch at B=4096 must stay within noise (<5%) of the
   raw launch, i.e. no regression vs the PR 6 clean path.
2. **Armed tracer**: what arming actually costs (two monotonic-clock
   reads + one ring append per span, at batch granularity).
3. **Attribution**: what ``explain=True`` adds to the hybrid admission
   path (one extra detail-capturing launch over the already-encoded
   table), and whether the batched attribution agrees with the
   sequential oracle on the seeded mixed stream.
4. **Cost attribution** (DESIGN.md §13): with a :class:`Profiler` armed
   over one end-to-end ``admit_mixed_ex`` at B=4096, the exclusive
   phase times must explain >=90% of the measured wall window, the armed
   overhead is recorded, and the disarmed admit path is compared against
   the committed HEAD baseline (the <2% disarmed-seam bar).

Same schemas, mix, and encode budget as ``benchmarks/registry.py``.
Also renders the shared MetricRegistry to
``results/metrics_snapshot.prom`` after a small end-to-end serve burst,
so CI archives one Prometheus export covering the whole surface.
"""

from __future__ import annotations

import json
import random
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core.outcomes import ValidationOutcome
from repro.data.doc_table import encode_batch
from repro.obs import Profiler, Tracer
from repro.registry import SchemaRegistry
from repro.registry.presets import GATEWAY_SCHEMAS as SCHEMAS

from .registry import MAX_NODES, _mixed_stream

BATCH = 4096
DIFF_SAMPLE = 512  # differential-agreement sample of the mixed stream
RESULTS = Path(__file__).resolve().parents[1] / "results"


def _best_of(fn, n=5) -> float:
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _armed_admit(prof: Profiler, admit) -> None:
    """One admit pass with the (cleared) profiler armed -- measures what
    arming actually costs on top of the disarmed seams."""
    prof.clear()
    with prof:
        admit(False)


def _baseline_admit_us() -> float:
    """``admit_us_per_doc`` from the committed HEAD BENCH_observability
    baseline, or 0.0 when unavailable (first appearance / no git)."""
    import subprocess

    try:
        blob = subprocess.run(
            ["git", "show", "HEAD:results/BENCH_observability.json"],
            capture_output=True,
            text=True,
            check=True,
            cwd=Path(__file__).resolve().parents[1],
        ).stdout
        return float(json.loads(blob)["explain"]["admit_us_per_doc"])
    except Exception:
        return 0.0


def _serve_burst(reg: SchemaRegistry, docs, endpoints, n=64) -> None:
    """Push a small end-to-end burst through ServeEngine so the serve_*
    metric families (latency histograms, outcome counters) show up in
    the exported snapshot alongside the executor/registry families."""
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_config("granite-3-8b").reduced()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg,
        params,
        ServeConfig(batch_slots=2, max_len=64, default_max_tokens=4),
        registry=reg,
    )
    requests = [
        (e, json.dumps(d, sort_keys=True))
        for e, d in zip(endpoints[:n], docs[:n])
    ]
    engine.submit_batch(requests, explain=True)
    engine.submit(requests[0][1], requests[0][0])


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []
    rng = random.Random(0)

    reg = SchemaRegistry(use_pallas=False)
    for name, schema in SCHEMAS.items():
        reg.register(name, schema)
    bv = reg.batch_validator()
    docs, endpoints = _mixed_stream(BATCH, rng)
    ids = reg.schema_ids(endpoints).astype(np.int32)
    table = encode_batch(docs, max_nodes=MAX_NODES)
    keys = list(range(BATCH))

    # -- 1. disarmed seams: raw launch vs the instrumented isolated path -----
    bv.validate_ex(table, ids)  # warm the jit
    bv.validate_isolated(table, ids, keys=keys)
    t_raw = _best_of(lambda: bv.validate_ex(table, ids))
    t_disarmed = _best_of(lambda: bv.validate_isolated(table, ids, keys=keys))
    disarmed_pct = 100.0 * (t_disarmed - t_raw) / t_raw

    # -- 2. armed tracer: same launch with the ring buffer recording ---------
    with Tracer(capacity=4096) as tr:
        t_armed = _best_of(lambda: bv.validate_isolated(table, ids, keys=keys))
        spans_recorded = tr.recorded
    armed_pct = 100.0 * (t_armed - t_disarmed) / t_disarmed

    raw_us = t_raw / BATCH * 1e6
    disarmed_us = t_disarmed / BATCH * 1e6
    armed_us = t_armed / BATCH * 1e6
    lines.append(f"launch_raw,{raw_us:.3f},B={BATCH}")
    lines.append(
        f"launch_disarmed,{disarmed_us:.3f},overhead={disarmed_pct:.2f}%"
    )
    lines.append(
        f"launch_traced,{armed_us:.3f},overhead={armed_pct:.2f}%"
        f" spans={spans_recorded}"
    )

    # -- 3. hybrid admission: explain=False vs explain=True ------------------
    def admit(explain: bool):
        return reg.admit_mixed_ex(
            docs, endpoints, max_nodes=MAX_NODES, explain=explain
        )

    verdicts, _ = admit(False)  # warm (encode cache is per-call; jit persists)
    admit(True)
    n_invalid = sum(
        1 for v in verdicts if v.outcome is ValidationOutcome.INVALID
    )
    t_admit = _best_of(lambda: admit(False), n=3)
    t_explain = _best_of(lambda: admit(True), n=3)
    explain_pct = 100.0 * (t_explain - t_admit) / t_admit
    admit_us = t_admit / BATCH * 1e6
    explain_us = t_explain / BATCH * 1e6
    lines.append(f"admit_mixed,{admit_us:.3f},B={BATCH}")
    lines.append(
        f"admit_mixed_explain,{explain_us:.3f},overhead={explain_pct:.2f}%"
        f" invalid={n_invalid}"
    )

    # -- 4. cost attribution: armed profiler over one admit at B=4096 --------
    with Profiler() as prof:
        t0 = time.perf_counter_ns()
        admit(False)
        window_ns = time.perf_counter_ns() - t0
    attribution = prof.report(window_ns)
    t_admit_armed = _best_of(lambda: _armed_admit(prof, admit), n=3)
    profiler_armed_pct = 100.0 * (t_admit_armed - t_admit) / t_admit
    armed_admit_us = t_admit_armed / BATCH * 1e6
    lines.append(
        f"admit_attributed,{armed_admit_us:.3f},"
        f"coverage={attribution['coverage'] * 100:.1f}%"
        f" armed_overhead={profiler_armed_pct:.2f}%"
    )
    # disarmed seam bar (<2%): the same admit path against the committed
    # HEAD baseline -- cross-PR, so best-effort (first run has none)
    base_admit_us = _baseline_admit_us()
    disarmed_seam_pct = (
        100.0 * (admit_us - base_admit_us) / base_admit_us
        if base_admit_us
        else None
    )
    if disarmed_seam_pct is not None:
        lines.append(
            f"admit_disarmed_vs_baseline,{admit_us:.3f},"
            f"baseline_us={base_admit_us:.3f};delta={disarmed_seam_pct:+.2f}%"
        )

    # -- differential agreement vs the sequential oracle ---------------------
    sample_docs = docs[:DIFF_SAMPLE]
    sample_eps = endpoints[:DIFF_SAMPLE]
    verdicts, _ = reg.admit_mixed_ex(
        sample_docs, sample_eps, max_nodes=MAX_NODES, explain=True
    )
    agree = checked = 0
    for doc, ep, v in zip(sample_docs, sample_eps, verdicts):
        if v.outcome is not ValidationOutcome.INVALID or v.site is None:
            continue
        checked += 1
        ok, trace = reg.get(ep).validator.explain(doc)
        assert not ok
        if v.site.schema_path in {p for p, _ in trace}:
            agree += 1
    agreement = agree / checked if checked else 1.0
    lines.append(
        f"explain_agreement,{agreement * 100:.1f},"
        f"{agree}/{checked} invalid docs vs sequential"
    )

    payload = {
        "batch": BATCH,
        "max_nodes": MAX_NODES,
        "launch": {
            "raw_us_per_doc": raw_us,
            "disarmed_us_per_doc": disarmed_us,
            "traced_us_per_doc": armed_us,
            "disarmed_overhead_pct": disarmed_pct,
            "traced_overhead_pct": armed_pct,
            "spans_recorded": spans_recorded,
        },
        "explain": {
            "admit_us_per_doc": admit_us,
            "explain_us_per_doc": explain_us,
            "explain_overhead_pct": explain_pct,
            "n_invalid": n_invalid,
            "differential_checked": checked,
            "differential_agree": agree,
            "differential_agreement": agreement,
        },
        "profile": {
            "coverage": attribution["coverage"],
            "window_us": attribution["window_ns"] / 1e3,
            "attributed_us": attribution["attributed_ns"] / 1e3,
            "phases": attribution["phases"],
            "armed_admit_us_per_doc": armed_admit_us,
            "profiler_armed_overhead_pct": profiler_armed_pct,
            "baseline_admit_us": base_admit_us or None,
            "disarmed_seam_overhead_pct": disarmed_seam_pct,
        },
    }
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "BENCH_observability.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    report["observability"] = payload
    lines.append(f"# wrote {out}")

    # -- Prometheus snapshot artifact ----------------------------------------
    try:
        _serve_burst(reg, docs, endpoints)
    except Exception as exc:  # noqa: BLE001 -- snapshot still worth writing
        lines.append(f"# serve burst skipped: {type(exc).__name__}:{exc}")
    prom = RESULTS / "metrics_snapshot.prom"
    prom.write_text(reg.metrics.render_prometheus())
    lines.append(f"# wrote {prom}")
    return lines
