"""Benchmark harness entry point: ``python -m benchmarks.run``.

One module per paper table/figure:
  validation   -- Table 5 / Figure 6 (per-dataset runtimes + speedups)
  compile_time -- Figure 5 (compile time vs schema size)
  ablations    -- Figure 7 (per-optimization contribution)
  batched      -- beyond-paper TPU-form executor + coverage
  registry     -- beyond-paper multi-tenant mixed traffic (linked tape)
  recursive    -- beyond-paper recursive-$ref unrolling (frontier routing)
  logical      -- beyond-paper logical-applicator circuits (tagged unions)
  robustness   -- fault-containment overhead + poisoned-batch throughput
  observability -- trace/metric seam overhead + explain attribution cost
  serve_load   -- open-loop Poisson arrival-rate sweep (latency percentiles)
  roofline     -- §Roofline terms from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV lines and writes the full report
to results/bench_report.json.  The batched module additionally emits
results/BENCH_batched.json (dense vs owner-sorted-CSR docs/s per batch
size + tape coverage) for machine-readable perf tracking across PRs.
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from typing import Dict

RESULTS = Path(__file__).resolve().parents[1] / "results"


def main() -> None:
    from . import (
        ablations,
        batched,
        compile_time,
        logical,
        observability,
        recursive,
        registry,
        robustness,
        roofline,
        serve_load,
        validation,
    )

    modules = [
        ("validation", validation),
        ("compile_time", compile_time),
        ("ablations", ablations),
        ("batched", batched),
        ("registry", registry),
        ("recursive", recursive),
        ("logical", logical),
        ("robustness", robustness),
        ("observability", observability),
        ("serve_load", serve_load),
        ("roofline", roofline),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    report: Dict[str, object] = {}
    print("name,us_per_call,derived")
    for name, mod in modules:
        if only and name != only:
            continue
        t0 = time.time()
        try:
            for line in mod.run(report):
                print(line)
        except Exception as exc:  # noqa: BLE001 -- keep the harness going
            print(f"{name}/ERROR,0,{type(exc).__name__}:{exc}")
        print(f"{name}/_elapsed,{(time.time()-t0)*1e6:.0f},seconds={time.time()-t0:.1f}")
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "bench_report.json").write_text(json.dumps(report, indent=2, default=str))


if __name__ == "__main__":
    main()
