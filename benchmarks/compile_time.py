"""Figure 5 analogue: schema compilation time vs schema size."""

from __future__ import annotations

import os
import time
from typing import Dict, List

from repro.core import compile_schema
from repro.data.corpus import make_corpus

SCALE = float(os.environ.get("BENCH_CORPUS_SCALE", "0.1"))
REPS = 3


def run(report: Dict[str, object]) -> List[str]:
    corpus = make_corpus(scale=SCALE)
    rows = []
    lines = []
    for ds in corpus:
        best = float("inf")
        for _ in range(REPS):
            t0 = time.perf_counter()
            compiled = compile_schema(ds.schema)
            best = min(best, time.perf_counter() - t0)
        rows.append(
            {
                "name": ds.name,
                "schema_kb": ds.schema_bytes / 1024,
                "compile_ms": best * 1e3,
                "instructions": compiled.instruction_count(),
            }
        )
    rows.sort(key=lambda r: r["schema_kb"])
    for r in rows:
        lines.append(
            f"compile/{r['name']},{r['compile_ms']*1e3:.1f},"
            f"kb={r['schema_kb']:.1f};instructions={r['instructions']}"
        )
    report["compile_time"] = rows
    return lines
