"""Figure 5 analogue: schema compilation time vs schema size, plus the
register()-time schema-algebra cost/benefit ledger (DESIGN.md §15).

Two sections:

- ``compile_time`` -- raw ``compile_schema`` wall time over the scaled
  corpus (the paper's compile-cost amortization argument).
- ``analysis`` -- the ahead-of-time pipeline over the gateway presets
  plus directed prune-heavy schemas: analysis wall time per schema and
  the pre- vs post-normalization tape shape (Â, M̂, horizon, circuit
  count, location count), i.e. what branch pruning buys the batched
  executor before a single document is validated.

Emits ``results/BENCH_compile.json``; the ``*_us_per_schema`` leaves
are regression-gated by ``scripts/bench_gate.py``.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.analysis import analyze_schema
from repro.core import compile_schema
from repro.core.tape import try_build_tape
from repro.data.corpus import make_corpus
from repro.registry.presets import GATEWAY_SCHEMAS

RESULTS = Path(__file__).resolve().parents[1] / "results"

SCALE = float(os.environ.get("BENCH_CORPUS_SCALE", "0.1"))
REPS = 3

# Directed prune-heavy schemas: shapes where the analyzer provably
# removes work before lowering (dead tagged-union branches, duplicated
# allOf constraints, unsatisfiable disjuncts).
PRUNE_SCHEMAS: Dict[str, Any] = {
    "dead_branches": {
        "type": "object",
        "required": ["kind"],
        "properties": {"kind": {"enum": ["a", "b"]}},
        "anyOf": [
            {"properties": {"kind": {"const": "a"}}, "required": ["kind"]},
            {"properties": {"kind": {"const": "b"}}, "required": ["kind"]},
            {"type": "string", "minLength": 8, "maxLength": 2},
            {"type": "integer", "minimum": 10, "maximum": 3},
            {"type": "number", "exclusiveMinimum": 5, "maximum": 5},
        ],
    },
    "dup_allof": {
        "allOf": [
            {"type": "object", "required": ["id"], "properties": {"id": {"type": "integer", "minimum": 0}}},
            {"type": "object", "required": ["id"], "properties": {"id": {"minimum": 0}}},
            {"required": ["id"]},
            {"minProperties": 0},
        ],
    },
    "contradictory_oneof": {
        "type": "object",
        "properties": {
            "mode": {"type": "string", "enum": ["x", "y", "z"]},
            "n": {"type": "integer", "minimum": 0, "maximum": 100},
        },
        "oneOf": [
            {"properties": {"mode": {"const": "x"}, "n": {"maximum": 10}}},
            {"properties": {"mode": {"const": "w", "enum": ["x", "y", "z"]}}},
            {"properties": {"n": {"type": "integer", "minimum": 50, "maximum": 20}}},
        ],
    },
}


def _tape_shape(schema: Any) -> Optional[Dict[str, int]]:
    compiled = compile_schema(schema)
    tape, _ = try_build_tape(compiled)
    if tape is None:
        return None
    return {
        "n_locations": int(tape.n_locations),
        "a_hat": int(tape.max_rows_per_loc),
        "m_hat": int(tape.max_member_props),
        "horizon": int(tape.max_loc_depth) + 1,
        "n_circuits": int(tape.n_circuits),
        "n_assertions": int(tape.n_assertions),
    }


def _analysis_rows() -> List[Dict[str, Any]]:
    rows: List[Dict[str, Any]] = []
    targets = {**GATEWAY_SCHEMAS, **PRUNE_SCHEMAS}
    for name, schema in targets.items():
        best = float("inf")
        report = None
        for _ in range(REPS):
            t0 = time.perf_counter()
            report = analyze_schema(schema)
            best = min(best, time.perf_counter() - t0)
        pre = _tape_shape(schema)
        post = _tape_shape(report.normalized)
        row: Dict[str, Any] = {
            "name": name,
            "analysis_us": best * 1e6,
            "normalized": report.changed,
            "pruned_branches": report.pruned_branches,
            "folded_assertions": report.folded_assertions
            + report.tightened_bounds
            + report.removed_noops,
            "verified": report.verified,
        }
        if pre is not None:
            row["pre"] = pre
        if post is not None:
            row["post"] = post
        if pre is not None and post is not None:
            row["delta"] = {k: post[k] - pre[k] for k in pre}
        rows.append(row)
    return rows


def run(report: Dict[str, object]) -> List[str]:
    corpus = make_corpus(scale=SCALE)
    rows = []
    lines = []
    for ds in corpus:
        best = float("inf")
        for _ in range(REPS):
            t0 = time.perf_counter()
            compiled = compile_schema(ds.schema)
            best = min(best, time.perf_counter() - t0)
        rows.append(
            {
                "name": ds.name,
                "schema_kb": ds.schema_bytes / 1024,
                "compile_ms": best * 1e3,
                "instructions": compiled.instruction_count(),
            }
        )
    rows.sort(key=lambda r: r["schema_kb"])
    for r in rows:
        lines.append(
            f"compile/{r['name']},{r['compile_ms']*1e3:.1f},"
            f"kb={r['schema_kb']:.1f};instructions={r['instructions']}"
        )
    report["compile_time"] = rows

    # -- schema-algebra ledger (DESIGN.md §15) ----------------------------
    analysis_rows = _analysis_rows()
    n = max(1, len(analysis_rows))
    analysis_us = sum(r["analysis_us"] for r in analysis_rows) / n
    pruned = sum(r["pruned_branches"] for r in analysis_rows)
    folded = sum(r["folded_assertions"] for r in analysis_rows)
    loc_delta = sum(r.get("delta", {}).get("n_locations", 0) for r in analysis_rows)
    payload = {
        "analysis": {
            "analysis_us_per_schema": analysis_us,
            "pruned_branches": pruned,
            "folded_assertions": folded,
            "n_locations_delta": loc_delta,
            "schemas": analysis_rows,
        },
        "compile_time": rows,
    }
    report["analysis"] = payload["analysis"]
    for r in analysis_rows:
        d = r.get("delta", {})
        lines.append(
            f"compile/analyze_{r['name']},{r['analysis_us']:.1f},"
            f"pruned={r['pruned_branches']};folded={r['folded_assertions']};"
            f"dloc={d.get('n_locations', 0)};da_hat={d.get('a_hat', 0)};"
            f"dhorizon={d.get('horizon', 0)};dcirc={d.get('n_circuits', 0)}"
        )
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_compile.json").write_text(json.dumps(payload, indent=2))
    lines.append("compile/bench_json,0,results/BENCH_compile.json")
    return lines
