"""Figure 7 analogue: per-optimization ablations (§6.2.3).

Warm validation time with each optimization disabled, one at a time:
semi-perfect hashing (-> raw string comparison), unrolling, regex
specialization, instruction reordering.  Reports overall speedup from each
optimization and the single most-affected dataset, mirroring the paper's
presentation.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

from repro.core import CompilerOptions, Validator, compile_schema
from repro.core.doc_model import parse_document
from repro.data.corpus import make_corpus

SCALE = float(os.environ.get("BENCH_CORPUS_SCALE", "0.25"))
ROUNDS = int(os.environ.get("BENCH_WARM_ROUNDS", "3"))

ABLATIONS = {
    "hashing": dict(options=CompilerOptions(), use_hashing=False),
    "unrolling": dict(options=CompilerOptions(unroll=False), use_hashing=True),
    "regex": dict(options=CompilerOptions(regex_specialize=False), use_hashing=True),
    "reordering": dict(options=CompilerOptions(reorder=False), use_hashing=True),
    "cisc": dict(options=CompilerOptions(cisc=False), use_hashing=True),
}


def _warm_time(validator, docs) -> float:
    best = float("inf")
    for _ in range(ROUNDS):
        t0 = time.perf_counter()
        for d in docs:
            validator.is_valid(d, parsed=True)
        best = min(best, time.perf_counter() - t0)
    return best


def run(report: Dict[str, object]) -> List[str]:
    corpus = make_corpus(scale=SCALE)
    lines: List[str] = []
    baseline_total = 0.0
    ablation_total = {k: 0.0 for k in ABLATIONS}
    per_ds = {k: [] for k in ABLATIONS}

    for ds in corpus:
        docs = [parse_document(d) for d in ds.documents]
        base = Validator(compile_schema(ds.schema))
        t_base = _warm_time(base, docs)
        baseline_total += t_base
        for name, spec in ABLATIONS.items():
            v = Validator(
                compile_schema(ds.schema, options=spec["options"]),
                use_hashing=spec["use_hashing"],
            )
            t = _warm_time(v, docs)
            ablation_total[name] += t
            per_ds[name].append((ds.name, t / max(t_base, 1e-12)))

    results = {}
    for name in ABLATIONS:
        overall = ablation_total[name] / max(baseline_total, 1e-12)
        worst = max(per_ds[name], key=lambda kv: kv[1])
        best = min(per_ds[name], key=lambda kv: kv[1])
        results[name] = {
            "overall_slowdown_without": overall,
            "most_affected": worst,
            "least_affected": best,
        }
        lines.append(
            f"ablation/{name},{overall:.3f},max={worst[1]:.2f}@{worst[0]};min={best[1]:.2f}@{best[0]}"
        )
    report["ablations"] = results
    return lines
