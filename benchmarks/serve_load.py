"""Open-loop serve load: Poisson arrivals swept across offered rates.

The closed-loop ``us_per_doc`` aggregates elsewhere in this harness
answer "how fast is a saturated batch"; they cannot say what a *client*
experiences at a given offered load, because closed-loop drivers slow
down with the server (coordinated omission).  This harness is open-loop:
arrivals are a seeded Poisson process whose timestamps are fixed up
front, independent of how the server keeps up, so queueing delay past
the saturation knee shows up honestly in the tail percentiles.

Two runtimes share the same arrival streams (``--runtime`` axis):

- **batch** -- the PR 8 synchronous baseline: the driver admits every
  arrived request at once through ``ServeEngine.submit_batch`` (the
  "caller hands us a batch" model).
- **stream** -- the §14 scheduler: each request is ``offer``-ed at its
  arrival instant, queues on its link group's lane, and drains when its
  latency budget expires or the lane fills; the cost model routes each
  drain batched-vs-sequential.

Mechanics: the engines are synchronous, so the driver maintains a
virtual clock; service time is measured on the real wall clock and
queueing is implied by the fixed arrival process.  The offer/parse wall
is billed into server busy time for the stream runtime too, so the
comparison between runtimes stays honest.

Emits ``results/BENCH_serve_load.json``: p50/p99/p999 latency per
offered rate for each runtime (``rates`` keeps its PR 8 meaning --
the batch baseline -- so committed gate baselines keep comparing),
plus a ``stream_vs_batch`` p99 comparison per shared rate, queue-depth
gauge series, and scheduler/cost-model snapshots.
"""

from __future__ import annotations

import argparse
import json
import os
import random
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from .registry import MAX_NODES, _mixed_stream

# offered load sweep (docs/s): below, near, and past the admission
# plane's single-process saturation on CI hardware
RATES = (500.0, 2000.0, 8000.0)
# CI bounds the sweep wall time by shrinking the per-rate request count
# and the launch cap (each warmed power-of-two shape is one jit compile,
# and the compiles -- not the sweep itself -- dominate a short run)
REQUESTS_PER_RATE = int(os.environ.get("SERVE_LOAD_REQUESTS", "1024"))
MAX_BATCH = int(os.environ.get("SERVE_LOAD_MAX_BATCH", "256"))
# which runtimes to sweep: "batch", "stream", or "both"
RUNTIME = os.environ.get("SERVE_LOAD_RUNTIME", "both")
# stream admission deadline (seconds a request may wait for riders)
STREAM_MAX_DELAY_S = float(os.environ.get("SERVE_LOAD_MAX_DELAY_S", "0.002"))
TRACE_POINTS = 64  # gauge samples kept per rate (decimated time series)
RESULTS = Path(__file__).resolve().parents[1] / "results"


def _build_engine():
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.registry import SchemaRegistry
    from repro.registry.presets import GATEWAY_SCHEMAS as SCHEMAS
    from repro.serve.engine import ServeConfig, ServeEngine

    reg = SchemaRegistry(use_pallas=False)
    for name, schema in SCHEMAS.items():
        reg.register(name, schema)
    cfg = get_config("granite-3-8b").reduced()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    return ServeEngine(
        cfg,
        params,
        ServeConfig(
            batch_slots=2,
            max_len=64,
            default_max_tokens=4,
            admission_max_nodes=MAX_NODES,
        ),
        registry=reg,
    )


def _requests(n: int, rng: random.Random) -> List:
    docs, endpoints = _mixed_stream(n, rng)
    return [
        (e, json.dumps(d, sort_keys=True)) for e, d in zip(endpoints, docs)
    ]


def _percentile_row(latencies: np.ndarray) -> Dict[str, float]:
    p50, p99, p999 = np.percentile(latencies, [50.0, 99.0, 99.9])
    return {
        "p50_ms": float(p50) * 1e3,
        "p99_ms": float(p99) * 1e3,
        "p999_ms": float(p999) * 1e3,
        "mean_ms": float(latencies.mean()) * 1e3,
    }


def _sweep_rate(engine, requests, rate: float, rng: random.Random) -> Dict:
    """One offered-load point, batch runtime: the PR 8 synchronous
    baseline (admit everything arrived in one ``submit_batch``)."""
    n = len(requests)
    arrivals = np.cumsum(rng_exponential(rng, n, rate))
    latencies = np.zeros(n)
    trace: List[Dict[str, float]] = []
    m = engine.registry.metrics
    g_queue = m.gauge(
        "serve_queue_depth", "arrived-but-unserved requests at launch time"
    )
    g_inflight = m.gauge(
        "serve_inflight", "requests inside the current admission launch"
    )

    free = 0.0  # virtual time the server finishes its current launch
    idx = 0
    launches = 0
    busy_s = 0.0
    while idx < n:
        start = max(free, arrivals[idx])
        # everything that has arrived by the launch instant rides along
        end = idx + 1
        while end < n and arrivals[end] <= start and end - idx < MAX_BATCH:
            end += 1
        depth = int(np.searchsorted(arrivals, start, side="right")) - idx
        g_queue.set(depth)
        g_inflight.set(end - idx)
        t0 = time.perf_counter()
        engine.submit_batch(requests[idx:end])
        wall = time.perf_counter() - t0
        busy_s += wall
        completion = start + wall
        latencies[idx:end] = completion - arrivals[idx:end]
        trace.append(
            {
                "t_s": round(float(start), 6),
                "queue_depth": depth,
                "in_flight": end - idx,
                "launch_wall_s": round(wall, 6),
            }
        )
        free = completion
        idx = end
        launches += 1
    # decimate the per-launch series to a bounded artifact
    if len(trace) > TRACE_POINTS:
        stride = len(trace) / TRACE_POINTS
        trace = [trace[int(i * stride)] for i in range(TRACE_POINTS)]
    makespan = max(float(arrivals[-1]), free)
    return {
        "offered_rate_per_s": rate,
        "requests": n,
        "launches": launches,
        "mean_batch": n / launches,
        **_percentile_row(latencies),
        "achieved_rate_per_s": n / makespan,
        "utilization": busy_s / makespan,
        "max_queue_depth": max(t["queue_depth"] for t in trace),
        "gauges": trace,
    }


def _sweep_rate_stream(engine, scheduler, requests, rate: float, rng: random.Random) -> Dict:
    """One offered-load point, stream runtime: requests are offered at
    their arrival instants and the §14 scheduler decides when (and how)
    to drain.  Offer/parse wall is billed into server busy time so the
    stream runtime gets no free parsing relative to the batch baseline.
    """
    n = len(requests)
    arrivals = np.cumsum(rng_exponential(rng, n, rate))
    tickets: List[Optional[object]] = [None] * n
    trace: List[Dict[str, float]] = []
    now = 0.0
    idx = 0
    busy_s = 0.0
    drains = 0
    max_depth = 0
    while idx < n or scheduler.depth():
        fire = scheduler.next_fire_s(now=now)
        next_arrival = arrivals[idx] if idx < n else None
        if next_arrival is not None and (fire is None or next_arrival <= fire):
            now = max(now, float(next_arrival))
            endpoint, request_json = requests[idx]
            t0 = time.perf_counter()
            tickets[idx] = scheduler.offer(endpoint, request_json, now=now)
            wall = time.perf_counter() - t0
            busy_s += wall
            now += wall
            idx += 1
            continue
        if fire is not None:
            now = max(now, fire)
        r = scheduler.drain(now=now, force=idx >= n)
        if r is None:
            continue
        busy_s += r.wall_s
        now += r.wall_s
        drains += 1
        max_depth = max(max_depth, scheduler.depth() + r.n)
        trace.append(
            {
                "t_s": round(now - r.wall_s, 6),
                "lane": r.lane,
                "route": r.route,
                "in_flight": r.n,
                "launch_wall_s": round(r.wall_s, 6),
            }
        )
    latencies = np.array([t.latency_s for t in tickets])
    queue_delays = np.array([t.queue_delay_s for t in tickets])
    if len(trace) > TRACE_POINTS:
        stride = len(trace) / TRACE_POINTS
        trace = [trace[int(i * stride)] for i in range(TRACE_POINTS)]
    makespan = max(float(arrivals[-1]), now)
    return {
        "offered_rate_per_s": rate,
        "requests": n,
        "launches": drains,
        "mean_batch": n / max(drains, 1),
        **_percentile_row(latencies),
        "queue_delay_p99_us": float(np.percentile(queue_delays, 99.0)) * 1e6,
        "achieved_rate_per_s": n / makespan,
        "utilization": busy_s / makespan,
        "max_queue_depth": max_depth,
        "gauges": trace,
    }


def rng_exponential(rng: random.Random, n: int, rate: float) -> np.ndarray:
    """Seeded exponential inter-arrival gaps (stdlib RNG: reproducible
    without coupling to numpy's global state)."""
    return np.asarray([rng.expovariate(rate) for _ in range(n)])


def _warm(engine) -> None:
    """Warm every power-of-two launch shape up to MAX_BATCH once so the
    sweep measures steady-state serving, not jit traces (a cold-start
    sweep is a different experiment; record the warm one).

    Group-partitioned admission splits a mixed batch into per-group
    sub-batches whose pow2 buckets depend on the traffic mix, so the
    submit_batch warm alone no longer covers every launch shape --
    ``warm_groups`` pre-traces each link group's validator at every
    pow2 bucket directly."""
    sizes = []
    size = 1
    while size <= MAX_BATCH:
        sizes.append(size)
        size *= 2
    engine.registry.warm_groups(sizes, max_nodes=MAX_NODES)
    rng = random.Random(0xA220)
    warm = _requests(MAX_BATCH, rng)
    for size in sizes:
        engine.submit_batch(warm[:size])


def run(report: Dict[str, object], runtime: Optional[str] = None) -> List[str]:
    lines: List[str] = []
    runtime = runtime or RUNTIME
    sweep_batch = runtime in ("batch", "both")
    sweep_stream = runtime in ("stream", "both")

    payload: Dict[str, object] = {
        "requests_per_rate": REQUESTS_PER_RATE,
        "max_batch": MAX_BATCH,
        "max_nodes": MAX_NODES,
        "arrival_process": "poisson(seeded, open-loop, virtual clock)",
        "runtime_axis": runtime,
    }

    batch_rows: List[Dict] = []
    if sweep_batch:
        rng = random.Random(0xA221)
        engine = _build_engine()
        _warm(engine)
        for rate in RATES:
            requests = _requests(REQUESTS_PER_RATE, rng)
            row = _sweep_rate(engine, requests, rate, rng)
            batch_rows.append(row)
            lines.append(
                f"serve_load/batch_rate_{int(rate)},{row['p50_ms'] * 1e3:.1f},"
                f"p99_ms={row['p99_ms']:.3f};p999_ms={row['p999_ms']:.3f};"
                f"mean_batch={row['mean_batch']:.1f};util={row['utilization']:.2f}"
            )
        # "rates" keeps its PR 8 meaning (batch-runtime rows) so the
        # committed p99_ms gate baselines keep comparing across the
        # runtime-axis change
        payload["rates"] = batch_rows
        payload["endpoint_slo"] = {
            e: {
                k: v
                for k, v in engine.slo_status(e).items()
                if k in ("objective_s", "target", "good_ratio", "burn_rate", "count")
            }
            for e in engine.registry.endpoints()
        }

    stream_rows: List[Dict] = []
    if sweep_stream:
        # fresh engine + metrics: the stream runtime's histograms must
        # not mix with the batch baseline's
        rng = random.Random(0xA221)  # same seed -> same arrival streams
        engine = _build_engine()
        _warm(engine)
        scheduler = engine.scheduler(
            max_delay_s=STREAM_MAX_DELAY_S, max_batch=MAX_BATCH
        )
        for rate in RATES:
            requests = _requests(REQUESTS_PER_RATE, rng)
            row = _sweep_rate_stream(engine, scheduler, requests, rate, rng)
            stream_rows.append(row)
            lines.append(
                f"serve_load/stream_rate_{int(rate)},{row['p50_ms'] * 1e3:.1f},"
                f"p99_ms={row['p99_ms']:.3f};p999_ms={row['p999_ms']:.3f};"
                f"mean_batch={row['mean_batch']:.1f};util={row['utilization']:.2f}"
            )
        payload["stream_rates"] = stream_rows
        payload["stream"] = {
            "max_delay_s": STREAM_MAX_DELAY_S,
            "scheduler": scheduler.snapshot(),
        }
        payload["stream_endpoint_slo"] = {
            e: {
                k: v
                for k, v in engine.slo_status(e).items()
                if k in ("objective_s", "target", "good_ratio", "burn_rate", "count")
            }
            for e in engine.registry.endpoints()
        }

    if sweep_batch and sweep_stream:
        comparison = []
        for b, s in zip(batch_rows, stream_rows):
            comparison.append(
                {
                    "offered_rate_per_s": b["offered_rate_per_s"],
                    "batch_p99_ms": b["p99_ms"],
                    "stream_p99_ms": s["p99_ms"],
                    "stream_speedup_p99": b["p99_ms"] / s["p99_ms"]
                    if s["p99_ms"] > 0
                    else 0.0,
                }
            )
        payload["stream_vs_batch"] = comparison
        for c in comparison:
            lines.append(
                f"serve_load/stream_vs_batch_{int(c['offered_rate_per_s'])},"
                f"{c['stream_p99_ms'] * 1e3:.1f},"
                f"batch_p99_ms={c['batch_p99_ms']:.3f};"
                f"speedup={c['stream_speedup_p99']:.2f}x"
            )

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_load.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    report["serve_load"] = payload
    lines.append(f"# wrote {out}")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--runtime",
        choices=("batch", "stream", "both"),
        default=RUNTIME,
        help="which serve runtime(s) to sweep",
    )
    args = ap.parse_args()
    report: Dict[str, object] = {}
    for line in run(report, runtime=args.runtime):
        print(line)


if __name__ == "__main__":
    main()
