"""Open-loop serve load: Poisson arrivals swept across offered rates.

The closed-loop ``us_per_doc`` aggregates elsewhere in this harness
answer "how fast is a saturated batch"; they cannot say what a *client*
experiences at a given offered load, because closed-loop drivers slow
down with the server (coordinated omission).  This harness is open-loop:
arrivals are a seeded Poisson process whose timestamps are fixed up
front, independent of how the server keeps up, so queueing delay past
the saturation knee shows up honestly in the tail percentiles.

Mechanics: the engine is synchronous, so the driver maintains a virtual
clock.  Requests arrive at exponential inter-arrival gaps; the server
starts its next launch at ``max(server_free, first_arrival)``, admits
every request that has arrived by then (capped at ``MAX_BATCH``) through
``ServeEngine.submit_batch``, and bills each request
``completion - arrival`` -- service time measured on the real wall
clock, queueing implied by the arrival process.  One request per launch
degenerates to ``ServeEngine.submit``-equivalent latency; bursts
amortize, exactly the continuous-batching trade the ROADMAP wants
arrival-rate sweeps over.

Emits ``results/BENCH_serve_load.json``: p50/p99/p999 latency per
offered rate plus queue-depth / in-flight gauge time series, and keeps
the shared MetricRegistry's ``serve_queue_depth`` / ``serve_inflight``
gauges fresh per launch so the Prometheus export carries the final
state.
"""

from __future__ import annotations

import json
import os
import random
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from .registry import MAX_NODES, _mixed_stream

# offered load sweep (docs/s): below, near, and past the admission
# plane's single-process saturation on CI hardware
RATES = (500.0, 2000.0, 8000.0)
# CI bounds the sweep wall time by shrinking the per-rate request count
# and the launch cap (each warmed power-of-two shape is one jit compile,
# and the compiles -- not the sweep itself -- dominate a short run)
REQUESTS_PER_RATE = int(os.environ.get("SERVE_LOAD_REQUESTS", "1024"))
MAX_BATCH = int(os.environ.get("SERVE_LOAD_MAX_BATCH", "256"))
TRACE_POINTS = 64  # gauge samples kept per rate (decimated time series)
RESULTS = Path(__file__).resolve().parents[1] / "results"


def _build_engine():
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.registry import SchemaRegistry
    from repro.registry.presets import GATEWAY_SCHEMAS as SCHEMAS
    from repro.serve.engine import ServeConfig, ServeEngine

    reg = SchemaRegistry(use_pallas=False)
    for name, schema in SCHEMAS.items():
        reg.register(name, schema)
    cfg = get_config("granite-3-8b").reduced()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    return ServeEngine(
        cfg,
        params,
        ServeConfig(
            batch_slots=2,
            max_len=64,
            default_max_tokens=4,
            admission_max_nodes=MAX_NODES,
        ),
        registry=reg,
    )


def _requests(n: int, rng: random.Random) -> List:
    docs, endpoints = _mixed_stream(n, rng)
    return [
        (e, json.dumps(d, sort_keys=True)) for e, d in zip(endpoints, docs)
    ]


def _sweep_rate(engine, requests, rate: float, rng: random.Random) -> Dict:
    """One offered-load point: virtual-clock open-loop simulation."""
    n = len(requests)
    arrivals = np.cumsum(rng_exponential(rng, n, rate))
    latencies = np.zeros(n)
    trace: List[Dict[str, float]] = []
    m = engine.registry.metrics
    g_queue = m.gauge(
        "serve_queue_depth", "arrived-but-unserved requests at launch time"
    )
    g_inflight = m.gauge(
        "serve_inflight", "requests inside the current admission launch"
    )

    free = 0.0  # virtual time the server finishes its current launch
    idx = 0
    launches = 0
    busy_s = 0.0
    while idx < n:
        start = max(free, arrivals[idx])
        # everything that has arrived by the launch instant rides along
        end = idx + 1
        while end < n and arrivals[end] <= start and end - idx < MAX_BATCH:
            end += 1
        depth = int(np.searchsorted(arrivals, start, side="right")) - idx
        g_queue.set(depth)
        g_inflight.set(end - idx)
        t0 = time.perf_counter()
        engine.submit_batch(requests[idx:end])
        wall = time.perf_counter() - t0
        busy_s += wall
        completion = start + wall
        latencies[idx:end] = completion - arrivals[idx:end]
        trace.append(
            {
                "t_s": round(float(start), 6),
                "queue_depth": depth,
                "in_flight": end - idx,
                "launch_wall_s": round(wall, 6),
            }
        )
        free = completion
        idx = end
        launches += 1
    # decimate the per-launch series to a bounded artifact
    if len(trace) > TRACE_POINTS:
        stride = len(trace) / TRACE_POINTS
        trace = [trace[int(i * stride)] for i in range(TRACE_POINTS)]
    p50, p99, p999 = np.percentile(latencies, [50.0, 99.0, 99.9])
    makespan = max(float(arrivals[-1]), free)
    return {
        "offered_rate_per_s": rate,
        "requests": n,
        "launches": launches,
        "mean_batch": n / launches,
        "p50_ms": float(p50) * 1e3,
        "p99_ms": float(p99) * 1e3,
        "p999_ms": float(p999) * 1e3,
        "mean_ms": float(latencies.mean()) * 1e3,
        "achieved_rate_per_s": n / makespan,
        "utilization": busy_s / makespan,
        "max_queue_depth": max(t["queue_depth"] for t in trace),
        "gauges": trace,
    }


def rng_exponential(rng: random.Random, n: int, rate: float) -> np.ndarray:
    """Seeded exponential inter-arrival gaps (stdlib RNG: reproducible
    without coupling to numpy's global state)."""
    return np.asarray([rng.expovariate(rate) for _ in range(n)])


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []
    rng = random.Random(0xA221)
    engine = _build_engine()

    # warm every power-of-two launch shape up to MAX_BATCH once so the
    # sweep measures steady-state serving, not jit traces (a cold-start
    # sweep is a different experiment; record the warm one)
    warm = _requests(MAX_BATCH, rng)
    size = 1
    while size <= MAX_BATCH:
        engine.submit_batch(warm[:size])
        size *= 2

    rows = []
    for rate in RATES:
        requests = _requests(REQUESTS_PER_RATE, rng)
        row = _sweep_rate(engine, requests, rate, rng)
        rows.append(row)
        lines.append(
            f"serve_load/rate_{int(rate)},{row['p50_ms'] * 1e3:.1f},"
            f"p99_ms={row['p99_ms']:.3f};p999_ms={row['p999_ms']:.3f};"
            f"mean_batch={row['mean_batch']:.1f};util={row['utilization']:.2f}"
        )

    payload = {
        "requests_per_rate": REQUESTS_PER_RATE,
        "max_batch": MAX_BATCH,
        "max_nodes": MAX_NODES,
        "arrival_process": "poisson(seeded, open-loop, virtual clock)",
        "rates": rows,
        "endpoint_slo": {
            e: {
                k: v
                for k, v in engine.slo_status(e).items()
                if k in ("objective_s", "target", "good_ratio", "burn_rate", "count")
            }
            for e in engine.registry.endpoints()
        },
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / "BENCH_serve_load.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    report["serve_load"] = payload
    lines.append(f"# wrote {out}")
    return lines
