"""Beyond-paper: multi-tenant mixed-traffic validation through the registry.

A gateway hosting several endpoint schemas sees *interleaved* traffic.
This benchmark compares three ways to validate one skewed mixed stream
(4 endpoint schemas at 70/15/10/5):

- **sequential** -- per-document compiled codegen validator (the paper's
  single-request critical path);
- **per-schema sub-batch dispatch** -- split the stream by endpoint,
  encode + validate each group on its own single-schema tape (what mixed
  traffic forces without a linker);
- **linked tape** -- ONE batched launch over the registry's linked tape
  with per-document schema ids (``registry/linker.py``).

Emits ``results/BENCH_registry.json`` with docs/s per batch size for all
three paths plus the linked-tape constants, so the multi-tenant perf
trajectory stays machine-readable across PRs.  jnp path on CPU; the
Pallas kernels are validated separately in tests with interpret=True.
"""

from __future__ import annotations

import json
import random
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core.batch_executor import BatchValidator
from repro.core.doc_model import parse_document
from repro.data.doc_table import encode_batch
from repro.registry import SchemaRegistry
from repro.registry.presets import GATEWAY_SCHEMAS as SCHEMAS

BATCH_SIZES = (64, 512, 4096)
MAX_NODES = 64
RESULTS = Path(__file__).resolve().parents[1] / "results"

# skewed mix: completions dominate, moderation is the tail; charge is the
# tagged-union endpoint (logical-applicator circuits, DESIGN.md §10)
MIX = [("complete", 60), ("chat", 15), ("embed", 10), ("charge", 10), ("moderate", 5)]


def _mk_request(endpoint: str, i: int, rng: random.Random):
    bad = i % 9 == 0  # ~11% invalid traffic
    if endpoint == "complete":
        req = {
            "prompt": "hello world " * rng.randint(1, 12),
            "max_tokens": rng.randint(1, 512),
            "temperature": round(rng.random(), 2),
        }
        if bad:
            req["max_tokens"] = -1
    elif endpoint == "chat":
        req = {
            "messages": [
                {"role": rng.choice(["system", "user"]), "content": "hi " * rng.randint(1, 6)}
                for _ in range(rng.randint(1, 3))
            ],
            "max_tokens": rng.randint(1, 256),
        }
        if bad:
            req["messages"][0]["role"] = "robot"
    elif endpoint == "embed":
        req = {"input": "text " * rng.randint(1, 16), "dimensions": rng.choice([64, 256, 1024])}
        if bad:
            req["dimensions"] = 2
    elif endpoint == "charge":
        kind = rng.choice(["card", "bank", "wallet"])
        if kind == "card":
            method = {"kind": kind, "number": "4111111111111111", "cvv": "123"}
        elif kind == "bank":
            method = {"kind": kind, "iban": "DE89370400440532013000"}
        else:
            method = {"kind": kind, "wallet_id": f"w-{rng.randint(0, 999)}"}
        req = {
            "amount": rng.randint(1, 500_000),
            "currency": rng.choice(["usd", "eur", "gbp"]),
            "method": method,
        }
        if bad:
            req["method"] = dict(method, kind=rng.choice(
                [k for k in ("card", "bank", "wallet") if k != kind]
            ))
    else:
        req = {"input": "msg " * rng.randint(1, 8), "category": rng.choice(["toxicity", "spam"])}
        if bad:
            req["category"] = "other"
    return req


def _mixed_stream(batch: int, rng: random.Random):
    lanes = [ep for ep, weight in MIX for _ in range(weight)]
    endpoints = [lanes[rng.randrange(len(lanes))] for _ in range(batch)]
    docs = [_mk_request(ep, i, rng) for i, ep in enumerate(endpoints)]
    return docs, endpoints


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []
    rng = random.Random(0)

    reg = SchemaRegistry(use_pallas=False)
    t0 = time.perf_counter()
    for name, schema in SCHEMAS.items():
        reg.register(name, schema)
    t_register = time.perf_counter() - t0
    linked = reg.linked_tape()
    assert linked is not None and len(linked.members) == len(SCHEMAS)
    bv_linked = reg.batch_validator()
    # single-schema executors for the dispatch baseline
    bv_single = {
        ep: BatchValidator(reg.get(ep).tape, use_pallas=False)
        for ep in SCHEMAS
    }

    rows = []
    for batch in BATCH_SIZES:
        docs, endpoints = _mixed_stream(batch, rng)
        ids = reg.schema_ids(endpoints)
        assert (ids >= 0).all()

        # -- sequential oracle ------------------------------------------------
        parsed = [parse_document(d) for d in docs]
        validators = {ep: reg.get(ep).validator for ep in SCHEMAS}
        seq_results = [
            validators[ep].is_valid(p, parsed=True) for ep, p in zip(endpoints, parsed)
        ]

        def run_seq():
            return [
                validators[ep].is_valid(p, parsed=True)
                for ep, p in zip(endpoints, parsed)
            ]

        # -- per-schema sub-batch dispatch -----------------------------------
        # Two baselines: *exact* warms a jit for each group's exact batch
        # size -- idealized, since real mixed traffic re-deals group sizes
        # every batch and would retrace constantly; *bucketed* pads each
        # group to a power-of-two batch (what a production dispatcher --
        # and our own registry.admit_mixed -- does to cap compilations).
        groups = {ep: [i for i, e in enumerate(endpoints) if e == ep] for ep in SCHEMAS}
        sub_tables = {
            ep: encode_batch([docs[i] for i in idx], max_nodes=MAX_NODES)
            for ep, idx in groups.items() if idx
        }
        bucket_tables = {}
        for ep, idx in groups.items():
            if not idx:
                continue
            bucket = 1 << (len(idx) - 1).bit_length() if len(idx) > 1 else 1
            bucket_tables[ep] = encode_batch(
                [docs[i] for i in idx] + [None] * (bucket - len(idx)),
                max_nodes=MAX_NODES,
            )
        dispatch_valid = np.zeros(batch, bool)
        dispatch_decided = np.zeros(batch, bool)

        def run_dispatch_exact():
            for ep, table in sub_tables.items():
                v, d = bv_single[ep].validate(table)
                idx = groups[ep]
                dispatch_valid[idx] = v
                dispatch_decided[idx] = d

        def run_dispatch_bucketed():
            for ep, table in bucket_tables.items():
                v, d = bv_single[ep].validate(table)
                idx = groups[ep]
                dispatch_valid[idx] = v[: len(idx)]
                dispatch_decided[idx] = d[: len(idx)]

        # -- linked tape: one launch -----------------------------------------
        table = encode_batch(docs, max_nodes=MAX_NODES)
        t0 = time.perf_counter()
        encode_batch(docs, max_nodes=MAX_NODES)
        t_encode = time.perf_counter() - t0

        def run_linked():
            return bv_linked.validate(table, ids)

        # warm every shape, then interleave best-of-5 so background load
        # hits all paths equally
        run_dispatch_exact()
        linked_valid, linked_decided = run_linked()
        timings = {"seq": [], "exact": [], "bucketed": [], "linked": []}
        contenders = [
            ("seq", run_seq),
            ("exact", run_dispatch_exact),
            ("bucketed", run_dispatch_bucketed),
            ("linked", run_linked),
        ]
        for _ in range(5):
            for name, fn in contenders:
                t0 = time.perf_counter()
                fn()
                timings[name].append(time.perf_counter() - t0)
        t_seq = min(timings["seq"])
        t_dispatch_exact = min(timings["exact"])
        t_dispatch = min(timings["bucketed"])
        t_linked = min(timings["linked"])
        run_dispatch_exact()  # leave exact-dispatch verdicts for the check

        # bit-identity: linked == per-schema dispatch; both == sequential
        # where decided (the acceptance criterion)
        np.testing.assert_array_equal(linked_valid, dispatch_valid)
        np.testing.assert_array_equal(linked_decided, dispatch_decided)
        assert all(
            bool(v) == r for v, r, d in zip(linked_valid, seq_results, linked_decided) if d
        )

        row = {
            "batch": batch,
            "mix": {ep: len(idx) for ep, idx in groups.items()},
            "decided_fraction": float(linked_decided.mean()),
            "sequential_docs_per_s": batch / t_seq,
            "dispatch_docs_per_s": batch / t_dispatch,  # bucketed (realistic)
            "dispatch_exact_docs_per_s": batch / t_dispatch_exact,
            "linked_docs_per_s": batch / t_linked,
            "sequential_us_per_doc": t_seq / batch * 1e6,
            "dispatch_us_per_doc": t_dispatch / batch * 1e6,
            "dispatch_exact_us_per_doc": t_dispatch_exact / batch * 1e6,
            "linked_us_per_doc": t_linked / batch * 1e6,
            "encode_us_per_doc": t_encode / batch * 1e6,
            "linked_speedup_vs_dispatch": t_dispatch / t_linked,
            "linked_speedup_vs_dispatch_exact": t_dispatch_exact / t_linked,
            "linked_speedup_vs_sequential": t_seq / t_linked,
        }
        rows.append(row)
        lines.append(
            f"registry/mixed_validation_b{batch},{row['linked_us_per_doc']:.2f},"
            f"dispatch_us={row['dispatch_us_per_doc']:.2f};"
            f"seq_us={row['sequential_us_per_doc']:.2f};"
            f"linked_x_dispatch={row['linked_speedup_vs_dispatch']:.2f}"
        )

    # -- link groups (DESIGN.md §14): window shrinkage vs the global tape --
    # The union member (charge) inflates the global linked windows to the
    # member maxima (Â 3->6, M-hat 4->8).  The group partition confines
    # that: report each group's local windows and the worst non-union
    # inflation ratio against the union-free reference (all members
    # minus the union endpoint linked together).
    from repro.registry import link_tapes

    union_free = link_tapes(
        tapes=[reg.get(ep).tape for ep in SCHEMAS if ep != "charge"],
        names=[ep for ep in SCHEMAS if ep != "charge"],
    )
    group_rows = {}
    worst_a = worst_m = 0.0
    for label, gs in reg.group_stats().items():
        non_union = "charge" not in gs["members"]
        ratio_a = gs["a_hat"] / union_free.max_rows_per_loc
        ratio_m = gs["m_hat"] / union_free.max_member_props
        if non_union:
            worst_a = max(worst_a, ratio_a)
            worst_m = max(worst_m, ratio_m)
        group_rows[label] = {
            **{k: gs[k] for k in ("members", "a_hat", "m_hat", "k", "horizon")},
            "signature_class": gs["signature_class"],
            "non_union": non_union,
            "a_hat_vs_union_free": round(ratio_a, 3),
            "m_hat_vs_union_free": round(ratio_m, 3),
        }
    # acceptance: non-union traffic within 1.2x of its union-free windows
    assert worst_a <= 1.2 and worst_m <= 1.2, (worst_a, worst_m)

    # differential: group-partitioned admission is bit-identical to the
    # legacy single-tape fast path, verdict for verdict
    reg_flat = SchemaRegistry(use_pallas=False, link_grouping=False)
    for name, schema in SCHEMAS.items():
        reg_flat.register(name, schema)
    diff_docs, diff_eps = _mixed_stream(256, random.Random(0xD1FF))
    grouped_v, _ = reg.admit_mixed_ex(diff_docs, diff_eps, max_nodes=MAX_NODES)
    flat_v, _ = reg_flat.admit_mixed_ex(diff_docs, diff_eps, max_nodes=MAX_NODES)
    assert [(v.outcome, v.valid) for v in grouped_v] == [
        (v.outcome, v.valid) for v in flat_v
    ]
    lines.append(
        f"registry/link_groups,{len(group_rows)},"
        f"worst_non_union_a_hat_ratio={worst_a:.2f};"
        f"worst_non_union_m_hat_ratio={worst_m:.2f}"
    )

    payload = {
        "schemas": list(SCHEMAS),
        "mix_weights": dict(MIX),
        "register_seconds": t_register,
        "linked_tape": {
            "members": list(linked.members),
            "locations": linked.n_locations,
            "prop_rows": linked.n_props,
            "assertions": linked.n_assertions,
            "a_hat": linked.max_rows_per_loc,
            "k": linked.max_hash_run,
            "max_loc_depth": linked.max_loc_depth,
            "member_horizons": linked.member_horizons.tolist(),
        },
        "link_groups": {
            "groups": group_rows,
            "union_free_reference": {
                "a_hat": int(union_free.max_rows_per_loc),
                "m_hat": int(union_free.max_member_props),
            },
            "worst_non_union_a_hat_ratio": round(worst_a, 3),
            "worst_non_union_m_hat_ratio": round(worst_m, 3),
            "grouped_vs_flat_bit_identical": True,
        },
        "throughput": rows,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_registry.json").write_text(json.dumps(payload, indent=2))
    lines.append("registry/bench_json,0,results/BENCH_registry.json")
    report["registry"] = payload
    return lines
