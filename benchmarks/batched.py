"""Beyond-paper: batched (TPU-form) executor throughput + corpus coverage.

Measures (a) what fraction of the benchmark corpus compiles to the
structural-subset tensor tape (the batch fast path), and (b) throughput of
the batched executor on an API-gateway-style request schema at increasing
batch sizes, comparing the historical **dense** layout (hash_match per
depth iteration + full (B*N x A) assertion matrix) against the
**owner-sorted CSR** layout (one hoisted hash pass + (B*N x A-hat)
windows).  jnp path on CPU; the Pallas path is validated separately in
tests with interpret=True.

Emits ``results/BENCH_batched.json`` -- docs/s per batch size for both
layouts, the tape-coverage fraction, and the per-tape A-hat/K constants --
so the perf trajectory stays machine-readable across PRs.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, List

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.doc_model import parse_document
from repro.core.tape import try_build_tape
from repro.data.corpus import make_corpus
from repro.data.doc_table import encode_batch
from repro.serve.engine import REQUEST_SCHEMA

SCALE = float(os.environ.get("BENCH_CORPUS_SCALE", "0.1"))
BATCH_SIZES = (64, 512, 4096)
RESULTS = Path(__file__).resolve().parents[1] / "results"


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []

    # -- (a) corpus coverage of the tensor tape ------------------------------
    corpus = make_corpus(scale=SCALE)
    batchable, reasons = 0, {}
    tape_stats = []
    for ds in corpus:
        tape, reason = try_build_tape(compile_schema(ds.schema))
        if tape is not None:
            batchable += 1
            tape_stats.append(
                {
                    "dataset": ds.name,
                    "a_hat": tape.max_rows_per_loc,
                    "k": tape.max_hash_run,
                    "assertions": tape.n_assertions,
                    "locations": tape.n_locations,
                }
            )
        else:
            reasons[ds.name] = reason
    coverage = batchable / len(corpus)
    lines.append(f"batched/corpus_coverage,{coverage*100:.1f},percent_of_38_datasets")

    # -- (b) throughput on the serving request schema -------------------------
    # the full engine schema uses propertyNames (key-loop) for `metadata`,
    # which stays on the sequential fallback; the batched path handles the
    # structural rest -- benchmark that subset explicitly
    schema = {k: v for k, v in REQUEST_SCHEMA.items() if k != "properties"}
    schema["properties"] = {
        k: v for k, v in REQUEST_SCHEMA["properties"].items() if k != "metadata"
    }
    compiled = compile_schema(schema)
    tape, reason = try_build_tape(compiled)
    assert tape is not None, f"request schema must be batchable: {reason}"
    seq = Validator(compiled)
    executors = {
        "dense": BatchValidator(tape, use_pallas=False, layout="dense"),
        "csr": BatchValidator(tape, use_pallas=False, layout="csr"),
    }

    import random

    rng = random.Random(0)
    def mk_request(i):
        req = {
            "prompt": "hello world " * rng.randint(1, 20),
            "max_tokens": rng.randint(1, 512),
            "temperature": round(rng.random(), 2),
        }
        if i % 7 == 0:
            req["bogus_field"] = True  # invalid: closed object
        if i % 11 == 0:
            req["max_tokens"] = -5  # invalid: minimum
        return req

    rows = []
    for batch in BATCH_SIZES:
        docs = [mk_request(i) for i in range(batch)]
        parsed = [parse_document(d) for d in docs]
        t0 = time.perf_counter()
        seq_results = [seq.is_valid(d, parsed=True) for d in parsed]
        t_seq = time.perf_counter() - t0

        table = encode_batch(docs, max_nodes=64)
        t0 = time.perf_counter()
        encode_batch(docs, max_nodes=64)
        t_encode = time.perf_counter() - t0

        row = {
            "batch": batch,
            "sequential_docs_per_s": batch / t_seq,
            "sequential_us_per_doc": t_seq / batch * 1e6,
            "encode_us_per_doc": t_encode / batch * 1e6,
        }
        for name, bv in executors.items():
            bv.validate(table)  # warm the jit
            t0 = time.perf_counter()
            valid, decided = bv.validate(table)
            t_batch = time.perf_counter() - t0
            assert all(
                bool(v) == r for v, r, d in zip(valid, seq_results, decided) if d
            )
            row[f"{name}_docs_per_s"] = batch / t_batch
            row[f"{name}_us_per_doc"] = t_batch / batch * 1e6
        row["csr_speedup_vs_dense"] = row["csr_docs_per_s"] / row["dense_docs_per_s"]
        rows.append(row)
        lines.append(
            f"batched/request_validation_b{batch},{row['csr_us_per_doc']:.2f},"
            f"dense_us={row['dense_us_per_doc']:.2f};"
            f"seq_us={row['sequential_us_per_doc']:.2f};"
            f"csr_x_dense={row['csr_speedup_vs_dense']:.2f}"
        )

    payload = {
        "schema": "api_gateway_request",
        "tape": {
            "a_hat": tape.max_rows_per_loc,
            "k": tape.max_hash_run,
            "assertions": tape.n_assertions,
            "prop_rows": tape.n_props,
            "locations": tape.n_locations,
        },
        "coverage": coverage,
        "corpus_tapes": tape_stats,
        "unbatchable": reasons,
        "throughput": rows,
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_batched.json").write_text(json.dumps(payload, indent=2))
    lines.append(f"batched/bench_json,0,results/BENCH_batched.json")
    report["batched"] = payload
    return lines
