"""Beyond-paper: batched (TPU-form) executor throughput + corpus coverage.

Measures (a) what fraction of the benchmark corpus compiles to the
structural-subset tensor tape (the batch fast path), and (b) throughput of
the batched executor vs the sequential engine on an API-gateway-style
request schema, at increasing batch sizes (jnp path on CPU; the Pallas
path is validated separately in tests with interpret=True).
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.doc_model import parse_document
from repro.core.tape import try_build_tape
from repro.data.corpus import make_corpus
from repro.data.doc_table import encode_batch
from repro.serve.engine import REQUEST_SCHEMA

SCALE = float(os.environ.get("BENCH_CORPUS_SCALE", "0.1"))


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []

    # -- (a) corpus coverage of the tensor tape ------------------------------
    corpus = make_corpus(scale=SCALE)
    batchable, reasons = 0, {}
    for ds in corpus:
        tape, reason = try_build_tape(compile_schema(ds.schema))
        if tape is not None:
            batchable += 1
        else:
            reasons[ds.name] = reason
    coverage = batchable / len(corpus)
    lines.append(f"batched/corpus_coverage,{coverage*100:.1f},percent_of_38_datasets")

    # -- (b) throughput on the serving request schema -------------------------
    # the full engine schema uses propertyNames (key-loop) for `metadata`,
    # which stays on the sequential fallback; the batched path handles the
    # structural rest -- benchmark that subset explicitly
    schema = {k: v for k, v in REQUEST_SCHEMA.items() if k != "properties"}
    schema["properties"] = {
        k: v for k, v in REQUEST_SCHEMA["properties"].items() if k != "metadata"
    }
    compiled = compile_schema(schema)
    tape, reason = try_build_tape(compiled)
    assert tape is not None, f"request schema must be batchable: {reason}"
    seq = Validator(compiled)
    bv = BatchValidator(tape, use_pallas=False)

    import random

    rng = random.Random(0)
    def mk_request(i):
        req = {
            "prompt": "hello world " * rng.randint(1, 20),
            "max_tokens": rng.randint(1, 512),
            "temperature": round(rng.random(), 2),
        }
        if i % 7 == 0:
            req["bogus_field"] = True  # invalid: closed object
        if i % 11 == 0:
            req["max_tokens"] = -5  # invalid: minimum
        return req

    rows = []
    for batch in (64, 512, 4096):
        docs = [mk_request(i) for i in range(batch)]
        parsed = [parse_document(d) for d in docs]
        t0 = time.perf_counter()
        seq_results = [seq.is_valid(d, parsed=True) for d in parsed]
        t_seq = time.perf_counter() - t0

        table = encode_batch(docs, max_nodes=64)
        bv.validate(table)  # warm the jit
        t0 = time.perf_counter()
        valid, decided = bv.validate(table)
        t_batch = time.perf_counter() - t0
        t0 = time.perf_counter()
        table2 = encode_batch(docs, max_nodes=64)
        t_encode = time.perf_counter() - t0
        assert all(bool(v) == r for v, r, d in zip(valid, seq_results, decided) if d)
        rows.append(
            {
                "batch": batch,
                "sequential_us_per_doc": t_seq / batch * 1e6,
                "batched_us_per_doc": t_batch / batch * 1e6,
                "encode_us_per_doc": t_encode / batch * 1e6,
            }
        )
        lines.append(
            f"batched/request_validation_b{batch},{t_batch/batch*1e6:.2f},"
            f"seq_us={t_seq/batch*1e6:.2f};encode_us={t_encode/batch*1e6:.2f}"
        )
    report["batched"] = {"coverage": coverage, "unbatchable": reasons, "throughput": rows}
    return lines
