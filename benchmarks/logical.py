"""Logical applicators on the batched path (assertion-group circuits).

Before DESIGN.md §10, ANY ``anyOf``/``oneOf``/``not``/``if`` schema fell
back 100% to the sequential engine -- and tagged unions (the most common
real-world API-payload shape for logical applicators) are exactly that.
This benchmark measures what the circuit lowering buys on
discriminated-union traffic:

* **throughput** -- a payments-style tagged union (``oneOf`` over four
  method shapes discriminated by ``kind``) at B in {64, 512, 4096}: the
  hybrid path (one batched launch, all documents decided) against the
  old all-sequential fallback (which is just the sequential engine, so
  ``speedup_vs_sequential`` IS the hybrid-vs-fallback ratio);
* **shape sweep** -- batched speedup as the union widens (2..8 branches)
  at B=4096, with the tape's circuit/window growth (C, A-hat) reported
  alongside.

Emits ``results/BENCH_logical.json``.
"""

from __future__ import annotations

import json
import random
import time
from pathlib import Path
from typing import Dict, List

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.doc_model import parse_document
from repro.core.tape import build_tape
from repro.data.doc_table import encode_batch

BATCH_SIZES = (64, 512, 4096)
RESULTS = Path(__file__).resolve().parents[1] / "results"

UNION_SCHEMA = {
    "type": "object",
    "required": ["amount", "method"],
    "properties": {
        "amount": {"type": "integer", "minimum": 1, "maximum": 1_000_000},
        "currency": {"enum": ["usd", "eur", "gbp"]},
        "method": {
            "type": "object",
            "required": ["kind"],
            "properties": {"kind": {"enum": ["card", "bank", "wallet", "crypto"]}},
            "oneOf": [
                {
                    "properties": {
                        "kind": {"const": "card"},
                        "number": {"type": "string", "minLength": 12, "maxLength": 19},
                        "cvv": {"type": "string", "minLength": 3, "maxLength": 4},
                    },
                    "required": ["number", "cvv"],
                },
                {
                    "properties": {
                        "kind": {"const": "bank"},
                        "iban": {"type": "string", "minLength": 15, "maxLength": 34},
                    },
                    "required": ["iban"],
                },
                {
                    "properties": {
                        "kind": {"const": "wallet"},
                        "wallet_id": {"type": "string", "pattern": "^w-"},
                    },
                    "required": ["wallet_id"],
                },
                {
                    "properties": {
                        "kind": {"const": "crypto"},
                        "address": {"type": "string", "minLength": 20},
                        "chain": {"enum": ["btc", "eth"]},
                    },
                    "required": ["address", "chain"],
                },
            ],
        },
    },
}


def _method(rng: random.Random) -> dict:
    kind = rng.choice(["card", "bank", "wallet", "crypto"])
    if kind == "card":
        m = {"kind": kind, "number": "4111111111111111", "cvv": "123"}
    elif kind == "bank":
        m = {"kind": kind, "iban": "DE8937040044053201"}
    elif kind == "wallet":
        m = {"kind": kind, "wallet_id": f"w-{rng.randint(0, 999)}"}
    else:
        m = {"kind": kind, "address": "bc1" + "q" * 20, "chain": rng.choice(["btc", "eth"])}
    r = rng.random()
    if r < 0.04:
        m.pop(rng.choice([k for k in m if k != "kind"]))  # missing branch field
    elif r < 0.08:
        m["kind"] = rng.choice(["card", "bank", "wallet", "crypto"])  # kind swap
    return m


def _doc(rng: random.Random) -> dict:
    out = {"amount": rng.randint(1, 500_000), "method": _method(rng)}
    if rng.random() < 0.5:
        out["currency"] = rng.choice(["usd", "eur", "gbp"])
    if rng.random() < 0.03:
        out["amount"] = 0  # below minimum
    return out


def _wide_union(n_branches: int) -> dict:
    kinds = [f"k{i}" for i in range(n_branches)]
    return {
        "type": "object",
        "required": ["kind"],
        "properties": {"kind": {"enum": kinds}},
        "oneOf": [
            {
                "properties": {
                    "kind": {"const": k},
                    f"f{i}": {"type": "integer", "minimum": 0},
                },
                "required": [f"f{i}"],
            }
            for i, k in enumerate(kinds)
        ],
    }


def _hybrid_time(bv, seq, table, parsed) -> Dict[str, float]:
    """One batched launch + sequential routing of undecided rows."""
    bv.validate(table)  # warm the jit for this shape
    t_launch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        valid, decided = bv.validate(table)
        t_launch = min(t_launch, time.perf_counter() - t0)
    t0 = time.perf_counter()
    routed = [
        bool(v) if d else seq.is_valid(p, parsed=True)
        for v, d, p in zip(valid, decided, parsed)
    ]
    t_route = time.perf_counter() - t0
    return {
        "seconds": t_launch + t_route,
        "launch_seconds": t_launch,
        "route_seconds": t_route,
        "fallback_rate": 1.0 - float(decided.mean()),
        "verdicts": routed,
    }


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []
    rng = random.Random(0x10C)
    payload: Dict[str, object] = {}

    compiled = compile_schema(UNION_SCHEMA)
    tape = build_tape(compiled)
    seq = Validator(compiled)
    seq_cg = Validator(compiled, engine="codegen")
    bv = BatchValidator(tape, use_pallas=False)

    payload["tape"] = {
        "locations": tape.n_locations,
        "n_circuits": tape.n_circuits,
        "max_circ_depth": tape.max_circ_depth,
        "a_hat": tape.max_rows_per_loc,
        "k": tape.max_hash_run,
        "horizon": tape.max_loc_depth + 1,
        "assertions": tape.n_assertions,
    }

    rows = []
    for batch in BATCH_SIZES:
        docs = [_doc(rng) for _ in range(batch)]
        parsed = [parse_document(d) for d in docs]
        t0 = time.perf_counter()
        seq_results = [seq.is_valid(p, parsed=True) for p in parsed]
        t_seq = time.perf_counter() - t0
        t0 = time.perf_counter()
        [seq_cg.is_valid(p, parsed=True) for p in parsed]
        t_seq_cg = time.perf_counter() - t0
        t0 = time.perf_counter()
        table = encode_batch(docs, max_nodes=16)
        t_encode = time.perf_counter() - t0
        hybrid = _hybrid_time(bv, seq, table, parsed)
        assert hybrid["verdicts"] == seq_results, "hybrid != sequential"
        rows.append(
            {
                "batch": batch,
                "invalid_rate": 1.0 - sum(seq_results) / batch,
                "sequential_us_per_doc": t_seq / batch * 1e6,
                "sequential_codegen_us_per_doc": t_seq_cg / batch * 1e6,
                "encode_us_per_doc": t_encode / batch * 1e6,
                "hybrid_us_per_doc": hybrid["seconds"] / batch * 1e6,
                "launch_us_per_doc": hybrid["launch_seconds"] / batch * 1e6,
                "fallback_rate": hybrid["fallback_rate"],
                # the pre-circuit behaviour was 100% sequential fallback,
                # so this ratio is hybrid vs the all-sequential baseline
                "speedup_vs_all_sequential": t_seq / hybrid["seconds"],
            }
        )
        lines.append(
            f"logical/union_b{batch},{rows[-1]['hybrid_us_per_doc']:.2f},"
            f"seq_us={rows[-1]['sequential_us_per_doc']:.2f};"
            f"x_allseq={rows[-1]['speedup_vs_all_sequential']:.2f};"
            f"fallback={rows[-1]['fallback_rate']:.3f}"
        )
    payload["throughput"] = rows

    # -- union-width sweep at the largest batch ---------------------------
    sweep = []
    batch = BATCH_SIZES[-1]
    for width in (2, 4, 8):
        schema = _wide_union(width)
        c = compile_schema(schema)
        t = build_tape(c)
        s = Validator(c)
        b = BatchValidator(t, use_pallas=False)
        docs = []
        for _ in range(batch):
            k = rng.randrange(width)
            d = {"kind": f"k{k}", f"f{k}": rng.randint(-1, 9)}
            if rng.random() < 0.1:
                d.pop(f"f{k}")
            docs.append(d)
        parsed = [parse_document(d) for d in docs]
        t0 = time.perf_counter()
        seq_results = [s.is_valid(p, parsed=True) for p in parsed]
        t_seq = time.perf_counter() - t0
        table = encode_batch(docs, max_nodes=8)
        hybrid = _hybrid_time(b, s, table, parsed)
        assert hybrid["verdicts"] == seq_results
        sweep.append(
            {
                "branches": width,
                "n_circuits": t.n_circuits,
                "a_hat": t.max_rows_per_loc,
                "hybrid_us_per_doc": hybrid["seconds"] / batch * 1e6,
                "sequential_us_per_doc": t_seq / batch * 1e6,
                "speedup_vs_all_sequential": t_seq / hybrid["seconds"],
            }
        )
    payload["width_sweep"] = sweep
    lines.append(
        f"logical/width8_b{batch},{sweep[-1]['hybrid_us_per_doc']:.2f},"
        f"x_allseq={sweep[-1]['speedup_vs_all_sequential']:.2f}"
    )

    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_logical.json").write_text(json.dumps(payload, indent=2))
    lines.append("logical/bench_json,0,results/BENCH_logical.json")
    report["logical"] = payload
    return lines


if __name__ == "__main__":
    out: Dict[str, object] = {}
    for line in run(out):
        print(line)
