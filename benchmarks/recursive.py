"""Beyond-paper: recursive-$ref schemas on the batched path (DESIGN.md §9).

Before bounded unrolling, ANY recursive schema fell back 100% to the
sequential engine.  This benchmark measures what the unrolled tape buys
on recursion-shaped traffic:

* **throughput** -- linked-list and binary-tree schemas at
  B in {64, 512, 4096}: the hybrid path (one batched launch + sequential
  routing of the frontier/undecided rows) against the old all-sequential
  fallback;
* **depth-distribution sweep** -- the same hybrid at increasing
  shares of documents deeper than the unroll budget (the overflow rate
  is the knob that decays batched throughput toward sequential);
* **unroll_depth sweep** -- overflow-fallback rate and tape size
  (locations / horizon / A-hat) as the budget grows on a fixed depth
  distribution.

Emits ``results/BENCH_recursive.json``.
"""

from __future__ import annotations

import json
import random
import time
from pathlib import Path
from typing import Dict, List

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.doc_model import parse_document
from repro.core.tape import build_tape
from repro.data.doc_table import encode_batch

BATCH_SIZES = (64, 512, 4096)
RESULTS = Path(__file__).resolve().parents[1] / "results"

LIST_SCHEMA = {
    "$defs": {
        "node": {
            "type": "object",
            "properties": {
                "value": {"type": "integer"},
                "next": {"$ref": "#/$defs/node"},
            },
            "required": ["value"],
        }
    },
    "$ref": "#/$defs/node",
}

TREE_SCHEMA = {
    "$defs": {
        "t": {
            "type": "object",
            "properties": {
                "v": {"type": "number", "minimum": 0},
                "left": {"$ref": "#/$defs/t"},
                "right": {"$ref": "#/$defs/t"},
            },
        }
    },
    "$ref": "#/$defs/t",
}


def _chain(rng: random.Random, depth: int) -> dict:
    doc = node = {"value": rng.randint(0, 9)}
    for _ in range(depth):
        node["next"] = node = {"value": rng.randint(0, 9)}
    if rng.random() < 0.05:
        node["value"] = "bad"  # ~5% invalid traffic (fails at the tail)
    return doc


def _tree(rng: random.Random, depth: int) -> dict:
    out = {"v": rng.random() if rng.random() > 0.1 else -1.0}
    if depth > 0:
        out["left"] = _tree(rng, depth - 1)
        if rng.random() < 0.7:
            out["right"] = _tree(rng, depth - 1)
    return out


def _sample_depth(rng: random.Random, unroll: int, deep_frac: float) -> int:
    if rng.random() < deep_frac:
        return unroll + rng.randint(1, 3)  # overruns the budget
    return rng.randint(0, unroll)


def _hybrid_time(bv, seq, table, parsed) -> Dict[str, float]:
    """One batched launch + sequential routing of undecided rows.

    Best-of-3 on the launch (jit already warm); like BENCH_batched /
    BENCH_registry, encode time is reported separately by the caller --
    the comparison is validate-vs-validate.
    """
    bv.validate(table)  # warm the jit for this shape
    t_launch = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        valid, decided = bv.validate(table)
        t_launch = min(t_launch, time.perf_counter() - t0)
    t0 = time.perf_counter()
    routed = [
        bool(v) if d else seq.is_valid(p, parsed=True)
        for v, d, p in zip(valid, decided, parsed)
    ]
    t_route = time.perf_counter() - t0
    return {
        "seconds": t_launch + t_route,
        "launch_seconds": t_launch,
        "route_seconds": t_route,
        "fallback_rate": 1.0 - float(decided.mean()),
        "verdicts": routed,
    }


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []
    rng = random.Random(0x5EC)

    payload: Dict[str, object] = {"schemas": {}}

    for name, schema, gen, unroll, max_nodes in (
        # max_nodes sized to the budgeted doc shapes: chain(4) is 10
        # nodes, a depth-3 tree at most 31 -- padding is pure overhead
        ("linked_list", LIST_SCHEMA, _chain, 4, 16),
        ("binary_tree", TREE_SCHEMA, _tree, 3, 32),
    ):
        compiled = compile_schema(schema)
        tape = build_tape(compiled, unroll_depth=unroll)
        seq = Validator(compiled)
        seq_cg = Validator(compiled, engine="codegen")
        bv = BatchValidator(tape, use_pallas=False)

        tape_facts = {
            "unroll_depth": tape.unroll_depth,
            "locations": tape.n_locations,
            "n_frontier": tape.n_frontier,
            "horizon": tape.max_loc_depth + 1,
            "a_hat": tape.max_rows_per_loc,
            "k": tape.max_hash_run,
        }

        # -- throughput: all docs within budget (the common case) ---------
        # realistic recursive payloads carry real nesting: depth skews
        # toward the budget (GeoJSON geometries, AST nodes) rather than
        # degenerate empty chains
        rows = []
        for batch in BATCH_SIZES:
            docs = [
                gen(rng, max(1, rng.randint(0, unroll * 2) % (unroll + 1)))
                for _ in range(batch)
            ]
            parsed = [parse_document(d) for d in docs]
            t0 = time.perf_counter()
            seq_results = [seq.is_valid(p, parsed=True) for p in parsed]
            t_seq = time.perf_counter() - t0
            t0 = time.perf_counter()
            [seq_cg.is_valid(p, parsed=True) for p in parsed]
            t_seq_cg = time.perf_counter() - t0
            t0 = time.perf_counter()
            table = encode_batch(docs, max_nodes=max_nodes)
            t_encode = time.perf_counter() - t0
            hybrid = _hybrid_time(bv, seq, table, parsed)
            assert hybrid["verdicts"] == seq_results, name
            rows.append(
                {
                    "batch": batch,
                    "sequential_us_per_doc": t_seq / batch * 1e6,
                    "sequential_codegen_us_per_doc": t_seq_cg / batch * 1e6,
                    "encode_us_per_doc": t_encode / batch * 1e6,
                    "hybrid_us_per_doc": hybrid["seconds"] / batch * 1e6,
                    "launch_us_per_doc": hybrid["launch_seconds"] / batch * 1e6,
                    "fallback_rate": hybrid["fallback_rate"],
                    "speedup_vs_sequential": t_seq / hybrid["seconds"],
                }
            )
            lines.append(
                f"recursive/{name}_b{batch},{rows[-1]['hybrid_us_per_doc']:.2f},"
                f"seq_us={rows[-1]['sequential_us_per_doc']:.2f};"
                f"x_seq={rows[-1]['speedup_vs_sequential']:.2f};"
                f"fallback={rows[-1]['fallback_rate']:.3f}"
            )

        # -- depth-distribution sweep at B=4096 ---------------------------
        # deeper-than-budget docs need wider tables (a depth-6 tree is
        # ~250 nodes); the sweep pays that honestly
        sweep = []
        batch = BATCH_SIZES[-1]
        sweep_nodes = max_nodes * (2 if name == "linked_list" else 8)
        for deep_frac in (0.0, 0.05, 0.2, 0.5):
            docs = [
                gen(rng, _sample_depth(rng, unroll, deep_frac))
                for _ in range(batch)
            ]
            parsed = [parse_document(d) for d in docs]
            t0 = time.perf_counter()
            seq_results = [seq.is_valid(p, parsed=True) for p in parsed]
            t_seq = time.perf_counter() - t0
            table = encode_batch(docs, max_nodes=sweep_nodes)
            hybrid = _hybrid_time(bv, seq, table, parsed)
            assert hybrid["verdicts"] == seq_results, name
            sweep.append(
                {
                    "deep_fraction": deep_frac,
                    "fallback_rate": hybrid["fallback_rate"],
                    "hybrid_us_per_doc": hybrid["seconds"] / batch * 1e6,
                    "speedup_vs_sequential": t_seq / hybrid["seconds"],
                }
            )

        # -- unroll_depth sweep: overflow rate vs budget ------------------
        depth_sweep = []
        docs = [gen(rng, _sample_depth(rng, 4, 0.15)) for _ in range(512)]
        table = encode_batch(docs, max_nodes=sweep_nodes)
        for budget in (1, 2, 4, 6, 8):
            t = build_tape(compiled, unroll_depth=budget)
            b = BatchValidator(t, use_pallas=False)
            _, decided = b.validate(table)
            depth_sweep.append(
                {
                    "unroll_depth": budget,
                    "locations": t.n_locations,
                    "n_frontier": t.n_frontier,
                    "horizon": t.max_loc_depth + 1,
                    "overflow_fallback_rate": 1.0 - float(decided.mean()),
                }
            )
        lines.append(
            f"recursive/{name}_overflow_at_d4,"
            f"{depth_sweep[2]['overflow_fallback_rate']:.3f},"
            f"locations={depth_sweep[2]['locations']}"
        )

        payload["schemas"][name] = {
            "tape": tape_facts,
            "throughput": rows,
            "depth_distribution_sweep": sweep,
            "unroll_depth_sweep": depth_sweep,
        }

    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_recursive.json").write_text(json.dumps(payload, indent=2))
    lines.append("recursive/bench_json,0,results/BENCH_recursive.json")
    report["recursive"] = payload
    return lines
