"""Table 5 / Figure 6 analogue: per-dataset validation runtime.

Compares, per corpus dataset:
  * ``blaze``   -- compiled, all optimizations on (the paper's system)
  * ``codegen`` -- beyond-paper closure compilation (the paper's §8
                   future work, core/codegen.py)
  * ``unopt``   -- compiled with every §4 optimization disabled + string
                   comparison instead of semi-perfect hashing
  * ``naive``   -- the schema-walking interpreter (the "existing
                   validator" comparison point, cf. Python jsonschema)

Cold = first pass over the documents right after compilation; warm = best
of ``WARM_ROUNDS`` subsequent passes (paper §6.2.2 methodology).  Summary
= total across datasets + geomean speedup vs each baseline.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, List

from repro.core import CompilerOptions, NaiveValidator, Validator, compile_schema
from repro.core.doc_model import parse_document
from repro.data.corpus import make_corpus

SCALE = float(os.environ.get("BENCH_CORPUS_SCALE", "0.25"))
WARM_ROUNDS = int(os.environ.get("BENCH_WARM_ROUNDS", "3"))

_UNOPT = CompilerOptions(
    unroll=False, regex_specialize=False, reorder=False, cisc=False, elide=False
)


def _time_pass(validator, docs, *, parsed=True) -> float:
    t0 = time.perf_counter()
    for d in docs:
        validator.is_valid(d, parsed=True) if parsed else validator.is_valid(d)
    return time.perf_counter() - t0


def run(report: Dict[str, object]) -> List[str]:
    lines = []
    corpus = make_corpus(scale=SCALE)
    totals = {
        "blaze": [0.0, 0.0], "codegen": [0.0, 0.0],
        "unopt": [0.0, 0.0], "naive": [0.0, 0.0],
    }
    rows = []
    for ds in corpus:
        docs_parsed = [parse_document(d) for d in ds.documents]

        t0 = time.perf_counter()
        compiled = compile_schema(ds.schema)
        compile_s = time.perf_counter() - t0
        blaze = Validator(compiled)
        codegen = Validator(compiled, engine="codegen")
        unopt = Validator(compile_schema(ds.schema, options=_UNOPT), use_hashing=False)
        naive = NaiveValidator(ds.schema)

        # correctness cross-check on this dataset (documents are valid by
        # construction; all engines must agree)
        for d, dp in zip(ds.documents[:25], docs_parsed[:25]):
            a = blaze.is_valid(dp, parsed=True)
            b = naive.is_valid(d)
            c = codegen.is_valid(dp, parsed=True)
            assert a and b and c, f"validator disagreement on {ds.name}"

        cold = {
            "blaze": _time_pass(blaze, docs_parsed),
            "codegen": _time_pass(codegen, docs_parsed),
            "unopt": _time_pass(unopt, docs_parsed),
        }
        t0 = time.perf_counter()
        for d in ds.documents:
            naive.is_valid(d)
        cold["naive"] = time.perf_counter() - t0

        warm = {k: float("inf") for k in cold}
        for _ in range(WARM_ROUNDS):
            warm["blaze"] = min(warm["blaze"], _time_pass(blaze, docs_parsed))
            warm["codegen"] = min(warm["codegen"], _time_pass(codegen, docs_parsed))
            warm["unopt"] = min(warm["unopt"], _time_pass(unopt, docs_parsed))
            t0 = time.perf_counter()
            for d in ds.documents:
                naive.is_valid(d)
            warm["naive"] = min(warm["naive"], time.perf_counter() - t0)

        n = len(ds.documents)
        for k in totals:
            totals[k][0] += cold[k]
            totals[k][1] += warm[k]
        rows.append(
            dict(
                name=ds.name, docs=n, compile_s=compile_s,
                schema_kb=ds.schema_bytes / 1024,
                **{f"{k}_cold_ms": cold[k] * 1e3 for k in cold},
                **{f"{k}_warm_ms": warm[k] * 1e3 for k in warm},
            )
        )
        lines.append(
            f"validation/{ds.name},{warm['blaze']/n*1e6:.2f},"
            f"naive_x={warm['naive']/max(warm['blaze'],1e-12):.1f};"
            f"unopt_x={warm['unopt']/max(warm['blaze'],1e-12):.1f}"
        )

    cold_speedup = totals["naive"][0] / max(totals["blaze"][0], 1e-12)
    warm_speedup = totals["naive"][1] / max(totals["blaze"][1], 1e-12)
    unopt_speedup = totals["unopt"][1] / max(totals["blaze"][1], 1e-12)
    cg_cold = totals["naive"][0] / max(totals["codegen"][0], 1e-12)
    cg_warm = totals["naive"][1] / max(totals["codegen"][1], 1e-12)
    lines.append(f"validation/TOTAL_cold_speedup_vs_naive,{cold_speedup:.2f},x")
    lines.append(f"validation/TOTAL_warm_speedup_vs_naive,{warm_speedup:.2f},x")
    lines.append(f"validation/TOTAL_warm_speedup_vs_unopt,{unopt_speedup:.2f},x")
    lines.append(f"validation/TOTAL_codegen_cold_speedup_vs_naive,{cg_cold:.2f},x")
    lines.append(f"validation/TOTAL_codegen_warm_speedup_vs_naive,{cg_warm:.2f},x")
    report["validation"] = {"rows": rows, "totals": totals,
                            "speedups": {"cold_vs_naive": cold_speedup,
                                         "warm_vs_naive": warm_speedup,
                                         "warm_vs_unopt": unopt_speedup,
                                         "codegen_cold_vs_naive": cg_cold,
                                         "codegen_warm_vs_naive": cg_warm}}
    return lines
