"""Fault-containment overhead + degraded-mode throughput (DESIGN.md §11).

Two questions, both machine-checked across PRs via
``results/BENCH_robustness.json``:

1. **Clean-path overhead**: what does the containment machinery (the
   ``validate_isolated`` launch wrapper with its fault seam, plus the
   pre-encode admission resource guard) cost when *no* fault is armed?
   Must stay <5% of the linked-launch µs/doc that ``BENCH_registry``
   reports at B=4096.
2. **Poisoned throughput**: with 1–10% of documents injected to fail at
   launch, how much throughput does the bisecting isolator preserve for
   the healthy rows (worst case O(P·log B) extra launches)?

Same schemas, mix, and encode budget as ``benchmarks/registry.py`` so
the numbers are directly comparable.
"""

from __future__ import annotations

import json
import random
import time
from pathlib import Path
from typing import Dict, List

import numpy as np

from repro.core.outcomes import GuardLimits, resource_guard
from repro.data.doc_table import encode_batch
from repro.registry import SchemaRegistry
from repro.registry.presets import GATEWAY_SCHEMAS as SCHEMAS
from repro.serve.faults import FaultInjector

from .registry import MAX_NODES, _mixed_stream

BATCH = 4096
POISON_RATES = (0.01, 0.05, 0.10)
RESULTS = Path(__file__).resolve().parents[1] / "results"


def _best_of(fn, n=5) -> float:
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []
    rng = random.Random(0)

    reg = SchemaRegistry(use_pallas=False)
    for name, schema in SCHEMAS.items():
        reg.register(name, schema)
    bv = reg.batch_validator()
    docs, endpoints = _mixed_stream(BATCH, rng)
    ids = reg.schema_ids(endpoints).astype(np.int32)
    table = encode_batch(docs, max_nodes=MAX_NODES)
    keys = list(range(BATCH))

    # -- clean path: raw launch vs the containment wrapper -------------------
    raw_valid, raw_decided, _ = bv.validate_ex(table, ids)  # warm the jit
    iso_valid, iso_decided, _, errors = bv.validate_isolated(table, ids, keys=keys)
    assert not errors and (raw_valid == iso_valid).all()
    assert (raw_decided == iso_decided).all()

    t_raw = _best_of(lambda: bv.validate_ex(table, ids))
    t_iso = _best_of(lambda: bv.validate_isolated(table, ids, keys=keys))
    overhead_pct = 100.0 * (t_iso - t_raw) / t_raw

    # -- admission guard (runs per document, before encode) ------------------
    limits = GuardLimits()
    t_guard = _best_of(lambda: [resource_guard(d, limits) for d in docs])

    raw_us = t_raw / BATCH * 1e6
    iso_us = t_iso / BATCH * 1e6
    guard_us = t_guard / BATCH * 1e6
    lines.append(f"launch_raw,{raw_us:.3f},B={BATCH}")
    lines.append(f"launch_isolated,{iso_us:.3f},overhead={overhead_pct:.2f}%")
    lines.append(f"resource_guard,{guard_us:.3f},per-doc pre-encode")

    # -- throughput under injected poison ------------------------------------
    poisoned_rows = []
    for rate in POISON_RATES:
        inj = FaultInjector(seed=42).rate("launch", rate)
        n_poison = len(inj.poisoned_keys("launch", keys))

        def poisoned():
            with FaultInjector(seed=42).rate("launch", rate):
                return bv.validate_isolated(table, ids, keys=keys)

        _, p_decided, _, p_errors = poisoned()  # warm bisection shapes
        assert len(p_errors) == n_poison
        healthy = int(p_decided.sum())
        t_poison = _best_of(poisoned, n=3)
        poisoned_rows.append(
            {
                "rate": rate,
                "n_poisoned": n_poison,
                "healthy_decided": healthy,
                "total_us_per_doc": t_poison / BATCH * 1e6,
                "healthy_docs_per_s": healthy / t_poison,
                "slowdown_vs_clean": t_poison / t_iso,
            }
        )
        lines.append(
            f"poison_{int(rate * 100)}pct,{t_poison / BATCH * 1e6:.3f},"
            f"x{t_poison / t_iso:.2f} vs clean"
        )

    payload = {
        "batch": BATCH,
        "max_nodes": MAX_NODES,
        "clean_path": {
            "launch_raw_us_per_doc": raw_us,
            "launch_isolated_us_per_doc": iso_us,
            "containment_overhead_pct": overhead_pct,
            "guard_us_per_doc": guard_us,
        },
        "poisoned": poisoned_rows,
    }
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "BENCH_robustness.json"
    out.write_text(json.dumps(payload, indent=2) + "\n")
    report["robustness"] = payload
    lines.append(f"# wrote {out}")
    return lines
