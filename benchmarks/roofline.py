"""§Roofline: three-term roofline per (arch x shape x mesh) from the
dry-run artifacts in results/dryrun/.

    compute    = dot_flops_per_device      / peak_FLOP/s          (197 TF bf16)
    memory     = hbm_traffic_per_device    / HBM bandwidth        (819 GB/s)
    collective = collective_bytes_per_dev  / ICI bandwidth        (50 GB/s)

All three are *seconds per step per chip* (per-device quantities divided by
per-chip rates == job totals divided by chip-aggregate rates).  The
dominant term is the bottleneck; roofline fraction = compute / max(terms).
Also reports MODEL_FLOPS (6ND / 2ND analytic) and the useful-compute ratio
MODEL_FLOPS / HLO_dot_flops (catches remat/dispatch waste).
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells() -> List[dict]:
    cells = []
    for path in sorted(RESULTS.glob("*.json")):
        cells.append(json.loads(path.read_text()))
    return cells


def roofline_row(cell: dict) -> Optional[dict]:
    if cell.get("status") != "ok":
        return None
    from repro.configs import get_config
    from repro.models.flops import hbm_bytes_lower_bound, model_flops

    chips = cell["chips"]
    flops_dev = cell["cost"]["dot_flops_per_device"]
    bytes_dev = cell["cost"]["hbm_traffic_bytes_per_device"]
    coll_dev = cell["collective_bytes_per_device"]

    cfg = get_config(cell["arch"])
    t_compute = flops_dev / PEAK_FLOPS
    # HLO traffic is an upper bound (CPU backend fuses less than TPU:
    # every intermediate round-trips); the analytic floor is weights +
    # optimizer + cache traffic.  TPU truth lies between.
    t_memory_hlo = bytes_dev / HBM_BW
    floor_dev = hbm_bytes_lower_bound(cfg, cell["shape"]) / chips
    t_memory_floor = floor_dev / HBM_BW
    t_collective = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory_hlo, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    terms_opt = {
        "compute": t_compute, "memory": t_memory_floor, "collective": t_collective
    }

    mf = model_flops(cfg, cell["shape"])
    mf_dev = mf / chips
    useful = mf_dev / flops_dev if flops_dev else 0.0
    frac = t_compute / max(terms.values()) if max(terms.values()) > 0 else 0.0
    frac_opt = (
        t_compute / max(terms_opt.values()) if max(terms_opt.values()) > 0 else 0.0
    )
    # TPU-expected resident set: arguments (weights+opt+cache) + one temp
    # working set; raw bytes_per_device keeps CPU while-copy artifacts
    mem = cell["memory"]
    resident = mem["argument_bytes"] + max(
        0, min(mem["temp_bytes"], mem["temp_bytes"] // 3)
    )
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": cell["mesh"],
        "chips": chips,
        "compute_s": t_compute,
        "memory_s": t_memory_hlo,
        "memory_floor_s": t_memory_floor,
        "collective_s": t_collective,
        "dominant": dominant,
        "roofline_fraction": frac,
        "roofline_fraction_optimistic": frac_opt,
        "model_flops_per_device": mf_dev,
        "hlo_dot_flops_per_device": flops_dev,
        "useful_compute_ratio": useful,
        "hbm_gib_per_device": cell["memory"]["bytes_per_device"] / 2**30,
        "fits_v5e_16g": cell["memory"]["bytes_per_device"] < 16 * 2**30,
    }


def markdown_table(rows: List[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s (hlo/floor) | collective s | "
        "bottleneck | frac (hlo/floor) | useful ratio | HBM GiB | fits |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.2e} / {r['memory_floor_s']:.2e} | {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['roofline_fraction']:.2f} / {r['roofline_fraction_optimistic']:.2f} "
            f"| {r['useful_compute_ratio']:.2f} "
            f"| {r['hbm_gib_per_device']:.2f} | {'Y' if r['fits_v5e_16g'] else 'N'} |\n"
        )
    return hdr + body


def run(report: Dict[str, object]) -> List[str]:
    lines: List[str] = []
    rows = []
    for cell in load_cells():
        row = roofline_row(cell)
        if row is None:
            continue
        rows.append(row)
        lines.append(
            f"roofline/{row['arch']}__{row['shape']}__{row['mesh']},"
            f"{max(row['compute_s'], row['memory_s'], row['collective_s'])*1e6:.1f},"
            f"bottleneck={row['dominant']};frac={row['roofline_fraction']:.2f}"
        )
    report["roofline"] = rows
    out = RESULTS.parent / "roofline_table.md"
    out.write_text(markdown_table([r for r in rows if r["mesh"] == "16x16"]))
    lines.append(f"roofline/table,0,written_to={out}")
    return lines


if __name__ == "__main__":
    rep: Dict[str, object] = {}
    for line in run(rep):
        print(line)
