"""Cost-attribution profiler, event log, and SLO tracking (DESIGN.md §13).

Three layers under test: the :mod:`repro.obs.profile` seam contract
(disarmed is one None check, armed attribution is nesting-aware and
double-count-free), the sampled :class:`EventLog` ring, and the
SLO/burn-rate math over the serving latency histograms -- plus the
profiler-armed smoke test over the real admission path that CI runs in
tier-1.
"""

import io
import json
import time

import pytest

from repro.obs.events import EventLog
from repro.obs.metrics import Histogram
from repro.obs.profile import (
    Profiler,
    phase,
    profiler_armed,
    set_profiler,
)
from repro.obs.slo import SLObjective, SLOTracker, good_count, slo_status
from repro.registry import SchemaRegistry

SCHEMA = {
    "type": "object",
    "required": ["a"],
    "properties": {"a": {"type": "integer", "minimum": 0}},
    "additionalProperties": False,
}


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------


class TestProfiler:
    def test_disarmed_is_noop(self):
        assert not profiler_armed()
        with phase("anything"):
            pass  # must not raise, must not record
        # the disarmed seam returns one shared object (no allocation)
        assert phase("a") is phase("b")

    def test_phases_accumulate(self):
        with Profiler() as prof:
            assert profiler_armed()
            for _ in range(3):
                with phase("work"):
                    pass
        assert not profiler_armed()  # disarmed on exit
        stats = prof.stats()
        assert stats["work"].calls == 3
        assert stats["work"].total_ns >= stats["work"].self_ns >= 0

    def test_nested_phases_attribute_exclusive_time(self):
        with Profiler() as prof:
            with phase("outer"):
                time.sleep(0.002)
                with phase("inner"):
                    time.sleep(0.004)
        outer, inner = prof.stats()["outer"], prof.stats()["inner"]
        # inclusive: outer contains inner; exclusive: outer excludes it
        assert outer.total_ns >= inner.total_ns
        assert outer.self_ns == outer.total_ns - inner.total_ns
        # sum of exclusive time never double-counts
        assert prof.attributed_ns() == outer.self_ns + inner.self_ns
        assert prof.attributed_ns() <= outer.total_ns

    def test_coverage_and_report(self):
        with Profiler() as prof:
            t0 = time.perf_counter_ns()
            with phase("a"):
                time.sleep(0.002)
            with phase("b"):
                time.sleep(0.001)
            window = time.perf_counter_ns() - t0
        cov = prof.coverage(window)
        assert 0.5 < cov <= 1.0 + 1e-9  # sleeps dominate the window
        rep = prof.report(window)
        assert rep["coverage"] == pytest.approx(cov)
        assert list(rep["phases"]) == ["a", "b"]  # sorted by self_ns
        assert rep["phases"]["a"]["window_frac"] > rep["phases"]["b"]["window_frac"]
        assert rep["unattributed_ns"] == window - rep["attributed_ns"]
        assert prof.coverage(0) == 0.0
        prof.clear()
        assert prof.stats() == {} and prof.attributed_ns() == 0

    def test_nested_arming_restores_previous(self):
        outer = Profiler()
        prev = set_profiler(outer)
        try:
            with Profiler() as inner:
                with phase("x"):
                    pass
            assert "x" in inner.stats() and "x" not in outer.stats()
            with phase("y"):
                pass
            assert "y" in outer.stats()  # restored
        finally:
            set_profiler(prev)

    def test_admission_path_attribution_smoke(self):
        """The tier-1 armed smoke: a profiler over a real mixed admission
        must see the taxonomy phases and explain most of the window."""
        reg = SchemaRegistry(use_pallas=False)
        reg.register("ep", SCHEMA)
        docs = [{"a": i} for i in range(24)] + [{"a": -1}, {}, {"a": "x"}]
        eps = ["ep"] * len(docs)
        reg.admit_mixed_ex(docs, eps)  # warm the jit outside the window
        with Profiler() as prof:
            t0 = time.perf_counter_ns()
            verdicts, _ = reg.admit_mixed_ex(docs, eps)
            window = time.perf_counter_ns() - t0
        assert len(verdicts) == len(docs)
        names = set(prof.stats())
        assert {"admit.guard", "admit.encode", "admit.launch",
                "admit.verdicts", "encode.walk", "encode.hash",
                "encode.pack", "executor.execute"} <= names
        # warm small-batch coverage is noisier than the B=4096 bench bar
        assert prof.coverage(window) > 0.5


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------


class TestEventLog:
    def test_validation(self):
        with pytest.raises(ValueError):
            EventLog(capacity=0)
        with pytest.raises(ValueError):
            EventLog(sample=1.5)

    def test_sampling_rate_is_exact_and_deterministic(self):
        ev = EventLog(capacity=16, sample=0.25)
        picks = [ev.want() for _ in range(100)]
        assert sum(picks) == 25  # exact long-run rate
        ev2 = EventLog(capacity=16, sample=0.25)
        assert picks == [ev2.want() for _ in range(100)]  # same schedule
        assert all(EventLog(sample=1.0).want() for _ in range(5))
        off = EventLog(sample=0.0)
        assert not any(off.want() for _ in range(50))

    def test_ring_keeps_newest(self):
        ev = EventLog(capacity=4)
        for i in range(10):
            ev.emit(n=i)
        assert ev.recorded == 10
        assert [r["n"] for r in ev.recent()] == [6, 7, 8, 9]
        assert all("ts" in r for r in ev.recent())

    def test_flush_jsonl_and_clear(self, tmp_path):
        ev = EventLog(capacity=8)
        ev.emit(endpoint="ep", outcome="admitted", ts=1.0)
        ev.emit(endpoint="ep", outcome="invalid", ts=2.0)
        dest = tmp_path / "events.jsonl"
        assert ev.flush(str(dest)) == 2
        lines = dest.read_text().splitlines()
        assert [json.loads(l)["outcome"] for l in lines] == [
            "admitted", "invalid"
        ]
        assert ev.recent() == [] and ev.flush(str(dest)) == 0
        # file-object destination appends without touching the filesystem
        buf = io.StringIO()
        ev.emit(n=1)
        assert ev.flush(buf) == 1
        assert json.loads(buf.getvalue())["n"] == 1


# ---------------------------------------------------------------------------
# SLO math
# ---------------------------------------------------------------------------


class TestSLO:
    def test_objective_validation(self):
        with pytest.raises(ValueError):
            SLObjective(objective_s=0.0)
        with pytest.raises(ValueError):
            SLObjective(target=1.0)
        assert SLObjective(target=0.99).error_budget == pytest.approx(0.01)

    def test_good_count_edges_and_interpolation(self):
        h = Histogram((0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        assert good_count(h, 0.1) == pytest.approx(1.0)  # exact at an edge
        assert good_count(h, 1.0) == pytest.approx(2.0)
        # midway through the (0.1, 1.0] bucket: linear interpolation
        assert good_count(h, 0.55) == pytest.approx(1.5)
        # past the last finite edge: +Inf observations count as bad
        assert good_count(h, 100.0) == pytest.approx(2.0)

    def test_slo_status_burn_rate(self):
        h = Histogram((0.1, 1.0))
        for _ in range(98):
            h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        s = slo_status(h, SLObjective(objective_s=0.1, target=0.99))
        assert s["count"] == 100 and s["good"] == pytest.approx(98.0)
        assert s["good_ratio"] == pytest.approx(0.98)
        # 2% bad against a 1% budget: burning twice as fast as provisioned
        assert s["burn_rate"] == pytest.approx(2.0)
        # empty histogram: vacuously healthy
        empty = slo_status(Histogram((0.1,)), SLObjective())
        assert empty["good_ratio"] == 1.0 and empty["burn_rate"] == 0.0

    def test_tracker_windows_are_deltas(self):
        h = Histogram((0.1, 1.0))
        tr = SLOTracker(SLObjective(objective_s=0.1, target=0.9))
        for _ in range(10):
            h.observe(0.05)  # all good
        first = tr.update(h)
        assert first["window_count"] == 10
        assert first["window_burn_rate"] == pytest.approx(0.0)
        for _ in range(10):
            h.observe(5.0)  # all bad
        second = tr.update(h)
        assert second["window_count"] == 10
        assert second["window_good_ratio"] == pytest.approx(0.0)
        assert second["window_burn_rate"] == pytest.approx(10.0)  # 1/0.1
        # cumulative view still blends both windows
        assert second["good_ratio"] == pytest.approx(0.5)
        # idle window: no traffic, vacuously healthy
        third = tr.update(h)
        assert third["window_count"] == 0
        assert third["window_burn_rate"] == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# engine integration: events + SLO surfaces
# ---------------------------------------------------------------------------


def _engine(**kw):
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_config("granite-3-8b").reduced()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    return ServeEngine(
        cfg,
        params,
        ServeConfig(batch_slots=2, max_len=64, default_max_tokens=4),
        **kw,
    )


class TestEngineIntegration:
    @pytest.fixture(scope="class")
    def engine(self):
        e = _engine(events=EventLog(capacity=64))
        e.register_endpoint("ep", SCHEMA)
        return e

    def test_submit_emits_sampled_event(self, engine):
        engine.events.clear()
        engine.submit(json.dumps({"a": 1}), "ep")
        engine.submit(json.dumps({"a": -1}), "ep")
        engine.submit("{broken", "ep")
        kinds = [r["kind"] for r in engine.events.recent()]
        assert kinds == ["submit"] * 3
        by_outcome = {r["outcome"] for r in engine.events.recent()}
        assert {"admitted", "invalid", "rejected_guard"} <= by_outcome
        ok = engine.events.recent()[0]
        assert ok["endpoint"] == "ep" and ok["latency_s"] > 0
        assert "parse_s" in ok["stages"] and "validate_s" in ok["stages"]

    def test_submit_batch_emits_batch_events(self, engine):
        engine.events.clear()
        engine.submit_batch(
            [("ep", json.dumps({"a": i})) for i in range(4)]
            + [("ep", "{broken")]
        )
        records = engine.events.recent()
        assert len(records) == 5
        batch = [r for r in records if r["outcome"] != "rejected_guard"]
        assert len(batch) == 4
        assert len({r["batch_id"] for r in batch}) == 1
        assert all(r["stages"]["batch_rows"] == 4 for r in batch)
        guard = [r for r in records if r["outcome"] == "rejected_guard"]
        # true wall from batch entry to the parse reject (DESIGN.md §14
        # closed the historical 0.0-observation under-count)
        assert guard and guard[0]["latency_s"] > 0.0

    def test_flush_events(self, engine, tmp_path):
        engine.events.clear()
        engine.submit(json.dumps({"a": 1}), "ep")
        dest = tmp_path / "ev.jsonl"
        assert engine.flush_events(str(dest)) == 1
        assert json.loads(dest.read_text())["kind"] == "submit"
        # detached engine: flush is a no-op that reports 0
        engine2 = _engine()
        assert engine2.flush_events(str(dest)) == 0

    def test_slo_in_endpoint_stats_and_prometheus(self, engine):
        from repro.serve.engine import DEFAULT_SLO

        engine.submit(json.dumps({"a": 1}), "ep")
        per = engine.endpoint_stats()["ep"]
        slo = per["slo"]
        assert slo["objective_s"] == DEFAULT_SLO.objective_s
        assert 0.0 <= slo["good_ratio"] <= 1.0
        text = engine.render_metrics()
        assert 'serve_slo_good_ratio{endpoint="ep"}' in text
        assert 'serve_slo_burn_rate{endpoint="ep"}' in text

    def test_set_slo_overrides_default(self, engine):
        engine.set_slo("ep", SLObjective(objective_s=4.0, target=0.5))
        assert engine.slo_status("ep")["objective_s"] == 4.0
        assert engine.slo_status("ep")["target"] == 0.5
