"""Spec-conformance corpus (offline stand-in for the official
JSON-Schema-Test-Suite, Blaze §6.1).

Each case is (name, schema, [(document, expected_valid), ...]).  Every case
is checked against BOTH the compiled executor and the naive interpreter,
and with every optimization disabled one at a time -- optimizations must
never change semantics.
"""

import pytest

from repro.core import CompilerOptions, NaiveValidator, Validator, compile_schema

D2020 = "https://json-schema.org/draft/2020-12/schema"
D7 = "http://json-schema.org/draft-07/schema#"
D4 = "http://json-schema.org/draft-04/schema#"


def s2020(**kw):
    return {"$schema": D2020, **kw}


CASES = [
    # ---------------- type ----------------
    ("type string", s2020(type="string"), [
        ("foo", True), ("", True), (1, False), (1.5, False), (None, False),
        (True, False), ([], False), ({}, False),
    ]),
    ("type integer accepts 1.0", s2020(type="integer"), [
        (1, True), (1.0, True), (1.5, False), ("1", False), (True, False),
    ]),
    ("type number", s2020(type="number"), [
        (1, True), (1.5, True), ("1", False), (True, False),
    ]),
    ("type boolean excludes ints", s2020(type="boolean"), [
        (True, True), (False, True), (0, False), (1, False),
    ]),
    ("type null", s2020(type="null"), [(None, True), (0, False), (False, False)]),
    ("type array", s2020(type="array"), [([], True), ([1], True), ({}, False)]),
    ("type object", s2020(type="object"), [({}, True), ([], False)]),
    ("type union", s2020(type=["string", "number"]), [
        ("a", True), (3, True), (3.5, True), (None, False), (True, False),
    ]),
    # ---------------- const / enum ----------------
    ("const number cross-type", s2020(const=1), [
        (1, True), (1.0, True), (True, False), ("1", False), (2, False),
    ]),
    ("const object", s2020(const={"a": [1, 2]}), [
        ({"a": [1, 2]}, True), ({"a": [1, 2.0]}, True), ({"a": [2, 1]}, False), ({}, False),
    ]),
    ("enum", s2020(enum=["red", "green", 3, None]), [
        ("red", True), (3, True), (3.0, True), (None, True), ("blue", False), (True, False),
    ]),
    ("enum bool vs int", s2020(enum=[0, 1]), [
        (0, True), (1, True), (False, False), (True, False),
    ]),
    # integer-valued float const/enum: JSON 1.0 and 1 are the same number
    ("const integer-valued float", s2020(const=2.0), [
        (2, True), (2.0, True), (2.5, False), (True, False), ("2", False),
    ]),
    ("enum integer-valued floats", s2020(enum=[1.0, 3.0, 4.5]), [
        (1, True), (1.0, True), (3, True), (4.5, True), (4, False),
        (2, False), (True, False),
    ]),
    # ---------------- numbers ----------------
    ("minimum", s2020(minimum=1.1), [
        (1.1, True), (2, True), (1, False), ("x", True), (None, True),
    ]),
    ("exclusiveMinimum", s2020(exclusiveMinimum=1.1), [
        (1.2, True), (1.1, False), (1, False),
    ]),
    ("maximum", s2020(maximum=3.0), [(3.0, True), (3, True), (3.5, False)]),
    ("exclusiveMaximum", s2020(exclusiveMaximum=3.0), [(2.9, True), (3.0, False)]),
    ("min and max", s2020(minimum=0, maximum=10), [
        (0, True), (10, True), (5.5, True), (-1, False), (11, False),
    ]),
    ("multipleOf int", s2020(multipleOf=2), [
        (4, True), (0, True), (-6, True), (7, False), (4.5, False), ("x", True),
    ]),
    ("multipleOf fraction", s2020(multipleOf=0.5), [
        (1.5, True), (1.25, False),
    ]),
    # decimal multipleOf has no exact binary form: the float remainder of
    # 19.99 / 0.01 is nonzero, but per spec (decimal numbers) it IS a
    # multiple -- the classic conformance bug of popular validators
    ("multipleOf decimal precision", s2020(type="number", multipleOf=0.01), [
        (19.99, True), (0.07, True), (1.0, True), (19.994, False),
        (0.015, False), (0, True),
    ]),
    ("multipleOf tiny scale", s2020(multipleOf=1e-8), [
        (3e-8, True), (1e-6, True), (2.5e-8, False),
    ]),
    ("multipleOf decimal divisor of ints", s2020(multipleOf=0.1), [
        (1, True), (4.5, True), (4.55, False),
    ]),
    # large quotients: the integral-looking float fast path must not
    # swallow non-multiples (quotient 500000.5; quotient >= 2^53 where
    # every float is integral -- 1e30 is 10^30, not a multiple of 7)
    ("multipleOf large quotient", s2020(multipleOf=2), [
        (1000000, True), (1000001, False),
    ]),
    ("multipleOf huge value small divisor", s2020(multipleOf=7), [
        (1e30, False), (7e30, True), (3e30, False),
    ]),
    # ---------------- strings ----------------
    ("minLength", s2020(minLength=2), [
        ("ab", True), ("a", False), ("", False), (1, True),
    ]),
    ("maxLength", s2020(maxLength=2), [("ab", True), ("abc", False)]),
    ("pattern search semantics", s2020(pattern="b.b"), [
        ("bab", True), ("xxbabxx", True), ("bb", False), (5, True),
    ]),
    ("pattern anchored prefix", s2020(pattern="^x-"), [
        ("x-foo", True), ("ax-foo", False), ("x", False),
    ]),
    ("pattern dot-all elision", s2020(pattern=".*"), [("", True), ("anything", True)]),
    ("pattern non-empty", s2020(pattern=".+"), [("", False), ("a", True)]),
    ("pattern length range", s2020(pattern="^.{3,5}$"), [
        ("abc", True), ("abcde", True), ("ab", False), ("abcdef", False),
    ]),
    ("pattern exact literal", s2020(pattern="^foo$"), [("foo", True), ("foox", False)]),
    ("pattern suffix", s2020(pattern="-x$"), [("foo-x", True), ("foo-xy", False)]),
    ("pattern contains literal", s2020(pattern="oo"), [("book", True), ("bok", False)]),
    # ---------------- objects ----------------
    ("required", s2020(required=["a", "b"]), [
        ({"a": 1, "b": 2}, True), ({"a": 1}, False), ({}, False), ([], True), ("x", True),
    ]),
    ("minProperties", s2020(minProperties=1), [({"a": 1}, True), ({}, False)]),
    ("maxProperties", s2020(maxProperties=1), [({"a": 1}, True), ({"a": 1, "b": 2}, False)]),
    ("properties", s2020(properties={"a": {"type": "integer"}}), [
        ({"a": 1}, True), ({"a": "x"}, False), ({}, True), ({"b": "x"}, True),
    ]),
    ("properties false schema", s2020(properties={"a": False}), [
        ({}, True), ({"b": 1}, True), ({"a": 1}, False),
    ]),
    ("patternProperties", s2020(patternProperties={"^S_": {"type": "string"}}), [
        ({"S_0": "x"}, True), ({"S_0": 1}, False), ({"other": 1}, True),
    ]),
    ("properties + patternProperties both apply",
     s2020(properties={"foo": {"minimum": 0}}, patternProperties={"f.o": {"maximum": 10}}), [
        ({"foo": 5}, True), ({"foo": -1}, False), ({"foo": 11}, False),
    ]),
    ("additionalProperties false", s2020(
        properties={"a": {}}, patternProperties={"^x": {}}, additionalProperties=False), [
        ({"a": 1}, True), ({"x1": 1}, True), ({"b": 1}, False), ({}, True),
    ]),
    ("additionalProperties schema", s2020(
        properties={"a": {}}, additionalProperties={"type": "integer"}), [
        ({"a": "s", "b": 1}, True), ({"b": "s"}, False),
    ]),
    ("additionalProperties alone", s2020(additionalProperties={"type": "boolean"}), [
        ({"x": True}, True), ({"x": 1}, False), ({}, True),
    ]),
    ("propertyNames", s2020(propertyNames={"maxLength": 3}), [
        ({"abc": 1}, True), ({"abcd": 1}, False), ({}, True),
    ]),
    ("propertyNames false", s2020(propertyNames=False), [
        ({}, True), ({"a": 1}, False),
    ]),
    ("dependentRequired", s2020(dependentRequired={"a": ["b"]}), [
        ({"a": 1, "b": 2}, True), ({"a": 1}, False), ({"b": 2}, True), ({}, True),
    ]),
    ("dependentSchemas", s2020(dependentSchemas={"a": {"required": ["b"]}}), [
        ({"a": 1, "b": 2}, True), ({"a": 1}, False), ({"c": 3}, True),
    ]),
    # ---------------- arrays ----------------
    ("minItems/maxItems", s2020(minItems=1, maxItems=2), [
        ([1], True), ([1, 2], True), ([], False), ([1, 2, 3], False),
    ]),
    ("uniqueItems", s2020(uniqueItems=True), [
        ([1, 2], True), ([1, 1], False), ([1, 1.0], False), ([0, False], True),
        ([{"a": 1}, {"a": 1}], False), ([{"a": 1}, {"a": 2}], True),
        ([[1], [1]], False), ([], True),
    ]),
    # JSON equality semantics: numbers compare cross-type (1 == 1.0) but
    # booleans are never numbers (0 != false, 1 != true), and big integers
    # must not collide through float coercion (2**53 vs 2**53 + 1)
    ("uniqueItems equality coercion", s2020(uniqueItems=True), [
        ([0, False], True), ([1, True], True), ([1, 1.0], False),
        ([0.0, 0], False), ([2**53, 2**53 + 1], True),
        ([2**53, float(2**53)], False),
        ([[0], [False]], True), ([[1], [1.0]], False),
        ([{"a": 0}, {"a": False}], True), ([{"a": 1}, {"a": 1.0}], False),
    ]),
    ("items schema", s2020(items={"type": "integer"}), [
        ([1, 2], True), ([1, "x"], False), ([], True),
    ]),
    ("prefixItems", s2020(prefixItems=[{"type": "integer"}, {"type": "string"}]), [
        ([1, "a"], True), ([1], True), (["a"], False), ([1, 2], False), ([1, "a", None], True),
    ]),
    ("prefixItems + items", s2020(
        prefixItems=[{"type": "integer"}], items={"type": "string"}), [
        ([1, "a", "b"], True), ([1, "a", 2], False), ([1], True),
    ]),
    ("items false closes array", s2020(prefixItems=[{}], items=False), [
        ([1], True), ([], True), ([1, 2], False),
    ]),
    ("contains", s2020(contains={"type": "integer"}), [
        (["a", 1], True), (["a"], False), ([], False),
    ]),
    ("minContains/maxContains", s2020(contains={"type": "integer"}, minContains=2, maxContains=3), [
        ([1, 2], True), ([1, 2, 3], True), ([1], False), ([1, 2, 3, 4], False),
        ([1, "a", 2], True),
    ]),
    ("minContains zero", s2020(contains={"type": "integer"}, minContains=0), [
        ([], True), (["a"], True),
    ]),
    ("contains true as size", s2020(contains=True, minContains=2), [
        ([1, 2], True), ([1], False),
    ]),
    # ---------------- logical ----------------
    ("allOf", s2020(allOf=[{"minimum": 0}, {"maximum": 10}]), [
        (5, True), (-1, False), (11, False),
    ]),
    ("anyOf", s2020(anyOf=[{"type": "string"}, {"minimum": 5}]), [
        ("x", True), (6, True), (3, False),
    ]),
    ("oneOf exactly one", s2020(oneOf=[{"minimum": 0}, {"maximum": 10}]), [
        (-5, True), (15, True), (5, False),
    ]),
    ("not", s2020(**{"not": {"type": "string"}}), [(1, True), ("x", False)]),
    ("not false always passes", s2020(**{"not": False}), [(1, True), ("x", True)]),
    ("not true always fails", s2020(**{"not": True}), [(1, False)]),
    ("if/then/else", s2020(**{
        "if": {"type": "integer"}, "then": {"minimum": 0}, "else": {"minLength": 2}}), [
        (5, True), (-5, False), ("ab", True), ("a", False), (None, True),
    ]),
    ("if/then only", s2020(**{"if": {"type": "integer"}, "then": {"minimum": 0}}), [
        (5, True), (-5, False), ("x", True),
    ]),
    ("then without if ignored", s2020(**{"then": {"minimum": 0}}), [(-5, True)]),
    ("if with required CISC", s2020(**{
        "if": {"required": ["a"]}, "then": {"required": ["b"]}}), [
        ({"a": 1, "b": 2}, True), ({"a": 1}, False), ({"c": 1}, True), (3, True),
    ]),
    ("nested oneOf unroll", s2020(oneOf=[
        {"properties": {"kind": {"const": "a"}, "v": {"type": "integer"}}, "required": ["kind"]},
        {"properties": {"kind": {"const": "b"}, "v": {"type": "string"}}, "required": ["kind"]},
    ]), [
        ({"kind": "a", "v": 1}, True), ({"kind": "b", "v": "s"}, True),
        ({"kind": "a", "v": "s"}, False), ({}, False),
    ]),
    # ---------------- $ref ----------------
    ("ref to defs", s2020(**{
        "$defs": {"positive": {"minimum": 0}},
        "properties": {"a": {"$ref": "#/$defs/positive"}}}), [
        ({"a": 1}, True), ({"a": -1}, False),
    ]),
    ("ref with escaping", s2020(**{
        "$defs": {"a/b": {"type": "integer"}, "c~d": {"type": "string"}},
        "properties": {
            "x": {"$ref": "#/$defs/a~1b"},
            "y": {"$ref": "#/$defs/c~0d"}}}), [
        ({"x": 1, "y": "s"}, True), ({"x": "s"}, False), ({"y": 1}, False),
    ]),
    ("recursive ref tree", s2020(**{
        "type": "object",
        "properties": {
            "value": {"type": "integer"},
            "children": {"type": "array", "items": {"$ref": "#"}}},
        "required": ["value"]}), [
        ({"value": 1}, True),
        ({"value": 1, "children": [{"value": 2}, {"value": 3, "children": []}]}, True),
        ({"value": 1, "children": [{"value": "x"}]}, False),
        ({"value": 1, "children": [{"children": []}]}, False),
    ]),
    ("ref repeated many times labels", s2020(**{
        "$defs": {"t": {"type": "integer"}},
        "properties": {k: {"$ref": "#/$defs/t"} for k in "abcdefgh"}}), [
        ({"a": 1, "h": 2}, True), ({"a": "x"}, False),
    ]),
    ("anchor ref", s2020(**{
        "$defs": {"x": {"$anchor": "pos", "minimum": 0}},
        "properties": {"a": {"$ref": "#pos"}}}), [
        ({"a": 3}, True), ({"a": -3}, False),
    ]),
    ("dynamicRef single context", s2020(**{
        "$defs": {"x": {"$dynamicAnchor": "T", "type": "integer"}},
        "properties": {"a": {"$dynamicRef": "#T"}}}), [
        ({"a": 3}, True), ({"a": "s"}, False),
    ]),
    # ---------------- unevaluated* ----------------
    ("unevaluatedProperties false static", s2020(
        properties={"a": {}}, unevaluatedProperties=False), [
        ({"a": 1}, True), ({"b": 1}, False),
    ]),
    ("unevaluatedProperties schema", s2020(
        properties={"a": {}}, unevaluatedProperties={"type": "integer"}), [
        ({"a": "s", "b": 1}, True), ({"b": "s"}, False),
    ]),
    ("unevaluatedProperties sees through allOf", s2020(
        allOf=[{"properties": {"city": {"type": "string"}}}],
        properties={"name": {"type": "string"}},
        unevaluatedProperties=False), [
        ({"name": "bob", "city": "dc"}, True), ({"zip": "x"}, False),
    ]),
    ("unevaluatedProperties with anyOf branches", s2020(
        anyOf=[
            {"required": ["a"], "properties": {"a": {"type": "integer"}}},
            {"required": ["b"], "properties": {"b": {"type": "integer"}}},
        ],
        unevaluatedProperties=False), [
        ({"a": 1}, True), ({"b": 1}, True), ({"a": 1, "b": 1}, True),
        ({"a": 1, "c": 1}, False),
    ]),
    ("unevaluatedProperties if/then", s2020(**{
        "if": {"required": ["kind"], "properties": {"kind": {"const": "x"}}},
        "then": {"properties": {"payload": {}}},
        "properties": {"kind": {}},
        "unevaluatedProperties": False}), [
        ({"kind": "x", "payload": 1}, True),
        ({"kind": "y", "payload": 1}, False),
        ({"kind": "y"}, True),
    ]),
    ("unevaluatedItems static prefix", s2020(
        prefixItems=[{"type": "integer"}], unevaluatedItems=False), [
        ([1], True), ([1, 2], False), ([], True),
    ]),
    ("unevaluatedItems schema", s2020(
        prefixItems=[{"type": "integer"}], unevaluatedItems={"type": "string"}), [
        ([1, "a"], True), ([1, 2], False),
    ]),
    ("unevaluatedItems sees through allOf", s2020(
        allOf=[{"prefixItems": [{"type": "integer"}, {"type": "integer"}]}],
        unevaluatedItems=False), [
        ([1, 2], True), ([1, 2, 3], False),
    ]),
    ("unevaluatedItems with contains", s2020(
        contains={"type": "integer"}, unevaluatedItems={"type": "string"}), [
        ([1, "a"], True), ([1, None], False), (["a", 1, "b"], True),
    ]),
    # 2020-12: contains marks matched items evaluated even with
    # minContains: 0 (the applicator still annotates)
    ("unevaluatedItems contains minContains zero", s2020(
        contains={"type": "string"}, minContains=0, unevaluatedItems=False), [
        ([], True), (["x"], True), (["x", "y"], True), ([1], False),
        (["x", 1], False),
    ]),
    # contains annotations from a FAILED anyOf branch must not leak into
    # the unevaluatedItems residue
    ("unevaluatedItems contains in failed branch", s2020(
        anyOf=[{"contains": {"type": "string"}, "minContains": 2},
               {"minItems": 1}],
        unevaluatedItems=False), [
        (["x"], False), (["x", "y"], True), ([1], False), (["x", "y", 1], False),
    ]),
    # multi-passing-branch annotation union: BOTH passing anyOf branches
    # contribute evaluated sets (no annotation-dropping short-circuit)
    ("unevaluatedProperties anyOf multi-branch union", s2020(
        anyOf=[{"properties": {"a": {"type": "string"}}, "required": ["a"]},
               {"properties": {"b": {"type": "integer"}}, "required": ["b"]}],
        unevaluatedProperties=False), [
        ({"a": "x"}, True), ({"b": 1}, True), ({"a": "x", "b": 1}, True),
        ({"a": "x", "c": 1}, False), ({"b": 1, "a": 2}, False),
    ]),
    ("unevaluatedItems anyOf multi-branch union", s2020(
        anyOf=[{"prefixItems": [{"type": "string"}]},
               {"prefixItems": [{"type": "integer"}, {"type": "integer"}]}],
        unevaluatedItems=False), [
        (["x"], True), ([1, 2], True), (["x", 2], False), ([1, 2, 3], False),
    ]),
    ("unevaluatedProperties oneOf branches", s2020(
        oneOf=[{"properties": {"a": {"type": "string"}}, "required": ["a"]},
               {"properties": {"b": {"type": "integer"}}, "required": ["b"]}],
        unevaluatedProperties=False), [
        ({"a": "x"}, True), ({"b": 1}, True), ({"a": "x", "b": 1}, False),
        ({"a": "x", "c": 3}, False),
    ]),
    # ---------------- misc / interactions ----------------
    ("deeply nested", s2020(properties={"a": {"properties": {"b": {"properties": {
        "c": {"type": "integer", "minimum": 0}}}}}}), [
        ({"a": {"b": {"c": 1}}}, True), ({"a": {"b": {"c": -1}}}, False),
        ({"a": {"b": {}}}, True), ({"a": 3}, True),
    ]),
    ("empty schema", s2020(), [(1, True), (None, True), ({"x": [1]}, True)]),
    ("false schema via not true", s2020(**{"not": {}}), [(1, False), ({}, False)]),
    ("heterogeneous doc", s2020(
        type="object",
        properties={
            "tags": {"type": "array", "items": {"type": "string"}, "uniqueItems": True},
            "meta": {"type": "object", "additionalProperties": {"type": "number"}},
        }), [
        ({"tags": ["a", "b"], "meta": {"x": 1.5}}, True),
        ({"tags": ["a", "a"]}, False),
        ({"meta": {"x": "s"}}, False),
    ]),
    # ---------------- draft-7 ----------------
    ("draft7 items array form", {"$schema": D7, "items": [
        {"type": "integer"}, {"type": "string"}], "additionalItems": {"type": "boolean"}}, [
        ([1, "a", True], True), ([1, "a", 1], False), ([1], True), (["a"], False),
    ]),
    ("draft7 additionalItems false", {"$schema": D7, "items": [{}], "additionalItems": False}, [
        ([1], True), ([1, 2], False),
    ]),
    ("draft7 dependencies mixed", {"$schema": D7, "dependencies": {
        "a": ["b"], "c": {"required": ["d"]}}}, [
        ({"a": 1, "b": 2}, True), ({"a": 1}, False),
        ({"c": 1, "d": 2}, True), ({"c": 1}, False), ({}, True),
    ]),
    ("draft7 definitions ref", {"$schema": D7, "definitions": {"t": {"type": "integer"}},
     "properties": {"a": {"$ref": "#/definitions/t"}}}, [
        ({"a": 1}, True), ({"a": "x"}, False),
    ]),
    # ---------------- draft-4 ----------------
    ("draft4 exclusiveMinimum boolean", {"$schema": D4, "minimum": 5, "exclusiveMinimum": True}, [
        (6, True), (5, False),
    ]),
    ("draft4 inclusive default", {"$schema": D4, "minimum": 5}, [(5, True), (4, False)]),
]


@pytest.mark.parametrize("name,schema,docs", CASES, ids=[c[0] for c in CASES])
def test_conformance_compiled(name, schema, docs):
    v = Validator(compile_schema(schema))
    for doc, expected in docs:
        assert v.is_valid(doc) is expected, f"{name}: doc={doc!r} expected={expected}"


@pytest.mark.parametrize("name,schema,docs", CASES, ids=[c[0] for c in CASES])
def test_conformance_interpreter(name, schema, docs):
    v = NaiveValidator(schema)
    for doc, expected in docs:
        assert v.is_valid(doc) is expected, f"{name}: doc={doc!r} expected={expected}"


_ABLATIONS = {
    "no_unroll": CompilerOptions(unroll=False),
    "no_regex": CompilerOptions(regex_specialize=False),
    "no_reorder": CompilerOptions(reorder=False),
    "no_cisc": CompilerOptions(cisc=False),
    "no_elide": CompilerOptions(elide=False),
    "all_off": CompilerOptions(
        unroll=False, regex_specialize=False, reorder=False, cisc=False, elide=False
    ),
}


@pytest.mark.parametrize("ablation", list(_ABLATIONS), ids=list(_ABLATIONS))
@pytest.mark.parametrize("name,schema,docs", CASES, ids=[c[0] for c in CASES])
def test_conformance_ablations_semantics_preserved(ablation, name, schema, docs):
    """Optimizations must never change validation results (§3.5)."""
    v = Validator(compile_schema(schema, options=_ABLATIONS[ablation]))
    for doc, expected in docs:
        assert v.is_valid(doc) is expected, f"{name}[{ablation}]: doc={doc!r}"


@pytest.mark.parametrize("name,schema,docs", CASES, ids=[c[0] for c in CASES])
def test_conformance_hash_ablation(name, schema, docs):
    v = Validator(compile_schema(schema), use_hashing=False)
    for doc, expected in docs:
        assert v.is_valid(doc) is expected, f"{name}[no-hash]: doc={doc!r}"
