"""Differential testing of the batched (TPU-form) executor against the
sequential oracle on the structural schema subset."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.tape import try_build_tape
from repro.data.doc_table import encode_batch, encode_document

# -- structural-subset schema strategy ----------------------------------------

_keys = st.sampled_from(["a", "b", "name", "kind", "value", "tags", "n1"])


def _subset_schemas(depth):
    leaf = st.one_of(
        st.builds(lambda t: {"type": t},
                  st.sampled_from(["string", "integer", "number", "boolean", "null", "array", "object"])),
        st.builds(lambda n: {"minimum": n}, st.integers(-5, 5)),
        st.builds(lambda n: {"maximum": n}, st.integers(-5, 5)),
        st.builds(lambda n: {"exclusiveMinimum": n}, st.integers(-5, 5)),
        st.builds(lambda n: {"multipleOf": n}, st.sampled_from([1, 2, 0.5])),
        st.builds(lambda n: {"minLength": n}, st.integers(0, 5)),
        st.builds(lambda n: {"maxLength": n}, st.integers(0, 8)),
        st.builds(lambda p: {"pattern": p}, st.sampled_from([".*", ".+", "^x-", "^.{2,4}$", "^ab$"])),
        st.builds(lambda v: {"const": v},
                  st.one_of(st.none(), st.booleans(), st.integers(-5, 5), st.text(max_size=6))),
        st.builds(lambda v: {"enum": v},
                  st.lists(st.one_of(st.integers(-3, 3), st.text(max_size=4)), min_size=1, max_size=3)),
        st.builds(lambda n: {"minItems": n}, st.integers(0, 3)),
        st.builds(lambda n: {"maxItems": n}, st.integers(0, 4)),
        st.builds(lambda ks: {"required": ks}, st.lists(_keys, max_size=2, unique=True)),
        st.builds(lambda n: {"minProperties": n}, st.integers(0, 2)),
    )
    if depth <= 0:
        return leaf
    sub = _subset_schemas(depth - 1)
    return st.one_of(
        leaf,
        st.builds(lambda props: {"properties": props},
                  st.dictionaries(_keys, sub, min_size=1, max_size=3)),
        st.builds(lambda props, req: {"properties": props, "required": req,
                                      "additionalProperties": False},
                  st.dictionaries(_keys, sub, min_size=1, max_size=3),
                  st.lists(_keys, max_size=1)),
        st.builds(lambda props, ap: {"properties": props, "additionalProperties": ap},
                  st.dictionaries(_keys, sub, min_size=1, max_size=2), sub),
        st.builds(lambda s: {"items": s}, sub),
        st.builds(lambda pre, tail: {"prefixItems": pre, "items": tail},
                  st.lists(sub, min_size=1, max_size=2),
                  st.one_of(st.just(False), sub)),
    )


subset_schemas = _subset_schemas(2)

_doc_scalars = st.one_of(
    st.none(), st.booleans(), st.integers(-8, 8),
    st.sampled_from([0.5, 1.0, 2.5, -3.0, 4.4]),
    st.text(max_size=6), st.sampled_from(["x-foo", "ab", "x" * 40]),
)
_docs = st.recursive(
    _doc_scalars,
    lambda c: st.one_of(
        st.lists(c, max_size=4),
        st.dictionaries(_keys, c, max_size=4),
    ),
    max_leaves=12,
)


@settings(max_examples=200, deadline=None)
@given(schema=subset_schemas, docs=st.lists(_docs, min_size=1, max_size=6))
def test_batch_matches_sequential(schema, docs):
    compiled = compile_schema(schema)
    tape, reason = try_build_tape(compiled)
    if tape is None:
        return  # outside the structural subset: sequential fallback
    seq = Validator(compiled)
    expected = [seq.is_valid(d) for d in docs]
    table = encode_batch(docs, max_nodes=64, max_depth=8)
    bv = BatchValidator(tape, max_depth=8, use_pallas=False)
    valid, decided = bv.validate(table)
    for i, (v, d) in enumerate(zip(valid, decided)):
        if d:
            assert bool(v) == expected[i], (schema, docs[i])


@settings(max_examples=10, deadline=None)
@given(schema=subset_schemas, docs=st.lists(_docs, min_size=1, max_size=3))
def test_batch_pallas_path_matches_jnp(schema, docs):
    compiled = compile_schema(schema)
    tape, _ = try_build_tape(compiled)
    if tape is None:
        return
    table = encode_batch(docs, max_nodes=64, max_depth=8)
    v1, _ = BatchValidator(tape, max_depth=8, use_pallas=False).validate(table)
    v2, _ = BatchValidator(tape, max_depth=8, use_pallas=True).validate(table)
    np.testing.assert_array_equal(v1, v2)


class TestEncoder:
    def test_node_budget_overflow(self):
        doc = {"k%d" % i: i for i in range(100)}
        assert encode_document(doc, max_nodes=16) is None

    def test_depth_budget_overflow(self):
        doc = [[[[[1]]]]]
        assert encode_document(doc, max_nodes=64, max_depth=3) is None

    def test_bfs_children_contiguous(self):
        doc = {"a": [1, 2], "b": {"c": 3}}
        cols = encode_document(doc, max_nodes=16)
        # root=0, a=1, b=2, then a's items 3,4, then b's child 5
        assert cols["child_start"][0] == 1
        assert cols["child_start"][1] == 3
        assert cols["child_start"][2] == 5
        assert cols["parent"][3] == 1 and cols["parent"][4] == 1
        assert cols["parent"][5] == 2

    def test_overflow_marks_undecided(self):
        docs = [{"a": 1}, {"k%d" % i: i for i in range(100)}]
        table = encode_batch(docs, max_nodes=8)
        assert table.ok.tolist() == [True, False]
