"""The codegen (closure-compiled) engine must agree with the interpreter
on the full conformance corpus and on random schema/document pairs."""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings

from repro.core import NaiveValidator, Validator, compile_schema

try:  # pytest inserts tests/ on sys.path (no package); PYTHONPATH=. gives tests.*
    from test_conformance import CASES
    from test_differential import json_docs, schemas
except ImportError:  # pragma: no cover
    from tests.test_conformance import CASES
    from tests.test_differential import json_docs, schemas


@pytest.mark.parametrize("name,schema,docs", CASES, ids=[c[0] for c in CASES])
def test_codegen_conformance(name, schema, docs):
    v = Validator(compile_schema(schema), engine="codegen")
    for doc, expected in docs:
        assert v.is_valid(doc) is expected, f"{name}: doc={doc!r} expected={expected}"


@settings(max_examples=300, deadline=None)
@given(schema=schemas, doc=json_docs)
def test_codegen_matches_interpreter(schema, doc):
    compiled = compile_schema(schema)
    interp = Validator(compiled)
    cg = Validator(compiled, engine="codegen")
    assert interp.is_valid(doc) is cg.is_valid(doc), (schema, doc)


@settings(max_examples=100, deadline=None)
@given(schema=schemas, doc=json_docs)
def test_codegen_matches_naive(schema, doc):
    cg = Validator(compile_schema(schema), engine="codegen")
    naive = NaiveValidator(schema)
    assert cg.is_valid(doc) is naive.is_valid(doc), (schema, doc)
