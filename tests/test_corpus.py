"""Corpus generator invariants: documents valid by construction, sizes
track Table 3, determinism."""

import json

import pytest

from repro.core import NaiveValidator, Validator, compile_schema
from repro.data.corpus import TABLE3, make_corpus, make_dataset


@pytest.fixture(scope="module")
def small_corpus():
    return make_corpus(scale=0.05)


def test_corpus_has_38_datasets(small_corpus):
    assert len(small_corpus) == 38


def test_documents_validate(small_corpus):
    for ds in small_corpus[:10]:
        compiled = Validator(compile_schema(ds.schema))
        naive = NaiveValidator(ds.schema)
        for doc in ds.documents[:20]:
            assert compiled.is_valid(doc), (ds.name, doc)
            assert naive.is_valid(doc), (ds.name, doc)


def test_schema_sizes_track_table3(small_corpus):
    for ds, (name, _, kb, _) in zip(small_corpus, TABLE3):
        assert ds.name == name
        # grown past the target, within a generous factor
        assert ds.schema_bytes >= kb * 1024 * 0.9, (name, ds.schema_bytes, kb)
        assert ds.schema_bytes <= kb * 1024 * 3 + 4096, (name, ds.schema_bytes, kb)


def test_deterministic(small_corpus):
    ds1 = make_dataset("babelrc", 50, 6.5, 140, seed=42, scale=0.2)
    ds2 = make_dataset("babelrc", 50, 6.5, 140, seed=42, scale=0.2)
    assert json.dumps(ds1.schema, sort_keys=True) == json.dumps(ds2.schema, sort_keys=True)
    assert ds1.documents == ds2.documents


def test_dialects(small_corpus):
    by_name = {ds.name: ds for ds in small_corpus}
    assert "2020-12" in by_name["cql2"].dialect
    assert "2020-12" in by_name["openapi"].dialect
    assert "draft-07" in by_name["babelrc"].dialect


def test_invalid_mutations_rejected(small_corpus):
    """Mutate valid docs; the validator must catch type violations."""
    ds = small_corpus[0]
    v = Validator(compile_schema(ds.schema))
    n = NaiveValidator(ds.schema)
    caught = 0
    for doc in ds.documents[:30]:
        mutated = dict(doc)
        for key, value in list(mutated.items()):
            if isinstance(value, str):
                mutated[key] = [1, 2, 3]
                break
            if isinstance(value, (int, float)) and not isinstance(value, bool):
                mutated[key] = "not-a-number"
                break
        got_c, got_n = v.is_valid(mutated), n.is_valid(mutated)
        assert got_c == got_n, (ds.name, mutated)
        caught += not got_c
    assert caught > 0
