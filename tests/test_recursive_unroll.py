"""Differential coverage for bounded $ref-recursion unrolling (DESIGN.md §9).

Recursive schemas (linked lists, trees, mutual recursion) now build
location tapes: ``ControlLabel``/``ControlJump`` cycles unroll up to the
``unroll_depth`` budget and the frontier locations carry the
``LOC_FRONTIER`` sentinel.  The contract under test:

* documents shallower than the budget are **decided** on the batched
  path and bit-identical to the sequential oracle (CSR == dense too);
* documents that reach a frontier are **undecided** -- never vacuously
  valid -- and ``validate_ex`` flags them so callers can count
  ``unroll_overflow`` fallbacks distinctly;
* a mixed registry with a recursive member linked in stays bit-identical
  to per-schema sequential dispatch.
"""

import random

import numpy as np
import pytest

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.tape import LOC_FRONTIER, build_tape, try_build_tape
from repro.data.doc_table import encode_batch
from repro.data.pipeline import AdmissionController
from repro.registry import SchemaRegistry

LIST_SCHEMA = {
    "$defs": {
        "node": {
            "type": "object",
            "properties": {
                "value": {"type": "integer"},
                "next": {"$ref": "#/$defs/node"},
            },
            "required": ["value"],
        }
    },
    "$ref": "#/$defs/node",
}

TREE_SCHEMA = {
    "$defs": {
        "t": {
            "type": "object",
            "properties": {
                "v": {"type": "number", "minimum": 0},
                "left": {"$ref": "#/$defs/t"},
                "right": {"$ref": "#/$defs/t"},
            },
        }
    },
    "$ref": "#/$defs/t",
}

MUTUAL_SCHEMA = {
    "$defs": {
        "a": {
            "type": "object",
            "properties": {"tag": {"const": "a"}, "b": {"$ref": "#/$defs/b"}},
            "required": ["tag"],
        },
        "b": {
            "type": "object",
            "properties": {"tag": {"const": "b"}, "a": {"$ref": "#/$defs/a"}},
            "required": ["tag"],
        },
    },
    "$ref": "#/$defs/a",
}

def chain(depth: int, bad_at=None) -> dict:
    doc = node = {"value": "bad" if bad_at == 0 else 0}
    for k in range(1, depth + 1):
        node["next"] = node = {"value": "bad" if bad_at == k else k}
    return doc


def mutual_chain(depth: int, bad_at=None) -> dict:
    tags = ["a", "b"]
    doc = node = {"tag": "x" if bad_at == 0 else "a"}
    for k in range(1, depth + 1):
        t = tags[k % 2]
        node[t] = node = {"tag": "x" if bad_at == k else t}
    return doc


class TestLinkedListUnroll:
    def _build(self, unroll_depth=4):
        compiled = compile_schema(LIST_SCHEMA)
        tape, reason = try_build_tape(compiled, unroll_depth=unroll_depth)
        assert tape is not None, reason
        return compiled, tape

    def test_tape_builds_with_frontier(self):
        _, tape = self._build()
        assert tape.n_frontier == 1
        assert tape.unroll_depth == 4
        # the frontier entry edge carries the sentinel
        assert (tape.prop_child_loc == LOC_FRONTIER).sum() == 1
        # frontier subtrees do not inflate the horizon: 4 chain levels +
        # the scalar value child
        assert tape.max_loc_depth == 5

    def test_depths_straddling_budget(self):
        compiled, tape = self._build()
        seq = Validator(compiled)
        docs = [chain(d) for d in range(8)]
        docs += [chain(3, bad_at=2), chain(4, bad_at=4), chain(6, bad_at=1)]
        table = encode_batch(docs, max_nodes=64)
        bv = BatchValidator(tape, use_pallas=False)
        valid, decided, frontier = bv.validate_ex(table)
        # depth <= unroll_depth: decided, bit-identical to sequential
        for i, doc in enumerate(docs):
            if decided[i]:
                assert bool(valid[i]) == seq.is_valid(doc), doc
        depths = list(range(8)) + [3, 4, 6]
        for i, d in enumerate(depths):
            assert bool(decided[i]) == (d <= 4), (i, d)
            # frontier-reaching docs are undecided, never vacuously valid
            assert bool(frontier[i]) == (d > 4), (i, d)

    def test_csr_dense_pallas_bit_identity(self):
        _, tape = self._build()
        docs = [chain(d) for d in range(7)]
        table = encode_batch(docs, max_nodes=64)
        ref = BatchValidator(tape, use_pallas=False, layout="csr")
        v0, d0 = ref.validate(table)
        for kwargs in (
            dict(use_pallas=False, layout="dense"),
            dict(use_pallas=True, layout="csr"),
        ):
            v, d = BatchValidator(tape, **kwargs).validate(table)
            np.testing.assert_array_equal(v, v0, err_msg=repr(kwargs))
            np.testing.assert_array_equal(d, d0, err_msg=repr(kwargs))

    def test_unroll_depth_one(self):
        compiled, tape = self._build(unroll_depth=1)
        seq = Validator(compiled)
        docs = [chain(0), chain(1), chain(2)]
        table = encode_batch(docs, max_nodes=32)
        valid, decided = BatchValidator(tape, use_pallas=False).validate(table)
        assert decided.tolist() == [True, True, False]
        assert [bool(v) for v, d in zip(valid, decided) if d] == [
            seq.is_valid(docs[0]),
            seq.is_valid(docs[1]),
        ]

    def test_node_budget_forces_earlier_frontier(self):
        compiled = compile_schema(LIST_SCHEMA)
        tape = build_tape(compiled, unroll_depth=64, unroll_node_budget=8)
        assert tape.n_frontier >= 1
        assert tape.n_locations <= 8 + 2  # one level may finish past the cap
        docs = [chain(1), chain(20)]
        table = encode_batch(docs, max_nodes=128)
        valid, decided = BatchValidator(tape, use_pallas=False).validate(table)
        assert bool(decided[0]) and bool(valid[0])
        assert not bool(decided[1])


class TestRecursionShapes:
    def test_tree_recursion(self):
        compiled = compile_schema(TREE_SCHEMA)
        tape, reason = try_build_tape(compiled, unroll_depth=3)
        assert tape is not None, reason
        assert tape.n_frontier > 1  # one frontier per exhausted branch
        seq = Validator(compiled)

        def tree(depth, neg=False):
            out = {"v": -1 if neg else depth}
            if depth > 0:
                out["left"] = tree(depth - 1, neg)
                out["right"] = tree(depth - 1)
            return out

        docs = [tree(0), tree(2), tree(3), tree(4), tree(2, neg=True), {"v": -3}]
        table = encode_batch(docs, max_nodes=128)
        valid, decided, frontier = BatchValidator(
            tape, use_pallas=False
        ).validate_ex(table)
        assert decided.tolist() == [True, True, True, False, True, True]
        assert frontier.tolist() == [False, False, False, True, False, False]
        for i, d in enumerate(decided):
            if d:
                assert bool(valid[i]) == seq.is_valid(docs[i]), docs[i]

    def test_mutual_recursion(self):
        compiled = compile_schema(MUTUAL_SCHEMA)
        tape, reason = try_build_tape(compiled, unroll_depth=4)
        assert tape is not None, reason
        seq = Validator(compiled)
        depths = list(range(13)) + [3, 2]
        docs = [mutual_chain(d) for d in range(13)]
        docs += [mutual_chain(3, bad_at=3), mutual_chain(2, bad_at=0)]
        table = encode_batch(docs, max_nodes=64)
        valid, decided, frontier = BatchValidator(
            tape, use_pallas=False
        ).validate_ex(table)
        assert frontier.tolist() == (~decided).tolist()
        # each label gets its own budget: labels a AND b both re-expand
        # up to 4 times, so the a->b->a chain stays decided through doc
        # depth 9 and hits the frontier at 10
        assert decided.tolist() == [d <= 9 for d in depths]
        for i, d in enumerate(decided):
            if d:
                assert bool(valid[i]) == seq.is_valid(docs[i]), docs[i]

    def test_recursion_through_items(self):
        schema = {
            "$defs": {
                "deep": {
                    "type": "array",
                    "items": {"$ref": "#/$defs/deep"},
                }
            },
            "$ref": "#/$defs/deep",
        }
        compiled = compile_schema(schema)
        tape, reason = try_build_tape(compiled, unroll_depth=3)
        assert tape is not None, reason
        # the frontier edge rides loc_item, not a property row
        assert (tape.loc_item == LOC_FRONTIER).any()
        seq = Validator(compiled)

        def nest(depth):
            out = []
            for _ in range(depth):
                out = [out]
            return out

        docs = [nest(1), nest(3), nest(5), [1], [[["x"]]]]
        table = encode_batch(docs, max_nodes=64)
        valid, decided, frontier = BatchValidator(
            tape, use_pallas=False
        ).validate_ex(table)
        for i, d in enumerate(decided):
            if d:
                assert bool(valid[i]) == seq.is_valid(docs[i]), docs[i]
        assert bool(frontier[2]) and not bool(decided[2])  # nest(5) overran
        assert bool(decided[1])  # nest(3) fits the budget

    def test_recursion_through_additional_properties(self):
        schema = {
            "$defs": {
                "bag": {
                    "type": "object",
                    "additionalProperties": {"$ref": "#/$defs/bag"},
                }
            },
            "$ref": "#/$defs/bag",
        }
        compiled = compile_schema(schema)
        tape, reason = try_build_tape(compiled, unroll_depth=2)
        assert tape is not None, reason
        assert (tape.loc_addl == LOC_FRONTIER).any()
        seq = Validator(compiled)
        docs = [{}, {"a": {}}, {"a": {"b": {}}}, {"a": {"b": {"c": {}}}}, {"a": 1}]
        table = encode_batch(docs, max_nodes=64)
        valid, decided, frontier = BatchValidator(
            tape, use_pallas=False
        ).validate_ex(table)
        assert bool(frontier[3]) and not bool(decided[3])
        for i, d in enumerate(decided):
            if d:
                assert bool(valid[i]) == seq.is_valid(docs[i]), docs[i]


_LEAVES = [
    {"type": "integer"},
    {"type": "number", "minimum": 0},
    {"enum": ["x", "y", 3]},
    {"const": 7},
    {"type": "string", "minLength": 1},
]


def _rand_recursive_schema(rng: random.Random):
    """Random list/tree/mutual-recursive schema + a doc generator."""
    leaf = rng.choice(_LEAVES)
    shape = rng.randrange(3)
    if shape == 0:  # linked list
        schema = {
            "$defs": {
                "n": {
                    "type": "object",
                    "properties": {"v": leaf, "next": {"$ref": "#/$defs/n"}},
                }
            },
            "$ref": "#/$defs/n",
        }

        def gen(depth, ok):
            doc = node = {"v": _leaf_value(rng, leaf, ok or depth > 0)}
            for k in range(depth):
                node["next"] = node = {
                    "v": _leaf_value(rng, leaf, ok or k < depth - 1)
                }
            return doc

    elif shape == 1:  # binary tree
        schema = {
            "$defs": {
                "t": {
                    "type": "object",
                    "properties": {
                        "v": leaf,
                        "l": {"$ref": "#/$defs/t"},
                        "r": {"$ref": "#/$defs/t"},
                    },
                }
            },
            "$ref": "#/$defs/t",
        }

        def gen(depth, ok):
            def rec(d):
                out = {"v": _leaf_value(rng, leaf, ok or d < depth)}
                if d > 0:
                    if rng.random() < 0.8:
                        out["l"] = rec(d - 1)
                    if rng.random() < 0.8:
                        out["r"] = rec(d - 1)
                return out

            return rec(depth)

    else:  # mutual recursion
        schema = {
            "$defs": {
                "a": {
                    "type": "object",
                    "properties": {"v": leaf, "b": {"$ref": "#/$defs/b"}},
                },
                "b": {
                    "type": "object",
                    "properties": {"w": leaf, "a": {"$ref": "#/$defs/a"}},
                },
            },
            "$ref": "#/$defs/a",
        }

        def gen(depth, ok):
            keys = ["v", "w"]
            links = ["b", "a"]
            doc = node = {"v": _leaf_value(rng, leaf, ok or depth > 0)}
            for k in range(depth):
                nxt = {keys[(k + 1) % 2]: _leaf_value(rng, leaf, ok or k < depth - 1)}
                node[links[k % 2]] = node = nxt
            return doc

    return schema, gen


def _leaf_value(rng: random.Random, leaf: dict, ok: bool):
    if ok:
        good = {"integer": 3, "number": 1.5, "string": "yes"}
        if "enum" in leaf:
            return rng.choice(leaf["enum"])
        if "const" in leaf:
            return leaf["const"]
        return good[leaf["type"]]
    return rng.choice([None, "no" if leaf.get("type") != "string" else 9, -4.5, []])


class TestRecursiveDifferentialFuzz:
    def test_fuzz_straddles_unroll_depth(self):
        rng = random.Random(0xF30)
        decided_total = frontier_total = sites_total = 0
        # every distinct tape shape jit-compiles two executors: keep the
        # trial count CI-friendly (matching test_batch_csr's budget)
        for trial in range(14):
            unroll = rng.choice([2, 3, 4])
            schema, gen = _rand_recursive_schema(rng)
            compiled = compile_schema(schema)
            tape, reason = try_build_tape(compiled, unroll_depth=unroll)
            assert tape is not None, (schema, reason)
            seq = Validator(compiled)
            docs = [
                gen(rng.randrange(unroll + 3), rng.random() < 0.7)
                for _ in range(12)
            ]
            table = encode_batch(docs, max_nodes=256)
            csr = BatchValidator(tape, max_depth=16, use_pallas=False)
            dense = BatchValidator(
                tape, max_depth=16, use_pallas=False, layout="dense"
            )
            v, d, f = csr.validate_ex(table)
            v2, d2 = dense.validate(table)
            np.testing.assert_array_equal(v, v2, err_msg=repr(schema))
            np.testing.assert_array_equal(d, d2, err_msg=repr(schema))
            # frontier-reaching docs are exactly the undecided ones here
            # (depths fit both encoder and executor budgets)
            np.testing.assert_array_equal(f, ~d, err_msg=repr(schema))
            for i, doc in enumerate(docs):
                if d[i]:
                    assert bool(v[i]) == seq.is_valid(doc), (schema, doc)
            # failure sites, not just verdicts: batched attribution on the
            # decided-invalid rows must agree with the sequential trace
            invalid = [i for i in range(len(docs)) if d[i] and not v[i]]
            if invalid:
                sites_total += len(invalid)
                sites = csr.explain_batch(table, docs=docs)
                for i in invalid:
                    site = sites[i]
                    assert site is not None, (schema, docs[i])
                    ok, trace = seq.explain(docs[i])
                    assert not ok, (schema, docs[i])
                    assert site.schema_path in {p for p, _ in trace}, (
                        schema, docs[i], site, trace
                    )
            decided_total += int(d.sum())
            frontier_total += int(f.sum())
        # the fuzzer must exercise both sides of the budget
        assert decided_total >= 30
        assert frontier_total >= 15
        assert sites_total >= 10  # and the site differential must bite


class TestMixedRegistryWithRecursion:
    FLAT = {
        "type": "object",
        "properties": {"name": {"type": "string", "minLength": 1}},
        "required": ["name"],
        "additionalProperties": False,
    }
    SEQ_ONLY = {
        "type": "object",
        "propertyNames": {"maxLength": 8},  # LoopKeys: outside the subset
    }

    def _registry(self):
        reg = SchemaRegistry(unroll_depth=3)
        reg.register("flat", self.FLAT)
        reg.register("list", LIST_SCHEMA)
        reg.register("keys", self.SEQ_ONLY)
        return reg

    def test_recursive_member_links_and_stays_bit_identical(self):
        reg = self._registry()
        tape = reg.linked_tape()
        assert tape is not None and "list" in tape.members
        # per-member unroll metadata survives linking
        li = list(tape.members).index("list")
        assert tape.member_unroll_depths[li] == 3
        assert tape.member_n_frontier[li] >= 1
        assert tape.member_n_frontier[list(tape.members).index("flat")] == 0

        rng = random.Random(5)
        docs, endpoints = [], []
        for i in range(40):
            e = rng.choice(["flat", "list", "keys"])
            endpoints.append(e)
            if e == "flat":
                docs.append({"name": "ok"} if i % 3 else {"name": ""})
            elif e == "list":
                docs.append(chain(rng.randrange(6), bad_at=1 if i % 5 == 0 else None))
            else:
                docs.append({"k" * (i % 12 + 1): 1})
        verdicts, counts = reg.admit_mixed(docs, endpoints)
        for doc, e, got in zip(docs, endpoints, verdicts):
            assert got == reg.get(e).validator.is_valid(doc), (e, doc)
        assert counts.batch_validated > 0
        assert counts.unroll_overflow > 0  # deep lists overran the budget
        assert counts.fallback_validated >= counts.unroll_overflow

    def test_registry_stats_record_unroll_facts(self):
        reg = self._registry()
        st = reg.get("list").stats
        assert st.batchable and st.unroll_depth == 3 and st.n_frontier >= 1
        assert reg.get("flat").stats.n_frontier == 0
        reasons = reg.fallback_reasons()
        assert set(reasons) == {"keys"}
        assert "LOOP_KEYS" in reasons["keys"]

    def test_admission_controller_counts_and_reasons(self):
        reg = self._registry()
        ctrl = AdmissionController(registry=reg, endpoint="list")
        records = [chain(1), chain(5), chain(2, bad_at=2), chain(7)]
        oks = ctrl.admit(records)
        seq = reg.get("list").validator
        assert oks == [seq.is_valid(r) for r in records]
        assert ctrl.stats.unroll_overflow == 2  # chain(5), chain(7)
        assert ctrl.stats.batch_validated == 2
        assert ctrl.stats.fallback_validated == 2
        assert ctrl.fallback_reasons == {"keys": reg.get("keys").stats.fallback_reason}
