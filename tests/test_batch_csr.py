"""Differential coverage for the owner-sorted CSR batch path.

Hypothesis-free (the CI image may lack it): a seeded ``random``-based
schema/document fuzzer compares the CSR executor against the sequential
oracle and checks CSR vs dense bit-identity, plus directed cases for enum
OR-groups, the depth>max_depth undecided flag, and the
>32-required-properties ``UnsupportedForBatch`` fallback.
"""

import random

import numpy as np
import pytest

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.tape import UnsupportedForBatch, build_tape, try_build_tape
from repro.data.doc_table import encode_batch

_KEYS = ["a", "b", "name", "kind", "value", "tags", "n1", "x"]


def _rand_leaf(rng: random.Random) -> dict:
    choice = rng.randrange(12)
    if choice == 0:
        return {"type": rng.choice(
            ["string", "integer", "number", "boolean", "null", "array", "object"])}
    if choice == 1:
        return {"minimum": rng.randint(-5, 5)}
    if choice == 2:
        return {"maximum": rng.randint(-5, 5)}
    if choice == 3:
        return {"exclusiveMinimum": rng.randint(-5, 5)}
    if choice == 4:
        return {"multipleOf": rng.choice([1, 2, 0.5])}
    if choice == 5:
        return {"minLength": rng.randint(0, 5)}
    if choice == 6:
        return {"maxLength": rng.randint(0, 8)}
    if choice == 7:
        return {"pattern": rng.choice([".*", ".+", "^x-", "^.{2,4}$", "^ab$"])}
    if choice == 8:
        return {"const": rng.choice([None, True, False, rng.randint(-5, 5), "ab", ""])}
    if choice == 9:
        # enum -> OR-group rows; mixed types force several row ops per group
        n = rng.randint(1, 5)
        pool = [None, True, False, -2, 0, 3, "a", "bb", "x-foo", 1.5]
        return {"enum": [rng.choice(pool) for _ in range(n)]}
    if choice == 10:
        return {"minItems": rng.randint(0, 3)}
    return {"required": rng.sample(_KEYS, rng.randint(0, 2))}


def _rand_schema(rng: random.Random, depth: int) -> dict:
    if depth <= 0 or rng.random() < 0.4:
        return _rand_leaf(rng)
    choice = rng.randrange(4)
    if choice == 0:
        props = {k: _rand_schema(rng, depth - 1)
                 for k in rng.sample(_KEYS, rng.randint(1, 3))}
        out = {"properties": props}
        if rng.random() < 0.5:
            out["required"] = rng.sample(sorted(props), rng.randint(0, len(props)))
        if rng.random() < 0.4:
            out["additionalProperties"] = False
        return out
    if choice == 1:
        return {"properties": {k: _rand_schema(rng, depth - 1)
                               for k in rng.sample(_KEYS, rng.randint(1, 2))},
                "additionalProperties": _rand_schema(rng, depth - 1)}
    if choice == 2:
        return {"items": _rand_schema(rng, depth - 1)}
    return {"prefixItems": [_rand_schema(rng, depth - 1)
                            for _ in range(rng.randint(1, 2))],
            "items": rng.choice([False, _rand_schema(rng, depth - 1)])}


def _rand_doc(rng: random.Random, depth: int):
    if depth <= 0 or rng.random() < 0.45:
        return rng.choice([
            None, True, False, rng.randint(-8, 8),
            rng.choice([0.5, 1.0, 2.5, -3.0]),
            rng.choice(["", "a", "ab", "x-foo", "value", "x" * 40]),
        ])
    if rng.random() < 0.5:
        return [_rand_doc(rng, depth - 1) for _ in range(rng.randint(0, 4))]
    return {k: _rand_doc(rng, depth - 1)
            for k in rng.sample(_KEYS, rng.randint(0, 4))}


class TestDifferentialFuzz:
    def test_csr_matches_sequential_and_dense(self):
        rng = random.Random(0xB1A2E)
        tapes = 0
        # every distinct tape shape recompiles both executors: keep the
        # trial count CI-friendly
        for trial in range(60):
            schema = _rand_schema(rng, 3)
            compiled = compile_schema(schema)
            tape, _ = try_build_tape(compiled)
            if tape is None:
                continue
            tapes += 1
            docs = [_rand_doc(rng, 3) for _ in range(rng.randint(1, 6))]
            seq = Validator(compiled)
            expected = [seq.is_valid(d) for d in docs]
            table = encode_batch(docs, max_nodes=64, max_depth=8)
            csr = BatchValidator(tape, max_depth=8, use_pallas=False, layout="csr")
            dense = BatchValidator(tape, max_depth=8, use_pallas=False, layout="dense")
            v_c, d_c = csr.validate(table)
            v_d, d_d = dense.validate(table)
            # bit-identical across layouts (the acceptance criterion)
            np.testing.assert_array_equal(v_c, v_d, err_msg=repr(schema))
            np.testing.assert_array_equal(d_c, d_d, err_msg=repr(schema))
            for i, (v, d) in enumerate(zip(v_c, d_c)):
                if d:
                    assert bool(v) == expected[i], (schema, docs[i])
        assert tapes >= 20  # the fuzzer must actually exercise the tape path

    def test_csr_pallas_matches_jnp(self):
        rng = random.Random(7)
        checked = 0
        while checked < 10:
            schema = _rand_schema(rng, 2)
            tape, _ = try_build_tape(compile_schema(schema))
            if tape is None:
                continue
            checked += 1
            docs = [_rand_doc(rng, 3) for _ in range(3)]
            table = encode_batch(docs, max_nodes=64, max_depth=8)
            v1, d1 = BatchValidator(
                tape, max_depth=8, use_pallas=False).validate(table)
            v2, d2 = BatchValidator(
                tape, max_depth=8, use_pallas=True).validate(table)
            np.testing.assert_array_equal(v1, v2)
            np.testing.assert_array_equal(d1, d2)


class TestEnumOrGroups:
    SCHEMA = {
        "type": "object",
        "properties": {
            "kind": {"enum": ["alpha", "beta", 3, None, True, 2.5]},
            "nested": {"properties": {"kind": {"enum": ["x", "y"]}}},
        },
    }

    def _run(self, docs):
        compiled = compile_schema(self.SCHEMA)
        tape, reason = try_build_tape(compiled)
        assert tape is not None, reason
        seq = Validator(compiled)
        table = encode_batch(docs, max_nodes=32)
        valid, decided = BatchValidator(tape, use_pallas=False).validate(table)
        assert decided.all()
        return valid, [seq.is_valid(d) for d in docs]

    def test_group_membership(self):
        docs = [
            {"kind": "alpha"}, {"kind": "beta"}, {"kind": 3}, {"kind": None},
            {"kind": True}, {"kind": 2.5}, {"kind": "gamma"}, {"kind": 4},
            {"kind": False}, {"kind": [1]}, {},
            {"nested": {"kind": "x"}}, {"nested": {"kind": "z"}},
        ]
        valid, expected = self._run(docs)
        assert [bool(v) for v in valid] == expected

    def test_windows_are_owner_sorted_csr(self):
        tape = build_tape(compile_schema(self.SCHEMA))
        owners = tape.asrt_owner
        assert (np.diff(owners) >= 0).all(), "rows must be owner-sorted"
        # windows partition the rows and bound A-hat
        for l in range(tape.n_locations):
            s, n = int(tape.loc_asrt_start[l]), int(tape.loc_asrt_len[l])
            assert (owners[s : s + n] == l).all()
            assert n <= tape.max_rows_per_loc
            # groups contiguous within the window, AND rows first
            grp = tape.asrt_group[s : s + n]
            nonzero = grp[grp > 0]
            assert (np.diff(grp) >= 0).all() or len(set(grp.tolist())) == len(
                np.unique(grp)
            )
            assert list(nonzero) == sorted(nonzero)
        assert tape.max_rows_per_loc == int(tape.loc_asrt_len.max())

    def test_hash_runs_cover_duplicate_keys(self):
        # "kind" appears under two owners -> one hash run of length 2
        tape = build_tape(compile_schema(self.SCHEMA))
        assert tape.max_hash_run >= 2
        runs = tape.psort_run_len
        h = tape.psort_hash
        for r in range(1, tape.n_props):
            same = (h[r] == h[r - 1]).all()
            assert same == (runs[r] > 1 and runs[r] == runs[r - 1])


class TestMultipleOfPrecision:
    def test_decimal_and_large_quotients_match_sequential(self):
        schema = {"type": "number", "multipleOf": 0.01}
        compiled = compile_schema(schema)
        tape = build_tape(compiled)
        seq = Validator(compiled)
        docs = [19.99, 19.994, 0.07, 1.0, 0, 0.015, 3, -19.99]
        table = encode_batch(docs, max_nodes=8)
        valid, decided = BatchValidator(tape, use_pallas=False).validate(table)
        assert decided.all()
        assert valid.tolist() == [seq.is_valid(d) for d in docs]

        # large quotients: the tolerance is capped, so 1000001 % 2 stays
        # False on the batched path too (quotient 500000.5)
        schema2 = {"type": "integer", "multipleOf": 2}
        compiled2 = compile_schema(schema2)
        tape2 = build_tape(compiled2)
        seq2 = Validator(compiled2)
        docs2 = [1000000, 1000001, 999999, 2000002]
        table2 = encode_batch(docs2, max_nodes=8)
        valid2, decided2 = BatchValidator(tape2, use_pallas=False).validate(table2)
        assert decided2.all()
        assert valid2.tolist() == [seq2.is_valid(d) for d in docs2]
        assert valid2.tolist() == [True, False, False, True]


class TestDepthBudget:
    def test_deeper_than_max_depth_is_undecided(self):
        schema = {"properties": {"a": {"properties": {"a": {"properties": {
            "a": {"properties": {"a": {"const": 1}}}}}}}}}
        compiled = compile_schema(schema)
        tape, reason = try_build_tape(compiled)
        assert tape is not None, reason
        shallow = {"a": 1}
        deep_ok = {"a": {"a": {"a": {"a": 1}}}}  # const site at depth 4
        deep_bad = {"a": {"a": {"a": {"a": 2}}}}
        table = encode_batch([shallow, deep_ok, deep_bad], max_nodes=32, max_depth=16)
        bv = BatchValidator(tape, max_depth=3, use_pallas=False)
        valid, decided = bv.validate(table)
        # depth-3 budget cannot see the const at depth 5: undecided, not
        # vacuously valid (the silent-correctness fix)
        assert decided.tolist() == [True, False, False]
        assert bool(valid[0])
        # routed to the sequential executor, verdicts recover
        seq = Validator(compiled)
        routed = [
            bool(v) if d else seq.is_valid(doc)
            for v, d, doc in zip(valid, decided, [shallow, deep_ok, deep_bad])
        ]
        assert routed == [True, True, False]

    def test_deep_docs_decided_with_enough_budget(self):
        schema = {"properties": {"a": {"properties": {"a": {"const": 1}}}}}
        tape = build_tape(compile_schema(schema))
        table = encode_batch([{"a": {"a": 1}}, {"a": {"a": 2}}], max_nodes=32)
        valid, decided = BatchValidator(tape, use_pallas=False).validate(table)
        assert decided.tolist() == [True, True]
        assert valid.tolist() == [True, False]


class TestUnsupportedFallback:
    def test_more_than_32_required_props_falls_back(self):
        schema = {"required": [f"k{i:02d}" for i in range(40)]}
        tape, reason = try_build_tape(compile_schema(schema))
        assert tape is None
        assert "required" in reason
        with pytest.raises(UnsupportedForBatch):
            build_tape(compile_schema(schema))

    def test_32_required_props_still_batchable(self):
        keys = [f"k{i:02d}" for i in range(32)]
        schema = {"type": "object", "required": keys}
        tape, reason = try_build_tape(compile_schema(schema))
        assert tape is not None, reason
        docs = [{k: 1 for k in keys}, {k: 1 for k in keys[:31]}, {}]
        table = encode_batch(docs, max_nodes=64)
        valid, decided = BatchValidator(tape, use_pallas=False).validate(table)
        assert decided.all()
        assert valid.tolist() == [True, False, False]
