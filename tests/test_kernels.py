"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp ref
oracle, swept over shapes and content distributions (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core.tape import AOP
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.data.doc_table import key_lanes


# ---------------------------------------------------------------------------
# hash_match
# ---------------------------------------------------------------------------


def _random_lanes(rng, n, pool):
    """Lanes drawn from a pool of real key hashes (forces collisions)."""
    idx = rng.integers(0, len(pool), n)
    return np.stack([pool[i] for i in idx]), idx


_KEYS = ["a", "b", "name", "kind", "value", "x" * 40, "y" * 40, "nested", "tags", ""]
_POOL = [key_lanes(k) for k in _KEYS]


class TestHashMatch:
    @pytest.mark.parametrize("n,m", [(1, 1), (7, 5), (128, 64), (300, 130), (513, 257)])
    def test_shapes_match_ref(self, n, m):
        rng = np.random.default_rng(n * 1000 + m)
        q_lanes, _ = _random_lanes(rng, n, _POOL)
        t_lanes, _ = _random_lanes(rng, m, _POOL)
        q_owner = rng.integers(0, 4, n).astype(np.int32)
        t_owner = rng.integers(0, 4, m).astype(np.int32)
        got = kops.hash_match(
            jnp.asarray(q_lanes), jnp.asarray(q_owner),
            jnp.asarray(t_lanes), jnp.asarray(t_owner),
            block_n=128, block_m=128,
        )
        want = kref.hash_match_ref(
            jnp.asarray(q_lanes), jnp.asarray(q_owner),
            jnp.asarray(t_lanes), jnp.asarray(t_owner),
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=30, deadline=None)
    @given(
        n=st.integers(1, 40),
        m=st.integers(1, 40),
        seed=st.integers(0, 2**16),
    )
    def test_property_sweep(self, n, m, seed):
        rng = np.random.default_rng(seed)
        q_lanes, _ = _random_lanes(rng, n, _POOL)
        t_lanes, _ = _random_lanes(rng, m, _POOL)
        q_owner = rng.integers(-1, 3, n).astype(np.int32)
        t_owner = rng.integers(0, 3, m).astype(np.int32)
        got = kops.hash_match(
            jnp.asarray(q_lanes), jnp.asarray(q_owner),
            jnp.asarray(t_lanes), jnp.asarray(t_owner),
            block_n=8, block_m=8,
        )
        want = kref.hash_match_ref(
            jnp.asarray(q_lanes), jnp.asarray(q_owner),
            jnp.asarray(t_lanes), jnp.asarray(t_owner),
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_no_match_returns_minus_one(self):
        q = jnp.asarray(np.stack([key_lanes("zzz")]))
        t = jnp.asarray(np.stack([key_lanes("aaa")]))
        got = kops.hash_match(
            q, jnp.zeros(1, jnp.int32), t, jnp.zeros(1, jnp.int32)
        )
        assert int(got[0]) == -1

    def test_owner_mismatch_blocks_match(self):
        lanes = jnp.asarray(np.stack([key_lanes("k")]))
        got = kops.hash_match(
            lanes, jnp.array([1], jnp.int32), lanes, jnp.array([2], jnp.int32)
        )
        assert int(got[0]) == -1

    def test_first_match_wins(self):
        lanes = np.stack([key_lanes("k")] * 3)
        got = kops.hash_match(
            jnp.asarray(lanes[:1]),
            jnp.zeros(1, jnp.int32),
            jnp.asarray(lanes),
            jnp.zeros(3, jnp.int32),
        )
        assert int(got[0]) == 0


# ---------------------------------------------------------------------------
# assertion_eval
# ---------------------------------------------------------------------------


def _random_nodes(rng, n):
    return {
        "type": jnp.asarray(rng.integers(0, 7, n).astype(np.int32)),
        "is_int": jnp.asarray(rng.integers(0, 2, n).astype(np.int32)),
        "num": jnp.asarray(rng.normal(0, 10, n).astype(np.float32)),
        "size": jnp.asarray(rng.integers(0, 20, n).astype(np.int32)),
        "acquired": jnp.asarray(rng.integers(0, 2**16, n).astype(np.int32)),
        "str_hash": jnp.asarray(
            np.stack([_POOL[i] for i in rng.integers(0, len(_POOL), n)])
        ),
        "str_prefix": jnp.asarray(rng.integers(0, 2**32, (n, 2), dtype=np.uint64).astype(np.uint32)),
    }


def _random_asrt(rng, a):
    return {
        "op": jnp.asarray(rng.integers(0, 19, a).astype(np.int32)),
        "f0": jnp.asarray(rng.normal(0, 5, a).astype(np.float32)),
        "i0": jnp.asarray(rng.integers(0, 0xFF, a).astype(np.int32)),
        "i1": jnp.asarray(rng.integers(0, 2, a).astype(np.int32)),
        "u0": jnp.asarray(rng.integers(0, 2**32, a, dtype=np.uint64).astype(np.uint32)),
        "u1": jnp.asarray(rng.integers(0, 2**32, a, dtype=np.uint64).astype(np.uint32)),
        "hash": jnp.asarray(
            np.stack([_POOL[i] for i in rng.integers(0, len(_POOL), a)])
        ),
    }


class TestAssertionEval:
    @pytest.mark.parametrize("n,a", [(1, 1), (5, 17), (128, 128), (200, 70), (257, 129)])
    def test_shapes_match_ref(self, n, a):
        rng = np.random.default_rng(n * 31 + a)
        nodes, asrts = _random_nodes(rng, n), _random_asrt(rng, a)
        got = kops.assertion_eval(nodes, asrts, block_n=128, block_a=128)
        want = kref.assertion_eval_ref(nodes, asrts)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(1, 30), a=st.integers(1, 30), seed=st.integers(0, 2**16))
    def test_property_sweep(self, n, a, seed):
        rng = np.random.default_rng(seed)
        nodes, asrts = _random_nodes(rng, n), _random_asrt(rng, a)
        got = kops.assertion_eval(nodes, asrts, block_n=8, block_a=8)
        want = kref.assertion_eval_ref(nodes, asrts)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_precondition_semantics(self):
        """Wrong-typed nodes pass AND rows (paper §5.2)."""
        nodes = {
            "type": jnp.asarray([4], jnp.int32),  # string
            "is_int": jnp.zeros(1, jnp.int32),
            "num": jnp.zeros(1, jnp.float32),
            "size": jnp.asarray([3], jnp.int32),
            "str_hash": jnp.zeros((1, 8), jnp.uint32),
            "str_prefix": jnp.zeros((1, 2), jnp.uint32),
        }
        asrts = {
            "op": jnp.asarray([AOP.NUM_GE], jnp.int32),
            "f0": jnp.asarray([100.0], jnp.float32),
            "i0": jnp.zeros(1, jnp.int32),
            "i1": jnp.zeros(1, jnp.int32),
            "u0": jnp.zeros(1, jnp.uint32),
            "u1": jnp.zeros(1, jnp.uint32),
            "hash": jnp.zeros((1, 8), jnp.uint32),
        }
        assert int(kops.assertion_eval(nodes, asrts)[0, 0]) == 1

    def test_str_prefix_check(self):
        from repro.data.doc_table import _str_prefix8

        p0, p1 = _str_prefix8(b"x-hello")
        nodes = {
            "type": jnp.asarray([4], jnp.int32),
            "is_int": jnp.zeros(1, jnp.int32),
            "num": jnp.zeros(1, jnp.float32),
            "size": jnp.asarray([7], jnp.int32),
            "str_hash": jnp.zeros((1, 8), jnp.uint32),
            "str_prefix": jnp.asarray([[p0, p1]], jnp.uint32),
        }
        pfx = b"x-".ljust(8, b"\x00")
        asrts = {
            "op": jnp.asarray([AOP.STR_PREFIX], jnp.int32),
            "f0": jnp.zeros(1, jnp.float32),
            "i0": jnp.asarray([2], jnp.int32),
            "i1": jnp.zeros(1, jnp.int32),
            "u0": jnp.asarray([int.from_bytes(pfx[:4], "big")], jnp.uint32),
            "u1": jnp.asarray([int.from_bytes(pfx[4:], "big")], jnp.uint32),
            "hash": jnp.zeros((1, 8), jnp.uint32),
        }
        assert int(kops.assertion_eval(nodes, asrts)[0, 0]) == 1
