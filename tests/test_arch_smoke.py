"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one prefill/decode step on CPU, asserting output
shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model
from repro.train import optimizer as opt


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prefix = (
        jax.random.normal(key, (B, cfg.prefix_len, cfg.d_model), dtype=cfg.dtype())
        if cfg.prefix_len
        else None
    )
    ocfg = opt.OptimizerConfig(warmup_steps=1, total_steps=10)
    state = opt.init(ocfg, params)

    def loss_fn(p):
        return model.loss(p, tokens, labels, prefix, remat=False)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), arch
    new_params, new_state, metrics = opt.update(ocfg, grads, state, params)
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert changed, arch

    logits = model.logits_train(params, tokens, prefix, remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab), arch
    assert not bool(jnp.isnan(logits).any()), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S = 2, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prefix = (
        jax.random.normal(key, (B, cfg.prefix_len, cfg.d_model), dtype=cfg.dtype())
        if cfg.prefix_len
        else None
    )
    total = S + (cfg.prefix_len or 0)
    logits, cache = model.prefill(params, tokens, max_len=total + 4, prefix_embeddings=prefix)
    assert logits.shape == (B, 1, cfg.padded_vocab), arch
    tok = jnp.argmax(logits[:, -1:], axis=-1)
    logits2, cache = model.decode_step(params, tok, cache, jnp.int32(total))
    assert logits2.shape == (B, 1, cfg.padded_vocab), arch
    assert not bool(jnp.isnan(logits2).any()), arch
    # argmax never selects a padded-vocab id
    assert int(jnp.argmax(logits2[0, 0])) < cfg.vocab_size, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_close_to_published(arch):
    """Analytic parameter count lands within 2x of the published size --
    catches config transcription errors."""
    published_b = {
        "musicgen-large": 3.3, "jamba-1.5-large-398b": 398.0, "arctic-480b": 480.0,
        "moonshot-v1-16b-a3b": 16.0, "internvl2-76b": 76.0, "qwen1.5-32b": 32.0,
        "starcoder2-7b": 7.0, "granite-3-8b": 8.0, "phi4-mini-3.8b": 3.8,
        "rwkv6-3b": 3.0,
    }[arch]
    n = get_config(arch).param_count() / 1e9
    assert published_b / 2 <= n <= published_b * 2, (arch, n, published_b)


def test_decode_matches_teacher_forcing():
    """Prefill+decode produce the same logits as the full forward pass."""
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    toks = jax.random.randint(key, (1, 9), 0, cfg.vocab_size)
    full = model.logits_train(params, toks, remat=False)
    lp, cache = model.prefill(params, toks[:, :8], max_len=16)
    np.testing.assert_allclose(
        np.asarray(lp[0, 0], np.float32), np.asarray(full[0, 7], np.float32),
        rtol=0.1, atol=0.15,
    )
    ld, _ = model.decode_step(params, toks[:, 8:9], cache, jnp.int32(8))
    np.testing.assert_allclose(
        np.asarray(ld[0, 0], np.float32), np.asarray(full[0, 8], np.float32),
        rtol=0.1, atol=0.15,
    )


def test_chunked_attention_matches_dense():
    """The flash-style chunked path equals the dense path numerically."""
    from repro.models import layers as L

    cfg = get_config("granite-3-8b").reduced()
    key = jax.random.PRNGKey(3)
    p = L.attention_init(key, cfg)
    B, S = 1, 64
    x = jax.random.normal(key, (B, S, cfg.d_model), dtype=jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    dense, _ = L.attention(p, cfg, x, pos)
    old = L.CHUNKED_ATTN_THRESHOLD, L.Q_CHUNK
    try:
        L.CHUNKED_ATTN_THRESHOLD, L.Q_CHUNK = 1, 16
        chunked, _ = L.attention(p, cfg, x, pos)
    finally:
        L.CHUNKED_ATTN_THRESHOLD, L.Q_CHUNK = old
    np.testing.assert_allclose(
        np.asarray(dense, np.float32), np.asarray(chunked, np.float32),
        rtol=2e-2, atol=2e-3,
    )


def test_int8_kv_cache_close_to_bf16():
    """Quantized decode logits stay close to the bf16-cache logits."""
    import dataclasses

    base = get_config("granite-3-8b").reduced()
    key = jax.random.PRNGKey(4)
    toks = jax.random.randint(key, (1, 8), 0, base.vocab_size)
    outs = {}
    for dtype in ("bfloat16", "int8"):
        cfg = dataclasses.replace(base, kv_cache_dtype=dtype)
        model = Model(cfg)
        params = Model(base).init(key)  # same weights
        lp, cache = model.prefill(params, toks, max_len=12)
        ld, _ = model.decode_step(
            params, jnp.argmax(lp[:, -1:], -1), cache, jnp.int32(8)
        )
        outs[dtype] = np.asarray(ld, np.float32)
    # int8 KV introduces bounded error; top-1 must agree on this toy case
    assert outs["bfloat16"][0, 0].argmax() == outs["int8"][0, 0].argmax()
