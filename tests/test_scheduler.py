"""Streaming scheduler coverage (DESIGN.md §14): drain-timing-independent
verdicts under injected faults, breaker-tripped groups not starving
healthy lanes, queue-delay-inclusive latency accounting (the §13
under-count regression), cost-model routing, and bit-identity between
the stream runtime and the synchronous ``submit_batch`` path."""

import json

import pytest

jax = pytest.importorskip("jax")

from repro.core import BreakerConfig, ValidationOutcome
from repro.serve.faults import FaultInjector
from repro.serve.scheduler import (
    CostModel,
    SchedulerConfig,
    _bucket,
    seed_priors_from_bench,
)

FLAT = {
    "type": "object",
    "required": ["a"],
    "additionalProperties": False,
    "properties": {
        "a": {"type": "integer", "minimum": 0},
        "b": {"type": "string", "minLength": 1},
    },
}
DEEP = {
    "type": "object",
    "properties": {
        "x": {"type": "number", "maximum": 10},
        "nested": {
            "type": "object",
            "properties": {
                "name": {"const": 5},
                "deep": {"properties": {"q": {"const": 1}, "r": {"const": 2}}},
            },
        },
        "p1": {"type": "integer"},
        "p2": {"type": "integer"},
        "p3": {"type": "integer"},
        "p4": {"type": "integer"},
        "p5": {"type": "integer"},
    },
}


class Clock:
    """Deterministic injectable clock (breaker/deadline tests)."""

    def __init__(self):
        self.t = 0.0

    def advance(self, dt):
        self.t += dt

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def model_bundle():
    from repro.configs import get_config
    from repro.models import Model

    cfg = get_config("granite-3-8b").reduced()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(model_bundle, registry=None):
    from repro.registry import SchemaRegistry
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg, params = model_bundle
    reg = registry if registry is not None else SchemaRegistry(use_pallas=False)
    eng = ServeEngine(
        cfg,
        params,
        ServeConfig(batch_slots=2, max_len=64, default_max_tokens=4),
        registry=reg,
    )
    eng.register_endpoint("flat", FLAT)
    eng.register_endpoint("deep", DEEP)
    return eng


def _stream():
    """Fixed request mix: both groups, valid/invalid/guard-reject rows."""
    rows = [
        ("flat", json.dumps({"a": 1, "b": "x"})),  # valid
        ("deep", json.dumps({"x": 3, "nested": {"name": 5}})),  # valid
        ("flat", json.dumps({"a": -1})),  # invalid: minimum
        ("flat", "{broken"),  # guard: parse
        ("deep", json.dumps({"x": 99})),  # invalid: maximum
        ("nosuch", "{}"),  # guard: unknown endpoint
        ("deep", json.dumps({"p1": 1, "p2": 2})),  # valid
        ("flat", json.dumps({"b": ""})),  # invalid: required
        ("deep", json.dumps({"nested": {"name": 4}})),  # invalid: const
        ("flat", json.dumps({"a": 7})),  # valid
    ]
    return rows * 3  # 30 requests, serials 1..30


def _fingerprint(tickets):
    return [
        (t.endpoint, t.serial, t.result.outcome, t.result.error)
        for t in tickets
    ]


def _hist_totals(engine, family="serve_request_seconds"):
    children = engine.registry.metrics.family_children(family)
    return (
        sum(h.count for h in children.values()),
        sum(h.sum for h in children.values()),
    )


# ---------------------------------------------------------------------------
# Determinism: verdicts independent of drain timing, faults included
# ---------------------------------------------------------------------------


class TestDrainTimingIndependence:
    def _run(self, model_bundle, eager):
        """Offer the fixed stream under a seeded fault plan; ``eager``
        drains after every offer (batches of ~1), else one bulk flush
        (full lanes).  Outcomes must not depend on the difference."""
        eng = _engine(model_bundle)
        sched = eng.scheduler(
            max_delay_s=0.0 if eager else 60.0,
            route="batched",
            profile_every=0,
            bench_priors=None,
        )
        # fault keys are per-request ("stream", serial) -- identical in
        # both runs because serials track offer order on a fresh engine
        inj = (
            FaultInjector(seed=5)
            .rate("encode", 0.15)
            .poison("launch", ("stream", 7), ("stream", 22))
            .rate("fallback", 0.3)
        )
        tickets = []
        with inj:
            for i, (ep, req) in enumerate(_stream()):
                tickets.append(sched.offer(ep, req, now=float(i)))
                if eager:
                    sched.pump(now=float(i))
            sched.flush(now=1e9)
        assert inj.fired.get("launch", 0) > 0
        assert sched.depth() == 0
        assert all(t.done for t in tickets)
        return eng, tickets

    def test_outcomes_identical_across_drain_timings(self, model_bundle):
        _, eager = self._run(model_bundle, eager=True)
        _, bulk = self._run(model_bundle, eager=False)
        assert _fingerprint(eager) == _fingerprint(bulk)
        # the poisoned serials were isolated, not spread to batch mates
        by_serial = {t.serial: t for t in bulk}
        for s in (7, 22):
            assert (
                by_serial[s].result.outcome
                is ValidationOutcome.ERROR_ISOLATED
            )

    def test_stats_reconcile(self, model_bundle):
        eng, tickets = self._run(model_bundle, eager=False)
        assert eng.stats.received == len(tickets)
        assert eng.stats.received == sum(eng.stats.outcomes.values())
        # one latency observation per request, guard rejects included
        count, _ = _hist_totals(eng)
        assert count == len(tickets)


# ---------------------------------------------------------------------------
# Differential: stream runtime == submit_batch, request by request
# ---------------------------------------------------------------------------


class TestStreamVsBatchIdentity:
    @pytest.mark.parametrize("route", ["batched", "sequential"])
    def test_bit_identical_results(self, model_bundle, route):
        rows = _stream()
        ref = _engine(model_bundle)
        expected = ref.submit_batch(rows)
        eng = _engine(model_bundle)
        sched = eng.scheduler(
            max_delay_s=60.0, route=route, profile_every=0, bench_priors=None
        )
        tickets = [
            sched.offer(ep, req, now=0.0) for ep, req in rows
        ]
        sched.flush(now=0.0)
        got = [t.result for t in tickets]
        assert [(r.outcome, r.error) for r in got] == [
            (r.outcome, r.error) for r in expected
        ]


# ---------------------------------------------------------------------------
# Breaker-tripped group routes to fallback without starving other lanes
# ---------------------------------------------------------------------------


class TestBreakerGroupIsolation:
    def test_open_breaker_does_not_starve_other_groups(self, model_bundle):
        from repro.registry import SchemaRegistry

        clock = Clock()
        reg = SchemaRegistry(
            use_pallas=False,
            fallback_max_steps=4,
            fallback_deadline_s=None,
            breaker=BreakerConfig(threshold=2, cooldown_s=300.0),
            clock=clock,
        )
        eng = _engine(model_bundle, registry=reg)
        sched = eng.scheduler(
            max_delay_s=0.0,
            route="sequential",
            profile_every=0,
            bench_priors=None,
        )
        # two slow deep docs exhaust the 4-step fallback budget -> two
        # consecutive timeouts trip deep's breaker
        slow = json.dumps({"x": 3, "nested": {"name": 5}})
        for _ in range(2):
            t = sched.offer("deep", slow, now=clock.t)
            sched.pump(now=clock.t)
            assert t.result.outcome is ValidationOutcome.TIMED_OUT
        assert reg.breaker("deep").state == "open"
        # interleave deep (breaker open) with flat traffic; deep's lane
        # head is OLDER, so a starvation bug would block flat behind it
        deep_tix = [sched.offer("deep", slow, now=clock.t) for _ in range(3)]
        flat_tix = [
            sched.offer("flat", json.dumps(7), now=clock.t) for _ in range(3)
        ]
        reports = sched.flush(now=clock.t)
        assert {r.lane for r in reports} == {
            reg.group_of("deep").label,
            reg.group_of("flat").label,
        }
        for t in deep_tix:
            assert t.result.outcome is ValidationOutcome.UNDECIDED_FALLBACK
            assert "circuit open" in t.result.error
        for t in flat_tix:  # fail-fast type check fits the step budget
            assert t.result.outcome is ValidationOutcome.INVALID
        assert reg.breaker("flat").state == "closed"
        assert sched.depth() == 0


# ---------------------------------------------------------------------------
# Latency accounting: queue delay included, guard rejects billed true wall
# ---------------------------------------------------------------------------


class TestLatencyAccounting:
    def test_submit_batch_guard_rejects_observe_true_wall(self, model_bundle):
        eng = _engine(model_bundle)
        results = eng.submit_batch([("flat", "{broken")] * 4)
        assert all(
            r.outcome is ValidationOutcome.REJECTED_GUARD for r in results
        )
        count, total = _hist_totals(eng)
        assert count == 4
        assert total > 0.0  # regression: guard rejects observed 0.0

    def test_scheduler_latency_includes_queue_delay(self, model_bundle):
        eng = _engine(model_bundle)
        sched = eng.scheduler(
            max_delay_s=60.0, profile_every=0, bench_priors=None
        )
        tickets = [
            sched.offer("flat", json.dumps({"a": i}), now=0.0)
            for i in range(4)
        ]
        sched.flush(now=5.0)  # drained 5 virtual seconds after arrival
        for t in tickets:
            assert t.queue_delay_s == pytest.approx(5.0)
            assert t.latency_s >= 5.0  # queue delay + real drain wall
        count, total = _hist_totals(eng)
        assert count == 4 and total >= 20.0
        qcount, qtotal = _hist_totals(eng, "serve_queue_delay_seconds")
        assert qcount == 4 and qtotal == pytest.approx(20.0)

    def test_offer_guard_reject_is_terminal_and_billed(self, model_bundle):
        eng = _engine(model_bundle)
        sched = eng.scheduler(profile_every=0, bench_priors=None)
        t = sched.offer("flat", "{broken", now=0.0)
        assert t.done and t.result.outcome is ValidationOutcome.REJECTED_GUARD
        assert t.latency_s > 0.0
        assert sched.depth() == 0
        assert sched.stats.rejected_at_offer == 1
        count, total = _hist_totals(eng)
        assert count == 1 and total > 0.0

    def test_endpoint_stats_reports_link_group(self, model_bundle):
        eng = _engine(model_bundle)
        reg = eng.registry
        all_stats = eng.endpoint_stats()
        for ep in ("flat", "deep"):
            stats = all_stats[ep]
            g = reg.group_of(ep)
            assert stats["link_group"] == g.label
            assert stats["group_members"] == len(g.members)
            assert stats["group_a_hat"] == int(g.tape.max_rows_per_loc)
            assert stats["group_m_hat"] == int(g.tape.max_member_props)
            assert stats["group_horizon"] == int(g.tape.max_loc_depth) + 1
        # the two endpoints deliberately land in different groups
        assert all_stats["flat"]["link_group"] != all_stats["deep"]["link_group"]


# ---------------------------------------------------------------------------
# Cost model: bucketing, EMA updates, routing flips, bench priors
# ---------------------------------------------------------------------------


class TestCostModel:
    def test_bucket_is_pow2(self):
        assert [_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 256)] == [
            1, 2, 4, 4, 8, 8, 16, 256,
        ]

    def test_priors_then_ema(self):
        cfg = SchedulerConfig(
            launch_fixed_us=1000.0,
            launch_us_per_doc=10.0,
            seq_us_per_doc=50.0,
            ema_alpha=0.5,
            bench_priors=None,
        )
        cm = CostModel(cfg)
        # priors: batched pays the padded bucket, sequential pays n
        assert cm.batched_us("g", 3) == 1000.0 + 10.0 * 4
        assert cm.sequential_us("g", 3) == 150.0
        assert not cm.prefer_batched("g", 3)
        assert cm.prefer_batched("g", 100)  # 2040 < 5000
        # a measured launch replaces the prior for that (lane, bucket)
        cm.observe("g", "batched", 3, 80.0)
        assert cm.batched_us("g", 3) == 80.0
        cm.observe("g", "batched", 3, 120.0)
        assert cm.batched_us("g", 3) == pytest.approx(100.0)  # EMA(0.5)
        assert cm.batched_us("g", 5) == 1000.0 + 10.0 * 8  # other bucket
        # sequential EMA is per-doc, per lane
        cm.observe("g", "sequential", 4, 40.0)
        assert cm.sequential_us("g", 2) == pytest.approx(20.0)
        assert cm.sequential_us("other", 2) == 100.0  # lane-isolated
        snap = cm.snapshot()
        assert snap["launch_ema_us"]["g@4"] == pytest.approx(100.0)

    def test_seed_priors_from_bench(self, tmp_path):
        bench = tmp_path / "BENCH_registry.json"
        bench.write_text(
            json.dumps(
                {
                    "throughput": [
                        {
                            "batch": 64,
                            "linked_us_per_doc": 40.0,
                            "encode_us_per_doc": 60.0,
                            "sequential_us_per_doc": 5.0,
                        },
                        {
                            "batch": 512,
                            "linked_us_per_doc": 30.0,
                            "encode_us_per_doc": 50.0,
                            "sequential_us_per_doc": 9.0,
                        },
                    ]
                }
            )
        )
        priors = seed_priors_from_bench(bench)
        # line through (64, 6400) and (512, 40960): slope ~77.14
        assert priors["launch_us_per_doc"] == pytest.approx(77.142857, rel=1e-4)
        assert priors["launch_fixed_us"] == pytest.approx(1462.857, rel=1e-3)
        assert priors["seq_us_per_doc"] == 9.0  # most conservative row
        assert seed_priors_from_bench(tmp_path / "missing.json") is None

    def test_sequential_only_endpoints_get_own_lane(self, model_bundle):
        eng = _engine(model_bundle)
        eng.register_endpoint("slow", {"uniqueItems": True})
        sched = eng.scheduler(
            max_delay_s=60.0, profile_every=0, bench_priors=None
        )
        t = sched.offer("slow", json.dumps([1, 2]), now=0.0)
        assert "seq:slow" in sched.snapshot()["lanes"]
        (report,) = sched.flush(now=0.0)
        assert report.route == "sequential"
        assert t.result.outcome is ValidationOutcome.ADMITTED
