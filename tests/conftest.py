"""Shared test configuration.

Arms the tape-invariant linter (DESIGN.md §15) for the whole suite:
every ``build_tape``/``link_tapes`` call in any test asserts the full
structural contract (CSR window coverage, psort integrity, circuit DAG
shape, frontier wiring, linked offsets) before the tape is used.
"""

import os

os.environ.setdefault("REPRO_LINT_TAPES", "1")
