"""Batched first-failure attribution vs the sequential oracle (DESIGN.md §12).

The differential contract: on a document violating exactly ONE schema
keyword, ``BatchValidator.explain_batch`` must attribute the same schema
location the sequential ``Validator.explain`` reports innermost -- both
engines see a single failure, so there is no tie-break slack.  Multi-
failure documents get the weaker membership check (the batched pick is
one of the sequential trace's failing locations) plus the documented
tie-break (lowest BFS node; assertion < required < closed within a node;
lowest assertion row; structural beats circuit at the same node).
"""

import random

import numpy as np
import pytest

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.explain import FailureSite, keyword_of, node_pointer
from repro.core.outcomes import ValidationOutcome
from repro.core.tape import try_build_tape
from repro.data.doc_table import encode_batch
from repro.registry import SchemaRegistry

SCHEMA = {
    "type": "object",
    "required": ["id", "name"],
    "additionalProperties": False,
    "properties": {
        "id": {"type": "integer", "minimum": 0, "maximum": 1_000_000},
        "name": {"type": "string", "minLength": 2, "maxLength": 32},
        "kind": {"enum": ["basic", "pro", "trial"]},
        "score": {"type": "number", "minimum": 0, "maximum": 1},
        "tags": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
    },
}

VALID = {"id": 7, "name": "ok", "kind": "pro", "score": 0.5, "tags": ["a"]}


def _harness(schema):
    compiled = compile_schema(schema)
    tape, reason = try_build_tape(compiled)
    assert tape is not None, reason
    return Validator(compiled), BatchValidator(tape, max_depth=8, use_pallas=False)


def _single_failure_corpus(seed=0):
    """Invalid documents each violating exactly one keyword."""
    rng = random.Random(seed)
    corpus = []
    mutations = [
        lambda d: d.pop("id"),  # required
        lambda d: d.pop("name"),  # required
        lambda d: d.update(id="x"),  # type (id)
        lambda d: d.update(id=-rng.randint(1, 9)),  # minimum
        lambda d: d.update(id=2_000_000),  # maximum
        lambda d: d.update(name="x"),  # minLength
        lambda d: d.update(name="x" * 40),  # maxLength
        lambda d: d.update(name=rng.randint(0, 9)),  # type (name)
        lambda d: d.update(kind="enterprise"),  # enum
        lambda d: d.update(score=1.5),  # maximum (score)
        lambda d: d.update(score="high"),  # type (score)
        lambda d: d.update(tags=["a", "b", "c", "d", "e"]),  # maxItems
        lambda d: d.update(tags=["a", 3]),  # items type
        lambda d: d.update(surprise=1),  # additionalProperties
    ]
    for k in range(40):
        doc = dict(VALID)
        mutations[k % len(mutations)](doc)
        corpus.append(doc)
    return corpus


class TestDifferentialAttribution:
    def test_single_failure_corpus_agrees_with_sequential(self):
        seq, bv = _harness(SCHEMA)
        docs = _single_failure_corpus()
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        valid, decided = bv.validate(table)
        assert decided.all() and not valid.any()
        sites = bv.explain_batch(table, docs=docs)
        for doc, site in zip(docs, sites):
            ok, trace = seq.explain(doc)
            assert not ok and site is not None, doc
            seq_paths = {p for p, _ in trace}
            # single violation: the innermost sequential path IS the
            # batched attribution (no tie-break slack)
            assert site.schema_path == trace[0][0], (doc, site, trace)
            assert site.schema_path in seq_paths

    def test_multi_failure_site_is_a_sequential_failure(self):
        seq, bv = _harness(SCHEMA)
        docs = [
            {"id": "x", "name": 0, "kind": "zz"},
            {"name": "q" * 50, "score": -3, "extra": 1},
            {},
        ]
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        sites = bv.explain_batch(table, docs=docs)
        for doc, site in zip(docs, sites):
            ok, trace = seq.explain(doc)
            assert not ok and site is not None
            assert site.schema_path in {p for p, _ in trace}, (doc, site, trace)

    def test_valid_documents_attribute_none(self):
        _, bv = _harness(SCHEMA)
        docs = [VALID, {"id": 1, "name": "yo"}]
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        assert bv.explain_batch(table, docs=docs) == [None, None]

    def test_circuit_attribution_names_the_applicator(self):
        schema = {
            "type": "object",
            "properties": {
                "n": {"anyOf": [{"type": "integer", "minimum": 10}, {"type": "string"}]},
                "m": {"not": {"type": "null"}},
            },
        }
        seq, bv = _harness(schema)
        docs = [{"n": 3}, {"m": None}, {"n": "fine"}]
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        sites = bv.explain_batch(table, docs=docs)
        assert sites[0].schema_path == "/properties/n/anyOf"
        assert sites[0].keyword == "anyOf"
        assert sites[0].instance_path == "/n"
        assert sites[1].schema_path == "/properties/m/not"
        assert sites[2] is None
        for doc, site in zip(docs[:2], sites[:2]):
            ok, trace = seq.explain(doc)
            assert not ok
            assert site.schema_path in {p for p, _ in trace}

    def test_instance_pointers(self):
        _, bv = _harness(SCHEMA)
        docs = [
            {"id": 1, "name": "ok", "tags": ["a", 3]},
            {"id": "x", "name": "ok"},
        ]
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        sites = bv.explain_batch(table, docs=docs)
        assert sites[0].instance_path == "/tags/1"
        assert sites[1].instance_path == "/id"
        # without docs: attribution still lands, pointers stay empty
        sites = bv.explain_batch(table)
        assert sites[0].schema_path and sites[0].instance_path == ""


class TestTieBreak:
    def test_lowest_bfs_node_wins(self):
        # id (BFS node 1) and tags items (deeper) both fail -> id wins
        seq, bv = _harness(SCHEMA)
        docs = [{"id": "x", "name": "ok", "tags": [3]}]
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        (site,) = bv.explain_batch(table, docs=docs)
        assert site.instance_path == "/id"

    def test_assertion_beats_required_at_the_same_node(self):
        # root object: type passes; required fails at the root while a
        # property assertion fails deeper -> the root required pick wins
        # (lowest node), but a root-level assertion must outrank it
        schema = {
            "type": "object",
            "required": ["a"],
            "properties": {"a": {"type": "integer"}},
            "minProperties": 2,
        }
        compiled = compile_schema(schema)
        tape, reason = try_build_tape(compiled)
        if tape is None:
            pytest.skip(f"outside structural subset: {reason}")
        bv = BatchValidator(tape, max_depth=8, use_pallas=False)
        docs = [{}]  # fails minProperties (assertion) AND required
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        (site,) = bv.explain_batch(table, docs=docs)
        # both anchor at node 0: kind 0 (assertion) < kind 1 (required)
        assert site.keyword != "required", site

    def test_structural_beats_circuit_at_same_node(self):
        schema = {
            "type": "object",
            "properties": {
                "v": {
                    "type": "integer",
                    "minimum": 5,
                    "anyOf": [{"minimum": 100}, {"maximum": -100}],
                }
            },
        }
        compiled = compile_schema(schema)
        tape, reason = try_build_tape(compiled)
        if tape is None:
            pytest.skip(f"outside structural subset: {reason}")
        bv = BatchValidator(tape, max_depth=8, use_pallas=False)
        docs = [{"v": 2}]  # fails plain minimum AND the anyOf circuit
        table = encode_batch(docs, max_nodes=64, max_depth=8)
        (site,) = bv.explain_batch(table, docs=docs)
        assert site.keyword == "minimum", site  # structural wins the tie


class TestNodePointer:
    def test_bfs_order_replay(self):
        doc = {"a": [10, {"b": 1}], "c": "x"}
        # BFS: 0={root} 1=[10,{b:1}] 2="x" 3=10 4={b:1} 5=1
        assert node_pointer(doc, 0) == ""
        assert node_pointer(doc, 1) == "/a"
        assert node_pointer(doc, 2) == "/c"
        assert node_pointer(doc, 3) == "/a/0"
        assert node_pointer(doc, 4) == "/a/1"
        assert node_pointer(doc, 5) == "/a/1/b"
        assert node_pointer(doc, 99) == ""

    def test_rfc6901_escaping(self):
        doc = {"a/b": 1, "c~d": 2}
        assert node_pointer(doc, 1) == "/a~1b"
        assert node_pointer(doc, 2) == "/c~0d"

    def test_keyword_of(self):
        assert keyword_of("/properties/a/minLength") == "minLength"
        assert keyword_of("/type") == "type"
        assert keyword_of("") == ""

    def test_render(self):
        s = FailureSite("/properties/a/type", "type", "/a")
        assert "'/a'" in s.render() and "type" in s.render()


class TestRegistryExplainPlumbing:
    def test_admit_mixed_ex_explain_carries_sites(self):
        reg = SchemaRegistry(use_pallas=False)
        reg.register("users", SCHEMA)
        docs = [VALID, {"id": -5, "name": "ok"}, {"id": 1}]
        verdicts, _ = reg.admit_mixed_ex(docs, ["users"] * 3, explain=True)
        assert verdicts[0].site is None
        assert verdicts[1].outcome is ValidationOutcome.INVALID
        assert isinstance(verdicts[1].site, FailureSite)
        # min+max fuse into AssertionNumberBounds carrying the bare
        # parent path -- same provenance the sequential trace reports
        assert verdicts[1].site.schema_path == "/properties/id"
        assert verdicts[1].site.render() == verdicts[1].reason
        assert verdicts[2].site is not None  # missing "name"
        assert verdicts[2].site.keyword == "required"

    def test_explain_false_keeps_generic_reason(self):
        reg = SchemaRegistry(use_pallas=False)
        reg.register("users", SCHEMA)
        verdicts, _ = reg.admit_mixed_ex(
            [{"id": -5, "name": "ok"}], ["users"], explain=False
        )
        assert verdicts[0].reason == "schema validation failed"
        assert verdicts[0].site is None

    def test_sequential_fallback_explain(self):
        reg = SchemaRegistry(use_pallas=False)
        # outside the structural subset -> sequential-only endpoint
        reg.register("pat", {"type": "string", "pattern": "^a+$"})
        v = reg.validate_one("pat", "bbb", explain=True)
        assert v.outcome is ValidationOutcome.INVALID
        assert v.site is not None and v.site.keyword == "pattern"
        # explain=False: generic reason, no site
        v = reg.validate_one("pat", "bbb")
        assert v.site is None and v.reason == "schema validation failed"

    def test_batched_and_sequential_sites_agree(self):
        reg = SchemaRegistry(use_pallas=False)
        reg.register("users", SCHEMA)
        docs = _single_failure_corpus(seed=3)
        verdicts, _ = reg.admit_mixed_ex(docs, ["users"] * len(docs), explain=True)
        for doc, verdict in zip(docs, verdicts):
            assert verdict.outcome is ValidationOutcome.INVALID
            seq = reg.validate_one("users", doc, explain=True)
            assert verdict.site.schema_path == seq.site.schema_path, doc
