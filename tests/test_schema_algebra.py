"""Differential suite for the ahead-of-time schema algebra (DESIGN.md §15).

Soundness contract under test: every rewrite the analyzer performs --
constant folding, allOf flattening, bound tightening, branch pruning --
must preserve the *verdict* of every instance, as judged by the naive
reference interpreter.  Covered by:

- seeded random schema/document fuzzing (original vs normalized)
- the vendored conformance corpus re-run against normalized schemas
- directed prune cases asserting the tape actually shrinks
- directed subsumption verdicts (equivalent / widened / narrowed /
  incomparable) plus the registry's swap semantics built on them
  (equivalence => metadata-only no-op, widening => warning + counter)
- structural dedup of linked segments and per-schema unroll sizing
"""

import json
import os
import random
import warnings
from pathlib import Path

import pytest

from repro.analysis import analyze_schema, compare, structural_hash
from repro.analysis.unroll import recommend_unroll_depth
from repro.core import NaiveValidator, compile_schema
from repro.core.tape import try_build_tape
from repro.registry.registry import SchemaRegistry, WidenedSwapWarning

CORPUS = Path(__file__).parent / "conformance"

# ---------------------------------------------------------------------------
# seeded random schema / document generators
# ---------------------------------------------------------------------------

_KEYS = ["a", "b", "c", "kind", "n", "s"]


def _rand_schema(rng: random.Random, depth: int = 0):
    """Small random schema biased toward the keywords the analyzer
    rewrites (bounds, enums, logical applicators, duplicates)."""
    roll = rng.random()
    if depth >= 3 or roll < 0.15:
        return rng.choice(
            [
                {"type": "integer", "minimum": rng.randint(-5, 5)},
                {"type": "integer", "minimum": 4, "maximum": rng.randint(0, 8)},
                {"type": "string", "minLength": rng.randint(0, 3)},
                {"type": "string", "minLength": 5, "maxLength": rng.randint(0, 9)},
                {"enum": [1, 2, "x"]},
                {"const": rng.choice([1, "x", True, None])},
                {"type": rng.choice(["number", "boolean", "null", "array"])},
                True,
                False,
            ]
        )
    if roll < 0.45:
        props = {
            k: _rand_schema(rng, depth + 1)
            for k in rng.sample(_KEYS, rng.randint(1, 3))
        }
        out = {"type": "object", "properties": props}
        if rng.random() < 0.5:
            out["required"] = rng.sample(list(props), rng.randint(1, len(props)))
        if rng.random() < 0.2:
            out["additionalProperties"] = False
        if rng.random() < 0.2:
            out["minProperties"] = rng.randint(0, 2)
        return out
    kw = rng.choice(["allOf", "anyOf", "oneOf", "not", "if"])
    if kw == "not":
        return {"not": _rand_schema(rng, depth + 1)}
    if kw == "if":
        return {
            "if": _rand_schema(rng, depth + 1),
            "then": _rand_schema(rng, depth + 1),
        }
    return {kw: [_rand_schema(rng, depth + 1) for _ in range(rng.randint(1, 3))]}


def _rand_doc(rng: random.Random, depth: int = 0):
    roll = rng.random()
    if depth >= 2 or roll < 0.5:
        return rng.choice(
            [None, True, False, 0, 1, 2, 4, 5, -3, 1.5, "", "x", "hello", "abcdef"]
        )
    if roll < 0.8:
        return {
            k: _rand_doc(rng, depth + 1)
            for k in rng.sample(_KEYS, rng.randint(0, 4))
        }
    return [_rand_doc(rng, depth + 1) for _ in range(rng.randint(0, 3))]


def test_fuzz_normalize_preserves_verdicts():
    rng = random.Random(0xB1A2E)
    checked = 0
    for _ in range(150):
        schema = _rand_schema(rng)
        report = analyze_schema(schema)
        try:
            naive_orig = NaiveValidator(schema)
            naive_norm = NaiveValidator(report.normalized)
        except Exception:
            continue
        for _ in range(20):
            doc = _rand_doc(rng)
            try:
                want = naive_orig.is_valid(doc)
            except Exception:
                continue
            got = naive_norm.is_valid(doc)
            assert got == want, (
                f"verdict drift on {doc!r}:\n  original   {schema!r}\n"
                f"  normalized {report.normalized!r}"
            )
            checked += 1
    assert checked > 1000  # the fuzz loop must actually exercise pairs


def test_fuzz_normalized_compiled_engine_agrees():
    """The compiled (codegen) engine over the *normalized* schema must
    match the naive interpreter over the *original* -- the end-to-end
    contract the registry's smoke verifier enforces at register()."""
    from repro.core import Validator

    rng = random.Random(0xC0FFEE)
    for _ in range(40):
        schema = _rand_schema(rng)
        report = analyze_schema(schema)
        try:
            naive = NaiveValidator(schema)
            compiled = Validator(compile_schema(report.normalized), engine="codegen")
        except Exception:
            continue
        for _ in range(10):
            doc = _rand_doc(rng)
            try:
                want = naive.is_valid(doc)
                got = compiled.is_valid(doc)
            except Exception:
                continue
            assert got == want, (doc, schema, report.normalized)


def test_conformance_corpus_survives_normalization():
    """Re-run the vendored corpus with every schema normalized: the
    expected verdicts must hold exactly."""
    cases = 0
    for path in sorted(CORPUS.glob("*.json")):
        for group in json.loads(path.read_text()):
            schema = group["schema"]
            report = analyze_schema(schema)
            naive = NaiveValidator(report.normalized)
            for test in group["tests"]:
                try:
                    got = naive.is_valid(test["data"])
                except Exception:
                    continue  # outside the naive envelope either way
                assert got == test["valid"], (
                    f"{path.name}: {group['description']} / "
                    f"{test['description']}: normalized verdict {got}, "
                    f"expected {test['valid']}\n  normalized: "
                    f"{report.normalized!r}"
                )
                cases += 1
    assert cases >= 90


# ---------------------------------------------------------------------------
# directed pruning: proofs shrink the tape
# ---------------------------------------------------------------------------


def test_prune_dead_branches_shrinks_tape():
    schema = {
        "type": "object",
        "required": ["kind"],
        "properties": {"kind": {"enum": ["a", "b"]}},
        "anyOf": [
            {"properties": {"kind": {"const": "a"}}, "required": ["kind"]},
            {"properties": {"kind": {"const": "b"}}, "required": ["kind"]},
            {"type": "string", "minLength": 8, "maxLength": 2},
            {"type": "integer", "minimum": 10, "maximum": 3},
        ],
    }
    report = analyze_schema(schema)
    assert report.verified and report.pruned_branches >= 2
    pre, _ = try_build_tape(compile_schema(schema))
    post, _ = try_build_tape(compile_schema(report.normalized))
    assert pre is not None and post is not None
    assert post.max_rows_per_loc < pre.max_rows_per_loc
    assert post.n_assertions < pre.n_assertions
    # verdicts unchanged on both sides of every pruned boundary
    naive = NaiveValidator(schema)
    post_naive = NaiveValidator(report.normalized)
    for doc in [{"kind": "a"}, {"kind": "b"}, {"kind": "c"}, {}, "xx", 5, 11]:
        assert naive.is_valid(doc) == post_naive.is_valid(doc), doc


def test_unsat_schema_folds_to_false():
    report = analyze_schema(
        {"type": "integer", "minimum": 10, "maximum": 3}
    )
    assert report.normalized is False
    report = analyze_schema(
        {"allOf": [{"const": 1}, {"const": 2}]}
    )
    assert report.normalized is False


def test_unknown_keywords_are_kept():
    """unknown => keep: schemas the analyzer cannot model pass through
    byte-identical (no counters, no rewrite)."""
    for schema in (
        {"$dynamicRef": "#x"},
        {"$ref": "#/$defs/a/allOf/0", "$defs": {"a": {"allOf": [{}]}}},
        {"unevaluatedProperties": False, "anyOf": [True, {"type": "object"}]},
    ):
        report = analyze_schema(schema)
        assert report.normalized == schema
        assert report.pruned_branches == 0


# ---------------------------------------------------------------------------
# subsumption verdicts
# ---------------------------------------------------------------------------

BASE = {
    "type": "object",
    "required": ["a"],
    "properties": {"a": {"type": "integer", "minimum": 0, "maximum": 10}},
}


def _with_bounds(lo, hi):
    s = json.loads(json.dumps(BASE))
    s["properties"]["a"]["minimum"] = lo
    s["properties"]["a"]["maximum"] = hi
    return s


def test_subsumption_lattice():
    assert compare(BASE, json.loads(json.dumps(BASE))).verdict == "equivalent"
    # annotation-only and key-order changes hash equal -> equivalent
    ann = dict(BASE, title="same", description="prose")
    assert structural_hash(ann) == structural_hash(BASE)
    assert compare(BASE, ann).verdict == "equivalent"
    assert compare(BASE, _with_bounds(-5, 10)).verdict == "widened"
    assert compare(BASE, _with_bounds(2, 10)).verdict == "narrowed"
    assert compare(_with_bounds(0, 5), _with_bounds(2, 10)).verdict == "incomparable"


def test_subsumption_unknown_on_unmodeled_keywords():
    old = {"type": "string", "pattern": "^a+$"}
    new = {"type": "string", "pattern": "^a*$"}
    assert compare(old, new).verdict in ("unknown", "widened")


# ---------------------------------------------------------------------------
# registry swap semantics
# ---------------------------------------------------------------------------


def test_equivalent_swap_is_metadata_only_noop():
    reg = SchemaRegistry(use_pallas=False)
    e1 = reg.register("ep", BASE)
    gen = reg.generation
    group1 = reg.group_of("ep")
    validator1 = None if group1 is None else group1.validator
    # reordered keys + added prose: proven equivalent
    variant = {
        "properties": {"a": {"maximum": 10, "minimum": 0, "type": "integer"}},
        "required": ["a"],
        "type": "object",
        "title": "same shape",
    }
    e2 = reg.register("ep", variant)
    assert e2 is e1  # the serving entry, not a new version
    assert reg.generation == gen  # no relink, no jit discard
    assert reg.swap_verdicts()["ep"] == "equivalent"
    group2 = reg.group_of("ep")
    assert group2 is group1  # group object survived
    if validator1 is not None:
        assert group2.validator is validator1


def test_widened_swap_warns_and_counts():
    reg = SchemaRegistry(use_pallas=False)
    reg.register("ep", _with_bounds(0, 10))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        e2 = reg.register("ep", _with_bounds(-5, 10))
    assert any(issubclass(w.category, WidenedSwapWarning) for w in caught)
    assert e2.version == 2  # the swap itself proceeds
    assert reg.swap_verdicts()["ep"] == "widened"
    assert e2.stats.subsumption == "widened"
    counter = reg.metrics.counter(
        "registry_swap_widened_total",
        "hot-swaps proven to accept strictly more instances",
        endpoint="ep",
    )
    assert counter.value >= 1


def test_narrowed_swap_proceeds_silently():
    reg = SchemaRegistry(use_pallas=False)
    reg.register("ep", _with_bounds(0, 10))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        e2 = reg.register("ep", _with_bounds(2, 10))
    assert not any(issubclass(w.category, WidenedSwapWarning) for w in caught)
    assert e2.version == 2
    assert reg.swap_verdicts()["ep"] == "narrowed"


def test_analysis_off_pins_legacy_behavior():
    reg = SchemaRegistry(use_pallas=False, analysis=False)
    e1 = reg.register("ep", BASE)
    gen = reg.generation
    e2 = reg.register("ep", dict(BASE, title="not a verbatim match"))
    assert e2.version == e1.version + 1  # no proof machinery, real swap
    assert reg.generation > gen
    assert reg.swap_verdicts() == {}


# ---------------------------------------------------------------------------
# structural dedup of linked segments
# ---------------------------------------------------------------------------


def test_linked_segment_dedup():
    reg = SchemaRegistry(use_pallas=False)
    a = {"type": "object", "properties": {"x": {"type": "string"}}, "required": ["x"]}
    b = {
        "required": ["x"],
        "properties": {"x": {"type": "string"}},
        "type": "object",
        "description": "same shape, different prose",
    }
    reg.register("dup_a", a)
    entry_b = reg.register("dup_b", b)
    assert entry_b.stats.dedup_subgraphs >= 1
    (group,) = reg.groups()
    assert group.members == ("dup_a", "dup_b")
    assert group.linked_members == ("dup_a",)  # one physical segment
    assert group.member_index == {"dup_a": 0, "dup_b": 0}
    # both endpoints validate correctly through the shared segment
    verdicts, counts = reg.admit_mixed(
        [{"x": "hi"}, {"x": "yo"}, {}, {"x": 1}],
        ["dup_a", "dup_b", "dup_b", "dup_b"],
    )
    assert verdicts == [True, True, False, False]
    assert counts.batch_validated == 4
    reg2 = SchemaRegistry(use_pallas=False, dedup_links=False)
    reg2.register("dup_a", a)
    reg2.register("dup_b", b)
    (group2,) = reg2.groups()
    assert group2.linked_members == ("dup_a", "dup_b")  # opt-out keeps both


def test_dedup_does_not_merge_distinct_schemas():
    reg = SchemaRegistry(use_pallas=False)
    reg.register("p", {"type": "object", "properties": {"x": {"type": "string"}}})
    reg.register(
        "q", {"type": "object", "properties": {"x": {"type": "string", "minLength": 2}}}
    )
    for g in reg.groups():
        assert g.linked_members == g.members


# ---------------------------------------------------------------------------
# unroll sizing
# ---------------------------------------------------------------------------

RECURSIVE = {
    "$defs": {
        "node": {
            "type": "object",
            "properties": {"v": {"type": "integer"}, "next": {"$ref": "#/$defs/node"}},
            "required": ["v"],
        }
    },
    "$ref": "#/$defs/node",
}


def test_unroll_recommendation_and_overrides(monkeypatch):
    compiled = compile_schema(RECURSIVE)
    rec = recommend_unroll_depth(compiled)
    assert rec >= 1
    # flat schema: recommendation is the default
    flat = compile_schema({"type": "object", "properties": {"a": {"type": "integer"}}})
    from repro.core.tape import DEFAULT_UNROLL_DEPTH

    assert recommend_unroll_depth(flat) == DEFAULT_UNROLL_DEPTH

    # auto mode picks the recommendation
    reg = SchemaRegistry(use_pallas=False)
    entry = reg.register("rec", RECURSIVE)
    assert entry.stats.unroll_depth == rec

    # env override wins over the recommendation
    monkeypatch.setenv("REPRO_UNROLL_DEPTH", "2")
    reg2 = SchemaRegistry(use_pallas=False)
    entry2 = reg2.register("rec", RECURSIVE)
    assert entry2.stats.unroll_depth == 2
    monkeypatch.delenv("REPRO_UNROLL_DEPTH")

    # explicit constructor kwarg pins hardest
    reg3 = SchemaRegistry(use_pallas=False, unroll_depth=3)
    entry3 = reg3.register("rec", RECURSIVE)
    assert entry3.stats.unroll_depth == 3


# ---------------------------------------------------------------------------
# posture surfaces
# ---------------------------------------------------------------------------


def test_endpoint_stats_surfaces_analysis_posture():
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_config("granite-3-8b").reduced()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    eng = ServeEngine(
        cfg, params, ServeConfig(batch_slots=2, max_len=64, default_max_tokens=4)
    )
    eng.register_endpoint(
        "ep",
        {
            "type": "object",
            "required": ["a"],
            "properties": {"a": {"type": "integer", "minimum": 0}},
            "anyOf": [{"type": "object"}, {"type": "string", "minLength": 9, "maxLength": 1}],
        },
    )
    eng.register_endpoint(
        "ep",
        {
            "type": "object",
            "required": ["a"],
            "properties": {"a": {"type": "integer", "minimum": -1}},
        },
    )
    per = eng.endpoint_stats()["ep"]
    assert per["analysis_normalized"] is True or per["pruned_branches"] >= 0
    assert "folded_assertions" in per and "dedup_subgraphs" in per
    assert per["last_swap_subsumption"] in (
        "widened",
        "unknown",
        "incomparable",
        "narrowed",
        "equivalent",
    )


def test_analysis_report_builds_clean():
    from repro.analysis.report import build_report

    report = build_report()
    assert report["lint_failures"] == []
    assert set(report["endpoints"]) == {
        "chat",
        "complete",
        "embed",
        "moderate",
        "charge",
    }
    assert report["totals"]["folded_assertions"] >= 1
