"""Failure-trace diagnostics (paper §8's error-message option)."""

from repro.core import Validator, compile_schema

SCHEMA = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "type": "object",
    "required": ["name"],
    "additionalProperties": False,
    "properties": {
        "name": {"type": "string", "minLength": 2},
        "age": {"type": "integer", "minimum": 0},
        "tags": {"type": "array", "items": {"type": "string"}},
    },
}


def _validator():
    return Validator(compile_schema(SCHEMA))


class TestExplain:
    def test_valid_document_empty_trace(self):
        ok, trace = _validator().explain({"name": "bob", "age": 3})
        assert ok and trace == []

    def test_missing_required_points_at_required(self):
        ok, trace = _validator().explain({"age": 3})
        assert not ok
        assert any("required" in path for path, _ in trace), trace

    def test_minimum_failure_points_at_keyword(self):
        ok, trace = _validator().explain({"name": "bob", "age": -1})
        assert not ok
        paths = [p for p, _ in trace]
        assert any("age" in p for p in paths), trace

    def test_nested_item_failure(self):
        ok, trace = _validator().explain({"name": "bob", "tags": ["a", 1]})
        assert not ok
        assert any("items" in p or "tags" in p for p, _ in trace), trace

    def test_trace_does_not_leak_into_hot_path(self):
        v = _validator()
        v.explain({"age": 3})
        assert v.ctx.trace is None
        assert v.is_valid({"name": "ok"}) is True

    def test_explain_agrees_with_is_valid(self):
        v = _validator()
        docs = [
            {"name": "bob"}, {"age": 1}, {"name": "x"}, 5, [],
            {"name": "bob", "zzz": 1}, {"name": "bob", "tags": []},
        ]
        for d in docs:
            ok, trace = v.explain(d)
            assert ok == v.is_valid(d), d
            assert ok == (trace == []) or not ok, d
