"""Property-based differential testing: the compiled executor and the naive
interpreter are two independent implementations of the same spec -- on any
(schema, document) pair they must agree (Blaze §3.5 correctness argument).
"""

import math

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core import CompilerOptions, NaiveValidator, Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.tape import try_build_tape
from repro.data.doc_table import encode_batch

# ---------------------------------------------------------------------------
# Random JSON documents
# ---------------------------------------------------------------------------

json_scalars = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(min_value=-(2**31), max_value=2**31),
    st.floats(allow_nan=False, allow_infinity=False, width=32),
    st.text(max_size=12),
    st.text(alphabet="abxy-_ .$/~", max_size=40),
)

json_docs = st.recursive(
    json_scalars,
    lambda children: st.one_of(
        st.lists(children, max_size=5),
        st.dictionaries(st.text(max_size=8), children, max_size=5),
        st.dictionaries(
            st.sampled_from(["a", "b", "kind", "name", "value", "x-e", "S_1", "tags"]),
            children,
            max_size=6,
        ),
    ),
    max_leaves=20,
)

# ---------------------------------------------------------------------------
# Random JSON Schemas (bounded depth, drawn from realistic keyword templates)
# ---------------------------------------------------------------------------

_key_names = st.sampled_from(["a", "b", "kind", "name", "value", "x-e", "S_1", "tags"])
_types = st.sampled_from(
    ["string", "integer", "number", "boolean", "null", "array", "object"]
)
_patterns = st.sampled_from(
    [".*", ".+", "^x-", "^.{2,4}$", "a", "^S_", "b.b", "^foo$", "-x$", "[0-9]+"]
)


def _schemas(depth: int):
    leaf = st.one_of(
        st.builds(lambda t: {"type": t}, _types),
        st.builds(lambda t, u: {"type": [t, u]}, _types, _types),
        st.builds(lambda n: {"minimum": n}, st.integers(-5, 5)),
        st.builds(lambda n: {"maximum": n}, st.integers(-5, 5)),
        st.builds(lambda n: {"exclusiveMinimum": n}, st.integers(-5, 5)),
        st.builds(lambda n: {"multipleOf": n}, st.sampled_from([1, 2, 0.5, 3])),
        st.builds(lambda n: {"minLength": n}, st.integers(0, 5)),
        st.builds(lambda n: {"maxLength": n}, st.integers(0, 8)),
        st.builds(lambda p: {"pattern": p}, _patterns),
        st.builds(lambda v: {"const": v}, json_scalars),
        st.builds(lambda v: {"enum": v}, st.lists(json_scalars, min_size=1, max_size=4)),
        st.builds(lambda n: {"minItems": n}, st.integers(0, 3)),
        st.builds(lambda n: {"maxItems": n}, st.integers(0, 4)),
        st.just({"uniqueItems": True}),
        st.builds(lambda ks: {"required": ks}, st.lists(_key_names, max_size=3, unique=True)),
        st.builds(lambda n: {"minProperties": n}, st.integers(0, 3)),
        st.builds(lambda n: {"maxProperties": n}, st.integers(0, 4)),
        st.builds(lambda p: {"propertyNames": {"pattern": p}}, _patterns),
        st.just(True),
        st.just(False),
    )
    if depth <= 0:
        return leaf
    sub = _schemas(depth - 1)
    composite = st.one_of(
        leaf,
        st.builds(
            lambda props: {"properties": props},
            st.dictionaries(_key_names, sub, min_size=1, max_size=4),
        ),
        st.builds(
            lambda props, closed: {"properties": props, "additionalProperties": closed},
            st.dictionaries(_key_names, sub, min_size=1, max_size=3),
            st.one_of(st.booleans(), sub),
        ),
        st.builds(lambda p, s: {"patternProperties": {p: s}}, _patterns, sub),
        st.builds(lambda s: {"items": s}, sub),
        st.builds(
            lambda pre, tail: {"prefixItems": pre, "items": tail},
            st.lists(sub, min_size=1, max_size=3),
            st.one_of(st.booleans(), sub),
        ),
        st.builds(lambda s: {"contains": s}, sub),
        st.builds(
            lambda s, lo, hi: {"contains": s, "minContains": lo, "maxContains": hi},
            sub,
            st.integers(0, 2),
            st.integers(2, 4),
        ),
        st.builds(lambda xs: {"allOf": xs}, st.lists(sub, min_size=1, max_size=3)),
        st.builds(lambda xs: {"anyOf": xs}, st.lists(sub, min_size=1, max_size=3)),
        st.builds(lambda xs: {"oneOf": xs}, st.lists(sub, min_size=1, max_size=3)),
        st.builds(lambda s: {"not": s}, sub),
        st.builds(
            lambda i, t, e: {"if": i, "then": t, "else": e}, sub, sub, sub
        ),
        st.builds(
            lambda k, s: {"dependentSchemas": {k: s}}, _key_names, sub
        ),
        st.builds(
            lambda k, ks: {"dependentRequired": {k: ks}},
            _key_names,
            st.lists(_key_names, max_size=2),
        ),
        st.builds(
            lambda props, s: {"properties": props, "unevaluatedProperties": s},
            st.dictionaries(_key_names, sub, max_size=3),
            st.one_of(st.booleans(), sub),
        ),
        st.builds(
            lambda branches, s: {"anyOf": branches, "unevaluatedProperties": s},
            st.lists(
                st.builds(
                    lambda props, req: {"properties": props, "required": req},
                    st.dictionaries(_key_names, sub, min_size=1, max_size=2),
                    st.lists(_key_names, max_size=1),
                ),
                min_size=1,
                max_size=2,
            ),
            st.one_of(st.booleans(), sub),
        ),
        st.builds(
            lambda pre, s: {"prefixItems": pre, "unevaluatedItems": s},
            st.lists(sub, min_size=1, max_size=2),
            st.one_of(st.booleans(), sub),
        ),
    )
    return composite


def _maybe_wrap_in_ref(s):
    """Hoist some schemas behind a root-level $defs reference (valid refs
    are root-relative, so this wrapper only appears at the top level)."""
    if not isinstance(s, dict):
        return s
    return {
        "$defs": {"node": s},
        "allOf": [{"$ref": "#/$defs/node"}],
    }


schemas = st.one_of(
    _schemas(2),
    _schemas(2).map(_maybe_wrap_in_ref),
).map(
    lambda s: {"$schema": "https://json-schema.org/draft/2020-12/schema", **s}
    if isinstance(s, dict)
    else s
)


@settings(max_examples=400, deadline=None)
@given(schema=schemas, doc=json_docs)
def test_compiled_matches_interpreter(schema, doc):
    compiled = Validator(compile_schema(schema))
    naive = NaiveValidator(schema)
    assert compiled.is_valid(doc) is naive.is_valid(doc), (schema, doc)


@settings(max_examples=150, deadline=None)
@given(schema=schemas, doc=json_docs)
def test_optimizations_preserve_semantics(schema, doc):
    """Fully-optimized vs fully-unoptimized compilation must agree."""
    fast = Validator(compile_schema(schema))
    slow = Validator(
        compile_schema(
            schema,
            options=CompilerOptions(
                unroll=False, regex_specialize=False, reorder=False, cisc=False, elide=False
            ),
        ),
        use_hashing=False,
    )
    assert fast.is_valid(doc) is slow.is_valid(doc), (schema, doc)


@settings(max_examples=150, deadline=None)
@given(doc=json_docs)
def test_empty_schema_accepts_everything(doc):
    assert Validator(compile_schema(True)).is_valid(doc)
    assert Validator(compile_schema({})).is_valid(doc)


@settings(max_examples=50, deadline=None)
@given(doc=json_docs)
def test_false_schema_rejects_everything(doc):
    assert not Validator(compile_schema(False)).is_valid(doc)


@settings(max_examples=40, deadline=None)
@given(schema=schemas, docs=st.lists(json_docs, min_size=1, max_size=4))
def test_failure_sites_match_sequential_trace(schema, docs):
    """Differential attribution, not just verdicts (DESIGN.md §12): on
    every decided-invalid document the batched ``explain_batch`` site must
    name a schema location the sequential trace also blames.  Schemas the
    tape compiler cannot batch are skipped -- the seeded fuzzers in
    test_logical_circuit/test_recursive_unroll cover their own streams."""
    compiled = compile_schema(schema)
    tape, _ = try_build_tape(compiled)
    if tape is None:
        return
    seq = Validator(compiled)
    table = encode_batch(docs, max_nodes=64, max_depth=8)
    bv = BatchValidator(tape, max_depth=8, use_pallas=False)
    valid, decided = bv.validate(table)
    invalid = [i for i in range(len(docs)) if decided[i] and not valid[i]]
    if not invalid:
        return
    sites = bv.explain_batch(table, docs=docs)
    for i in invalid:
        site = sites[i]
        assert site is not None, (schema, docs[i])
        ok, trace = seq.explain(docs[i])
        assert not ok, (schema, docs[i])
        assert site.schema_path in {p for p, _ in trace}, (
            schema, docs[i], site, trace
        )
