"""Registry + tape-linker coverage: relocation invariants, mixed-schema
differential fuzz vs the sequential oracle (in the style of
test_batch_csr.py), versioning/eviction/hot-swap, and multi-tenant
admission through the pipeline."""

import random

import numpy as np
import pytest

from repro.core import ValidationOutcome, Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.tape import build_tape, try_build_tape
from repro.data.doc_table import encode_batch
from repro.data.pipeline import AdmissionController
from repro.registry import (
    SchemaRegistry,
    group_signature,
    link_tapes,
    pow2_class,
    segment_tape,
    signature_label,
)
from repro.serve.faults import FaultInjector

from test_batch_csr import _rand_doc, _rand_schema

S1 = {
    "type": "object",
    "required": ["name"],
    "additionalProperties": False,
    "properties": {
        "name": {"type": "string", "minLength": 1},
        "age": {"type": "integer", "minimum": 0},
    },
}
S2 = {
    "type": "object",
    "required": ["name"],
    "properties": {
        "name": {"enum": ["a", "b"]},
        "kind": {"enum": ["x", "y", 3]},
        "tags": {"type": "array", "items": {"type": "string"}, "maxItems": 3},
    },
}
S3 = {
    "type": "object",
    "properties": {
        "x": {"type": "number", "maximum": 10},
        "nested": {
            "type": "object",
            "properties": {"name": {"const": 5}, "deep": {"properties": {"q": {"const": 1}}}},
        },
    },
}
SCHEMAS = [S1, S2, S3]


def _tapes():
    return [build_tape(compile_schema(s)) for s in SCHEMAS]


class TestRelocationInvariants:
    def test_windows_stay_contiguous_and_owner_sorted(self):
        tapes = _tapes()
        linked = link_tapes(tapes, names=["s1", "s2", "s3"])
        owners = linked.asrt_owner
        real = owners >= 0
        assert (np.diff(owners[real]) >= 0).all(), "global owner sort must survive linking"
        for loc in range(linked.n_locations):
            s, n = int(linked.loc_asrt_start[loc]), int(linked.loc_asrt_len[loc])
            assert (owners[s : s + n] == loc).all()
            assert n <= linked.max_rows_per_loc
        assert int(linked.loc_asrt_len.max()) == linked.max_rows_per_loc

    def test_member_windows_relocate_verbatim(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        for m, tape in enumerate(tapes):
            lo = int(linked.loc_offsets[m])
            for loc in range(tape.n_locations):
                ms, n = int(tape.loc_asrt_start[loc]), int(tape.loc_asrt_len[loc])
                ls = int(linked.loc_asrt_start[lo + loc])
                assert int(linked.loc_asrt_len[lo + loc]) == n
                sl, msl = slice(ls, ls + n), slice(ms, ms + n)
                np.testing.assert_array_equal(linked.asrt_op[sl], tape.asrt_op[msl])
                np.testing.assert_array_equal(linked.asrt_f0[sl], tape.asrt_f0[msl])
                np.testing.assert_array_equal(linked.asrt_i0[sl], tape.asrt_i0[msl])
                np.testing.assert_array_equal(linked.asrt_hash[sl], tape.asrt_hash[msl])
                # group structure is preserved up to the per-member offset
                grp_l, grp_m = linked.asrt_group[sl], tape.asrt_group[msl]
                np.testing.assert_array_equal(grp_l > 0, grp_m > 0)
                nz = grp_m > 0
                if nz.any():
                    off = grp_l[nz] - grp_m[nz]
                    assert len(set(off.tolist())) == 1 and off[0] >= 0

    def test_or_group_ids_globally_unique(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        seen = {}
        for m, tape in enumerate(tapes):
            ao = int(linked.asrt_offsets[m])
            n = np.count_nonzero(tape.asrt_owner >= 0)
            for g in linked.asrt_group[ao : ao + n]:
                if g > 0:
                    assert seen.setdefault(int(g), m) == m, "group id spans members"

    def test_psort_runs_never_span_members(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        assert (np.diff(linked.psort_member) >= 0).all(), "member segments must be contiguous"
        h, member = linked.psort_hash, linked.psort_member
        for r in range(1, linked.n_props):
            if member[r] == member[r - 1] and (h[r] == h[r - 1]).all():
                assert linked.psort_run_len[r] == linked.psort_run_len[r - 1] > 1
        # runs are intact within members: every run's rows share one member
        run_start = 0
        while run_start < linked.n_props:
            run_len = max(1, int(linked.psort_run_len[run_start]))
            run = member[run_start : run_start + run_len]
            assert (run == run[0]).all()
            run_start += run_len

    def test_member_prop_segments_cover_psort(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        starts, lens = linked.member_prop_start, linked.member_prop_len
        assert int(starts[0]) == 0
        for m in range(1, linked.n_members):
            assert starts[m] == starts[m - 1] + lens[m - 1]
        assert int(starts[-1] + lens[-1]) == linked.n_props
        assert linked.max_member_props == int(lens.max())
        for m in range(linked.n_members):
            seg = linked.psort_member[starts[m] : starts[m] + lens[m]]
            assert (seg == m).all()

    def test_constants_are_member_maxima(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        assert linked.max_rows_per_loc == max(t.max_rows_per_loc for t in tapes)
        assert linked.max_hash_run == max(t.max_hash_run for t in tapes)
        assert linked.max_loc_depth == max(t.max_loc_depth for t in tapes)
        np.testing.assert_array_equal(
            linked.member_horizons, [t.max_loc_depth + 1 for t in tapes]
        )
        np.testing.assert_array_equal(linked.roots, linked.loc_offsets)
        assert linked.n_locations == sum(t.n_locations for t in tapes)
        assert linked.n_members == len(tapes)

    def test_member_of_location(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        for m, tape in enumerate(tapes):
            lo = int(linked.loc_offsets[m])
            assert linked.member_of_location(lo) == m
            assert linked.member_of_location(lo + tape.n_locations - 1) == m
        with pytest.raises(IndexError):
            linked.member_of_location(linked.n_locations)

    def test_location_tables_relocate(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        for m, tape in enumerate(tapes):
            lo = int(linked.loc_offsets[m])
            sl = slice(lo, lo + tape.n_locations)
            np.testing.assert_array_equal(linked.loc_closed[sl], tape.loc_closed)
            np.testing.assert_array_equal(
                linked.loc_required_mask[sl], tape.loc_required_mask
            )
            reloc = np.where(tape.loc_addl >= 0, tape.loc_addl + lo, tape.loc_addl)
            np.testing.assert_array_equal(linked.loc_addl[sl], reloc)
            reloc = np.where(tape.loc_item >= 0, tape.loc_item + lo, tape.loc_item)
            np.testing.assert_array_equal(linked.loc_item[sl], reloc)

    def test_single_member_link_roundtrips(self):
        tape = _tapes()[0]
        linked = link_tapes([tape], names=["only"])
        np.testing.assert_array_equal(linked.asrt_op, tape.asrt_op)
        np.testing.assert_array_equal(linked.psort_hash, tape.psort_hash)
        np.testing.assert_array_equal(linked.roots, [0])
        docs = [{"name": "x"}, {"name": ""}, {}]
        table = encode_batch(docs, max_nodes=32)
        v1, d1 = BatchValidator(tape, use_pallas=False).validate(table)
        v2, d2 = BatchValidator(linked, use_pallas=False).validate(table)
        np.testing.assert_array_equal(v1, v2)
        np.testing.assert_array_equal(d1, d2)


class TestMixedBatchDifferential:
    def test_directed_mixed_batch(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        docs = [
            {"name": "x", "age": 3}, {"name": "", "age": 3}, {"name": "x", "bogus": 1},
            {"name": "a", "kind": 3}, {"name": "c"}, {"name": "a", "tags": ["q", 1]},
            {"x": 5}, {"x": 50}, {"nested": {"name": 5}}, {"nested": {"name": 6}},
        ]
        ids = np.array([0, 0, 0, 1, 1, 1, 2, 2, 2, 2], np.int32)
        table = encode_batch(docs, max_nodes=32)
        seqs = [Validator(compile_schema(s)) for s in SCHEMAS]
        for layout in ("csr", "dense"):
            bv = BatchValidator(linked, use_pallas=False, layout=layout)
            valid, decided = bv.validate(table, ids)
            assert decided.all()
            for i, d in enumerate(docs):
                assert bool(valid[i]) == seqs[ids[i]].is_valid(d), (layout, d)

    def test_fuzz_mixed_vs_sequential_and_per_schema(self):
        rng = random.Random(0x11C8)
        linked_batches = 0
        trial = 0
        while linked_batches < 12 and trial < 120:
            trial += 1
            members, tapes, seqs = [], [], []
            for _ in range(rng.randint(2, 4)):
                schema = _rand_schema(rng, 3)
                compiled = compile_schema(schema)
                tape, _ = try_build_tape(compiled)
                if tape is not None:
                    members.append(schema)
                    tapes.append(tape)
                    seqs.append(Validator(compiled))
            if len(tapes) < 2:
                continue
            linked_batches += 1
            linked = link_tapes(tapes)
            docs = [_rand_doc(rng, 3) for _ in range(rng.randint(2, 8))]
            ids = np.array(
                [rng.randrange(len(tapes)) for _ in docs], np.int32
            )
            table = encode_batch(docs, max_nodes=64, max_depth=8)
            bv = BatchValidator(linked, max_depth=8, use_pallas=False)
            valid, decided = bv.validate(table, ids)
            # (1) bit-identical to per-schema single-tape dispatch
            for m in range(len(tapes)):
                idx = [i for i in range(len(docs)) if ids[i] == m]
                if not idx:
                    continue
                sub = encode_batch([docs[i] for i in idx], max_nodes=64, max_depth=8)
                v, d = BatchValidator(tapes[m], max_depth=8, use_pallas=False).validate(sub)
                np.testing.assert_array_equal(v, valid[idx], err_msg=repr(members[m]))
                np.testing.assert_array_equal(d, decided[idx], err_msg=repr(members[m]))
            # (2) decided rows match the sequential oracle
            for i, (v, d) in enumerate(zip(valid, decided)):
                if d:
                    assert bool(v) == seqs[ids[i]].is_valid(docs[i]), (
                        members[ids[i]], docs[i],
                    )
        assert linked_batches >= 12

    def test_linked_pallas_matches_jnp(self):
        tapes = _tapes()
        linked = link_tapes(tapes)
        docs = [{"name": "x", "age": 1}, {"name": "a"}, {"x": 3}, {"name": ""}]
        ids = np.array([0, 1, 2, 0], np.int32)
        table = encode_batch(docs, max_nodes=32)
        v1, d1 = BatchValidator(linked, use_pallas=False).validate(table, ids)
        v2, d2 = BatchValidator(linked, use_pallas=True).validate(table, ids)
        np.testing.assert_array_equal(v1, v2)
        np.testing.assert_array_equal(d1, d2)

    def test_mixed_depth_budget_stays_per_member(self):
        deep = {"properties": {"a": {"properties": {"a": {"properties": {
            "a": {"properties": {"a": {"const": 1}}}}}}}}}
        shallow = {"properties": {"a": {"const": 1}}}
        t_deep = build_tape(compile_schema(deep))
        t_shallow = build_tape(compile_schema(shallow))
        linked = link_tapes([t_deep, t_shallow], names=["deep", "shallow"])
        docs = [
            {"a": {"a": {"a": {"a": 1}}}},  # deep member, below the budget
            {"a": 1},                        # deep member, shallow doc
            {"a": {"a": {"a": {"a": 1}}}},  # shallow member, deep doc
            {"a": 1},                        # shallow member
        ]
        ids = np.array([0, 0, 1, 1], np.int32)
        table = encode_batch(docs, max_nodes=32, max_depth=16)
        bv = BatchValidator(linked, max_depth=3, use_pallas=False)
        valid, decided = bv.validate(table, ids)
        # bit-identity with per-member dispatch: the deep member's horizon
        # exceeds the budget only for docs that actually reach below it;
        # the shallow member's docs stay statically decided
        bv_deep = BatchValidator(t_deep, max_depth=3, use_pallas=False)
        v_d, d_d = bv_deep.validate(encode_batch(docs[:2], max_nodes=32, max_depth=16))
        bv_sh = BatchValidator(t_shallow, max_depth=3, use_pallas=False)
        v_s, d_s = bv_sh.validate(encode_batch(docs[2:], max_nodes=32, max_depth=16))
        np.testing.assert_array_equal(decided, np.concatenate([d_d, d_s]))
        np.testing.assert_array_equal(valid[decided], np.concatenate([v_d, v_s])[decided])
        assert decided.tolist() == [False, True, True, True]


class TestSchemaRegistry:
    def test_register_version_evict(self):
        reg = SchemaRegistry()
        e1 = reg.register("users", S1)
        assert (e1.version, reg.versions("users")) == (1, [1])
        e2 = reg.register("users", S2)
        assert (e2.version, reg.versions("users")) == (2, [1, 2])
        assert reg.get("users").version == 2
        assert reg.get("users", version=1) is e1
        reg.evict("users", version=2)  # roll back to v1
        assert reg.get("users") is e1
        reg.evict("users")
        assert "users" not in reg.endpoints()
        with pytest.raises(KeyError):
            reg.get("users")

    def test_compile_stats_recorded(self):
        reg = SchemaRegistry()
        entry = reg.register("s2", S2)
        st = entry.stats
        assert st.batchable and st.n_locations > 0 and st.n_assertions > 0
        assert st.a_hat == entry.tape.max_rows_per_loc
        assert st.k == entry.tape.max_hash_run
        assert st.horizon == entry.tape.max_loc_depth + 1
        assert st.compile_seconds >= 0 and st.instruction_count > 0
        # logical applicators are batchable now (circuits); uniqueItems
        # still is not -- keep a genuinely sequential-only member here
        bad = reg.register("seq-only", {"uniqueItems": True})
        assert not bad.stats.batchable and bad.stats.fallback_reason
        union = reg.register("union", {"anyOf": [{"type": "string"}, {"minimum": 0}]})
        assert union.stats.batchable and union.stats.n_circuits >= 3

    def test_incremental_relink_reuses_segments(self):
        reg = SchemaRegistry()
        reg.register("a", S1)
        assert reg.linked_tape() is not None
        seg_a = reg._segments[("a", 1)]
        gen = reg.generation
        reg.register("b", S2)
        assert reg.generation > gen
        linked = reg.linked_tape()  # lazy re-link on access
        assert list(linked.members) == ["a", "b"]
        assert reg._segments[("a", 1)] is seg_a, "unchanged member must re-link from cache"
        # linked state is cached per generation
        assert reg.linked_tape() is linked

    def test_register_snapshots_schema_by_value(self):
        reg = SchemaRegistry()
        s = {"properties": {"v": {"type": "integer"}}}
        reg.register("ep", s)
        s["properties"]["v"]["type"] = "string"  # caller mutates in place
        e2 = reg.register("ep", s)  # must be a real new version, not a no-op
        assert e2.version == 2
        assert reg.get("ep").validator.is_valid({"v": "x"})
        assert not reg.get("ep").validator.is_valid({"v": 1})

    def test_versions_survive_full_eviction(self):
        # version numbers must be monotonic per endpoint forever: a
        # re-registered endpoint reusing (endpoint, 1) would collide with
        # the cached linked-state signature and serve the OLD schema
        reg = SchemaRegistry()
        reg.register("a", S1)
        reg.register("b", {"properties": {"y": {"type": "integer", "minimum": 100}}})
        reg.batch_validator()  # cache the linked state for (a,1),(b,1)
        reg.evict("b")
        e = reg.register("b", {"properties": {"y": {"type": "integer", "maximum": 0}}})
        assert e.version == 2  # not a reused version 1
        table = encode_batch([{"y": 5}], max_nodes=16)
        valid, decided = reg.validate_mixed(table, ["b"])
        assert decided[0] and not valid[0]  # new schema serves, not stale tape

    def test_admit_mixed_splits_oversize_from_undecided(self):
        deep = {"properties": {"a": {"properties": {"a": {"properties": {
            "a": {"properties": {"a": {"const": 1}}}}}}}}}
        ctrl = AdmissionController(deep, max_depth=3, batch_max_nodes=8)
        big = {"k%d" % i: i for i in range(20)}  # > 8 nodes: encoder budget
        oks = ctrl.admit([{"a": {"a": {"a": {"a": 1}}}}, big, {"a": 1}])
        assert oks == [True, True, True]
        assert ctrl.stats.undecided == 1  # the deep doc (depth budget)
        assert ctrl.stats.oversize == 1  # the wide doc (encoder budget)
        assert ctrl.stats.batch_validated == 1

    def test_noop_generation_bumps_keep_jitted_validator(self):
        reg = SchemaRegistry()
        reg.register("a", S1)
        reg.register("a", S2)  # v2 serves
        bv = reg.batch_validator()
        assert bv is not None
        # none of these change the batchable serving membership: the
        # jitted linked validator must survive (no recompile stall)
        reg.evict("a", version=1)  # non-serving version
        assert reg.batch_validator() is bv
        reg.register("slow", {"uniqueItems": True})  # sequential-only
        assert reg.batch_validator() is bv
        reg.evict("slow")
        assert reg.batch_validator() is bv
        entry = reg.register("a", S2)  # identical serving schema: no-op
        assert entry.version == 2 and reg.batch_validator() is bv
        reg.register("a", S3)  # real hot-swap -> re-link
        assert reg.batch_validator() is not bv

    def test_hot_swap_changes_verdicts_without_stalling_members(self):
        reg = SchemaRegistry()
        reg.register("a", S1)
        reg.register("b", {"properties": {"v": {"type": "integer"}}})
        docs = [{"v": 3}, {"v": "s"}]
        table = encode_batch(docs, max_nodes=16)
        valid, decided = reg.validate_mixed(table, ["b", "b"])
        assert decided.all() and valid.tolist() == [True, False]
        seg_a = reg._segments[("a", 1)]
        reg.register("b", {"properties": {"v": {"type": "string"}}})  # v2
        valid, decided = reg.validate_mixed(table, ["b", "b"])
        assert decided.all() and valid.tolist() == [False, True]
        assert reg._segments[("a", 1)] is seg_a

    def test_validate_mixed_routes_unbatchable_to_fallback(self):
        reg = SchemaRegistry()
        reg.register("fast", S1)
        reg.register("slow", {"uniqueItems": True})  # sequential-only
        docs = [{"name": "x"}, 42, {"name": ""}]
        endpoints = ["fast", "slow", "fast"]
        table = encode_batch(docs, max_nodes=16)
        valid, decided = reg.validate_mixed(table, endpoints)
        assert decided.tolist() == [True, False, True]
        assert valid[0] and not valid[2]
        assert np.array_equal(reg.schema_ids(endpoints), [0, -1, 0])
        # the caller's routing contract
        verdict = [
            bool(v) if d else reg.get(e).validator.is_valid(doc)
            for v, d, e, doc in zip(valid, decided, endpoints, docs)
        ]
        assert verdict == [True, True, False]  # 42 is not an array -> uniqueItems passes

    def test_validate_mixed_rejects_unknown_endpoint(self):
        reg = SchemaRegistry()
        reg.register("a", S1)
        table = encode_batch([{}], max_nodes=16)
        with pytest.raises(KeyError):
            reg.validate_mixed(table, ["nope"])

    def test_registry_without_batchable_members(self):
        reg = SchemaRegistry()
        reg.register("slow", {"uniqueItems": True})
        assert reg.linked_tape() is None and reg.batch_validator() is None
        table = encode_batch([1], max_nodes=16)
        valid, decided = reg.validate_mixed(table, ["slow"])
        assert not decided[0]


class TestMultiTenantAdmission:
    def test_admission_with_registry_and_endpoints(self):
        reg = SchemaRegistry()
        reg.register("u", S1)
        reg.register("t", S2)
        ctrl = AdmissionController(registry=reg, endpoint="u")
        records = [{"name": "x"}, {"name": "c"}, {"name": "a"}, {"name": ""}]
        endpoints = ["u", "t", "t", "u"]
        oks = ctrl.admit(records, endpoints)
        assert oks == [True, False, True, False]
        assert ctrl.stats.batch_validated == 4
        assert ctrl.stats.fallback_validated == 0
        assert ctrl.stats.admitted == 2 and ctrl.stats.rejected == 2

    def test_undecided_counter_observes_depth_fallbacks(self):
        deep = {"properties": {"a": {"properties": {"a": {"properties": {
            "a": {"properties": {"a": {"const": 1}}}}}}}}}
        ctrl = AdmissionController(deep, max_depth=3)
        oks = ctrl.admit([{"a": {"a": {"a": {"a": 1}}}}, {"a": 1}])
        assert oks == [True, True]
        assert ctrl.stats.undecided == 1
        assert ctrl.stats.fallback_validated == 1
        assert ctrl.stats.batch_validated == 1

    def test_use_pallas_and_layout_kwargs_exposed(self):
        ctrl = AdmissionController(S1, use_pallas=False, layout="dense")
        assert ctrl.registry.layout == "dense"
        assert ctrl.batch_validator is not None
        assert ctrl.batch_validator.layout == "dense"
        assert ctrl.batch_validator.use_pallas is False
        oks = ctrl.admit([{"name": "x"}, {"name": ""}])
        assert oks == [True, False]


# ---------------------------------------------------------------------------
# Link groups (DESIGN.md §14): Â-compatible partition of the registry
# ---------------------------------------------------------------------------


def _grouped_registry():
    reg = SchemaRegistry(use_pallas=False)
    reg.register("s1", S1)
    reg.register("s2", S2)
    reg.register("s3", S3)
    return reg


def _group_docs(n, seed=0):
    """Deterministic (docs, endpoints) mix spanning all three groups."""
    rng = random.Random(seed)
    pool = [
        ("s1", {"name": "x", "age": 3}),
        ("s1", {"name": "", "age": -1}),  # invalid
        ("s2", {"name": "a", "kind": "x", "tags": ["t"]}),
        ("s2", {"name": "z"}),  # invalid: enum
        ("s3", {"x": 3.5, "nested": {"name": 5}}),
        ("s3", {"x": 99}),  # invalid: maximum
    ]
    picks = [pool[rng.randrange(len(pool))] for _ in range(n)]
    return [d for _, d in picks], [e for e, _ in picks]


class TestLinkGroups:
    def test_pow2_class_and_labels(self):
        assert [pow2_class(x) for x in (1, 2, 3, 4, 5, 8, 9)] == [
            1, 2, 4, 4, 8, 8, 16,
        ]
        assert signature_label((2, 8, 4)) == "a2.m8.h4"

    def test_partition_keys_on_tape_signatures(self):
        reg = _grouped_registry()
        groups = reg.groups()
        # S1/S2/S3 have pairwise-distinct pow2 signatures -> 3 groups
        assert {g.members for g in groups} == {("s1",), ("s2",), ("s3",)}
        for g in groups:
            for m in g.members:
                assert group_signature(reg.get(m).tape) == g.key
                assert reg.group_of(m) is g
                assert g.member_index[m] < len(g.members)
            assert g.label == signature_label(g.key)

    def test_partition_is_order_independent(self):
        a = _grouped_registry()
        b = SchemaRegistry(use_pallas=False)
        for name, schema in (("s3", S3), ("s1", S1), ("s2", S2)):
            b.register(name, schema)
        assert {g.label: set(g.members) for g in a.groups()} == {
            g.label: set(g.members) for g in b.groups()
        }

    def test_link_grouping_false_is_single_group(self):
        reg = SchemaRegistry(use_pallas=False, link_grouping=False)
        reg.register("s1", S1)
        reg.register("s2", S2)
        (g,) = reg.groups()
        assert g.label == "all" and set(g.members) == {"s1", "s2"}

    def test_group_windows_stay_member_local(self):
        """The §8 inflation fix: a fat member in its own group no longer
        widens a lean group's launch windows (charge-style regression)."""
        reg = _grouped_registry()
        stats = reg.group_stats()
        lean = stats[signature_label(group_signature(reg.get("s1").tape))]
        t1 = reg.get("s1").tape
        assert lean["a_hat"] == int(t1.max_rows_per_loc)
        assert lean["horizon"] == int(t1.max_loc_depth) + 1
        # the flat (union) layout pays the fattest member's windows
        flat = SchemaRegistry(use_pallas=False, link_grouping=False)
        for name, schema in (("s1", S1), ("s2", S2), ("s3", S3)):
            flat.register(name, schema)
        union = flat.group_stats()["all"]
        assert union["m_hat"] > lean["m_hat"]
        assert union["horizon"] > lean["horizon"]

    def test_grouped_vs_flat_bit_identity(self):
        grouped = _grouped_registry()
        flat = SchemaRegistry(use_pallas=False, link_grouping=False)
        for name, schema in (("s1", S1), ("s2", S2), ("s3", S3)):
            flat.register(name, schema)
        docs, endpoints = _group_docs(96, seed=7)
        vg, cg = grouped.admit_mixed_ex(docs, endpoints)
        vf, cf = flat.admit_mixed_ex(docs, endpoints)
        assert [(v.outcome, v.valid) for v in vg] == [
            (v.outcome, v.valid) for v in vf
        ]
        assert cg.batch_validated == cf.batch_validated

    def test_unrelated_swap_keeps_other_groups_jitted(self):
        reg = _grouped_registry()
        v1 = reg.group_of("s1").validator
        reg.register("s2", S2)  # identical serving schema: no-op bump
        assert reg.group_of("s1").validator is v1
        assert reg.group_of("s2").validator is v1 or True  # own group free
        # real hot-swap of s2 relinks ONLY s2's group
        v3 = reg.group_of("s3").validator
        reg.register("s2", {"properties": {"q": {"const": 1}}})
        assert reg.group_of("s1").validator is v1
        assert reg.group_of("s3").validator is v3

    def test_per_group_fallback_attribution(self):
        reg = _grouped_registry()
        docs, endpoints = _group_docs(32, seed=3)
        label_of = {e: reg.group_of(e).label for e in ("s1", "s2", "s3")}
        # poison one row belonging to s1's group only (keys default to
        # row indices in admit_mixed_ex)
        victim = endpoints.index("s1")
        inj = FaultInjector(seed=1).poison("launch", victim)
        with inj:
            verdicts, counts = reg.admit_mixed_ex(docs, endpoints)
        assert verdicts[victim].outcome is ValidationOutcome.ERROR_ISOLATED
        hit = label_of["s1"]
        assert counts.per_group[hit]["error_isolated"] == 1
        for lbl in set(label_of.values()) - {hit}:
            assert counts.per_group.get(lbl, {}).get("error_isolated", 0) == 0
        assert reg.group_fallbacks()[hit]["error_isolated"] == 1
        assert reg.group_stats()[hit]["fallbacks"]["error_isolated"] == 1

    def test_per_group_counts_partition_batch_validated(self):
        reg = _grouped_registry()
        docs, endpoints = _group_docs(48, seed=11)
        _, counts = reg.admit_mixed_ex(docs, endpoints)
        total = sum(
            per["batch_validated"] for per in counts.per_group.values()
        )
        assert total == counts.batch_validated > 0

    def test_warm_groups_pretraces_pow2_shapes(self):
        reg = _grouped_registry()
        traced = reg.warm_groups([1, 3], max_nodes=64)
        assert traced == len(reg.groups()) * 2  # buckets 1 and 4, per group
        assert reg.warm_groups([1, 3], max_nodes=64) == 0  # idempotent
        for g in reg.groups():
            shapes = g.validator.seen_shapes()
            assert (1, 64) in shapes and (4, 64) in shapes
