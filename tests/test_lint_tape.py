"""Tape-invariant linter (DESIGN.md §15): clean tapes lint clean, and
each class of deliberate corruption is caught.

The whole tier-1 suite additionally runs with ``REPRO_LINT_TAPES=1``
(tests/conftest.py), so every ``build_tape``/``link_tapes`` call in any
test asserts these invariants implicitly; this file is the directed
positive/negative coverage.
"""

import copy

import numpy as np
import pytest

from repro.analysis.lint_tape import TapeLintError, assert_tape, lint_tape
from repro.core import compile_schema
from repro.core.tape import build_tape
from repro.registry.linker import link_tapes
from repro.registry.presets import GATEWAY_SCHEMAS
from repro.registry.registry import SchemaRegistry

RECURSIVE = {
    "$defs": {
        "node": {
            "type": "object",
            "properties": {"v": {"type": "integer"}, "next": {"$ref": "#/$defs/node"}},
            "required": ["v"],
        }
    },
    "$ref": "#/$defs/node",
}


def _tape(schema, **kw):
    return build_tape(compile_schema(schema), **kw)


# ---------------------------------------------------------------------------
# clean tapes lint clean
# ---------------------------------------------------------------------------


def test_presets_and_groups_lint_clean():
    reg = SchemaRegistry(use_pallas=False)
    for name, schema in GATEWAY_SCHEMAS.items():
        reg.register(name, schema)
    for name in GATEWAY_SCHEMAS:
        entry = reg.get(name)
        if entry.tape is not None:
            assert lint_tape(entry.tape) == [], name
    for g in reg.groups():
        assert lint_tape(g.tape) == [], g.label
    legacy = reg.linked_tape()
    if legacy is not None:
        assert lint_tape(legacy) == []


def test_recursive_frontier_tape_lints_clean():
    tape = _tape(RECURSIVE, unroll_depth=2)
    assert tape.n_frontier >= 1
    assert lint_tape(tape) == []
    linked = link_tapes([tape, _tape({"type": "object"})], names=["rec", "flat"])
    assert lint_tape(linked) == []


def test_assert_tape_raises_with_label():
    tape = _tape({"type": "object", "properties": {"a": {"type": "integer"}}})
    assert_tape(tape, label="ok-case")  # no raise
    bad = copy.deepcopy(tape)
    bad.loc_asrt_len[0] += 1
    with pytest.raises(TapeLintError) as ei:
        assert_tape(bad, label="bad-case")
    assert "bad-case" in str(ei.value)


# ---------------------------------------------------------------------------
# each corruption class is caught
# ---------------------------------------------------------------------------


def _charge_tape():
    # charge has circuits (oneOf tagged union) and several locations
    return _tape(GATEWAY_SCHEMAS["charge"])


def test_catches_csr_window_shift():
    bad = copy.deepcopy(_charge_tape())
    assert bad.n_locations >= 3
    bad.loc_asrt_start[2] += 1
    assert any("csr" in p for p in lint_tape(bad))


def test_catches_psort_order_break():
    bad = copy.deepcopy(_charge_tape())
    assert len(bad.psort_hash) >= 2
    # swap two adjacent psort lanes without touching the originals:
    # breaks either the lex-sort or the permutation/run bookkeeping
    for f in ("psort_hash", "psort_owner", "psort_orig_row"):
        arr = getattr(bad, f)
        arr[0], arr[1] = arr[1].copy(), arr[0].copy()
    assert lint_tape(bad) != []


def test_catches_psort_not_a_permutation():
    bad = copy.deepcopy(_charge_tape())
    bad.psort_orig_row[0] = bad.psort_orig_row[1]
    assert any("psort" in p for p in lint_tape(bad))


def test_catches_edge_into_frontier():
    tape = _tape(RECURSIVE, unroll_depth=2)
    frontier = np.flatnonzero(tape.loc_frontier)
    assert frontier.size >= 1
    bad = copy.deepcopy(tape)
    real = np.flatnonzero(bad.prop_owner >= 0)
    # retarget a property transition at a frontier location
    row = int(real[0])
    bad.prop_child_loc[row] = int(frontier[0])
    bad.psort_child_loc[np.flatnonzero(bad.psort_orig_row == row)[0]] = int(
        frontier[0]
    )
    assert any("frontier" in p for p in lint_tape(bad))


def test_catches_backward_edge():
    bad = copy.deepcopy(_charge_tape())
    real = np.flatnonzero((bad.prop_owner >= 0) & (bad.prop_child_loc >= 0))
    if real.size == 0:
        pytest.skip("no child transitions in this tape")
    row = int(real[0])
    bad.prop_child_loc[row] = 0  # child must be > owner; root never is
    bad.psort_child_loc[np.flatnonzero(bad.psort_orig_row == row)[0]] = 0
    assert lint_tape(bad) != []


def test_catches_circuit_level_break():
    bad = copy.deepcopy(_charge_tape())
    assert bad.n_circuits >= 1
    bad.circ_level[0] += 1
    assert any("circ" in p for p in lint_tape(bad))


def test_catches_circuit_parent_order_break():
    bad = copy.deepcopy(_charge_tape())
    if bad.n_circuits < 2:
        pytest.skip("need >=2 circuits")
    bad.circ_parent[0] = bad.n_circuits - 1  # parent must come first
    assert any("circ" in p for p in lint_tape(bad))


def test_catches_linked_offset_inconsistency():
    tapes = [
        _tape({"type": "object", "properties": {"a": {"type": "integer"}}}),
        _tape({"type": "object", "properties": {"b": {"type": "string"}}}),
    ]
    linked = link_tapes(tapes, names=["m0", "m1"])
    assert lint_tape(linked) == []
    bad = copy.deepcopy(linked)
    bad.loc_offsets[1] += 1
    assert any("linked" in p or "offset" in p for p in lint_tape(bad))
    bad2 = copy.deepcopy(linked)
    bad2.member_horizons[0] += 1
    assert lint_tape(bad2) != []


def test_catches_required_mask_drift():
    tape = _tape(
        {
            "type": "object",
            "required": ["a"],
            "properties": {"a": {"type": "integer"}},
        }
    )
    bad = copy.deepcopy(tape)
    owners = np.flatnonzero(bad.loc_required_mask != 0)
    assert owners.size >= 1
    bad.loc_required_mask[int(owners[0])] |= 1 << 30  # slot no row backs
    assert any("required" in p for p in lint_tape(bad))


def test_cli_clean_on_presets(capsys):
    from repro.analysis.lint_tape import main

    assert main(["--presets", "-q"]) == 0
    out = capsys.readouterr().out
    assert "clean" in out
