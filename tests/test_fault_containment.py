"""Chaos/differential suite for the fault-containment layer (DESIGN.md §11).

Every degradation invariant the serving stack promises is asserted here
under *injected*, seeded, deterministic faults:

- poison isolation: a batch with injected encode/launch faults returns
  ERROR_ISOLATED for exactly the poisoned rows and bit-identical
  verdicts for every other row, at batch sizes {64, 512, 4096};
- stats reconciliation: every received document lands in exactly one
  outcome class;
- the deadline-bounded fallback: depth bombs, step bombs, and
  backtracking-prone patterns return TIMED_OUT promptly;
- the circuit breaker trips and recovers deterministically (stub clock);
- hot-swap rollback: a failed registration never reaches serving.
"""

import json
import os
import time

import numpy as np
import pytest

from repro.core import (
    BreakerConfig,
    CircuitBreaker,
    DocumentDepthError,
    GuardLimits,
    ValidationBudget,
    ValidationOutcome,
    ValidationTimeout,
    Validator,
    compile_schema,
    resource_guard,
)
from repro.core.regex_opt import analyze_pattern
from repro.registry import RegistrationError, SchemaRegistry
from repro.serve.faults import FaultInjector, InjectedFault

SCHEMA = {
    "type": "object",
    "required": ["a"],
    "additionalProperties": False,
    "properties": {
        "a": {"type": "integer", "minimum": 0},
        "b": {"type": "string", "minLength": 1},
    },
}

OUTCOME_FIELDS = (
    "batch_validated",
    "fallback_validated",
    "rejected_guard",
    "error_isolated",
    "timed_out",
    "breaker_open",
)


def _docs(n, seed=0):
    """Deterministic valid/invalid mix for endpoint SCHEMA."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        r = rng.integers(0, 4)
        if r == 0:
            out.append({"a": int(rng.integers(0, 100))})
        elif r == 1:
            out.append({"a": int(rng.integers(0, 100)), "b": "x" * int(rng.integers(1, 5))})
        elif r == 2:
            out.append({"a": -1})  # invalid: minimum
        else:
            out.append({"b": ""})  # invalid: required + minLength
    return out


def _sum_outcomes(counts):
    return sum(getattr(counts, f) for f in OUTCOME_FIELDS)


@pytest.fixture(scope="module")
def registry():
    reg = SchemaRegistry()
    reg.register("t", SCHEMA)
    return reg


class Clock:
    """Deterministic injectable clock for breaker/deadline tests."""

    def __init__(self):
        self.t = 0.0

    def advance(self, dt):
        self.t += dt

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# Poison isolation (encode + launch) at {64, 512, 4096}
# ---------------------------------------------------------------------------


class TestPoisonIsolation:
    @pytest.mark.parametrize("B", [64, 512])
    def test_encode_poison_isolated(self, registry, B):
        self._check_point(registry, B, "encode")

    @pytest.mark.parametrize("B", [64, 512])
    def test_launch_poison_isolated(self, registry, B):
        self._check_point(registry, B, "launch")

    @pytest.mark.slow
    @pytest.mark.chaos
    @pytest.mark.parametrize("point", ["encode", "launch"])
    def test_poison_isolated_4096(self, registry, point):
        self._check_point(registry, 4096, point)

    @staticmethod
    def _check_point(registry, B, point):
        docs = _docs(B, seed=B)
        endpoints = ["t"] * B
        clean, clean_counts = registry.admit_mixed_ex(docs, endpoints)
        assert _sum_outcomes(clean_counts) == B
        poison = sorted({0, B // 3, B // 2, B - 1})
        inj = FaultInjector(seed=B).poison(point, *poison)
        with inj:
            got, counts = registry.admit_mixed_ex(docs, endpoints)
        assert inj.fired.get(point, 0) > 0
        assert _sum_outcomes(counts) == B
        assert counts.error_isolated == len(poison)
        for i in range(B):
            if i in poison:
                assert got[i].outcome is ValidationOutcome.ERROR_ISOLATED
                assert "injected" in got[i].reason
            else:
                # bit-identical to the poison-free run
                assert got[i].outcome is clean[i].outcome, i
                assert got[i].valid == clean[i].valid, i

    def test_rate_poison_is_deterministic(self, registry):
        docs = _docs(128, seed=9)
        endpoints = ["t"] * 128
        runs = []
        for _ in range(2):
            with FaultInjector(seed=3).rate("encode", 0.05) as inj:
                got, counts = registry.admit_mixed_ex(docs, endpoints)
            runs.append(([v.outcome for v in got], counts.error_isolated, dict(inj.fired)))
        assert runs[0] == runs[1]
        assert runs[0][1] > 0  # 5% of 128 rows should hit at least once

    def test_fallback_fault_isolated(self, registry):
        # tiny encode budget forces every row onto the sequential
        # fallback; poisoned rows are isolated there too
        docs = _docs(32, seed=5)
        endpoints = ["t"] * 32
        clean, _ = registry.admit_mixed_ex(docs, endpoints, max_nodes=1)
        with FaultInjector().poison("fallback", 7, 20):
            got, counts = registry.admit_mixed_ex(docs, endpoints, max_nodes=1)
        assert counts.batch_validated == 0
        assert counts.error_isolated == 2
        assert _sum_outcomes(counts) == 32
        for i in range(32):
            if i in (7, 20):
                assert got[i].outcome is ValidationOutcome.ERROR_ISOLATED
            else:
                assert (got[i].outcome, got[i].valid) == (clean[i].outcome, clean[i].valid)


# ---------------------------------------------------------------------------
# Admission guards + stats reconciliation
# ---------------------------------------------------------------------------


class TestGuardsAndReconciliation:
    def test_resource_guard_reasons(self):
        limits = GuardLimits(max_depth=4, max_nodes=10)
        deep = [[[[[1]]]]]
        assert "depth" in resource_guard(deep, limits)
        assert "nodes" in resource_guard(list(range(50)), limits)
        assert resource_guard({"a": 1}, limits) == ""

    def test_guard_rejects_before_encode(self):
        reg = SchemaRegistry(guard=GuardLimits(max_depth=4))
        reg.register("t", SCHEMA)
        bomb = {"a": 1}
        node = bomb
        for _ in range(10):
            node["x"] = {}
            node = node["x"]
        # an encode fault on the bomb's row never fires: guards run first
        with FaultInjector().poison("encode", 1) as inj:
            got, counts = reg.admit_mixed_ex([{"a": 1}, bomb], ["t", "t"])
        assert inj.fired.get("encode", 0) == 0
        assert got[0].outcome is ValidationOutcome.ADMITTED
        assert got[1].outcome is ValidationOutcome.REJECTED_GUARD
        assert "depth" in got[1].reason
        assert counts.rejected_guard == 1
        assert _sum_outcomes(counts) == 2

    def test_mixed_stream_reconciles(self, registry):
        docs = _docs(60, seed=11)
        docs[3] = [[[x] for x in range(2)]]  # valid JSON, invalid vs schema
        endpoints = ["t"] * len(docs)
        with FaultInjector(seed=1).rate("encode", 0.08).rate("fallback", 0.5):
            got, counts = registry.admit_mixed_ex(docs, endpoints, max_nodes=8)
        assert _sum_outcomes(counts) == len(docs)
        per_outcome = {}
        for v in got:
            per_outcome[v.outcome] = per_outcome.get(v.outcome, 0) + 1
        assert per_outcome.get(ValidationOutcome.ERROR_ISOLATED, 0) == counts.error_isolated
        assert (
            per_outcome.get(ValidationOutcome.ADMITTED, 0)
            + per_outcome.get(ValidationOutcome.INVALID, 0)
            == counts.batch_validated + counts.fallback_validated
        )


# ---------------------------------------------------------------------------
# Bounded fallback: step budget, wall clock, depth bombs, risky patterns
# ---------------------------------------------------------------------------


class TestBoundedFallback:
    def test_step_budget_times_out_fast(self):
        reg = SchemaRegistry(fallback_max_steps=500, fallback_deadline_s=None)
        reg.register("arr", {"type": "array", "items": {"type": "integer"}})
        big = list(range(10_000))
        t0 = time.perf_counter()
        v = reg.validate_one("arr", big)
        assert time.perf_counter() - t0 < 2.0
        assert v.outcome is ValidationOutcome.TIMED_OUT
        assert "budget" in v.reason

    def test_wall_clock_deadline(self):
        reg = SchemaRegistry(fallback_deadline_s=0.02, guard=GuardLimits(max_nodes=1 << 20))
        reg.register("arr", {"type": "array", "items": {"type": "integer", "minimum": 0}})
        big = list(range(400_000))
        t0 = time.perf_counter()
        v = reg.validate_one("arr", big)
        assert time.perf_counter() - t0 < 2.0
        assert v.outcome is ValidationOutcome.TIMED_OUT

    def test_depth_bomb_structured(self):
        # no guard: the bomb reaches the parser, which must reject in a
        # structured way (TIMED_OUT) rather than blowing the stack
        reg = SchemaRegistry(guard=GuardLimits(max_depth=1 << 20, max_nodes=1 << 20))
        reg.register("t", SCHEMA)
        bomb = 0
        for _ in range(50_000):
            bomb = [bomb]
        v = reg.validate_one("t", bomb)
        assert v.outcome is ValidationOutcome.TIMED_OUT

    def test_executor_depth_guard(self):
        # satellite: the sequential executor raises a structured error,
        # never RecursionError, on hostile nesting
        validator = Validator(compile_schema({"type": "object"}))
        bomb = 0
        for _ in range(50_000):
            bomb = [bomb]
        with pytest.raises(DocumentDepthError):
            validator.is_valid(bomb)

    def test_risky_pattern_classification(self):
        assert analyze_pattern("(a+)+$").risky
        assert analyze_pattern("^(\\d*)*x").risky
        assert not analyze_pattern("^x-").risky
        assert not analyze_pattern("^[a-z]{1,10}$").risky

    def test_risky_pattern_times_out(self):
        reg = SchemaRegistry()
        reg.register("p", {"type": "string", "pattern": "(a+)+$"})
        subject = "a" * 28 + "!"
        t0 = time.perf_counter()
        v = reg.validate_one("p", subject)
        assert time.perf_counter() - t0 < 1.0
        assert v.outcome is ValidationOutcome.TIMED_OUT
        assert "backtracking" in v.reason

    def test_unbounded_path_unchanged(self):
        # the clean (unbounded) executor still runs engine regexes,
        # risky or not -- containment applies only under a budget
        validator = Validator(compile_schema({"type": "string", "pattern": "(a+)+$"}))
        assert validator.is_valid("aaa")


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------


class TestCircuitBreaker:
    def test_unit_transitions(self):
        clock = Clock()
        b = CircuitBreaker(BreakerConfig(threshold=2, cooldown_s=10.0), clock=clock)
        assert b.allow()
        b.record_timeout()
        assert b.state == "closed" and b.allow()
        b.record_timeout()  # second consecutive -> trip
        assert b.state == "open" and not b.allow()
        clock.advance(9.0)
        assert not b.allow()
        clock.advance(1.5)
        assert b.allow()  # half-open probe
        assert b.state == "half_open"
        assert not b.allow()  # only one probe per window
        b.record_timeout()  # probe failed -> re-open
        assert b.state == "open" and b.trips == 2
        clock.advance(10.5)
        assert b.allow()
        b.record_success()
        assert b.state == "closed" and b.allow()

    def test_trips_and_recovers_through_registry(self):
        clock = Clock()
        reg = SchemaRegistry(
            fallback_max_steps=4,
            fallback_deadline_s=None,
            breaker=BreakerConfig(threshold=3, cooldown_s=30.0),
            clock=clock,
        )
        reg.register("t", SCHEMA)
        slow_doc = {"a": 1, "b": "x"}  # needs > 4 instructions
        for _ in range(3):
            v = reg.validate_one("t", slow_doc)
            assert v.outcome is ValidationOutcome.TIMED_OUT
        assert reg.breaker("t").state == "open"
        v = reg.validate_one("t", slow_doc)
        assert v.outcome is ValidationOutcome.UNDECIDED_FALLBACK
        assert "circuit open" in v.reason
        clock.advance(31.0)
        # half-open probe: an in-budget verdict (fail-fast type check)
        # closes the breaker again
        v = reg.validate_one("t", 5)
        assert v.outcome is ValidationOutcome.INVALID
        assert reg.breaker("t").state == "closed"
        v = reg.validate_one("t", 6)
        assert v.outcome is ValidationOutcome.INVALID

    def test_probe_timeout_reopens(self):
        clock = Clock()
        reg = SchemaRegistry(
            fallback_max_steps=4,
            fallback_deadline_s=None,
            breaker=BreakerConfig(threshold=2, cooldown_s=5.0),
            clock=clock,
        )
        reg.register("t", SCHEMA)
        slow_doc = {"a": 1, "b": "x"}
        for _ in range(2):
            reg.validate_one("t", slow_doc)
        assert reg.breaker("t").state == "open"
        clock.advance(5.5)
        v = reg.validate_one("t", slow_doc)  # probe times out again
        assert v.outcome is ValidationOutcome.TIMED_OUT
        assert reg.breaker("t").state == "open"
        assert reg.breaker("t").trips == 2


# ---------------------------------------------------------------------------
# Hot-swap safety
# ---------------------------------------------------------------------------


class TestHotSwap:
    def test_injected_link_fault_rolls_back(self):
        reg = SchemaRegistry()
        entry = reg.register("ep", SCHEMA)
        assert entry.version == 1
        new_schema = dict(SCHEMA, required=["a", "b"])
        with FaultInjector().poison("link", "ep"):
            with pytest.raises(RegistrationError, match="version 1 keeps serving"):
                reg.register("ep", new_schema)
        assert reg.get("ep").version == 1
        assert reg.get("ep").schema == SCHEMA
        assert "link" in reg.swap_failures()["ep"]
        # prior version still serves traffic
        got, _ = reg.admit_mixed_ex([{"a": 1}], ["ep"])
        assert got[0].outcome is ValidationOutcome.ADMITTED
        # a later clean swap succeeds and clears the failure record
        entry = reg.register("ep", new_schema)
        assert entry.version == 2
        assert "ep" not in reg.swap_failures()

    def test_build_failure_rolls_back(self):
        reg = SchemaRegistry()
        reg.register("ep", SCHEMA)
        bad = {"type": "string", "pattern": "("}  # invalid regex: build fails
        with pytest.raises(RegistrationError):
            reg.register("ep", bad)
        assert reg.get("ep").version == 1
        assert "build" in reg.swap_failures()["ep"]

    def test_first_registration_failure_raises(self):
        reg = SchemaRegistry()
        with pytest.raises(RegistrationError):
            reg.register("fresh", {"type": "string", "pattern": "("})
        assert "fresh" not in reg

    def test_smoke_verify_runs_probes(self):
        # well-formed schemas pass verification and register normally
        reg = SchemaRegistry()
        entry = reg.register("ok", {"type": "object", "required": ["x"]})
        assert entry.version == 1
        # verify="off" also works (no probes)
        entry = reg.register("ok2", SCHEMA, verify="off")
        assert entry.version == 1


# ---------------------------------------------------------------------------
# Serving engine: structured outcomes, payload hygiene, rollback surfacing
# ---------------------------------------------------------------------------


class TestServeEngineContainment:
    @pytest.fixture(scope="class")
    def engine(self):
        import jax

        from repro.configs import get_config
        from repro.models import Model
        from repro.serve.engine import ServeConfig, ServeEngine

        cfg = get_config("granite-3-8b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return ServeEngine(
            cfg, params, ServeConfig(batch_slots=2, max_len=64, default_max_tokens=4)
        )

    def test_submit_result_back_compat(self, engine):
        rid, err = engine.submit(json.dumps({"prompt": "hello"}))
        assert rid is not None and err == ""
        res = engine.submit(json.dumps({"prompt": ""}))
        assert res == (None, "schema validation failed")  # still a 2-tuple
        assert res.outcome is ValidationOutcome.INVALID

    def test_non_object_payloads_never_raise(self, engine):
        # satellite: non-dict JSON top-levels flow through the normal
        # validator verdict (REQUEST_SCHEMA wants an object -> INVALID)
        for payload in ('"5"', "5", "[]", "null", "true", "[1, 2]"):
            res = engine.submit(payload)
            assert res.request_id is None
            assert res.outcome is ValidationOutcome.INVALID, payload
        # on an open schema they are admitted (validation-only requests)
        engine.register_endpoint("open", {})
        res = engine.submit("[]", endpoint="open")
        assert res.request_id is not None
        assert res.outcome is ValidationOutcome.ADMITTED
        batch = engine.submit_batch([("open", "5"), ("open", '"x"')])
        assert all(r.request_id is not None for r in batch)

    def test_payload_guards(self, engine):
        res = engine.submit("[" * 200_000)  # deep + malformed
        assert res.request_id is None
        assert res.outcome is ValidationOutcome.REJECTED_GUARD
        huge = '{"prompt": "' + "x" * (engine.registry.guard.max_bytes + 16) + '"}'
        res = engine.submit(huge)
        assert res.outcome is ValidationOutcome.REJECTED_GUARD
        assert "guard cap" in res.error

    def test_outcomes_reconcile_with_received(self, engine):
        stats = engine.stats
        assert stats.received == sum(stats.outcomes.values())
        batch = engine.submit_batch(
            [
                ("default", json.dumps({"prompt": "ok"})),
                ("default", "{broken"),
                ("nosuch", "{}"),
                ("default", json.dumps({"prompt": ""})),
            ]
        )
        assert [r.outcome for r in batch] == [
            ValidationOutcome.ADMITTED,
            ValidationOutcome.REJECTED_GUARD,
            ValidationOutcome.REJECTED_GUARD,
            ValidationOutcome.INVALID,
        ]
        assert stats.received == sum(stats.outcomes.values())

    def test_hot_swap_rollback_surfaced(self, engine):
        good = engine.registry.get("default")
        entry = engine.register_endpoint("default", {"type": "string", "pattern": "("})
        assert entry.version == good.version  # prior version kept serving
        per = engine.endpoint_stats()["default"]
        assert per["version"] == good.version
        assert per["last_swap_error"].startswith("build:")
        rid, err = engine.submit(json.dumps({"prompt": "still serving"}))
        assert rid is not None, err


# ---------------------------------------------------------------------------
# Randomized poison-mix stress (the CI chaos step runs this for ~30 s)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
def test_randomized_poison_mix_stress(registry):
    """Seeded random traffic + poison mixes; every iteration re-asserts
    the isolation and reconciliation invariants.  Runtime is controlled
    by CHAOS_STRESS_SECONDS (default: a quick local smoke)."""
    budget_s = float(os.environ.get("CHAOS_STRESS_SECONDS", "2"))
    deadline = time.monotonic() + budget_s
    seed = 0
    iterations = 0
    while True:
        seed += 1
        docs = _docs(64, seed=seed)
        endpoints = ["t"] * 64
        clean, _ = registry.admit_mixed_ex(docs, endpoints)
        rng = np.random.default_rng(seed)
        rate = float(rng.uniform(0.01, 0.10))
        point = ["encode", "launch", "fallback"][seed % 3]
        with FaultInjector(seed=seed).rate(point, rate):
            got, counts = registry.admit_mixed_ex(docs, endpoints)
        assert _sum_outcomes(counts) == 64, f"seed {seed}: counters leak"
        for i in range(64):
            if got[i].outcome is ValidationOutcome.ERROR_ISOLATED:
                continue
            assert got[i].outcome is clean[i].outcome, f"seed {seed} row {i}"
            assert got[i].valid == clean[i].valid, f"seed {seed} row {i}"
        iterations += 1
        if time.monotonic() >= deadline:
            break
    assert iterations >= 1
