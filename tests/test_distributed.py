"""Distribution tests: sharding rules, activation constraints, gradient
compression, and a reduced multi-device dry-run.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count`` (the flag must be set
before the first jax init, and the main test process already initialised
jax single-device)."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

# every test here spawns a fresh python + jax subprocess (the
# XLA_FLAGS device-count flag must precede jax init): minutes, not
# seconds -- deselect locally with -m "not slow"
pytestmark = pytest.mark.slow

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=540,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardingRules:
    def test_param_specs_resolve(self):
        code = """
        import jax
        from repro.configs import get_config
        from repro.models import Model
        from repro.sharding import param_pspecs
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ("granite-3-8b", "jamba-1.5-large-398b", "arctic-480b", "rwkv6-3b"):
            cfg = get_config(arch).reduced()
            aparams = jax.eval_shape(lambda k: Model(cfg).init(k), jax.random.PRNGKey(0))
            specs = param_pspecs(aparams, mesh)
            names = set()
            for leaf, spec in zip(jax.tree.leaves(aparams), jax.tree.leaves(specs)):
                for dim, axis in enumerate(spec):
                    if axis is None: continue
                    size = 1
                    for a in (axis if isinstance(axis, tuple) else (axis,)):
                        size *= mesh.shape[a]
                    assert leaf.shape[dim] % size == 0, (arch, leaf.shape, spec)
                    names.add(axis if isinstance(axis, str) else axis[0])
            assert "model" in names, arch  # TP actually engaged
        print("OK")
        """
        assert "OK" in _run_subprocess(code)

    def test_sharded_train_step_runs(self):
        """A real sharded train step executes on 8 virtual devices and the
        loss matches the single-device step."""
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import Model
        from repro.sharding import shard_params
        from repro.train import optimizer as opt
        from repro.train.train_step import make_train_step
        cfg = get_config("granite-3-8b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
        ref_loss = float(model.loss(params, tokens, tokens, remat=False))

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ocfg = opt.OptimizerConfig()
        step, (psh, osh, bsh), _ = make_train_step(model, ocfg, mesh, batch=8, donate=False)
        params_s = jax.device_put(params, psh)
        opt_s = jax.device_put(opt.init(ocfg, params), osh)
        batch = jax.device_put({"tokens": tokens, "labels": tokens}, bsh)
        new_p, new_o, metrics = step(params_s, opt_s, batch)
        got = float(metrics["loss"])
        assert abs(got - ref_loss) / ref_loss < 0.05, (got, ref_loss)
        assert int(new_o.step) == 1
        print("OK", got, ref_loss)
        """
        assert "OK" in _run_subprocess(code)

    def test_compressed_psum_matches_mean(self):
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.train.train_step import compressed_psum
        mesh = jax.make_mesh((8,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        def f(xs):
            return compressed_psum({"g": xs}, "pod")["g"]
        out = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod", None),
                                out_specs=P("pod", None), check_rep=False))(x)
        expected = np.sum(np.asarray(x), axis=0)
        got = np.asarray(out)[0]
        err = np.abs(got - expected).max() / (np.abs(expected).max() + 1e-9)
        assert err < 0.02, err  # int8 quantization error bound
        print("OK", err)
        """
        assert "OK" in _run_subprocess(code)

    def test_dp_compressed_train_step(self):
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import Model
        from repro.train import optimizer as opt
        from repro.train.train_step import make_dp_compressed_step
        cfg = get_config("phi4-mini-3.8b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ocfg = opt.OptimizerConfig()
        mesh = jax.make_mesh((4,), ("pod",))
        step = make_dp_compressed_step(model, ocfg, mesh)
        opt_state = opt.init(ocfg, params)
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
        p, s, err, metrics = step(params, opt_state, err, tokens, tokens)
        assert np.isfinite(float(metrics["loss"]))
        # error-feedback buffers are populated after a compressed step
        total_err = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(err))
        assert total_err > 0
        print("OK", float(metrics["loss"]))
        """
        assert "OK" in _run_subprocess(code)


class TestDryRunReduced:
    """The dry-run machinery itself, on a small virtual mesh (the full
    512-device sweep runs via `python -m repro.launch.dryrun --all`)."""

    def test_lower_compile_reduced_mesh(self):
        code = """
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_config
        from repro.models import Model
        from repro.train import optimizer as opt
        from repro.train.train_step import make_train_step, make_decode_step
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_config("granite-3-8b").reduced()
        model = Model(cfg)
        ocfg = opt.OptimizerConfig()
        step, _, _ = make_train_step(model, ocfg, mesh, batch=8)
        aparams = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
        aopt = jax.eval_shape(lambda p: opt.init(ocfg, p), aparams)
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }
        compiled = step.lower(aparams, aopt, batch).compile()
        assert compiled.memory_analysis() is not None
        dstep, _, _ = make_decode_step(model, mesh, batch=8, max_len=64)
        acache = jax.eval_shape(lambda: model.init_cache(8, 64))
        compiled2 = dstep.lower(
            aparams, jax.ShapeDtypeStruct((8, 1), jnp.int32), acache,
            jax.ShapeDtypeStruct((), jnp.int32)).compile()
        txt = compiled.as_text()
        assert any(op in txt for op in ("all-reduce", "all-gather", "reduce-scatter"))
        print("OK")
        """
        assert "OK" in _run_subprocess(code)

    def test_hlo_analysis_trip_counts(self):
        code = """
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import analyze_hlo
        w = jnp.ones((128, 128), jnp.float32)
        x = jnp.ones((64, 128), jnp.float32)
        def scanned(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=12)
            return out
        comp = jax.jit(scanned).lower(x, w).compile()
        ha = analyze_hlo(comp.as_text())
        expected = 2 * 64 * 128 * 128 * 12
        assert abs(ha.dot_flops - expected) / expected < 0.01, (ha.dot_flops, expected)
        assert 12 in ha.while_trip_counts
        print("OK")
        """
        assert "OK" in _run_subprocess(code, devices=1)
