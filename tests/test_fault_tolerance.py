"""Fault-tolerance drills: checkpoint/restart, NaN rollback, transient
retry, straggler detection, elastic re-mesh (restore onto a different
sharding)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Model
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.supervisor import (
    SupervisorConfig,
    TrainSupervisor,
    _InjectedFault,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = opt.OptimizerConfig(warmup_steps=2, total_steps=50)
    opt_state = opt.init(ocfg, params)

    def step_fn(p, s, batch):
        def loss_fn(pp):
            return model.loss(pp, batch["tokens"], batch["labels"], remat=False)

        loss, grads = jax.value_and_grad(loss_fn)(p)
        new_p, new_s, m = opt.update(ocfg, grads, s, p)
        return new_p, new_s, dict(m, loss=loss)

    def batch(i):
        rng = np.random.default_rng(i)
        t = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)
        return {"tokens": jnp.asarray(t), "labels": jnp.asarray(t)}

    return cfg, model, params, opt_state, step_fn, batch


class TestCheckpoint:
    def test_roundtrip(self, setup, tmp_path):
        _, _, params, opt_state, _, _ = setup
        mgr = CheckpointManager(tmp_path, async_save=False)
        mgr.save(7, {"params": params, "opt_state": opt_state})
        assert mgr.latest_step() == 7
        step, restored = mgr.restore({"params": params, "opt_state": opt_state})
        assert step == 7
        for a, b in zip(jax.tree.leaves(restored["params"]), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_publish_and_gc(self, setup, tmp_path):
        _, _, params, _, _, _ = setup
        mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            mgr.save(s, {"p": params["final_norm"]})
        steps = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(steps) == 2 and steps[-1].endswith("4".zfill(10))
        assert mgr.latest_step() == 4

    def test_corruption_detected(self, setup, tmp_path):
        _, _, params, _, _, _ = setup
        mgr = CheckpointManager(tmp_path, async_save=False)
        mgr.save(1, {"p": params["final_norm"]})
        # leaves are .bin.zst with zstandard installed, plain .bin without
        victim = next((tmp_path / "step_0000000001").glob("leaf_*.bin*"))
        blob = bytearray(victim.read_bytes())
        # corrupt the compressed payload so decompress-or-crc fails
        blob[-1] ^= 0xFF
        victim.write_bytes(bytes(blob))
        with pytest.raises(Exception):
            mgr.restore({"p": params["final_norm"]})

    def test_async_save(self, setup, tmp_path):
        _, _, params, _, _, _ = setup
        mgr = CheckpointManager(tmp_path, async_save=True)
        mgr.save(5, {"p": params["final_norm"]})
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_elastic_restore_resharding(self, setup, tmp_path):
        """512-chip checkpoint restores onto a different mesh (here: the
        host mesh) by passing new shardings -- the node-failure path."""
        _, _, params, _, _, _ = setup
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        mgr = CheckpointManager(tmp_path, async_save=False)
        mgr.save(3, {"p": params["final_norm"]})
        shardings = {"p": jax.tree.map(
            lambda _: NamedSharding(mesh, P()), params["final_norm"]
        )}
        step, restored = mgr.restore({"p": params["final_norm"]}, shardings=shardings)
        assert step == 3
        leaf = jax.tree.leaves(restored["p"])[0]
        assert leaf.sharding.mesh.shape == {"data": 1, "model": 1}


class TestSupervisor:
    def test_nan_rollback(self, setup, tmp_path):
        cfg, model, params, opt_state, step_fn, batch = setup
        mgr = CheckpointManager(tmp_path, async_save=False)
        mgr.save(0, {"params": params, "opt_state": opt_state})

        calls = {"n": 0}

        def poisoned_step(p, s, b):
            calls["n"] += 1
            if calls["n"] == 2:
                new_p, new_s, m = step_fn(p, s, b)
                return new_p, new_s, dict(m, loss=jnp.float32(float("nan")))
            return step_fn(p, s, b)

        sup = TrainSupervisor(
            poisoned_step, mgr, SupervisorConfig(checkpoint_every=0)
        )
        p, s, hist = sup.run(
            params, opt_state, iter([batch(i) for i in range(4)]), num_steps=4
        )
        assert any(r.rolled_back for r in hist)
        assert sum(1 for r in hist if not r.rolled_back) == 3

    def test_transient_fault_retry(self, setup, tmp_path):
        cfg, model, params, opt_state, step_fn, batch = setup
        mgr = CheckpointManager(tmp_path, async_save=False)
        fail_at = {2: 1}  # step 2 fails once then succeeds

        def injector(step):
            if fail_at.get(step, 0) > 0:
                fail_at[step] -= 1
                raise _InjectedFault("boom")

        sup = TrainSupervisor(
            step_fn, mgr, SupervisorConfig(checkpoint_every=0), fault_injector=injector
        )
        p, s, hist = sup.run(
            params, opt_state, iter([batch(i) for i in range(4)]), num_steps=4
        )
        assert [r.retried for r in hist] == [0, 0, 1, 0]

    def test_straggler_flagged(self, setup, tmp_path):
        """Deterministic: a fake clock makes step 3 run 10x the EMA."""
        cfg, model, params, opt_state, step_fn, batch = setup
        mgr = CheckpointManager(tmp_path, async_save=False)

        # fake clock: each _one_step calls clock() twice (start, end);
        # step durations: 1s, 1s, 1s, 10s, 1s
        durations = [1.0, 1.0, 1.0, 10.0, 1.0]
        ticks = []
        t = 0.0
        for d in durations:
            ticks.extend([t, t + d])
            t += d
        it = iter(ticks)

        flagged = []
        sup = TrainSupervisor(
            step_fn,
            mgr,
            SupervisorConfig(checkpoint_every=0, straggler_factor=4.0),
            on_straggler=flagged.append,
            clock=lambda: next(it),
        )
        sup.run(params, opt_state, iter([batch(i) for i in range(5)]), num_steps=5)
        assert flagged == [3], flagged

    def test_resume_from_checkpoint(self, setup, tmp_path):
        cfg, model, params, opt_state, step_fn, batch = setup
        mgr = CheckpointManager(tmp_path, async_save=False)
        sup = TrainSupervisor(step_fn, mgr, SupervisorConfig(checkpoint_every=2))
        p, s, _ = sup.run(
            params, opt_state, iter([batch(i) for i in range(4)]), num_steps=4
        )
        # new supervisor (fresh process) resumes from the saved step
        sup2 = TrainSupervisor(step_fn, mgr, SupervisorConfig())
        start, p2, s2 = sup2.resume_or_init(params, opt_state)
        assert start == 4
        assert int(s2.step) == int(s.step)


class TestTrainingProgress:
    def test_loss_decreases(self, setup, tmp_path):
        """End-to-end: a few hundred params steps reduce loss on a fixed batch."""
        cfg, model, params, opt_state, step_fn, batch = setup
        b = batch(0)
        losses = []
        p, s = params, opt_state
        for _ in range(30):
            p, s, m = step_fn(p, s, b)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]
