"""Tier-1 gate for the vendored conformance corpus (scripts/conformance.py).

The CI job also runs ``scripts/conformance.sh`` standalone and uploads
the summary artifact; this test keeps the corpus inside the tier-1
signal so a conformance regression fails the ordinary test run too.
"""

import importlib.util
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def _load_runner():
    spec = importlib.util.spec_from_file_location(
        "conformance_runner", ROOT / "scripts" / "conformance.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_corpus_all_engines_agree():
    runner = _load_runner()
    summary = runner.run_corpus()
    assert not summary["failures"], summary["failures"][:10]
    totals = summary["totals"]
    # the corpus must actually exercise every engine, including a real
    # batched share (the logical applicators are batchable via circuits)
    for engine in ("naive", "interpreter", "codegen"):
        assert totals[engine]["passed"] >= 80 and totals[engine]["failed"] == 0
    assert totals["batched"]["passed"] >= 40
    assert totals["batched"]["failed"] == 0
