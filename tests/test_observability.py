"""Serving telemetry: metric registry, trace spans, stats reconciliation
(DESIGN.md §12).

The reconciliation invariant under test: every document received via
``submit``/``submit_batch``/``admit_mixed_ex`` -- including under
injected faults -- lands in exactly one outcome counter, and per-
endpoint latency histogram totals equal request counts.
"""

import json

import pytest

from repro.core.outcomes import ValidationOutcome
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
)
from repro.obs.trace import Tracer, set_tracer, span, trace_point, tracer_armed
from repro.registry import SchemaRegistry
from repro.serve.faults import FaultInjector

SCHEMA = {
    "type": "object",
    "required": ["a"],
    "properties": {"a": {"type": "integer", "minimum": 0}},
    "additionalProperties": False,
}


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_histogram(self):
        c = Counter()
        c.inc()
        c.inc(4)
        assert c.value == 5
        c.reset()
        assert c.value == 0
        g = Gauge()
        g.set(3)
        g.inc(-1)
        assert g.value == 2
        h = Histogram((1.0, 10.0))
        for v in (0.5, 5.0, 50.0):
            h.observe(v)
        assert h.count == 3 and h.sum == 55.5
        assert h.cumulative() == [(1.0, 1), (10.0, 2), (float("inf"), 3)]

    def test_observe_many_is_bulk(self):
        h = Histogram((1.0,))
        h.observe_many(0.5, 1000)
        assert h.count == 1000 and h.sum == 500.0
        assert h.cumulative()[0] == (1.0, 1000)

    def test_registry_families_and_labels(self):
        reg = MetricRegistry()
        a = reg.counter("requests_total", "reqs", endpoint="x")
        b = reg.counter("requests_total", endpoint="y")
        assert a is not b
        assert reg.counter("requests_total", endpoint="x") is a  # cached
        a.inc(2)
        b.inc(3)
        children = dict(reg.family_children("requests_total"))
        assert len(children) == 2
        with pytest.raises(ValueError):
            reg.gauge("requests_total")  # kind mismatch

    def test_render_prometheus_format(self):
        reg = MetricRegistry()
        reg.counter("reqs_total", "requests", endpoint="a").inc(3)
        reg.gauge("temp", "temperature").set(1.5)
        h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(5.0)
        text = reg.render_prometheus()
        assert "# TYPE reqs_total counter" in text
        assert 'reqs_total{endpoint="a"} 3' in text
        assert "# TYPE lat_seconds histogram" in text
        assert 'lat_seconds_bucket{le="0.1"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 2' in text
        assert "lat_seconds_count 2" in text
        assert "lat_seconds_sum 5.05" in text

    def test_snapshot_and_reset(self):
        reg = MetricRegistry()
        reg.counter("a_total").inc(7)
        reg.histogram("h", buckets=(1.0,)).observe(0.5)
        snap = reg.snapshot()
        assert snap["a_total"]["children"][0]["value"] == 7
        assert snap["h"]["children"][0]["count"] == 1
        reg.reset()
        assert reg.counter("a_total").value == 0
        assert reg.snapshot()["h"]["children"][0]["count"] == 0

    def test_default_latency_buckets_are_log_spaced(self):
        e = DEFAULT_LATENCY_BUCKETS
        assert len(e) == 13 and e[0] == 1e-6
        for lo, hi in zip(e, e[1:]):
            assert hi / lo == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


class TestTracer:
    def test_disarmed_is_noop(self):
        assert not tracer_armed()
        with span("anything", x=1):
            trace_point("p")  # must not raise, must not record

    def test_spans_record_nesting_and_duration(self):
        with Tracer() as tr:
            with span("outer", label="a"):
                with span("inner"):
                    pass
            trace_point("mark", n=3)
        assert not tracer_armed()  # disarmed on exit
        spans = tr.recent()
        names = [s.name for s in spans]
        # inner closes before outer; the point is instantaneous
        assert names == ["inner", "outer", "mark"]
        by = {s.name: s for s in spans}
        assert by["outer"].depth == 0 and by["inner"].depth == 1
        assert by["outer"].dur_ns >= by["inner"].dur_ns >= 0
        assert by["outer"].attrs == {"label": "a"}
        # point events carry the -1 duration sentinel
        assert by["mark"].attrs == {"n": 3} and by["mark"].dur_ns == -1
        assert by["mark"].dur_us == -1.0

    def test_ring_buffer_keeps_newest(self):
        with Tracer(capacity=4) as tr:
            for i in range(10):
                with span(f"s{i}"):
                    pass
        assert tr.recorded == 10
        assert [s.name for s in tr.recent()] == ["s6", "s7", "s8", "s9"]

    def test_nested_arming_restores_previous(self):
        outer = Tracer()
        prev = set_tracer(outer)
        try:
            with Tracer() as inner:
                with span("x"):
                    pass
            assert [s.name for s in inner.recent()] == ["x"]
            assert outer.recorded == 0  # inner shadowed outer
            with span("y"):
                pass
            assert [s.name for s in outer.recent()] == ["y"]  # restored
        finally:
            set_tracer(prev)

    def test_serving_path_emits_expected_spans(self):
        reg = SchemaRegistry(use_pallas=False)
        with Tracer(capacity=256) as tr:
            reg.register("ep", SCHEMA)
            reg.admit_mixed_ex([{"a": 1}, {"a": -1}], ["ep", "ep"])
        names = {s.name for s in tr.recent()}
        assert "registry.relink" in names
        assert "registry.guard" in names
        assert "registry.encode" in names
        assert "executor.launch" in names


# ---------------------------------------------------------------------------
# registry-backed stats + reconciliation
# ---------------------------------------------------------------------------


def _engine():
    import jax

    from repro.configs import get_config
    from repro.models import Model
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(
        cfg, params, ServeConfig(batch_slots=2, max_len=64, default_max_tokens=4)
    )


def _latency_total(engine):
    children = engine.registry.metrics.family_children("serve_request_seconds")
    return sum(h.count for h in children.values())


class TestStatsReconciliation:
    @pytest.fixture(scope="class")
    def engine(self):
        return _engine()

    def test_outcomes_prepopulated(self, engine):
        outcomes = engine.stats.outcomes
        assert set(outcomes) == {o.value for o in ValidationOutcome}

    def test_every_submit_lands_in_one_outcome_and_one_observation(self, engine):
        engine.register_endpoint("ep", SCHEMA)
        base_recv = engine.stats.received
        base_lat = _latency_total(engine)
        engine.submit(json.dumps({"a": 1}), "ep")  # admitted
        engine.submit(json.dumps({"a": -1}), "ep")  # invalid
        engine.submit("{broken", "ep")  # guard (parse)
        engine.submit("{}", "nosuch")  # guard (unknown endpoint)
        assert engine.stats.received == base_recv + 4
        assert engine.stats.received == sum(engine.stats.outcomes.values())
        assert _latency_total(engine) == base_lat + 4

    def test_submit_batch_reconciles_under_faults(self, engine):
        engine.register_endpoint("ep", SCHEMA)
        reqs = []
        for i in range(24):
            if i % 6 == 5:
                reqs.append(("ep", "{broken"))
            else:
                reqs.append(("ep", json.dumps({"a": i - 4})))
        base_recv = engine.stats.received
        base_lat = _latency_total(engine)
        inj = FaultInjector(seed=7).rate("encode", 0.2).rate("launch", 0.05)
        with inj:
            results = engine.submit_batch(reqs)
        assert len(results) == 24
        assert engine.stats.received == base_recv + 24
        assert engine.stats.received == sum(engine.stats.outcomes.values())
        # histogram totals == request counts (one observation per request)
        assert _latency_total(engine) == base_lat + 24

    def test_explain_true_observes_once_per_request(self, engine):
        """The extra explain launch must not add latency observations:
        exactly one serve_request_seconds entry per received request."""
        engine.register_endpoint("ep", SCHEMA)
        base_recv = engine.stats.received
        base_lat = _latency_total(engine)
        reqs = [("ep", json.dumps({"a": i - 3})) for i in range(8)]
        reqs.append(("ep", "{broken"))  # guard reject rides along
        results = engine.submit_batch(reqs, explain=True)
        assert len(results) == 9
        assert any("schema" in (r.error or "") or r.error for r in results)
        assert engine.stats.received == base_recv + 9
        assert _latency_total(engine) == base_lat + 9
        # single-submit explain path observes exactly once too
        engine.submit(json.dumps({"a": -1}), "ep", explain=True)
        assert _latency_total(engine) == base_lat + 10

    def test_bisect_retries_observe_once_per_request(self, engine):
        """Launch faults trigger isolated-bisect relaunches; the retried
        launches must not multiply latency observations per request."""
        engine.register_endpoint("ep", SCHEMA)
        reqs = [("ep", json.dumps({"a": i - 3})) for i in range(16)]
        base_recv = engine.stats.received
        base_lat = _latency_total(engine)
        inj = FaultInjector(seed=13).rate("launch", 0.3)
        with inj:
            results = engine.submit_batch(reqs)
        # the bisection actually relaunched (initial launch + retries)
        assert inj.fired.get("launch", 0) > 1
        assert len(results) == 16
        assert engine.stats.received == base_recv + 16
        assert _latency_total(engine) == base_lat + 16

    def test_admit_mixed_ex_reconciles_under_faults(self):
        reg = SchemaRegistry(use_pallas=False)
        reg.register("ep", SCHEMA)
        docs = [{"a": i - 8} for i in range(32)] + [{"a": None}, {}]
        inj = FaultInjector(seed=3).rate("encode", 0.25).rate("fallback", 0.5)
        with inj:
            verdicts, counts = reg.admit_mixed_ex(docs, ["ep"] * len(docs))
        assert len(verdicts) == len(docs)
        total = (
            counts.batch_validated
            + counts.fallback_validated
            + counts.rejected_guard
            + counts.error_isolated
            + counts.timed_out
            + counts.breaker_open
        )
        assert total == len(docs)

    def test_snapshot_and_reset(self, engine):
        engine.submit(json.dumps({"a": 1}), "ep")
        snap = engine.stats.snapshot()
        assert snap["received"] > 0
        assert snap["outcomes"] == engine.stats.outcomes
        assert "by_endpoint" in snap and "fallback_reasons" in snap
        engine.stats.reset()
        assert engine.stats.received == 0
        assert sum(engine.stats.outcomes.values()) == 0
        assert all(
            v == 0 for per in engine.stats.by_endpoint.values() for v in per.values()
        )
        # registration-time info survives traffic-counter resets
        assert engine.stats.fallback_reasons == snap["fallback_reasons"]

    def test_attribute_compat(self, engine):
        # the historical mutation idioms still work through the facade
        engine.stats.decode_steps += 3
        assert engine.stats.snapshot()["decode_steps"] >= 3
        engine.stats.validation_seconds += 0.25
        assert engine.stats.validation_seconds >= 0.25

    def test_pipeline_stats_reconcile(self):
        from repro.data.pipeline import AdmissionController

        ctrl = AdmissionController(SCHEMA)
        ctrl.admit_ex([{"a": 1}, {"a": -1}, {"a": "x"}, {}])
        s = ctrl.stats
        assert s.seen == 4
        assert s.admitted + s.rejected == s.seen
        snap = s.snapshot()
        assert snap["seen"] == 4
        s.reset()
        assert s.seen == 0
        # shared registry: pipeline counters render alongside executor's
        text = ctrl.registry.metrics.render_prometheus()
        assert "pipeline_seen_total" in text
        assert "executor_launches_total" in text


class TestServingMetricsSurface:
    @pytest.fixture(scope="class")
    def engine(self):
        e = _engine()
        e.register_endpoint("ep", SCHEMA)
        e.submit(json.dumps({"a": 1}), "ep")
        e.submit_batch([("ep", json.dumps({"a": 2}))] * 3)
        return e

    def test_executor_counters(self, engine):
        m = engine.registry.metrics
        assert m.counter("executor_launches_total").value > 0
        assert m.counter("executor_recompiles_total").value > 0
        assert m.counter("executor_launch_seconds_total").value > 0

    def test_breaker_gauge(self, engine):
        text = engine.render_metrics()
        assert 'breaker_state{endpoint="ep"} 0' in text

    def test_swap_counters(self, engine):
        m = engine.registry.metrics
        ok = m.counter("registry_swap_total", result="ok").value
        assert ok >= 2
        with pytest.raises(Exception):
            engine.registry.register("bad", {"type": "string", "pattern": "("})
        assert m.counter("registry_swap_total", result="failed").value >= 1

    def test_endpoint_stats_tape_shape(self, engine):
        per = engine.endpoint_stats()["ep"]
        for key in ("a_hat", "k", "horizon", "n_circuits", "n_frontier",
                    "unroll_depth"):
            assert key in per
        assert per["a_hat"] >= 1 and per["horizon"] >= 1
        assert per["batchable"] is True

    def test_prometheus_and_json_export(self, engine):
        text = engine.render_metrics()
        assert "serve_received_total" in text
        assert "serve_request_seconds_bucket" in text
        assert 'serve_outcomes_total{outcome="admitted"}' in text
        snap = engine.metrics_snapshot()
        assert json.dumps(snap)  # JSON-serializable
        assert "serve_received_total" in snap
