"""CI perf gate behavior (scripts/bench_gate.py, DESIGN.md §12/§13).

The contract under test: gated ``us_per_doc`` regressions beyond the
threshold fail; benchmarks with no committed baseline (first appearance)
pass with a "new benchmark" note; unreadable baselines are treated as
absent; unreadable *fresh* results fail; and every run writes the
machine-readable ``gate_summary.json`` that perf_report.py consumes.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "scripts"))

from bench_gate import gate  # noqa: E402


def _git(repo: Path, *args: str) -> None:
    subprocess.run(
        ["git", *args],
        cwd=repo,
        check=True,
        capture_output=True,
        env={
            "GIT_AUTHOR_NAME": "t",
            "GIT_AUTHOR_EMAIL": "t@t",
            "GIT_COMMITTER_NAME": "t",
            "GIT_COMMITTER_EMAIL": "t@t",
            "HOME": str(repo),
            "PATH": "/usr/bin:/bin:/usr/local/bin",
        },
    )


@pytest.fixture()
def repo(tmp_path):
    """A scratch git repo with one committed BENCH baseline."""
    _git(tmp_path, "init", "-q")
    results = tmp_path / "results"
    results.mkdir()
    (results / "BENCH_alpha.json").write_text(
        json.dumps({"throughput": {"fast_us_per_doc": 10.0, "docs": 100}})
    )
    _git(tmp_path, "add", "-A")
    _git(tmp_path, "commit", "-q", "-m", "baseline")
    return tmp_path


def _run(repo: Path, threshold: float = 0.25) -> tuple:
    rc = gate(
        "HEAD",
        threshold,
        results_dir=repo / "results",
        repo=repo,
    )
    summary = json.loads((repo / "results" / "gate_summary.json").read_text())
    return rc, summary


class TestBenchGate:
    def test_unchanged_results_pass(self, repo):
        rc, summary = _run(repo)
        assert rc == 0 and summary["status"] == "pass"
        assert summary["gated_comparisons"] == 1
        [cmp] = summary["comparisons"]
        assert cmp["path"] == "throughput.fast_us_per_doc"
        assert cmp["verdict"] == "ok"

    def test_regression_beyond_threshold_fails(self, repo):
        (repo / "results" / "BENCH_alpha.json").write_text(
            json.dumps({"throughput": {"fast_us_per_doc": 20.0, "docs": 100}})
        )
        rc, summary = _run(repo)
        assert rc == 1 and summary["status"] == "fail"
        assert "fast_us_per_doc" in summary["failures"][0]

    def test_regression_within_threshold_passes(self, repo):
        (repo / "results" / "BENCH_alpha.json").write_text(
            json.dumps({"throughput": {"fast_us_per_doc": 11.0}})
        )
        rc, summary = _run(repo)
        assert rc == 0 and summary["comparisons"][0]["delta_pct"] == pytest.approx(10.0)

    def test_improvements_never_fail(self, repo):
        (repo / "results" / "BENCH_alpha.json").write_text(
            json.dumps({"throughput": {"fast_us_per_doc": 1.0}})
        )
        rc, _ = _run(repo)
        assert rc == 0

    def test_new_benchmark_passes_with_note(self, repo):
        """A BENCH file with no committed baseline (e.g. the first
        BENCH_serve_load.json) must pass, noted as a new benchmark."""
        (repo / "results" / "BENCH_newthing.json").write_text(
            json.dumps({"p99_us_per_doc": 123.0})
        )
        rc, summary = _run(repo)
        assert rc == 0 and summary["status"] == "pass"
        assert summary["new_benchmarks"] == ["results/BENCH_newthing.json"]
        # the uncommitted file contributed no gated comparisons
        assert summary["gated_comparisons"] == 1

    def test_unparseable_baseline_treated_as_new(self, repo):
        (repo / "results" / "BENCH_broken.json").write_text("{not json")
        _git(repo, "add", "-A")
        _git(repo, "commit", "-q", "-m", "broken baseline")
        (repo / "results" / "BENCH_broken.json").write_text(
            json.dumps({"x_us_per_doc": 5.0})
        )
        rc, summary = _run(repo)
        assert rc == 0
        assert "results/BENCH_broken.json" in summary["new_benchmarks"]

    def test_unreadable_fresh_results_fail(self, repo):
        (repo / "results" / "BENCH_alpha.json").write_text("garbage{")
        rc, summary = _run(repo)
        assert rc == 1
        assert summary["unreadable"] == ["results/BENCH_alpha.json"]

    def test_allowlisted_keys_report_but_never_gate(self, repo):
        results = repo / "results"
        (results / "BENCH_noisy.json").write_text(
            json.dumps({"traced_us_per_doc": 10.0})
        )
        _git(repo, "add", "-A")
        _git(repo, "commit", "-q", "-m", "noisy baseline")
        (results / "BENCH_noisy.json").write_text(
            json.dumps({"traced_us_per_doc": 100.0})
        )
        rc, summary = _run(repo)
        assert rc == 0
        noisy = [c for c in summary["comparisons"] if c["allowlisted"]]
        assert noisy and noisy[0]["verdict"] == "noisy (allowlisted)"
