"""Unit + property tests for core internals: semi-perfect hashing (§4.1),
regex specialization (§4.3), compiler heuristics (§4.2/§4.4), CISC fusion
(§2.5) and static elision (§3.1.1)."""

import re

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core import CompilerOptions, compile_schema
from repro.core.hashing import (
    SHORT_LIMIT,
    hash_lanes,
    hashed_equal,
    is_short_hash,
    lanes_to_int,
    shash,
    shash_bytes,
)
from repro.core.instructions import (
    ArrayPrefix,
    AssertionArraySizeLess,
    AssertionEqual,
    AssertionNumberBounds,
    AssertionStringBounds,
    AssertionStringSizeGreater,
    AssertionType,
    ControlJump,
    ControlLabel,
    LoopPropertiesMatch,
    LoopPropertiesMatchClosed,
    OpCode,
    WhenDefines,
    WhenType,
    walk,
)
from repro.core.regex_opt import RegexKind, analyze_pattern


# ---------------------------------------------------------------------------
# Hashing (§4.1)
# ---------------------------------------------------------------------------


class TestSemiPerfectHash:
    @given(st.text(max_size=60))
    def test_hash_is_deterministic(self, s):
        assert shash(s) == shash(s)

    @given(st.text(max_size=10), st.text(max_size=10))
    def test_short_strings_perfect(self, a, b):
        """Hash equality is string equality for short strings (one-to-one)."""
        if len(a.encode()) <= SHORT_LIMIT and len(b.encode()) <= SHORT_LIMIT:
            assert (shash(a) == shash(b)) == (a == b)

    @given(st.binary(min_size=0, max_size=SHORT_LIMIT))
    def test_short_discriminator_zero(self, data):
        assert is_short_hash(shash_bytes(data))

    @given(st.binary(min_size=SHORT_LIMIT + 1, max_size=200))
    def test_long_discriminator_nonzero(self, data):
        h = shash_bytes(data)
        assert not is_short_hash(h)
        # constant-time digest: depends only on len, first, last byte
        digest = (len(data) + data[0] + data[-1]) % 255 + 1
        assert (h >> 248) == digest

    @given(st.text(max_size=64), st.text(max_size=64))
    def test_hashed_equal_matches_string_equal(self, a, b):
        assert hashed_equal(shash(a), a, shash(b), b) == (a == b)

    @given(st.text(max_size=64))
    def test_lane_roundtrip(self, s):
        h = shash(s)
        lanes = hash_lanes(h)
        assert lanes.shape == (8,)
        assert lanes_to_int(lanes) == h

    def test_paper_collision_example(self):
        """Same length + same first/last char => same (1-byte) digest."""
        a = "a" + "x" * 30 + "z"  # 32 bytes
        b = "a" + "y" * 30 + "z"
        assert len(a) == len(b) == 32
        assert shash(a) == shash(b)  # collision by construction
        assert not hashed_equal(shash(a), a, shash(b), b)  # resolved by compare


# ---------------------------------------------------------------------------
# Regex specialization (§4.3)
# ---------------------------------------------------------------------------


class TestRegexSpecialization:
    @pytest.mark.parametrize(
        "pattern,kind",
        [
            (".*", RegexKind.ALL),
            ("^.*$", RegexKind.ALL),
            (".+", RegexKind.NON_EMPTY),
            ("^.+$", RegexKind.NON_EMPTY),
            ("^.{3,5}$", RegexKind.LENGTH_RANGE),
            ("^.{3,}$", RegexKind.LENGTH_RANGE),
            ("^.{4}$", RegexKind.LENGTH_RANGE),
            ("^x-", RegexKind.PREFIX),
            ("^foo$", RegexKind.EXACT),
            ("-x$", RegexKind.SUFFIX),
            ("abc", RegexKind.CONTAINS),
            ("a|b", RegexKind.GENERIC),
            ("[0-9]+", RegexKind.GENERIC),
            ("^x-.*cfg$", RegexKind.GENERIC),
        ],
    )
    def test_classification(self, pattern, kind):
        assert analyze_pattern(pattern).kind is kind

    @pytest.mark.parametrize(
        "pattern",
        [".*", ".+", "^.{3,5}$", "^.{2,}$", "^.{4}$", "^x-", "^foo$", "-x$", "abc", "a|b"],
    )
    @given(s=st.text(max_size=20))
    @settings(max_examples=60, deadline=None)
    def test_plan_equals_engine(self, pattern, s):
        """Specialized plans must agree with the real regex engine."""
        plan = analyze_pattern(pattern)
        expected = re.search(pattern, s, re.DOTALL) is not None
        assert plan.matches(s) == expected

    def test_disabled_forces_engine(self):
        assert analyze_pattern(".*", enabled=False).kind is RegexKind.GENERIC


# ---------------------------------------------------------------------------
# Compiler heuristics (§4.2 unrolling, §3.3 ref inlining)
# ---------------------------------------------------------------------------


def _ops(compiled):
    return [type(i).__name__ for i in compiled.instructions]


class TestUnrollHeuristics:
    def test_few_properties_unrolled(self):
        """<=5 properties -> per-key instructions, no loop (§4.2)."""
        schema = {"properties": {k: {"type": "integer"} for k in "abcde"}}
        c = compile_schema(schema)
        assert not any(isinstance(i, LoopPropertiesMatch) for i in c.instructions)
        typed = [i for i in c.instructions if isinstance(i, AssertionType)]
        assert {i.rel_path for i in typed} == {(k,) for k in "abcde"}

    def test_many_optional_properties_looped(self):
        """>5 properties, none required -> LoopPropertiesMatch."""
        schema = {"properties": {f"k{i}": {"type": "integer"} for i in range(10)}}
        c = compile_schema(schema)
        assert any(isinstance(i, LoopPropertiesMatch) for i in c.instructions)

    def test_quarter_required_unrolls(self):
        """>=1/4 of properties required -> unroll even when many (§4.2)."""
        schema = {
            "properties": {f"k{i}": {"type": "integer"} for i in range(8)},
            "required": ["k0", "k1"],
        }
        c = compile_schema(schema)
        assert not any(isinstance(i, LoopPropertiesMatch) for i in c.instructions)

    def test_unroll_disabled(self):
        schema = {"properties": {"a": {"type": "integer"}}}
        c = compile_schema(schema, options=CompilerOptions(unroll=False))
        assert any(isinstance(i, LoopPropertiesMatch) for i in c.instructions)

    def test_oneof_branches_always_unroll(self):
        """properties directly under oneOf always unroll (§4.2)."""
        schema = {
            "oneOf": [
                {"properties": {f"k{i}": {"type": "integer"} for i in range(10)}},
                {"type": "string"},
            ]
        }
        c = compile_schema(schema)
        xor = next(i for i in c.instructions if i.op is OpCode.XOR)
        assert not any(
            isinstance(i, LoopPropertiesMatch) for grp in xor.groups for i in grp
        )


class TestRefHandling:
    def test_few_refs_inlined(self):
        schema = {
            "$defs": {"t": {"type": "integer"}},
            "properties": {"a": {"$ref": "#/$defs/t"}, "b": {"$ref": "#/$defs/t"}},
        }
        c = compile_schema(schema)
        all_insts = list(walk(c.instructions))
        assert not any(isinstance(i, (ControlLabel, ControlJump)) for i in all_insts)
        assert not c.labels

    def test_many_refs_labelled(self):
        schema = {
            "$defs": {"t": {"type": "integer"}},
            "properties": {f"k{i}": {"$ref": "#/$defs/t"} for i in range(7)},
        }
        c = compile_schema(schema)
        all_insts = list(walk(c.instructions))
        labels = [i for i in all_insts if isinstance(i, ControlLabel)]
        jumps = [i for i in all_insts if isinstance(i, ControlJump)]
        assert len(labels) == 1 and len(jumps) == 6
        assert c.labels[labels[0].label] == labels[0].children

    def test_recursive_ref_always_labelled(self):
        schema = {"properties": {"next": {"$ref": "#"}}}
        c = compile_schema(schema)
        all_insts = list(walk(c.instructions))
        assert any(isinstance(i, ControlJump) for i in all_insts) or c.labels


class TestCiscFusion:
    def test_string_bounds_fused(self):
        schema = {"type": "string", "minLength": 2, "maxLength": 5}
        c = compile_schema(schema)
        assert any(isinstance(i, AssertionStringBounds) for i in c.instructions)

    def test_number_bounds_fused(self):
        schema = {"minimum": 0, "maximum": 10}
        c = compile_schema(schema)
        assert any(isinstance(i, AssertionNumberBounds) for i in c.instructions)

    def test_singleton_enum_becomes_equal(self):
        c = compile_schema({"enum": ["only"]})
        assert any(isinstance(i, AssertionEqual) for i in c.instructions)

    def test_dependent_schemas_when_defines(self):
        c = compile_schema({"dependentSchemas": {"a": {"required": ["b"]}}})
        assert any(isinstance(i, WhenDefines) for i in c.instructions)

    def test_if_type_becomes_when_type(self):
        c = compile_schema({"if": {"type": "integer"}, "then": {"minimum": 0}})
        assert any(isinstance(i, WhenType) for i in c.instructions)

    def test_cisc_disabled(self):
        c = compile_schema(
            {"minimum": 0, "maximum": 10}, options=CompilerOptions(cisc=False)
        )
        assert not any(isinstance(i, AssertionNumberBounds) for i in c.instructions)


class TestStaticElision:
    def test_numeric_assertion_elided_for_string_type(self):
        """§3.1.1: minimum is redundant when type != number."""
        c = compile_schema({"type": "string", "minimum": 5})
        ops = {i.op for i in walk(c.instructions)}
        assert OpCode.GREATER_EQUAL not in ops and OpCode.NUMBER_BOUNDS not in ops

    def test_elision_disabled_keeps_assertion(self):
        c = compile_schema(
            {"type": "string", "minimum": 5}, options=CompilerOptions(elide=False)
        )
        ops = {i.op for i in walk(c.instructions)}
        assert OpCode.GREATER_EQUAL in ops

    def test_mincontains_zero_no_instructions(self):
        c = compile_schema({"contains": {"type": "integer"}, "minContains": 0})
        assert len(c.instructions) == 0

    def test_contains_true_becomes_size_check(self):
        c = compile_schema({"contains": True, "minContains": 2})
        assert any(i.op is OpCode.ARRAY_SIZE_GREATER for i in c.instructions)

    def test_items_false_becomes_size_check(self):
        c = compile_schema({"prefixItems": [{}], "items": False})
        assert any(isinstance(i, AssertionArraySizeLess) for i in c.instructions)

    def test_additional_properties_true_no_instructions(self):
        c = compile_schema({"additionalProperties": True})
        assert len(c.instructions) == 0

    def test_unevaluated_true_no_instructions(self):
        c = compile_schema({"unevaluatedProperties": True})
        assert len(c.instructions) == 0


class TestReordering:
    def test_cheap_before_expensive(self):
        """String length checks before regex (§3.1: fail fast on cheap ops)."""
        schema = {"type": "string", "pattern": "a|b", "minLength": 2}
        c = compile_schema(schema)
        names = _ops(c)
        assert names.index("AssertionStringSizeGreater") < names.index("AssertionRegex")

    def test_reorder_disabled_keeps_source_order(self):
        schema = {"pattern": "a|b", "minLength": 2}
        c = compile_schema(schema, options=CompilerOptions(reorder=False))
        names = _ops(c)
        # compiler emits length before pattern structurally; with reorder off
        # order is the emission order, stable regardless of cost
        assert "AssertionRegex" in names

    def test_closed_properties_compiles_to_match_closed(self):
        c = compile_schema(
            {"properties": {"a": {}}, "additionalProperties": False}
        )
        assert any(isinstance(i, LoopPropertiesMatchClosed) for i in c.instructions)


class TestInstructionCounts:
    def test_instruction_count_reported(self):
        c = compile_schema({"properties": {"a": {"type": "string"}}})
        assert c.instruction_count() >= 1

    def test_prefix_items_groups(self):
        c = compile_schema({"prefixItems": [{"type": "integer"}, {"type": "string"}]})
        ap = next(i for i in c.instructions if isinstance(i, ArrayPrefix))
        assert len(ap.groups) == 2
