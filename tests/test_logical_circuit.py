"""Batched logical applicators via assertion-group circuits (DESIGN.md §10).

Differential fuzz of nested ``anyOf``/``oneOf``/``not``/``if`` schemas
over the scalar subset against the sequential oracle, CSR==dense (and
spot-checked pallas) bit-identity, conditional-requiredness semantics,
mixed-registry linking with a tagged-union member, and precise
``UnsupportedForBatch`` reasons for out-of-subset branches.
"""

import random

import numpy as np
import pytest

from repro.core import Validator, compile_schema
from repro.core.batch_executor import BatchValidator
from repro.core.tape import build_tape, try_build_tape
from repro.data.doc_table import encode_batch
from repro.registry import SchemaRegistry

from test_batch_csr import _KEYS, _rand_doc, _rand_leaf

UNION = {
    "type": "object",
    "required": ["kind"],
    "properties": {"kind": {"enum": ["card", "bank", "wallet"]}},
    "oneOf": [
        {
            "properties": {
                "kind": {"const": "card"},
                "number": {"type": "string", "minLength": 12},
                "cvv": {"type": "string", "minLength": 3, "maxLength": 4},
            },
            "required": ["number", "cvv"],
        },
        {
            "properties": {
                "kind": {"const": "bank"},
                "iban": {"type": "string", "minLength": 15},
            },
            "required": ["iban"],
        },
        {
            "properties": {
                "kind": {"const": "wallet"},
                "wallet_id": {"type": "string", "pattern": "^w-"},
            },
            "required": ["wallet_id"],
        },
    ],
}

UNION_DOCS = [
    {"kind": "card", "number": "4111111111111111", "cvv": "123"},
    {"kind": "card", "number": "4111", "cvv": "123"},
    {"kind": "card", "number": "4111111111111111"},
    {"kind": "bank", "iban": "DE89370400440532013000"},
    {"kind": "bank", "iban": "short"},
    {"kind": "wallet", "wallet_id": "w-42"},
    {"kind": "wallet", "wallet_id": "x-42"},
    {"kind": "crypto", "wallet_id": "w-42"},
    {"number": "4111111111111111", "cvv": "123"},
    {},
    5,
    "card",
    None,
    [],
    # satisfies two branch tails but only one kind const -> still one
    {"kind": "card", "number": "4111111111111111", "cvv": "123",
     "iban": "DE89370400440532013000"},
]


def _check(schema, docs, *, max_nodes=64, max_depth=8, pallas=False):
    compiled = compile_schema(schema)
    seq = Validator(compiled)
    tape, reason = try_build_tape(compiled)
    assert tape is not None, (schema, reason)
    table = encode_batch(docs, max_nodes=max_nodes, max_depth=max_depth)
    expected = [seq.is_valid(d) for d in docs]
    layouts = [("csr", False), ("dense", False)] + ([("csr", True)] if pallas else [])
    results = {}
    for layout, use_pallas in layouts:
        bv = BatchValidator(
            tape, max_depth=max_depth, use_pallas=use_pallas, layout=layout
        )
        v, d = bv.validate(table)
        results[(layout, use_pallas)] = (v, d)
        for i, doc in enumerate(docs):
            if d[i]:
                assert bool(v[i]) == expected[i], (layout, use_pallas, schema, doc)
    base_v, base_d = results[("csr", False)]
    for key, (v, d) in results.items():
        np.testing.assert_array_equal(v, base_v, err_msg=repr((key, schema)))
        np.testing.assert_array_equal(d, base_d, err_msg=repr((key, schema)))
    return tape, results[("csr", False)]


class TestDirectedCircuits:
    def test_discriminated_union_all_layouts_and_pallas(self):
        tape, (v, d) = _check(UNION, UNION_DOCS, pallas=True)
        assert tape.n_circuits >= 4  # XOR1 + three branch ANDs
        assert d.all()

    def test_anyof_scalars(self):
        _check(
            {"anyOf": [{"type": "string"}, {"minimum": 10}, {"enum": [None, True]}]},
            ["x", 5, 15, 9.99, None, True, False, [], {}],
        )

    def test_oneof_overlap_counts_exactly_one(self):
        # 5 passes both branches -> oneOf fails; strings pass both
        # (precondition skip) -> fail; -5 and 15 pass exactly one
        _check(
            {"oneOf": [{"minimum": 0}, {"maximum": 10}]},
            [-5, 5, 15, "s", None, [], {}],
        )

    def test_not_and_nested_not(self):
        _check({"not": {"type": "string"}}, ["x", 5, None, [], {}])
        _check(
            {"not": {"not": {"type": "string"}}},
            ["x", 5, None, [], {}],
        )

    def test_not_vacuous_branch_fails(self):
        # inner group passes vacuously on objects without "a" -> not fails
        schema = {"not": {"properties": {"a": {"const": 1}}, "required": ["a"]}}
        _check(schema, [{"a": 1}, {"a": 2}, {}, 5])

    def test_circuit_at_missing_property_is_vacuous(self):
        # the applicator's target is absent -> instruction skipped -> pass
        schema = {"properties": {"x": {"oneOf": [{"type": "string"}, {"minimum": 100}]}}}
        _check(schema, [{"x": "s"}, {"x": 500}, {"x": 5}, {}, {"y": 1}, 5])

    def test_if_then_else(self):
        schema = {
            "if": {"properties": {"a": {"const": 1}}, "required": ["a"]},
            "then": {"required": ["b"]},
            "else": {"required": ["c"]},
        }
        docs = [{"a": 1, "b": 2}, {"a": 1}, {"a": 2, "c": 3}, {"a": 2},
                {"c": 1}, {}, 5, "s"]
        _check(schema, docs)

    def test_if_then_without_else(self):
        schema = {"if": {"type": "string"}, "then": {"minLength": 3}}
        _check(schema, ["ab", "abcd", 5, None, [], {}])

    def test_dependent_schemas_when_defines(self):
        schema = {"dependentSchemas": {"a": {"required": ["b"]}}}
        _check(schema, [{"a": 1, "b": 2}, {"a": 1}, {"b": 2}, {}, 5, []])

    def test_nested_anyof_in_oneof(self):
        schema = {
            "oneOf": [
                {"anyOf": [{"type": "string"}, {"type": "null"}]},
                {"minimum": 100},
            ]
        }
        _check(schema, ["s", None, 500, 5, [], {}])

    def test_enum_inside_branch(self):
        schema = {"properties": {"p": {"anyOf": [{"enum": ["a", "b", 3]},
                                                 {"type": "array"}]}}}
        _check(schema, [{"p": "a"}, {"p": 3}, {"p": []}, {"p": "z"}, {}, 5])

    def test_conditional_required_not_in_hard_mask(self):
        # branch-level `required` must observe, not demand: {} fails the
        # anyOf (both branches false) but non-objects pass (precondition)
        schema = {"anyOf": [{"required": ["a"]}, {"required": ["b"]}]}
        tape, _ = _check(schema, [{"a": 1}, {"b": 1}, {}, {"c": 1}, 5, "x", []])
        assert int(tape.loc_required_mask[0]) == 0

    def test_hard_and_conditional_required_share_slots(self):
        schema = {
            "required": ["a"],
            "anyOf": [{"required": ["b"]}, {"required": ["c"]}],
        }
        tape, _ = _check(
            schema,
            [{"a": 1, "b": 2}, {"a": 1, "c": 2}, {"a": 1}, {"b": 2}, {}, 5],
        )
        assert bin(int(tape.loc_required_mask[0])).count("1") == 1  # only "a"

    def test_when_array_size_conditions(self):
        # CISC'd if: {minItems} / {minItems,maxItems} forms
        _check(
            {"if": {"minItems": 2}, "then": {"maxItems": 3}},
            [[1, 2], [1, 2, 3, 4], [1], [], "s", 5],
        )
        _check(
            {"if": {"minItems": 1, "maxItems": 1}, "then": {"maxItems": 0}},
            [[1], [], [1, 2], "s", 5],
        )

    def test_depth_budget_still_undecided_with_circuits(self):
        # the circuit sits below the depth budget: documents reaching it
        # must stay undecided, never vacuously valid
        schema = {"properties": {"a": {"properties": {
            "b": {"anyOf": [{"type": "string"}, {"minimum": 100}]}}}}}
        compiled = compile_schema(schema)
        tape = build_tape(compiled)
        table = encode_batch([{"a": {"b": 5}}, {"x": 1}], max_nodes=64, max_depth=8)
        bv = BatchValidator(tape, max_depth=1, use_pallas=False)
        v, d = bv.validate(table)
        assert not d[0] and d[1]  # deep doc undecided, not vacuously valid
        assert bool(v[1])


class TestRoutingScopes:
    """Closed/additionalProperties scopes vs per-key routes (the
    conformance-sweep fixes found while wiring circuit descents)."""

    def test_required_only_key_validates_against_additional_properties(self):
        schema = {"required": ["r"], "properties": {"p": {"type": "integer"}},
                  "additionalProperties": {"type": "string"}}
        _check(schema, [{"r": 5}, {"r": "ok"}, {"r": "ok", "p": 1},
                        {"p": "bad"}, {"p": 2}, {}])

    def test_required_only_key_fails_closed_object(self):
        schema = {"required": ["r"], "properties": {"p": {}},
                  "additionalProperties": False}
        _check(schema, [{"r": 1, "p": 2}, {"p": 2}, {}, {"r": 1}])

    def test_branch_key_outside_closed_properties(self):
        # the branch descends into "z", which the closed base forbids
        schema = {
            "type": "object",
            "properties": {"p": {}},
            "additionalProperties": False,
            "anyOf": [{"properties": {"z": {"const": 1}}}, {"required": ["p"]}],
        }
        _check(schema, [{"p": 1}, {"z": 1}, {}, {"p": 1, "z": 1}])

    def test_branch_key_under_additional_properties_falls_back(self):
        schema = {
            "properties": {"p": {}},
            "additionalProperties": {"type": "string"},
            "anyOf": [{"properties": {"z": {"const": 1}}}, {"required": ["p"]}],
        }
        tape, reason = try_build_tape(compile_schema(schema))
        assert tape is None and "additionalProperties" in reason


class TestUnsupportedReasons:
    @pytest.mark.parametrize(
        "schema,fragment",
        [
            ({"items": {"anyOf": [{"type": "string"}]}},
             "not a unique instance path"),
            ({"additionalProperties": {"oneOf": [{"type": "string"}]}},
             "not a unique instance path"),
            ({"prefixItems": [{"not": {"type": "string"}}]},
             "not a unique instance path"),
            ({"not": {"items": {"type": "string"}}},
             "LOOP_ITEMS inside a logical applicator"),
            ({"anyOf": [{"type": "object", "additionalProperties": False}]},
             "additionalProperties: false inside a logical applicator"),
            ({"anyOf": [{"uniqueItems": True}, {"type": "string"}]},
             "UNIQUE"),
            ({"anyOf": [{"contains": {"type": "string"}}]},
             "LOOP_CONTAINS inside a logical applicator"),
        ],
    )
    def test_precise_reasons(self, schema, fragment):
        tape, reason = try_build_tape(compile_schema(schema))
        assert tape is None, schema
        assert fragment in reason, (schema, reason)

    def test_recursive_ref_inside_branch_falls_back(self):
        schema = {
            "$defs": {"n": {"properties": {"next": {"$ref": "#/$defs/n"}}}},
            "anyOf": [{"$ref": "#/$defs/n"}, {"type": "string"}],
        }
        tape, reason = try_build_tape(compile_schema(schema))
        assert tape is None and "logical applicator" in reason


class TestLinkedCircuits:
    def test_mixed_registry_with_union_member_bit_identical(self):
        reg = SchemaRegistry()
        reg.register("union", UNION)
        reg.register("plain", {
            "type": "object",
            "required": ["v"],
            "properties": {"v": {"type": "integer", "minimum": 0}},
        })
        rng = random.Random(0xC1C)
        plain_docs = [{"v": 1}, {"v": -1}, {"v": "s"}, {}, 5]
        docs, endpoints = [], []
        for i in range(len(UNION_DOCS) + len(plain_docs)):
            if i % 2 == 0 and i // 2 < len(UNION_DOCS):
                docs.append(UNION_DOCS[i // 2]); endpoints.append("union")
            else:
                docs.append(plain_docs[rng.randrange(len(plain_docs))])
                endpoints.append("plain")
        table = encode_batch(docs, max_nodes=64)
        valid, decided = reg.validate_mixed(table, endpoints)
        assert decided.all()
        # bit-identical to single-schema dispatch per member
        for ep in ("union", "plain"):
            sel = [i for i, e in enumerate(endpoints) if e == ep]
            sub = encode_batch([docs[i] for i in sel], max_nodes=64)
            bv = BatchValidator(reg.get(ep).tape, use_pallas=False)
            v1, d1 = bv.validate(sub)
            np.testing.assert_array_equal(valid[sel], v1)
            np.testing.assert_array_equal(decided[sel], d1)
        # and to the sequential oracle
        for doc, ep, v in zip(docs, endpoints, valid):
            assert bool(v) == reg.get(ep).validator.is_valid(doc), (ep, doc)

    def test_linked_circuit_relocation_invariants(self):
        from repro.registry import link_tapes

        t_union = build_tape(compile_schema(UNION))
        t_plain = build_tape(compile_schema(
            {"type": "object", "properties": {"v": {"type": "integer"}}}
        ))
        t_any = build_tape(compile_schema(
            {"anyOf": [{"type": "string"}, {"minimum": 0}]}
        ))
        linked = link_tapes([t_plain, t_union, t_any])
        assert linked.n_circuits == t_union.n_circuits + t_any.n_circuits
        np.testing.assert_array_equal(
            linked.member_n_circuits, [0, t_union.n_circuits, t_any.n_circuits]
        )
        assert linked.max_circ_depth == max(t_union.max_circ_depth, t_any.max_circ_depth)
        # member 1's circuit owners sit inside member 1's location range
        lo1, lo2 = int(linked.loc_offsets[1]), int(linked.loc_offsets[2])
        owners1 = linked.circ_owner[: t_union.n_circuits]
        assert ((owners1 >= lo1) & (owners1 < lo2)).all()
        # parents relocate inside the member's circuit block (-1 for roots)
        parents1 = linked.circ_parent[: t_union.n_circuits]
        assert ((parents1 == -1) | (parents1 < t_union.n_circuits)).all()
        # leaf wiring survives: per-member circuit leaf counts match
        circ = linked.asrt_circ[linked.asrt_circ >= 0]
        assert (np.sort(np.unique(circ)) < linked.n_circuits).all()


def _rand_logical(rng: random.Random, depth: int) -> dict:
    """Random schema biased toward logical applicators at unique paths."""
    if depth <= 0 or rng.random() < 0.3:
        return _rand_leaf(rng)
    c = rng.randrange(7)
    if c == 0:
        return {"anyOf": [_rand_logical(rng, depth - 1)
                          for _ in range(rng.randint(1, 3))]}
    if c == 1:
        return {"oneOf": [_rand_logical(rng, depth - 1)
                          for _ in range(rng.randint(1, 3))]}
    if c == 2:
        return {"not": _rand_logical(rng, depth - 1)}
    if c == 3:
        out = {"if": _rand_logical(rng, depth - 1)}
        if rng.random() < 0.8:
            out["then"] = _rand_logical(rng, depth - 1)
        if rng.random() < 0.5:
            out["else"] = _rand_logical(rng, depth - 1)
        return out
    if c == 4:
        return {"allOf": [_rand_logical(rng, depth - 1)
                          for _ in range(rng.randint(1, 2))]}
    props = {k: _rand_logical(rng, depth - 1)
             for k in rng.sample(_KEYS, rng.randint(1, 3))}
    out = {"properties": props}
    if rng.random() < 0.5:
        out["required"] = rng.sample(sorted(props), rng.randint(0, len(props)))
    return out


class TestDifferentialFuzz:
    def test_circuits_match_sequential_and_dense(self):
        rng = random.Random(0x10C1C)
        tapes = circuits = checked_sites = 0
        for trial in range(80):
            schema = _rand_logical(rng, 3)
            compiled = compile_schema(schema)
            tape, _ = try_build_tape(compiled)
            if tape is None:
                continue
            tapes += 1
            circuits += tape.n_circuits
            docs = [_rand_doc(rng, 3) for _ in range(rng.randint(1, 6))]
            seq = Validator(compiled)
            expected = [seq.is_valid(d) for d in docs]
            table = encode_batch(docs, max_nodes=64, max_depth=8)
            csr = BatchValidator(tape, max_depth=8, use_pallas=False, layout="csr")
            dense = BatchValidator(tape, max_depth=8, use_pallas=False, layout="dense")
            v_c, d_c = csr.validate(table)
            v_d, d_d = dense.validate(table)
            np.testing.assert_array_equal(v_c, v_d, err_msg=repr(schema))
            np.testing.assert_array_equal(d_c, d_d, err_msg=repr(schema))
            for i, (v, d) in enumerate(zip(v_c, d_c)):
                if d:
                    assert bool(v) == expected[i], (schema, docs[i])
            # failure sites, not just verdicts: the batched attribution
            # must name a keyword location the sequential trace also blames
            invalid = [i for i, (v, d) in enumerate(zip(v_c, d_c)) if d and not v]
            if invalid:
                checked_sites += len(invalid)
                sites = csr.explain_batch(table, docs=docs)
                for i in invalid:
                    site = sites[i]
                    assert site is not None, (schema, docs[i])
                    ok, trace = seq.explain(docs[i])
                    assert not ok, (schema, docs[i])
                    assert site.schema_path in {p for p, _ in trace}, (
                        schema, docs[i], site, trace
                    )
        assert tapes >= 25 and circuits >= 40  # the fuzzer must hit circuits
        assert checked_sites >= 40  # and the site differential must bite
