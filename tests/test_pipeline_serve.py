"""Data pipeline (admission, sharding, packing) and serving engine tests."""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import AdmissionController, ShardedPipeline
from repro.models import Model
from repro.serve.engine import REQUEST_SCHEMA, ServeConfig, ServeEngine

RECORD_SCHEMA = {
    "type": "object",
    "required": ["text"],
    "additionalProperties": False,
    "properties": {
        "text": {"type": "string", "minLength": 1},
        "quality": {"type": "number", "minimum": 0, "maximum": 1},
        "lang": {"enum": ["en", "fr", "de"]},
    },
}


def _records(n):
    recs = []
    for i in range(n):
        if i % 5 == 4:
            recs.append({"text": "", "quality": 0.5})  # invalid: minLength
        elif i % 7 == 6:
            recs.append({"text": "ok", "lang": "xx"})  # invalid: enum
        else:
            recs.append({"text": f"document number {i} " * 3, "quality": 0.9, "lang": "en"})
    return recs


class TestAdmission:
    def test_admission_counts(self):
        ctrl = AdmissionController(RECORD_SCHEMA)
        recs = _records(35)
        oks = ctrl.admit(recs)
        n_bad = sum(1 for i in range(35) if i % 5 == 4 or i % 7 == 6)
        assert sum(oks) == 35 - n_bad
        assert ctrl.stats.rejected == n_bad
        assert ctrl.stats.batch_validated + ctrl.stats.fallback_validated == 35

    def test_batch_fast_path_used(self):
        ctrl = AdmissionController(RECORD_SCHEMA)
        assert ctrl.batch_validator is not None  # structural subset
        ctrl.admit(_records(16))
        assert ctrl.stats.batch_validated > 0

    def test_fallback_on_unsupported_schema(self):
        schema = {"uniqueItems": True, "maxLength": 0}  # outside the tensor subset
        ctrl = AdmissionController(schema)
        assert ctrl.batch_validator is None
        oks = ctrl.admit([1, "s"])
        assert oks == [True, False]
        assert ctrl.stats.fallback_validated == 2


class TestShardedPipeline:
    def test_hosts_partition_records(self):
        recs = _records(64)
        seen = [set(), set()]
        for host in (0, 1):
            pipe = ShardedPipeline(
                RECORD_SCHEMA, recs, host_id=host, num_hosts=2,
                seq_len=32, batch_size=2,
            )
            for i, rec in pipe._shard_records():
                seen[host].add(i)
        assert seen[0].isdisjoint(seen[1])
        assert seen[0] | seen[1] == set(range(64))

    def test_batches_shape_and_masking(self):
        pipe = ShardedPipeline(
            RECORD_SCHEMA, _records(60), seq_len=32, batch_size=2
        )
        batches = list(pipe.batches())
        assert batches, "pipeline must yield at least one batch"
        for b in batches:
            assert b["tokens"].shape == (2, 32)
            assert b["labels"].shape == (2, 32)
            assert (b["labels"][:, -1] == -1).all()
        assert pipe.admission.stats.rejected > 0

    def test_deterministic_replay(self):
        recs = _records(60)
        a = [b["tokens"] for b in ShardedPipeline(
            RECORD_SCHEMA, recs, seq_len=32, batch_size=2).batches()]
        b = [b["tokens"] for b in ShardedPipeline(
            RECORD_SCHEMA, recs, seq_len=32, batch_size=2).batches()]
        assert len(a) == len(b)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


class TestServeEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        cfg = get_config("granite-3-8b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return ServeEngine(cfg, params, ServeConfig(batch_slots=2, max_len=64,
                                                    default_max_tokens=4))

    def test_rejects_invalid_requests(self, engine):
        rid, err = engine.submit(json.dumps({"prompt": ""}))  # minLength
        assert rid is None and "validation" in err
        rid, err = engine.submit(json.dumps({"max_tokens": 4}))  # missing prompt
        assert rid is None
        rid, err = engine.submit("{not json")
        assert rid is None and "malformed" in err
        rid, err = engine.submit(json.dumps({"prompt": "hi", "extra": 1}))
        assert rid is None  # closed object

    def test_serves_valid_requests(self, engine):
        ids = []
        for i in range(3):
            rid, err = engine.submit(
                json.dumps({"prompt": f"request {i}", "max_tokens": 3})
            )
            assert rid is not None, err
            ids.append(rid)
        results = engine.run_until_drained(max_steps=64)
        for rid in ids:
            assert rid in results
        assert engine.stats.completed >= 3
        assert engine.stats.validation_seconds < 1.0  # admission is cheap


class TestMultiEndpointServe:
    @pytest.fixture(scope="class")
    def engine(self):
        cfg = get_config("granite-3-8b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return ServeEngine(cfg, params, ServeConfig(batch_slots=2, max_len=64,
                                                    default_max_tokens=4))

    def test_multi_endpoint_submit_batch(self, engine):
        # hosted alongside "default": two more endpoints through the
        # registry; mixed burst admits via the linked tape in one launch
        engine.registry.register("echo", {
            "type": "object", "required": ["input"], "additionalProperties": False,
            "properties": {"input": {"type": "string", "minLength": 1}},
        })
        engine.registry.register("score", {
            "type": "object", "required": ["value"],
            "properties": {"value": {"type": "number", "minimum": 0, "maximum": 1}},
        })
        before = engine.stats.batch_validated
        results = engine.submit_batch([
            ("echo", json.dumps({"input": "hello"})),
            ("score", json.dumps({"value": 0.5})),
            ("score", json.dumps({"value": 2.0})),     # invalid: maximum
            ("echo", json.dumps({"input": ""})),       # invalid: minLength
            ("default", json.dumps({"prompt": "hi", "max_tokens": 2})),
            ("nope", json.dumps({})),                  # unknown endpoint
            ("echo", "{not json"),
        ])
        assert [rid is not None for rid, _ in results] == [
            True, True, False, False, True, False, False]
        assert "unknown endpoint" in results[5][1]
        assert "malformed" in results[6][1]
        # echo/score rows validated on the linked tape; "default" uses
        # propertyNames (sequential-only member)
        assert engine.stats.batch_validated - before >= 4
        engine.run_until_drained(max_steps=64)

    def test_per_endpoint_stats_and_submit_routing(self, engine):
        # self-contained: registers its own endpoint and asserts deltas
        engine.registry.register("stats-ep", {
            "type": "object", "required": ["input"],
            "properties": {"input": {"type": "string"}},
        })
        before = dict(engine.stats.by_endpoint.get("stats-ep",
                                                   {"admitted": 0, "rejected": 0}))
        rid, err = engine.submit(
            json.dumps({"input": "one more"}), endpoint="stats-ep"
        )
        assert rid is not None, err
        rid, _ = engine.submit(json.dumps({"input": 5}), endpoint="stats-ep")
        assert rid is None
        per = engine.stats.by_endpoint["stats-ep"]
        assert per["admitted"] - before["admitted"] == 1
        assert per["rejected"] - before["rejected"] == 1
        engine.run_until_drained(max_steps=64)
