#!/usr/bin/env bash
# Conformance corpus gate: every engine (naive / interpreter / codegen /
# batched) must agree with the vendored JSON-Schema-Test-Suite-style
# cases for the logical/unevaluated/uniqueItems keywords.  Emits
# results/conformance_summary.json for the CI artifact upload.
set -euo pipefail
cd "$(dirname "$0")/.."
exec python scripts/conformance.py "$@"
