#!/usr/bin/env python3
"""CI perf gate: diff fresh ``results/BENCH_*.json`` against the
committed snapshots (DESIGN.md §12).

Usage (after ``python -m benchmarks.run`` has refreshed the working-tree
results)::

    python scripts/bench_gate.py [--ref HEAD] [--threshold 0.25]

For every ``results/BENCH_*.json`` present in the working tree, the gate
loads the version committed at ``--ref`` via ``git show`` and walks both
JSON trees in parallel.  Numeric leaves whose key ends in
``us_per_doc`` (per-doc latency) or ``p99_ms`` (serve-load tail
latency at an offered rate) are latency-style (lower is better) and
**gated**: a fresh value more than ``threshold`` (default 25%) above
the committed value fails the gate.  Everything else -- counts,
percentages, throughputs -- is informational only.

Noisy fields that legitimately swing run-to-run sit on an allowlist and
are reported but never gated:

- ``traced_us_per_doc``     -- armed-tracer timing includes ring churn
- ``total_us_per_doc``      -- poisoned-batch bisection timing
  (BENCH_robustness) depends on fault placement

Benchmarks new in this PR (present in the tree, absent at ``--ref``)
**pass** with a ``new benchmark`` note -- their first committed snapshot
becomes the baseline for the next PR.  A baseline that exists but does
not parse (e.g. a historical merge artifact) is treated the same way,
never as a crash.

Besides the console report, the gate writes
``results/gate_summary.json`` -- machine-readable comparisons +
failures -- which ``scripts/perf_report.py`` folds into the
consolidated perf trajectory report.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results"

GATED_SUFFIXES = ("us_per_doc", "p99_ms", "us_per_schema")
ALLOWLIST = {"traced_us_per_doc", "total_us_per_doc"}


def _committed(ref: str, relpath: str, repo: Path = REPO) -> Any:
    try:
        blob = subprocess.run(
            ["git", "show", f"{ref}:{relpath}"],
            cwd=repo,
            capture_output=True,
            check=True,
        ).stdout
    except (subprocess.CalledProcessError, OSError):
        return None  # not committed at ref (new benchmark)
    try:
        return json.loads(blob)
    except json.JSONDecodeError:
        return None  # unparseable baseline: same disposition as absent


def _leaves(obj: Any, path: str = "") -> Iterator[Tuple[str, str, float]]:
    """Yield (dotted_path, leaf_key, value) for every numeric leaf."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _leaves(v, f"{path}.{k}" if path else str(k))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _leaves(v, f"{path}[{i}]")
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield path, path.rsplit(".", 1)[-1].rsplit("[", 1)[0], float(obj)


def gate(
    ref: str,
    threshold: float,
    *,
    results_dir: Path = RESULTS,
    repo: Path = REPO,
    summary_path: Optional[Path] = None,
) -> int:
    """Run the gate; returns the process exit code (0 pass, 1 fail).

    ``results_dir``/``repo`` are parameters so tests can gate a synthetic
    results tree against a scratch git repo.  ``summary_path`` (default:
    ``<results_dir>/gate_summary.json``) receives the machine-readable
    summary consumed by ``scripts/perf_report.py``.
    """
    failures: List[str] = []
    comparisons: List[Dict[str, Any]] = []
    new_benchmarks: List[str] = []
    unreadable: List[str] = []
    gated = 0
    for fresh_path in sorted(results_dir.glob("BENCH_*.json")):
        try:
            rel = fresh_path.relative_to(repo).as_posix()
        except ValueError:
            rel = fresh_path.name  # results tree outside the repo (tests)
        try:
            fresh = json.loads(fresh_path.read_text())
        except json.JSONDecodeError as exc:
            # a fresh BENCH file that does not parse means the benchmark
            # wrote garbage THIS run -- that is a failure, not a skip
            failures.append(f"{rel}: unreadable fresh results ({exc})")
            unreadable.append(rel)
            print(f"FAIL  {rel}: unreadable fresh results: {exc}")
            continue
        base = _committed(ref, rel, repo)
        if base is None:
            print(f"PASS  {rel}: no snapshot at {ref} (new benchmark)")
            new_benchmarks.append(rel)
            continue
        base_leaves = {p: v for p, _, v in _leaves(base)}
        for dotted, key, new in _leaves(fresh):
            if not key.endswith(GATED_SUFFIXES):
                continue
            old = base_leaves.get(dotted)
            if old is None or old <= 0:
                print(f"SKIP  {rel}:{dotted}: no baseline value")
                continue
            delta = (new - old) / old
            tag = "ALLOW" if key in ALLOWLIST else "GATE "
            verdict = "ok"
            if delta > threshold:
                if key in ALLOWLIST:
                    verdict = "noisy (allowlisted)"
                else:
                    verdict = "FAIL"
                    failures.append(
                        f"{rel}:{dotted}: {old:.3f} -> {new:.3f} "
                        f"(+{delta * 100:.1f}% > {threshold * 100:.0f}%)"
                    )
            gated += key not in ALLOWLIST
            comparisons.append(
                {
                    "file": rel,
                    "path": dotted,
                    "baseline": old,
                    "fresh": new,
                    "delta_pct": delta * 100,
                    "allowlisted": key in ALLOWLIST,
                    "verdict": verdict,
                }
            )
            print(
                f"{tag} {rel}:{dotted}: {old:.3f} -> {new:.3f} "
                f"({delta * +100:+.1f}%) {verdict}"
            )
    print(
        f"\nbench_gate: {gated} gated comparisons, "
        f"{len(new_benchmarks)} new benchmarks, {len(failures)} failures"
    )
    if failures:
        print("\nREGRESSIONS over threshold:")
        for f in failures:
            print(f"  {f}")
    summary = {
        "ref": ref,
        "threshold": threshold,
        "status": "fail" if failures else "pass",
        "gated_comparisons": gated,
        "comparisons": comparisons,
        "new_benchmarks": new_benchmarks,
        "unreadable": unreadable,
        "failures": failures,
    }
    out = summary_path if summary_path is not None else results_dir / "gate_summary.json"
    try:
        out.write_text(json.dumps(summary, indent=2) + "\n")
    except OSError as exc:  # the summary is an artifact, not the verdict
        print(f"bench_gate: could not write {out}: {exc}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ref", default="HEAD", help="git ref holding baselines")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="max tolerated fractional regression on gated keys",
    )
    args = ap.parse_args()
    if not RESULTS.is_dir():
        print("bench_gate: no results/ directory; run benchmarks first")
        return 1
    return gate(args.ref, args.threshold)


if __name__ == "__main__":
    sys.exit(main())
