#!/usr/bin/env python3
"""Consolidated perf trajectory report (DESIGN.md §13).

Folds every machine-readable artifact the harness emits --
``results/BENCH_*.json``, ``results/gate_summary.json`` (written by
``scripts/bench_gate.py``), and ``results/conformance_summary.json`` --
into one report in two renderings:

- ``results/perf_report.json`` -- the consolidated tree CI archives and
  downstream tooling queries
- ``results/perf_report.md``   -- the same numbers as a human-readable
  trajectory table (headline us/doc latencies, serve-load percentile
  sweep, phase attribution, SLO posture, gate verdict)

Usage::

    python scripts/perf_report.py [--results results/]

The report is assembled from whatever artifacts exist: a missing file
is reported as absent, never a crash, so the script is safe to run on a
partial results tree (e.g. CI jobs that only refreshed one benchmark).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results"

HEADLINE_SUFFIX = "us_per_doc"


def _load(path: Path) -> Optional[Any]:
    try:
        return json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None


def _leaves(obj: Any, path: str = "") -> Iterator[Tuple[str, str, float]]:
    """Yield (dotted_path, leaf_key, value) for every numeric leaf."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from _leaves(v, f"{path}.{k}" if path else str(k))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _leaves(v, f"{path}[{i}]")
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield path, path.rsplit(".", 1)[-1].rsplit("[", 1)[0], float(obj)


def _headlines(bench: Dict[str, Any]) -> Dict[str, float]:
    """The latency-style leaves bench_gate gates: the perf trajectory."""
    return {
        dotted: value
        for dotted, key, value in _leaves(bench)
        if key.endswith(HEADLINE_SUFFIX)
    }


def _conformance_totals(summary: Any) -> Optional[Dict[str, Dict[str, int]]]:
    if not isinstance(summary, dict) or "files" not in summary:
        return None
    totals: Dict[str, Dict[str, int]] = {}
    for per_engine in summary["files"].values():
        for engine, counts in per_engine.items():
            agg = totals.setdefault(
                engine, {"passed": 0, "failed": 0, "skipped": 0}
            )
            for k in agg:
                agg[k] += int(counts.get(k, 0))
    return totals


def collect(results_dir: Path = RESULTS) -> Dict[str, Any]:
    """Assemble the consolidated report tree from a results directory."""
    benchmarks: Dict[str, Any] = {}
    for path in sorted(results_dir.glob("BENCH_*.json")):
        name = path.stem[len("BENCH_") :]
        bench = _load(path)
        if bench is None:
            benchmarks[name] = {"error": "unreadable"}
            continue
        entry: Dict[str, Any] = {"headline": _headlines(bench)}
        if name == "serve_load":
            entry["rates"] = [
                {
                    k: row[k]
                    for k in (
                        "offered_rate_per_s",
                        "p50_ms",
                        "p99_ms",
                        "p999_ms",
                        "mean_batch",
                        "utilization",
                        "max_queue_depth",
                    )
                    if k in row
                }
                for row in bench.get("rates", [])
            ]
            entry["stream_rates"] = [
                {
                    k: row[k]
                    for k in (
                        "offered_rate_per_s",
                        "p50_ms",
                        "p99_ms",
                        "p999_ms",
                        "mean_batch",
                        "utilization",
                        "max_queue_depth",
                    )
                    if k in row
                }
                for row in bench.get("stream_rates", [])
            ]
            entry["stream_vs_batch"] = bench.get("stream_vs_batch", [])
            entry["endpoint_slo"] = bench.get("endpoint_slo", {})
        if name == "compile" and "analysis" in bench:
            a = bench["analysis"]
            entry["analysis"] = {
                "analysis_us_per_schema": a.get("analysis_us_per_schema"),
                "pruned_branches": a.get("pruned_branches"),
                "folded_assertions": a.get("folded_assertions"),
                "schemas": [
                    {
                        k: row[k]
                        for k in (
                            "name",
                            "analysis_us",
                            "pruned_branches",
                            "folded_assertions",
                            "delta",
                        )
                        if k in row
                    }
                    for row in a.get("schemas", [])
                ],
            }
        if name == "observability" and "profile" in bench:
            prof = bench["profile"]
            entry["attribution"] = {
                "coverage": prof.get("coverage"),
                "profiler_armed_overhead_pct": prof.get(
                    "profiler_armed_overhead_pct"
                ),
                "disarmed_seam_overhead_pct": prof.get(
                    "disarmed_seam_overhead_pct"
                ),
                "top_phases": dict(
                    sorted(
                        prof.get("phases", {}).items(),
                        key=lambda kv: kv[1].get("self_ns", 0.0),
                        reverse=True,
                    )[:8]
                ),
            }
        benchmarks[name] = entry

    gate = _load(results_dir / "gate_summary.json")
    conformance = _conformance_totals(
        _load(results_dir / "conformance_summary.json")
    )
    analysis = _load(results_dir / "analysis_report.json")
    return {
        "benchmarks": benchmarks,
        "gate": gate,
        "conformance": conformance,
        "analysis": analysis,
    }


def render_markdown(report: Dict[str, Any]) -> str:
    out: List[str] = ["# Perf trajectory report", ""]

    gate = report.get("gate")
    if gate:
        out.append(
            f"**Gate**: {gate['status']} "
            f"({gate['gated_comparisons']} gated comparisons, "
            f"{len(gate.get('new_benchmarks', []))} new benchmarks, "
            f"{len(gate.get('failures', []))} failures vs "
            f"`{gate.get('ref', '?')}` at {gate.get('threshold', 0) * 100:.0f}%)"
        )
    else:
        out.append("**Gate**: not run (no gate_summary.json)")
    out.append("")

    out.append("## Headline latencies (us/doc)")
    out.append("")
    out.append("| benchmark | metric | us/doc |")
    out.append("|---|---|---:|")
    for name, entry in report["benchmarks"].items():
        for dotted, value in sorted(entry.get("headline", {}).items()):
            out.append(f"| {name} | {dotted} | {value:.3f} |")
    out.append("")

    serve = report["benchmarks"].get("serve_load", {})
    if serve.get("rates"):
        out.append("## Open-loop serve load (Poisson arrivals)")
        out.append("")
        out.append(
            "| offered/s | p50 ms | p99 ms | p99.9 ms | mean batch "
            "| util | max queue |"
        )
        out.append("|---:|---:|---:|---:|---:|---:|---:|")
        for row in serve["rates"]:
            out.append(
                f"| {row['offered_rate_per_s']:.0f} "
                f"| {row['p50_ms']:.2f} | {row['p99_ms']:.2f} "
                f"| {row['p999_ms']:.2f} | {row['mean_batch']:.1f} "
                f"| {row['utilization']:.2f} | {row['max_queue_depth']} |"
            )
        out.append("")
    if serve.get("stream_vs_batch"):
        out.append("## Stream scheduler vs synchronous batch (p99)")
        out.append("")
        out.append("| offered/s | batch p99 ms | stream p99 ms | speedup |")
        out.append("|---:|---:|---:|---:|")
        for row in serve["stream_vs_batch"]:
            out.append(
                f"| {row['offered_rate_per_s']:.0f} "
                f"| {row['batch_p99_ms']:.2f} "
                f"| {row['stream_p99_ms']:.2f} "
                f"| {row['stream_speedup_p99']:.1f}x |"
            )
        out.append("")
    if serve.get("endpoint_slo"):
        out.append("## Per-endpoint SLO")
        out.append("")
        out.append("| endpoint | objective s | target | good ratio | burn |")
        out.append("|---|---:|---:|---:|---:|")
        for ep, s in sorted(serve["endpoint_slo"].items()):
            out.append(
                f"| {ep} | {s.get('objective_s', 0):.3f} "
                f"| {s.get('target', 0):.3f} "
                f"| {s.get('good_ratio', 0):.4f} "
                f"| {s.get('burn_rate', 0):.2f} |"
            )
        out.append("")

    obs = report["benchmarks"].get("observability", {})
    attr = obs.get("attribution")
    if attr:
        out.append("## Cost attribution (armed profiler, one admit)")
        out.append("")
        cov = attr.get("coverage")
        out.append(
            f"Coverage: **{cov * 100:.1f}%**" if cov is not None else
            "Coverage: n/a"
        )
        armed = attr.get("profiler_armed_overhead_pct")
        if armed is not None:
            out.append(f", armed overhead {armed:+.2f}%")
        seam = attr.get("disarmed_seam_overhead_pct")
        if seam is not None:
            out.append(f", disarmed seam vs baseline {seam:+.2f}%")
        out.append("")
        out.append("| phase | calls | self ms | share |")
        out.append("|---|---:|---:|---:|")
        total = sum(
            p.get("self_ns", 0.0) for p in attr.get("top_phases", {}).values()
        )
        for phase, p in sorted(
            attr.get("top_phases", {}).items(),
            key=lambda kv: kv[1].get("self_ns", 0.0),
            reverse=True,
        ):
            self_ns = p.get("self_ns", 0.0)
            share = self_ns / total if total else 0.0
            out.append(
                f"| {phase} | {p.get('calls', 0)} "
                f"| {self_ns / 1e6:.2f} | {share * 100:.1f}% |"
            )
        out.append("")

    compile_bench = report["benchmarks"].get("compile", {})
    comp_analysis = compile_bench.get("analysis")
    if comp_analysis:
        out.append("## Schema-algebra ledger (register()-time analysis)")
        out.append("")
        aus = comp_analysis.get("analysis_us_per_schema")
        out.append(
            f"Mean analysis cost: **{aus:.0f} us/schema**; "
            f"{comp_analysis.get('pruned_branches', 0)} branches pruned, "
            f"{comp_analysis.get('folded_assertions', 0)} assertions folded "
            f"across the preset + directed corpus."
        )
        out.append("")
        out.append("| schema | analyze us | pruned | folded | dA-hat | dcircuits |")
        out.append("|---|---:|---:|---:|---:|---:|")
        for row in comp_analysis.get("schemas", []):
            d = row.get("delta", {})
            out.append(
                f"| {row['name']} | {row.get('analysis_us', 0):.0f} "
                f"| {row.get('pruned_branches', 0)} "
                f"| {row.get('folded_assertions', 0)} "
                f"| {d.get('a_hat', 0)} | {d.get('n_circuits', 0)} |"
            )
        out.append("")

    analysis = report.get("analysis")
    if analysis and analysis.get("endpoints"):
        out.append("## Endpoint analysis posture (registry presets)")
        out.append("")
        out.append(
            "| endpoint | normalized | pruned | folded | dedup | lint |"
        )
        out.append("|---|---|---:|---:|---:|---|")
        for ep, p in sorted(analysis["endpoints"].items()):
            out.append(
                f"| {ep} | {'yes' if p.get('normalized') else 'no'} "
                f"| {p.get('pruned_branches', 0)} "
                f"| {p.get('folded_assertions', 0)} "
                f"| {p.get('dedup_subgraphs', 0)} "
                f"| {p.get('lint', '?')} |"
            )
        out.append("")

    conf = report.get("conformance")
    if conf:
        out.append("## Conformance totals")
        out.append("")
        out.append("| engine | passed | failed | skipped |")
        out.append("|---|---:|---:|---:|")
        for engine, counts in sorted(conf.items()):
            out.append(
                f"| {engine} | {counts['passed']} | {counts['failed']} "
                f"| {counts['skipped']} |"
            )
        out.append("")

    return "\n".join(out) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--results",
        type=Path,
        default=RESULTS,
        help="results directory to consolidate",
    )
    args = ap.parse_args()
    if not args.results.is_dir():
        print(f"perf_report: no such results directory: {args.results}")
        return 1
    report = collect(args.results)
    (args.results / "perf_report.json").write_text(
        json.dumps(report, indent=2) + "\n"
    )
    md = render_markdown(report)
    (args.results / "perf_report.md").write_text(md)
    n_bench = len(report["benchmarks"])
    gate = report.get("gate")
    print(
        f"perf_report: consolidated {n_bench} benchmarks, "
        f"gate={'absent' if gate is None else gate['status']} -> "
        f"{args.results / 'perf_report.json'}, "
        f"{args.results / 'perf_report.md'}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
