#!/usr/bin/env python
"""JSON-Schema-Test-Suite-style conformance corpus runner.

Loads the vendored case files under ``tests/conformance/`` (the official
suite's format: a list of groups, each ``{description, schema, tests:
[{description, data, valid}]}``) and runs every case through all four
engines:

* ``naive``        -- NaiveValidator (direct schema interpretation)
* ``interpreter``  -- compiled instruction interpreter (paper §5)
* ``codegen``      -- compiled closure engine
* ``batched``      -- the tensorised tape executor where the schema is
  batchable (hybrid contract: undecided documents route to the
  sequential verdict; unbatchable schemas count as ``skipped``)

Writes a pass/fail summary to ``results/conformance_summary.json`` (the
CI artifact) and exits non-zero if any engine disagrees with a corpus
expectation.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.core import NaiveValidator, Validator, compile_schema  # noqa: E402
from repro.core.batch_executor import BatchValidator  # noqa: E402
from repro.core.tape import try_build_tape  # noqa: E402
from repro.data.doc_table import encode_batch  # noqa: E402

CORPUS = ROOT / "tests" / "conformance"
RESULTS = ROOT / "results"

ENGINES = ("naive", "interpreter", "codegen", "batched")


def run_corpus() -> dict:
    summary = {
        "files": {},
        "totals": {e: {"passed": 0, "failed": 0, "skipped": 0} for e in ENGINES},
        "failures": [],
    }
    for path in sorted(CORPUS.glob("*.json")):
        file_stats = {e: {"passed": 0, "failed": 0, "skipped": 0} for e in ENGINES}
        for group in json.loads(path.read_text()):
            schema = group["schema"]
            naive = NaiveValidator(schema)
            compiled = compile_schema(schema)
            interp = Validator(compiled, engine="interpreter")
            codegen = Validator(compiled, engine="codegen")
            tape, _reason = try_build_tape(compiled)
            batch = (
                BatchValidator(tape, use_pallas=False) if tape is not None else None
            )
            for test in group["tests"]:
                doc, expected = test["data"], test["valid"]
                verdicts = {
                    "naive": naive.is_valid(doc),
                    "interpreter": interp.is_valid(doc),
                    "codegen": codegen.is_valid(doc),
                }
                if batch is None:
                    verdicts["batched"] = None  # skipped: outside the subset
                else:
                    table = encode_batch([doc], max_nodes=128, max_depth=16)
                    valid, decided = batch.validate(table)
                    # hybrid contract: undecided rows get the sequential verdict
                    verdicts["batched"] = (
                        bool(valid[0]) if decided[0] else interp.is_valid(doc)
                    )
                for engine in ENGINES:
                    got = verdicts[engine]
                    if got is None:
                        file_stats[engine]["skipped"] += 1
                    elif got is expected:
                        file_stats[engine]["passed"] += 1
                    else:
                        file_stats[engine]["failed"] += 1
                        summary["failures"].append(
                            {
                                "file": path.name,
                                "group": group["description"],
                                "test": test["description"],
                                "engine": engine,
                                "expected": expected,
                                "got": got,
                            }
                        )
        summary["files"][path.name] = file_stats
        for engine in ENGINES:
            for k in ("passed", "failed", "skipped"):
                summary["totals"][engine][k] += file_stats[engine][k]
    return summary


def main() -> int:
    summary = run_corpus()
    RESULTS.mkdir(exist_ok=True)
    out = RESULTS / "conformance_summary.json"
    out.write_text(json.dumps(summary, indent=1) + "\n")
    for engine, tot in summary["totals"].items():
        print(
            f"{engine:12s} passed={tot['passed']:4d} failed={tot['failed']:3d} "
            f"skipped={tot['skipped']:3d}"
        )
    if summary["failures"]:
        print(f"\n{len(summary['failures'])} failure(s); first 20:")
        for f in summary["failures"][:20]:
            print(f"  [{f['engine']}] {f['file']} :: {f['group']} :: {f['test']} "
                  f"expected={f['expected']} got={f['got']}")
        return 1
    print(f"\nOK -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
