#!/usr/bin/env bash
# Tier-1 verify: the exact command the roadmap pins (ROADMAP.md).
# Usage: scripts/tier1.sh [extra pytest args]
#
# The suite runs >5 min; --durations surfaces the hot spots so slow
# creep is visible per run.  The subprocess-spawning distributed tests
# are marked `slow` -- `scripts/tier1.sh -m "not slow"` is the quick
# local loop (CI always runs everything).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q --durations=15 "$@"
