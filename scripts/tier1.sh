#!/usr/bin/env bash
# Tier-1 verify: the exact command the roadmap pins (ROADMAP.md).
# Usage: scripts/tier1.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
