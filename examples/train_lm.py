"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on JSON records that pass Blaze admission (deliverable (b)).

The pipeline validates every record against the dataset schema before
tokenization; the supervisor checkpoints periodically and demonstrates
resume.  CPU-sized by default; pass --steps to change.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import itertools
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.corpus import make_dataset
from repro.data.pipeline import ShardedPipeline
from repro.models import Model
from repro.models.config import ArchConfig, LayerSpec
from repro.train import optimizer as opt
from repro.train.checkpoint import CheckpointManager
from repro.train.supervisor import SupervisorConfig, TrainSupervisor

# ~100M-parameter dense config (same family as granite-3-8b)
CFG = ArchConfig(
    name="granite-100m",
    family="dense",
    n_layers=8,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1536,
    vocab_size=512,  # byte tokenizer + specials
    period=(LayerSpec(mixer="attention", ffn="dense"),),
    max_seq_len=256,
)

RECORD_SCHEMA = {
    "type": "object",
    "required": ["text"],
    "properties": {"text": {"type": "string", "minLength": 8}},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    model = Model(CFG)
    print(f"params: {CFG.param_count()/1e6:.1f}M")
    params = model.init(jax.random.PRNGKey(0))
    ocfg = opt.OptimizerConfig(
        learning_rate=1e-3, warmup_steps=20, total_steps=args.steps
    )
    opt_state = opt.init(ocfg, params)

    # training records: JSON documents from the benchmark corpus generator,
    # admitted through the compiled validator
    ds = make_dataset("train-corpus", 4000, 8.0, 400, seed=7)
    records = [{"text": __import__("json").dumps(d)} for d in ds.documents]
    pipe = ShardedPipeline(
        RECORD_SCHEMA, records, seq_len=args.seq_len, batch_size=args.batch
    )

    @jax.jit
    def step_fn(p, s, batch):
        def loss_fn(pp):
            return model.loss(
                pp, jnp.asarray(batch["tokens"]), jnp.asarray(batch["labels"]),
                remat=False,
            )

        loss, grads = jax.value_and_grad(loss_fn)(p)
        new_p, new_s, m = opt.update(ocfg, grads, s, p)
        return new_p, new_s, dict(m, loss=loss)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2)
        sup = TrainSupervisor(step_fn, mgr, SupervisorConfig(checkpoint_every=50))
        batches = itertools.cycle(pipe.batches())
        t0 = time.time()
        params, opt_state, hist = sup.run(
            params, opt_state, batches, num_steps=args.steps
        )
        dt = time.time() - t0
        losses = [r.loss for r in hist if np.isfinite(r.loss)]
        print(
            f"steps={len(hist)} wall={dt:.1f}s "
            f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
            f"(admission: {pipe.admission.stats.admitted} admitted, "
            f"{pipe.admission.stats.rejected} rejected)"
        )
        assert losses[-1] < losses[0], "training must reduce loss"
        # demonstrate resume-from-checkpoint
        start, p2, s2 = TrainSupervisor(step_fn, mgr, SupervisorConfig()).resume_or_init(
            params, opt_state
        )
        print(f"resume: latest checkpoint at step {start}")


if __name__ == "__main__":
    main()
