"""API-gateway serving: Blaze request admission + batched LM decode.

The paper's deployment scenario end-to-end: every request is validated
against the request schema on the critical path, then served by a small
LM with continuous batching.

Run: PYTHONPATH=src python examples/api_gateway.py
"""

import json

import jax

from repro.configs import get_config
from repro.models import Model
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg, params, ServeConfig(batch_slots=2, max_len=96, default_max_tokens=8)
    )

    requests = [
        {"prompt": "The paper introduces", "max_tokens": 6},
        {"prompt": "JSON Schema validation is", "max_tokens": 6},
        {"prompt": ""},                                # invalid: minLength
        {"prompt": "ok", "max_tokens": 100000},        # invalid: maximum
        {"prompt": "Compilers amortize", "temperature": 0.2, "max_tokens": 6},
        {"prompt": "hi", "unexpected": True},          # invalid: closed
    ]
    ids = {}
    for req in requests:
        rid, err = engine.submit(json.dumps(req))
        status = f"admitted id={rid}" if rid is not None else f"rejected ({err})"
        print(f"  {status:40s} {json.dumps(req)[:60]}")
        if rid is not None:
            ids[rid] = req["prompt"]

    results = engine.run_until_drained(max_steps=128)
    print("\ncompletions (byte-level model, untrained -- shapes not prose):")
    for rid, prompt in ids.items():
        print(f"  [{rid}] {prompt!r} -> {results.get(rid, '')!r}")
    s = engine.stats
    print(
        f"\nstats: received={s.received} admitted={s.admitted} rejected={s.rejected} "
        f"completed={s.completed} decode_steps={s.decode_steps} "
        f"validation={s.validation_seconds*1e6:.0f}us total"
    )


if __name__ == "__main__":
    main()
