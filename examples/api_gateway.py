"""Multi-tenant API gateway: one linked tape validating every endpoint.

The paper's deployment scenario end-to-end, at gateway scale: the
schema registry hosts several endpoint request schemas (completions,
chat, embeddings, moderation -- plus the kitchen-sink default), the tape
linker fuses their location tapes into ONE linked tape, and a mixed
request burst is admitted in a single batched validation launch before
the expensive work (LM decode with continuous batching).

Run: PYTHONPATH=src python examples/api_gateway.py
"""

import json

import jax

from repro.configs import get_config
from repro.models import Model
from repro.registry.presets import GATEWAY_SCHEMAS
from repro.serve.engine import ServeConfig, ServeEngine


def main() -> None:
    cfg = get_config("granite-3-8b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg,
        params,
        ServeConfig(batch_slots=2, max_len=96, default_max_tokens=8),
        endpoint_schemas=GATEWAY_SCHEMAS,
    )

    linked = engine.registry.linked_tape()
    print(
        f"registry: {len(engine.registry.endpoints())} endpoints; linked tape "
        f"members={list(linked.members)} locations={linked.n_locations} "
        f"assertions={linked.n_assertions} A-hat={linked.max_rows_per_loc} "
        f"K={linked.max_hash_run}"
    )
    for ep in engine.registry.endpoints():
        st = engine.registry.get(ep).stats
        mode = "linked-tape" if st.batchable else f"sequential ({st.fallback_reason})"
        print(f"  {ep:10s} v{engine.registry.get(ep).version} "
              f"compile={st.compile_seconds*1e3:.1f}ms -> {mode}")

    # one mixed burst through ONE batched validation launch
    burst = [
        ("complete", {"prompt": "The paper introduces", "max_tokens": 6}),
        ("chat", {"messages": [{"role": "user", "content": "Compilers amortize"}],
                  "max_tokens": 6}),
        ("embed", {"input": "schema validation"}),
        ("moderate", {"input": "hello there", "category": "spam"}),
        ("complete", {"prompt": ""}),                       # invalid: minLength
        ("chat", {"messages": []}),                         # invalid: minItems
        ("embed", {"input": "x", "dimensions": 2}),         # invalid: minimum
        ("moderate", {"input": "hi", "category": "other"}), # invalid: enum
        ("complete", {"prompt": "ok", "max_tokens": 100000}),  # invalid: maximum
        ("default", {"prompt": "JSON Schema validation is", "max_tokens": 6,
                     "metadata": {"tenant": "acme"}}),      # sequential member
        ("chat", {"messages": [{"role": "user", "content": "hi"},
                               {"role": "assistant", "content": "hello"}],
                  "max_tokens": 6}),
    ]
    results = engine.submit_batch([(ep, json.dumps(req)) for ep, req in burst])
    ids = {}
    for (ep, req), (rid, err) in zip(burst, results):
        status = f"admitted id={rid}" if rid is not None else f"rejected ({err})"
        print(f"  {ep:10s} {status:32s} {json.dumps(req)[:48]}")
        if rid is not None:
            ids[rid] = ep

    completions = engine.run_until_drained(max_steps=128)
    print("\ncompletions (byte-level model, untrained -- shapes not prose):")
    for rid, ep in ids.items():
        print(f"  [{rid}] {ep:10s} -> {completions.get(rid, '')!r}")

    s = engine.stats
    print(
        f"\nstats: received={s.received} admitted={s.admitted} rejected={s.rejected} "
        f"completed={s.completed} decode_steps={s.decode_steps}\n"
        f"       batch_validated={s.batch_validated} "
        f"fallback_validated={s.fallback_validated} "
        f"validation={s.validation_seconds*1e6:.0f}us total"
    )
    print(f"       by_endpoint={s.by_endpoint}")

    # hot-swap: tighten the moderation schema; re-link is incremental
    moderate_v2 = dict(GATEWAY_SCHEMAS["moderate"])
    moderate_v2["properties"] = dict(
        moderate_v2["properties"], category={"enum": ["toxicity", "violence"]}
    )
    engine.registry.register("moderate", moderate_v2)
    rid, err = engine.submit(
        json.dumps({"input": "hi", "category": "spam"}), endpoint="moderate"
    )
    print(f"\nafter hot-swap to moderate v2: spam category -> "
          f"{'admitted' if rid is not None else f'rejected ({err})'}")


if __name__ == "__main__":
    main()
