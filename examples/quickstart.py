"""Quickstart: compile a JSON Schema with Blaze and validate documents.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import json
import time

from repro.core import CompilerOptions, NaiveValidator, Validator, compile_schema

SCHEMA = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "type": "object",
    "required": ["firstName", "lastName"],
    "additionalProperties": False,
    "properties": {
        "firstName": {"type": "string", "maxLength": 100},
        "middleName": {"type": "string", "maxLength": 100},
        "lastName": {"type": "string", "maxLength": 100},
        "age": {"type": "integer", "minimum": 0},
        "email": {"type": "string", "pattern": "^[^@]+@"},
        "role": {"enum": ["admin", "editor", "viewer"]},
    },
}

DOCS = [
    {"firstName": "Douglas", "lastName": "Jason", "age": 20},          # valid
    {"firstName": "Ada", "lastName": "L", "role": "admin"},            # valid
    {"firstName": "Bob"},                                              # missing lastName
    {"firstName": "Eve", "lastName": "X", "age": -1},                  # minimum
    {"firstName": "Mallory", "lastName": "Y", "color": "red"},         # closed object
]


def main() -> None:
    # Compile once (schemas change every ~65 days; validation runs per request)
    t0 = time.perf_counter()
    compiled = compile_schema(SCHEMA)
    print(f"compiled {compiled.instruction_count()} instructions "
          f"in {(time.perf_counter()-t0)*1e3:.2f} ms")

    validator = Validator(compiled)
    for doc in DOCS:
        print(f"  {'VALID  ' if validator.is_valid(doc) else 'INVALID'}  {json.dumps(doc)}")

    # Hot loop vs the naive interpreting validator.  Documents are parsed
    # once (the paper computes hashes at parse time, §4.1) -- an API
    # gateway parses each request exactly once anyway.
    from repro.core.doc_model import parse_document

    naive = NaiveValidator(SCHEMA)
    codegen = Validator(compiled, engine="codegen")
    parsed = [parse_document(d) for d in DOCS]
    n = 20_000
    timings = {}
    for name, fn in [
        ("blaze", lambda d: validator.is_valid(d, parsed=True)),
        ("codegen", lambda d: codegen.is_valid(d, parsed=True)),
    ]:
        t0 = time.perf_counter()
        for _ in range(n // len(DOCS)):
            for doc in parsed:
                fn(doc)
        timings[name] = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n // len(DOCS)):
        for doc in DOCS:
            naive.is_valid(doc)
    timings["naive"] = time.perf_counter() - t0
    print(f"\nhot loop ({n} validations):")
    for name in ("blaze", "codegen", "naive"):
        rel = timings["naive"] / timings[name]
        print(f"  {name:8s} {timings[name]*1e9/n:8.0f} ns/doc   ({rel:.1f}x vs naive)")


if __name__ == "__main__":
    main()
