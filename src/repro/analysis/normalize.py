"""Canonicalizer / normalizer / pruner pass pipeline (DESIGN.md §15).

``analyze_schema`` rewrites a schema toward a canonical form:

1.  **Constant folding** -- enum/const/type intersections, dedup and
    sorting of ``enum``/``required``/``type`` lists, singleton-enum ->
    ``const``, no-op removal (``minLength: 0`` etc.);
2.  **Bound tightening** -- redundant ``minimum`` vs numeric
    ``exclusiveMinimum`` (and the max side) collapse to the tighter;
3.  **allOf flattening + hoisting** -- nested allOf splice, and
    conjunctive keys hoisted/merged into the parent when their
    semantics are provably local (no interaction partner present);
4.  **Satisfiability pruning** -- subschemas proven unsatisfiable by
    the :mod:`.sat` over-approximation become ``false``; false
    branches drop out of ``anyOf``/``oneOf``; constant conditionals
    fold; ``not: false`` disappears.

Soundness contract: every rewrite fires only on a *proof* (the
keyword-local legality conditions in this file); anything unproven is
left alone.  Annotation-affecting removals (dropping an applicator
that could contribute evaluated-property/item annotations) are
additionally gated on the schema containing no ``unevaluated*``
keyword anywhere.  As a belt over the braces, the rewritten schema is
differentially probed against :class:`NaiveValidator` on boundary
instances; any disagreement reverts the whole rewrite and reports the
failure instead of serving it.
"""

from __future__ import annotations

import copy
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..core.doc_model import json_equal
from ..core.interpreter import NaiveValidator
from .sat import ANNOTATION_KEYS, _value_ok, conjoin, is_empty, is_top, summarize
from .structhash import canonical_json, structural_hash, subschema_hashes
from .subsume import schema_probes

__all__ = ["AnalysisReport", "analyze_schema"]

_MAX_REASONS = 32

# Keywords whose presence anywhere makes rewriting unsafe: resolution
# is dynamic-scope dependent, so structural rewrites could change
# which schema a reference lands on.
_DYNAMIC_KEYS = ("$dynamicRef", "$dynamicAnchor", "$recursiveRef", "$recursiveAnchor")

# Conjunctive keys safe to hoist from an allOf member into the parent
# when the parent does not already carry them: their semantics never
# depend on sibling keywords.
_HOISTABLE = frozenset(
    {
        "type",
        "enum",
        "const",
        "minimum",
        "maximum",
        "exclusiveMinimum",
        "exclusiveMaximum",
        "multipleOf",
        "minLength",
        "maxLength",
        "pattern",
        "minItems",
        "maxItems",
        "uniqueItems",
        "minProperties",
        "maxProperties",
        "required",
    }
)

# Keys that make an allOf member opaque to hoisting/merging entirely.
_OPAQUE_MEMBER_KEYS = frozenset(
    {"$ref", "$id", "id", "$anchor", "$defs", "definitions"} | set(_DYNAMIC_KEYS)
)

# `properties` interacts with these at the same node; hoisting
# properties across nodes is only legal when neither side has any.
_PROPERTIES_PARTNERS = frozenset(
    {"additionalProperties", "patternProperties", "unevaluatedProperties", "propertyNames"}
)


# Sentinels for _merge_conjunct: keep both copies / proven contradiction.
_KEEP = object()
_CONTRADICTION = object()

_MIN_LIKE = ("minimum", "minLength", "minItems", "minProperties")
_MAX_LIKE = ("maximum", "maxLength", "maxItems", "maxProperties")


def _num(v: Any) -> Optional[float]:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return v


def _intersect_types(a: Any, b: Any) -> Any:
    def expand(t: Any) -> Optional[frozenset]:
        items = [t] if isinstance(t, str) else t
        if not isinstance(items, list) or not all(isinstance(x, str) for x in items):
            return None
        s = frozenset(items)
        return s | {"integer"} if "number" in s else s

    ea, eb = expand(a), expand(b)
    if ea is None or eb is None:
        return _KEEP
    inter = ea & eb
    if not inter:
        return _CONTRADICTION
    if "number" in inter:
        inter = inter - {"integer"}
    out = sorted(inter)
    return out[0] if len(out) == 1 else out


def _merge_conjunct(key: str, a: Any, b: Any) -> Any:
    """Merge two copies of a conjunctive keyword.  Returns the merged
    value, ``_KEEP`` (cannot merge; keep both), or ``_CONTRADICTION``
    (provably empty intersection)."""
    if json_equal(a, b):
        return a
    if key == "type":
        return _intersect_types(a, b)
    if key == "required":
        if isinstance(a, list) and isinstance(b, list):
            return sorted(set(a) | set(b))
        return _KEEP
    if key == "enum":
        if isinstance(a, list) and isinstance(b, list):
            inter = [v for v in a if any(json_equal(v, w) for w in b)]
            return inter if inter else _CONTRADICTION
        return _KEEP
    if key == "const":
        return a if json_equal(a, b) else _CONTRADICTION
    if key in _MIN_LIKE:
        na, nb = _num(a), _num(b)
        return max(na, nb) if na is not None and nb is not None else _KEEP
    if key in _MAX_LIKE:
        na, nb = _num(a), _num(b)
        return min(na, nb) if na is not None and nb is not None else _KEEP
    if key == "exclusiveMinimum":
        na, nb = _num(a), _num(b)
        return max(na, nb) if na is not None and nb is not None else _KEEP
    if key == "exclusiveMaximum":
        na, nb = _num(a), _num(b)
        return min(na, nb) if na is not None and nb is not None else _KEEP
    if key == "uniqueItems":
        if isinstance(a, bool) and isinstance(b, bool):
            return a or b
    return _KEEP


@dataclass
class AnalysisReport:
    """Outcome of the register()-time analysis pipeline for one schema."""

    normalized: Any
    canonical_hash: str
    pruned_branches: int = 0
    folded_assertions: int = 0
    flattened_allof: int = 0
    removed_noops: int = 0
    tightened_bounds: int = 0
    dedup_subgraphs: int = 0  # filled in by the registry across members
    changed: bool = False
    verified: bool = False
    failure: Optional[str] = None
    seconds: float = 0.0
    reasons: List[str] = field(default_factory=list)
    subgraph_hashes: Dict[str, List[str]] = field(default_factory=dict)

    def note(self, reason: str) -> None:
        if len(self.reasons) < _MAX_REASONS:
            self.reasons.append(reason)

    def counters(self) -> Dict[str, int]:
        return {
            "pruned_branches": self.pruned_branches,
            "folded_assertions": self.folded_assertions,
            "flattened_allof": self.flattened_allof,
            "removed_noops": self.removed_noops,
            "tightened_bounds": self.tightened_bounds,
            "dedup_subgraphs": self.dedup_subgraphs,
        }


def _contains_key(schema: Any, keys: Tuple[str, ...]) -> bool:
    if isinstance(schema, dict):
        if any(k in schema for k in keys):
            return True
        return any(_contains_key(v, keys) for v in schema.values())
    if isinstance(schema, list):
        return any(_contains_key(v, keys) for v in schema)
    return False


def _pointer_refs_fragile(schema: Any) -> bool:
    """True when any ``$ref`` uses a JSON pointer deeper than
    ``#/$defs/<name>`` -- structural rewrites could break the path."""

    def visit(node: Any) -> bool:
        if isinstance(node, dict):
            ref = node.get("$ref")
            if isinstance(ref, str) and "#/" in ref:
                frag = ref.split("#", 1)[1]
                parts = [p for p in frag.split("/") if p]
                if len(parts) > 2 or (parts and parts[0] not in ("$defs", "definitions")):
                    return True
            return any(visit(v) for v in node.values())
        if isinstance(node, list):
            return any(visit(v) for v in node)
        return False

    return visit(schema)


def analyze_schema(schema: Any, *, verify: bool = True) -> AnalysisReport:
    """Run the full pass pipeline; never raises on malformed input --
    any internal failure reverts to the original schema."""
    t0 = time.perf_counter()
    rpt = AnalysisReport(normalized=schema, canonical_hash=structural_hash(schema))
    if isinstance(schema, bool):
        rpt.verified = True
        rpt.seconds = time.perf_counter() - t0
        return rpt
    if not isinstance(schema, dict):
        rpt.failure = "not a schema object"
        rpt.seconds = time.perf_counter() - t0
        return rpt
    if _contains_key(schema, _DYNAMIC_KEYS):
        rpt.note("skipped: dynamic-scope references present")
        rpt.verified = True
        rpt.seconds = time.perf_counter() - t0
        rpt.subgraph_hashes = subschema_hashes(schema)
        return rpt
    if _pointer_refs_fragile(schema):
        rpt.note("skipped: JSON-pointer $ref into schema structure")
        rpt.verified = True
        rpt.seconds = time.perf_counter() - t0
        rpt.subgraph_hashes = subschema_hashes(schema)
        return rpt

    # Annotation guard: `unevaluated*` observes which in-place
    # applicators *ran*, so removing always-true applicators is only
    # legal when no unevaluated keyword exists anywhere in the root.
    annotation_safe = not _contains_key(schema, ("unevaluatedProperties", "unevaluatedItems"))

    try:
        work = copy.deepcopy(schema)
        rewritten = _Rewriter(rpt, annotation_safe).rewrite(work)
    except Exception as exc:  # proof engine bug: keep the original
        rpt.failure = f"rewrite error: {type(exc).__name__}: {exc}"
        _revert(rpt)
        rpt.seconds = time.perf_counter() - t0
        rpt.subgraph_hashes = subschema_hashes(schema)
        return rpt

    changed = canonical_json(rewritten) != canonical_json(schema)
    if changed and verify:
        mismatch = _differential_check(schema, rewritten)
        if mismatch is not None:
            rpt.failure = f"differential mismatch on probe {mismatch!r}; rewrite reverted"
            _revert(rpt)
            rpt.seconds = time.perf_counter() - t0
            rpt.subgraph_hashes = subschema_hashes(schema)
            return rpt
    rpt.normalized = rewritten
    rpt.changed = changed
    rpt.verified = True
    rpt.canonical_hash = structural_hash(rewritten)
    rpt.subgraph_hashes = subschema_hashes(rewritten)
    rpt.seconds = time.perf_counter() - t0
    return rpt


def _revert(rpt: AnalysisReport) -> None:
    """Zero the rewrite counters after a revert: the served schema is
    the original, so no rewrite actually took effect."""
    rpt.pruned_branches = 0
    rpt.folded_assertions = 0
    rpt.flattened_allof = 0
    rpt.removed_noops = 0
    rpt.tightened_bounds = 0


def _differential_check(original: Any, rewritten: Any) -> Optional[Any]:
    """Probe both schemas; return the first disagreeing instance."""
    try:
        nv_a = NaiveValidator(original)
        nv_b = NaiveValidator(rewritten)
    except Exception:
        return "<oracle construction failed>"
    for probe in schema_probes(original):
        try:
            va = nv_a.is_valid(probe)
        except Exception:
            continue
        try:
            vb = nv_b.is_valid(probe)
        except Exception:
            return probe
        if va != vb:
            return probe
    return None


class _Rewriter:
    def __init__(self, rpt: AnalysisReport, annotation_safe: bool):
        self.rpt = rpt
        self.annotation_safe = annotation_safe

    # -- recursion over schema positions --------------------------------

    _SINGLE = (
        "additionalProperties",
        "unevaluatedProperties",
        "unevaluatedItems",
        "additionalItems",
        "contains",
        "propertyNames",
        "not",
        "if",
        "then",
        "else",
    )
    _LISTS = ("allOf", "anyOf", "oneOf", "prefixItems")
    _MAPS = ("properties", "patternProperties", "dependentSchemas", "$defs", "definitions")

    def rewrite(self, node: Any, depth: int = 0) -> Any:
        if not isinstance(node, dict) or depth > 32:
            return node

        for kw in self._SINGLE:
            if kw in node:
                node[kw] = self.rewrite(node[kw], depth + 1)
        items = node.get("items")
        if isinstance(items, list):
            node["items"] = [self.rewrite(s, depth + 1) for s in items]
        elif "items" in node:
            node["items"] = self.rewrite(items, depth + 1)
        for kw in self._LISTS:
            subs = node.get(kw)
            if isinstance(subs, list):
                node[kw] = [self.rewrite(s, depth + 1) for s in subs]
        for kw in self._MAPS:
            subs = node.get(kw)
            if isinstance(subs, dict):
                node[kw] = {k: self.rewrite(s, depth + 1) for k, s in subs.items()}

        node = self._fold_allof(node)
        if not isinstance(node, dict):
            return node
        node = self._fold_constants(node)
        if not isinstance(node, dict):
            return node
        node = self._tighten_bounds(node)
        node = self._drop_noops(node)
        node = self._fold_branches(node)
        if not isinstance(node, dict):
            return node
        node = self._prove_empty(node)
        if isinstance(node, dict):
            node = {k: node[k] for k in sorted(node)}
        return node

    # -- allOf flatten / hoist ------------------------------------------

    def _fold_allof(self, node: Dict[str, Any]) -> Any:
        members = node.get("allOf")
        if not isinstance(members, list):
            return node

        # splice nested pure-allOf members
        flat: List[Any] = []
        for m in members:
            if isinstance(m, dict) and set(m) == {"allOf"} and isinstance(m["allOf"], list):
                flat.extend(m["allOf"])
                self.rpt.flattened_allof += 1
                self.rpt.note("allOf: spliced nested allOf")
            else:
                flat.append(m)

        kept: List[Any] = []
        for m in flat:
            if m is False:
                self.rpt.note("allOf: false member collapses node")
                return False
            if is_top(m):
                # a TOP member asserts nothing and (being empty of
                # applicators) contributes no annotations
                self.rpt.removed_noops += 1
                self.rpt.note("allOf: dropped always-true member")
                continue
            if isinstance(m, dict) and not (set(m) & _OPAQUE_MEMBER_KEYS):
                m = self._hoist_member(node, m)
                if m is False:
                    return False
                if m is None:
                    continue
            kept.append(m)

        if kept:
            node["allOf"] = kept
        else:
            node.pop("allOf", None)
            self.rpt.note("allOf: emptied after folding")
        return node

    def _hoist_member(self, parent: Dict[str, Any], member: Dict[str, Any]) -> Any:
        """Move provably-local conjunctive keys from an allOf member
        into the parent.  Returns the reduced member, None when fully
        absorbed, or False when a contradiction is proven."""
        residue: Dict[str, Any] = {}
        for key, val in member.items():
            if key in ANNOTATION_KEYS:
                continue  # annotations on an allOf member are inert
            if key in _HOISTABLE:
                if key not in parent:
                    parent[key] = val
                    self.rpt.folded_assertions += 1
                    continue
                merged = _merge_conjunct(key, parent[key], val)
                if merged is _CONTRADICTION:
                    self.rpt.note(f"allOf: contradictory `{key}` intersection")
                    return False
                if merged is not _KEEP:
                    parent[key] = merged
                    self.rpt.folded_assertions += 1
                    continue
                residue[key] = val
            elif key == "properties" and isinstance(val, dict):
                if (set(parent) | set(member)) & _PROPERTIES_PARTNERS:
                    residue[key] = val
                    continue
                target = parent.setdefault("properties", {})
                if not isinstance(target, dict):
                    residue[key] = val
                    continue
                for pk, pv in val.items():
                    if pk in target:
                        if json_equal(target[pk], pv):
                            self.rpt.folded_assertions += 1
                        else:
                            target[pk] = self.rewrite({"allOf": [target[pk], pv]})
                    else:
                        target[pk] = pv
                        self.rpt.folded_assertions += 1
            else:
                residue[key] = val
        if residue:
            return residue
        self.rpt.note("allOf: member fully hoisted into parent")
        return None

    # -- constant folding ------------------------------------------------

    def _fold_constants(self, node: Dict[str, Any]) -> Any:
        t = node.get("type")
        if isinstance(t, list):
            seen: List[str] = []
            for x in t:
                if isinstance(x, str) and x not in seen:
                    seen.append(x)
            if "number" in seen and "integer" in seen:
                seen.remove("integer")
                self.rpt.folded_assertions += 1
                self.rpt.note("type: integer subsumed by number")
            if len(seen) != len(t):
                self.rpt.folded_assertions += 1
            seen.sort()
            node["type"] = seen[0] if len(seen) == 1 else seen
            if not seen:
                self.rpt.note("type: empty type list")
                return False

        enum = node.get("enum")
        if isinstance(enum, list):
            sibling = summarize({k: v for k, v in node.items() if k not in ("enum", "const")})
            kept: List[Any] = []
            for v in enum:
                if any(json_equal(v, w) for w in kept):
                    self.rpt.folded_assertions += 1
                    continue
                if not _value_ok(sibling, v):
                    self.rpt.folded_assertions += 1
                    self.rpt.note("enum: dropped candidate violating sibling constraints")
                    continue
                kept.append(v)
            if not kept:
                self.rpt.note("enum: no satisfiable candidate")
                return False
            kept.sort(key=canonical_json)
            if "const" not in node and len(kept) == 1:
                node.pop("enum")
                node["const"] = kept[0]
                self.rpt.folded_assertions += 1
                self.rpt.note("enum: singleton folded to const")
            else:
                node["enum"] = kept

        if "const" in node:
            sibling = summarize({k: v for k, v in node.items() if k not in ("enum", "const")})
            if not _value_ok(sibling, node["const"]):
                self.rpt.note("const: violates sibling constraints")
                return False
            enum = node.get("enum")
            if isinstance(enum, list):
                if any(json_equal(node["const"], v) for v in enum):
                    node.pop("enum")
                    self.rpt.folded_assertions += 1
                else:
                    self.rpt.note("const: not a member of sibling enum")
                    return False

        req = node.get("required")
        if isinstance(req, list) and all(isinstance(k, str) for k in req):
            uniq = sorted(set(req))
            if uniq != req:
                node["required"] = uniq
                self.rpt.folded_assertions += 1
        return node

    # -- bound tightening ------------------------------------------------

    def _tighten_bounds(self, node: Dict[str, Any]) -> Dict[str, Any]:
        for lo_key, xlo_key, pick_hi in (
            ("minimum", "exclusiveMinimum", True),
            ("maximum", "exclusiveMaximum", False),
        ):
            lo, xlo = node.get(lo_key), node.get(xlo_key)
            if isinstance(xlo, bool):
                continue  # draft-04 boolean form modifies minimum/maximum
            if not isinstance(lo, (int, float)) or isinstance(lo, bool):
                continue
            if not isinstance(xlo, (int, float)):
                continue
            if pick_hi:
                # x > xlo implies x >= lo when xlo >= lo
                drop = lo_key if xlo >= lo else xlo_key
            else:
                drop = lo_key if xlo <= lo else xlo_key
            node.pop(drop)
            self.rpt.tightened_bounds += 1
            self.rpt.note(f"bounds: `{drop}` subsumed by sibling bound")
        return node

    # -- no-op removal ---------------------------------------------------

    def _drop_noops(self, node: Dict[str, Any]) -> Dict[str, Any]:
        for key, noop in (
            ("minLength", 0),
            ("minItems", 0),
            ("minProperties", 0),
            ("uniqueItems", False),
            ("required", []),
        ):
            if key in node and node[key] == noop and isinstance(node[key], type(noop)):
                node.pop(key)
                self.rpt.removed_noops += 1
                self.rpt.note(f"noop: removed `{key}: {noop!r}`")
        for key in ("dependentRequired", "dependentSchemas", "patternProperties"):
            if key in node and node[key] == {}:
                node.pop(key)
                self.rpt.removed_noops += 1
        if node.get("additionalProperties") is True and self.annotation_safe:
            # AP:true evaluates every property (annotation-relevant);
            # removable only with no unevaluated* observer anywhere
            node.pop("additionalProperties")
            self.rpt.removed_noops += 1
        # `then`/`else` are inert without `if`
        if "if" not in node:
            for key in ("then", "else"):
                if key in node:
                    node.pop(key)
                    self.rpt.removed_noops += 1
                    self.rpt.note(f"noop: `{key}` without `if`")
        return node

    # -- branch pruning / conditional folding ----------------------------

    def _fold_branches(self, node: Dict[str, Any]) -> Any:
        parent_summary = summarize({k: v for k, v in node.items() if k not in ("anyOf", "oneOf")})

        for kw in ("anyOf", "oneOf"):
            branches = node.get(kw)
            if not isinstance(branches, list) or not branches:
                continue
            kept: List[Any] = []
            for br in branches:
                if br is False:
                    # a false branch never validates and contributes no
                    # annotations: dropping it is unconditionally sound
                    self.rpt.pruned_branches += 1
                    self.rpt.note(f"{kw}: dropped false branch")
                    continue
                if isinstance(br, dict):
                    reason = is_empty(summarize(br))
                    if reason is None:
                        # context-sensitive: branch conjoined with the
                        # node's own assertions
                        reason = is_empty(conjoin(parent_summary, summarize(br)))
                        if reason is not None:
                            reason = f"under node constraints: {reason}"
                    if reason is not None:
                        self.rpt.pruned_branches += 1
                        self.rpt.note(f"{kw}: pruned branch ({reason})")
                        continue
                kept.append(br)
            if not kept:
                self.rpt.note(f"{kw}: all branches unsatisfiable")
                return False
            if len(kept) == 1:
                # anyOf/oneOf of one branch == the branch applied
                # in-place (annotations identical: the branch still
                # runs as an in-place applicator)
                node.pop(kw)
                node.setdefault("allOf", []).append(kept[0])
                self.rpt.folded_assertions += 1
                self.rpt.note(f"{kw}: singleton folded into allOf")
                node = self._fold_allof(node)
                if not isinstance(node, dict):
                    return node
            else:
                if self.annotation_safe and kw == "anyOf" and any(is_top(br) for br in kept):
                    # always-satisfied anyOf; removable only when no
                    # unevaluated* keyword can observe the other
                    # branches' annotations
                    node.pop(kw)
                    self.rpt.removed_noops += 1
                    self.rpt.note("anyOf: always-true branch, applicator removed")
                else:
                    node[kw] = kept

        # not
        inner = node.get("not")
        if "not" in node:
            if inner is False or (isinstance(inner, dict) and is_empty(summarize(inner)) is not None):
                # `not <empty>` always passes; `not` contributes no annotations
                node.pop("not")
                self.rpt.removed_noops += 1
                self.rpt.note("not: inner schema unsatisfiable, keyword removed")
            elif is_top(inner):
                self.rpt.note("not: inner schema always true")
                return False

        # if/then/else constant folding
        cond = node.get("if")
        if "if" in node:
            if cond is False:
                # `if` fails: its annotations drop, `else` applies
                els = node.pop("else", None)
                node.pop("if")
                node.pop("then", None)
                if els is not None and not is_top(els):
                    if els is False:
                        self.rpt.note("if: false condition with false else")
                        return False
                    node.setdefault("allOf", []).append(els)
                self.rpt.folded_assertions += 1
                self.rpt.note("if: constant-false condition folded to else")
                node = self._fold_allof(node)
                if not isinstance(node, dict):
                    return node
            elif is_top(cond):
                # `if` passes vacuously (TOP carries no applicators,
                # so no annotations are lost); `then` applies
                then = node.pop("then", None)
                node.pop("if")
                node.pop("else", None)
                if then is not None and not is_top(then):
                    if then is False:
                        self.rpt.note("if: true condition with false then")
                        return False
                    node.setdefault("allOf", []).append(then)
                self.rpt.folded_assertions += 1
                self.rpt.note("if: constant-true condition folded to then")
                node = self._fold_allof(node)
                if not isinstance(node, dict):
                    return node
        return node

    # -- whole-node emptiness -------------------------------------------

    def _prove_empty(self, node: Dict[str, Any]) -> Any:
        reason = is_empty(summarize(node))
        if reason is not None:
            self.rpt.pruned_branches += 1
            self.rpt.note(f"node proven unsatisfiable: {reason}")
            return False
        return node
