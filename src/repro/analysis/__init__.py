"""Ahead-of-time schema algebra (DESIGN.md §15).

Static-analysis passes that run between schema submission and tape
build, applying the JSON-subschema line of work (PAPERS.md: *Type
Safety with JSON Subschema*; *JSON Schema Inclusion through
Refutational Normalization*) at ``register()`` time:

- :mod:`.structhash` -- canonical serialization + structural hashing,
  used for subgraph dedup across registry members;
- :mod:`.sat` -- conservative satisfiability summaries (interval /
  type-set / enum abstraction) that back every prune *proof*;
- :mod:`.normalize` -- the canonicalizer/normalizer pass pipeline,
  differentially verified against :class:`NaiveValidator`;
- :mod:`.subsume` -- inclusion/equivalence prover between endpoint
  versions (equivalence -> metadata-only hot swap);
- :mod:`.unroll` -- per-schema ``unroll_depth`` sizing from the
  compiled label graph's branching recursion bound;
- :mod:`.lint_tape` -- post-build static checker for
  LocationTape/LinkedTape invariants.

Soundness contract: rewrites happen only on *proofs*; any pass that
cannot prove its transform leaves the schema unchanged (unknown =>
keep).  The whole pipeline is wrapped in a differential verdict check
against the unmodified schema and reverts on any disagreement.
"""

from .normalize import AnalysisReport, analyze_schema
from .structhash import structural_hash, subschema_hashes
from .subsume import SubsumptionResult, compare
from .unroll import recommend_unroll_depth
from .lint_tape import TapeLintError, assert_tape, lint_tape

__all__ = [
    "AnalysisReport",
    "analyze_schema",
    "structural_hash",
    "subschema_hashes",
    "SubsumptionResult",
    "compare",
    "recommend_unroll_depth",
    "TapeLintError",
    "assert_tape",
    "lint_tape",
]
