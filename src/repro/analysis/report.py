"""Registry-wide analysis report: the schema-algebra posture artifact.

Runs the register()-time pipeline (DESIGN.md §15) over the gateway
preset schemas and emits one machine-readable JSON tree:

- per-endpoint analysis counters (pruned branches, folded assertions,
  structural-dedup overlap, normalization verdict, analysis wall time)
- link-group layout including the physical ``linked_members`` after
  canonical-hash segment dedup
- tape-lint status for every member and group tape

CI archives the output as ``results/analysis_report.json`` and
``scripts/perf_report.py`` folds it into the trajectory report.

Usage::

    python -m repro.analysis.report [--out results/analysis_report.json]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Optional

from .lint_tape import lint_tape

__all__ = ["build_report", "main"]


def build_report() -> Dict[str, Any]:
    """Assemble the posture tree over the registry presets."""
    from ..registry.presets import GATEWAY_SCHEMAS
    from ..registry.registry import SchemaRegistry

    reg = SchemaRegistry()
    for name, schema in GATEWAY_SCHEMAS.items():
        reg.register(name, schema)

    endpoints: Dict[str, Any] = {}
    lint_failures: List[str] = []
    for name in GATEWAY_SCHEMAS:
        entry = reg.get(name)
        st = entry.stats
        per: Dict[str, Any] = {
            "version": entry.version,
            "batchable": st.batchable,
            "analysis_seconds": round(st.analysis_seconds, 6),
            "normalized": st.normalized,
            "pruned_branches": st.pruned_branches,
            "folded_assertions": st.folded_assertions,
            "dedup_subgraphs": st.dedup_subgraphs,
            "analysis_failure": st.analysis_failure,
            "canonical_hash": entry.canonical_hash,
            "unroll_depth": st.unroll_depth,
            "a_hat": st.a_hat,
            "horizon": st.horizon,
            "n_circuits": st.n_circuits,
        }
        if entry.analysis is not None:
            per["reasons"] = list(entry.analysis.reasons)
        if entry.tape is not None:
            problems = lint_tape(entry.tape)
            per["lint"] = "ok" if not problems else "FAIL"
            lint_failures += [f"{name}: {p}" for p in problems]
        endpoints[name] = per

    groups: Dict[str, Any] = {}
    for g in reg.groups():
        problems = lint_tape(g.tape)
        groups[g.label] = {
            "members": list(g.members),
            "linked_members": list(g.linked_members),
            "deduped_segments": len(g.members) - len(g.linked_members),
            "a_hat": int(g.tape.max_rows_per_loc),
            "m_hat": int(g.tape.max_member_props),
            "horizon": int(g.tape.max_loc_depth) + 1,
            "lint": "ok" if not problems else "FAIL",
        }
        lint_failures += [f"group {g.label}: {p}" for p in problems]

    return {
        "endpoints": endpoints,
        "groups": groups,
        "swap_verdicts": reg.swap_verdicts(),
        "lint_failures": lint_failures,
        "totals": {
            "pruned_branches": sum(p["pruned_branches"] for p in endpoints.values()),
            "folded_assertions": sum(p["folded_assertions"] for p in endpoints.values()),
            "dedup_subgraphs": sum(p["dedup_subgraphs"] for p in endpoints.values()),
            "normalized_endpoints": sum(1 for p in endpoints.values() if p["normalized"]),
            "analysis_seconds": round(
                sum(p["analysis_seconds"] for p in endpoints.values()), 6
            ),
        },
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.analysis.report", description=__doc__)
    ap.add_argument(
        "--out",
        default="results/analysis_report.json",
        help="output path (default: results/analysis_report.json)",
    )
    args = ap.parse_args(argv)
    report = build_report()
    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(report, indent=2, sort_keys=True) + "\n")
    t = report["totals"]
    print(
        f"analysis report: {len(report['endpoints'])} endpoints, "
        f"{t['pruned_branches']} pruned, {t['folded_assertions']} folded, "
        f"{t['dedup_subgraphs']} dedup overlaps -> {out}"
    )
    if report["lint_failures"]:
        for f in report["lint_failures"]:
            print(f"  LINT {f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
