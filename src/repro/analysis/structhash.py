"""Canonical serialization and structural hashing of schemas.

Two schemas that are structurally identical after key ordering and
numeric normalization hash equal, which is what the registry's
subgraph dedup and the subsumption fast path key on.  The hash is
*syntactic* (post-normalization): it never claims semantic
equivalence beyond what byte-identical canonical forms give.
"""

from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, Iterator, List, Tuple

__all__ = ["canonical_json", "structural_hash", "subschema_hashes"]


def _normalize(value: Any) -> Any:
    """Fold int-valued floats to ints so 1.0 and 1 serialize alike
    (matching ``json_equal`` semantics in core.doc_model)."""
    if isinstance(value, bool):
        return value
    if isinstance(value, float) and value.is_integer():
        return int(value)
    if isinstance(value, dict):
        return {k: _normalize(v) for k, v in value.items()}
    if isinstance(value, list):
        return [_normalize(v) for v in value]
    return value


# Keys that can never influence validation in this repo's compiler or
# interpreter -- stripped before hashing so two schemas differing only
# in prose hash equal (and may share a linked segment).  ``format`` CAN
# assert under ``CompilerOptions.format_assertion`` and identifier /
# definition keys ($id, $anchor, $defs, ...) steer $ref resolution, so
# all of those stay in the hash.
_PURE_ANNOTATIONS = frozenset(
    {
        "title",
        "description",
        "$comment",
        "examples",
        "example",
        "default",
        "deprecated",
        "readOnly",
        "writeOnly",
        "contentMediaType",
        "contentEncoding",
    }
)


def _strip(schema: Any) -> Any:
    """Drop pure-annotation keys, recursing only into *schema
    positions* (a property NAMED "description" is data, not prose)."""
    if not isinstance(schema, dict):
        return schema
    out: Dict[str, Any] = {}
    for key, value in schema.items():
        if key in _PURE_ANNOTATIONS:
            continue
        if key in _SINGLE or (key == "items" and not isinstance(value, list)):
            out[key] = _strip(value)
        elif key in _LISTS or (key == "items" and isinstance(value, list)):
            out[key] = [_strip(v) for v in value] if isinstance(value, list) else _strip(value)
        elif key in _MAPS and isinstance(value, dict):
            out[key] = {k: _strip(v) for k, v in value.items()}
        else:
            out[key] = value
    return out


def canonical_json(schema: Any) -> str:
    """Deterministic serialization: sorted keys, no whitespace,
    int-valued floats folded, pure annotations stripped."""
    return json.dumps(_normalize(_strip(schema)), sort_keys=True, separators=(",", ":"))


def structural_hash(schema: Any) -> str:
    """Stable short digest of the canonical serialization."""
    return hashlib.blake2b(canonical_json(schema).encode("utf-8"), digest_size=16).hexdigest()


# Keyword positions holding a single subschema.
_SINGLE = (
    "additionalProperties",
    "unevaluatedProperties",
    "unevaluatedItems",
    "items",
    "additionalItems",
    "contains",
    "propertyNames",
    "not",
    "if",
    "then",
    "else",
)
# Keyword positions holding a list of subschemas.
_LISTS = ("allOf", "anyOf", "oneOf", "prefixItems")
# Keyword positions holding a map of subschemas.
_MAPS = ("properties", "patternProperties", "dependentSchemas", "$defs", "definitions")


def iter_subschemas(schema: Any, path: str = "#") -> Iterator[Tuple[str, Any]]:
    """Yield (json-pointer-ish path, subschema) for every schema
    position reachable from ``schema``, including itself."""
    if isinstance(schema, bool):
        yield path, schema
        return
    if not isinstance(schema, dict):
        return
    yield path, schema
    for kw in _SINGLE:
        if kw in schema:
            yield from iter_subschemas(schema[kw], f"{path}/{kw}")
    # draft-04 style `items: [..]` is a positional list
    items = schema.get("items")
    if isinstance(items, list):
        for i, sub in enumerate(items):
            yield from iter_subschemas(sub, f"{path}/items/{i}")
    for kw in _LISTS:
        subs = schema.get(kw)
        if isinstance(subs, list):
            for i, sub in enumerate(subs):
                yield from iter_subschemas(sub, f"{path}/{kw}/{i}")
    for kw in _MAPS:
        subs = schema.get(kw)
        if isinstance(subs, dict):
            for key, sub in subs.items():
                yield from iter_subschemas(sub, f"{path}/{kw}/{key}")


def subschema_hashes(schema: Any, *, min_size: int = 2) -> Dict[str, List[str]]:
    """Map structural hash -> paths of every *non-trivial* subschema.

    ``min_size`` filters out leaves (bare ``{"type": "string"}`` etc.)
    that would otherwise dominate the dedup report with noise: a
    subgraph only counts when it carries at least ``min_size`` keys.
    """
    out: Dict[str, List[str]] = {}
    for path, sub in iter_subschemas(schema):
        if not isinstance(sub, dict) or len(sub) < min_size:
            continue
        out.setdefault(structural_hash(sub), []).append(path)
    return out
