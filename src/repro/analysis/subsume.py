"""Subsumption proofs between endpoint schema versions.

``compare(old, new)`` classifies a hot-swap candidate against the
serving version on the verdict lattice:

    equivalent   old and new accept exactly the same instances
    widened      every old-valid instance stays valid; new accepts more
    narrowed     every new-valid instance was old-valid; new accepts less
    incomparable each accepts instances the other rejects
    unknown      no proof either way

Proof machinery (refutational, after *JSON Schema Inclusion through
Refutational Normalization*): a structural prover (:func:`includes`)
establishes inclusions over the :mod:`.sat` summary domain, and a
witness probe sweep through :class:`NaiveValidator` *refutes*
inclusions.  A positive verdict (equivalent / widened / narrowed)
requires a structural proof in the claimed direction plus a
refutation of the opposite direction (or a canonical-hash match,
which proves equivalence outright).  Anything unproven stays
``unknown`` -- the registry treats unknown like an ordinary swap.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..core.doc_model import json_equal
from ..core.interpreter import NaiveValidator
from .sat import Summary, is_top, summarize
from .structhash import structural_hash

__all__ = ["SubsumptionResult", "compare", "includes", "schema_probes"]

EQUIVALENT = "equivalent"
WIDENED = "widened"
NARROWED = "narrowed"
INCOMPARABLE = "incomparable"
UNKNOWN = "unknown"

_MAX_PROBES = 96
_STOCK_PROBES: Tuple[Any, ...] = (
    None,
    True,
    False,
    0,
    1,
    -1,
    3.5,
    "",
    "a",
    "payload",
    [],
    [1],
    ["a", "b"],
    {},
    {"a": 1},
)


@dataclass(frozen=True)
class SubsumptionResult:
    verdict: str
    # witness instances refuting an inclusion direction, for diagnostics
    witnesses: Tuple[Any, ...] = ()
    notes: Tuple[str, ...] = ()


def schema_probes(schema: Any, *, budget: int = _MAX_PROBES) -> List[Any]:
    """Deterministic witness candidates targeted at ``schema``'s
    decision boundaries: enum/const values, numeric bounds +/- 1,
    boundary-length strings/arrays, minimal required objects with and
    without each key, plus stock probes."""
    probes: List[Any] = []

    def add(p: Any) -> None:
        if len(probes) < budget and not any(json_equal(p, q) for q in probes):
            probes.append(p)

    def visit(node: Any, depth: int) -> None:
        if depth > 6 or not isinstance(node, dict) or len(probes) >= budget:
            return
        if "const" in node:
            add(node["const"])
        for v in node.get("enum", []) if isinstance(node.get("enum"), list) else []:
            add(v)
        if "default" in node:
            add(node["default"])
        for key in ("minimum", "maximum", "exclusiveMinimum", "exclusiveMaximum"):
            v = node.get(key)
            if isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v):
                add(v)
                add(v + 1)
                add(v - 1)
        for key in ("minLength", "maxLength"):
            v = node.get(key)
            if isinstance(v, int) and not isinstance(v, bool) and 0 <= v < 64:
                add("x" * v)
                add("x" * (v + 1))
                if v > 0:
                    add("x" * (v - 1))
        for key in ("minItems", "maxItems"):
            v = node.get(key)
            if isinstance(v, int) and not isinstance(v, bool) and 0 <= v < 16:
                add([0] * v)
                add([0] * (v + 1))
        req = node.get("required")
        props = node.get("properties") if isinstance(node.get("properties"), dict) else {}
        base: Dict[str, Any] = {}
        if isinstance(req, list) and all(isinstance(k, str) for k in req):
            for k in req:
                base[k] = _example_for(props.get(k, True))
            add(dict(base))
            add({**base, "__extra__": 1})
            for k in req:
                trimmed = {kk: vv for kk, vv in base.items() if kk != k}
                add(trimmed)
        if props:
            add({k: _example_for(sub) for k, sub in list(props.items())[:8]})
            # per-property boundary variants over the required base, so
            # widening/narrowing of a single property's bounds produces
            # a distinguishing object witness
            for k, sub in list(props.items())[:8]:
                for v in _boundary_values(sub):
                    add({**base, k: v})
        for sub in _child_schemas(node):
            visit(sub, depth + 1)

    visit(schema, 0)
    for p in _STOCK_PROBES:
        add(p)
    return probes


def _boundary_values(sub: Any) -> List[Any]:
    """Scalar candidates at ``sub``'s decision boundaries."""
    out: List[Any] = []
    if not isinstance(sub, dict):
        return out
    if "const" in sub:
        out.append(sub["const"])
    enum = sub.get("enum")
    if isinstance(enum, list):
        out.extend(enum[:6])
    for key in ("minimum", "maximum", "exclusiveMinimum", "exclusiveMaximum"):
        v = sub.get(key)
        if isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v):
            out.extend((v, v + 1, v - 1))
    for key in ("minLength", "maxLength"):
        v = sub.get(key)
        if isinstance(v, int) and not isinstance(v, bool) and 0 <= v < 64:
            out.extend(("x" * v, "x" * (v + 1)))
            if v > 0:
                out.append("x" * (v - 1))
    for member in sub.get("allOf", []) if isinstance(sub.get("allOf"), list) else []:
        out.extend(_boundary_values(member))
    return out[:24]


def _example_for(sub: Any) -> Any:
    """A cheap instance likely (not guaranteed) to satisfy ``sub``."""
    if not isinstance(sub, dict):
        return 1
    if "const" in sub:
        return sub["const"]
    enum = sub.get("enum")
    if isinstance(enum, list) and enum:
        return enum[0]
    if "default" in sub:
        return sub["default"]
    t = sub.get("type")
    if isinstance(t, list) and t:
        t = t[0]
    lo = sub.get("minimum", sub.get("exclusiveMinimum"))
    if t in ("number", "integer"):
        if isinstance(lo, (int, float)) and not isinstance(lo, bool):
            return int(lo) + 1
        return 1
    if t == "string":
        n = sub.get("minLength")
        return "x" * n if isinstance(n, int) and not isinstance(n, bool) else "x"
    if t == "array":
        return []
    if t == "object":
        req = sub.get("required")
        props = sub.get("properties") if isinstance(sub.get("properties"), dict) else {}
        if isinstance(req, list):
            return {k: _example_for(props.get(k, True)) for k in req if isinstance(k, str)}
        return {}
    if t == "boolean":
        return True
    if t == "null":
        return None
    return 1


def _child_schemas(node: Dict[str, Any]) -> List[Any]:
    out: List[Any] = []
    for kw in ("allOf", "anyOf", "oneOf", "prefixItems"):
        subs = node.get(kw)
        if isinstance(subs, list):
            out.extend(subs)
    for kw in ("items", "not", "if", "then", "else", "contains", "additionalProperties"):
        if isinstance(node.get(kw), (dict, bool)):
            out.append(node[kw])
    for kw in ("properties", "patternProperties", "dependentSchemas", "$defs", "definitions"):
        subs = node.get(kw)
        if isinstance(subs, dict):
            out.extend(subs.values())
    return out


# ---------------------------------------------------------------------------
# Structural inclusion prover
# ---------------------------------------------------------------------------

# Keywords the structural prover models; a schema using anything else
# is opaque and the prover answers None (unknown) unless the opaque
# side is the *sub* side of a TOP super-schema.
_MODELED = frozenset(
    {
        "type",
        "enum",
        "const",
        "minimum",
        "maximum",
        "exclusiveMinimum",
        "exclusiveMaximum",
        "minLength",
        "maxLength",
        "minItems",
        "maxItems",
        "minProperties",
        "maxProperties",
        "required",
        "properties",
        "additionalProperties",
        "allOf",
    }
)

from .sat import ANNOTATION_KEYS  # noqa: E402  (shared annotation key set)


def _fully_modeled(schema: Any, depth: int = 0) -> bool:
    """True when the structural prover models every constraining
    keyword in ``schema`` (so summarize() + per-key recursion capture
    its semantics *exactly*)."""
    if isinstance(schema, bool):
        return True
    if not isinstance(schema, dict) or depth > 8:
        return False
    for k, v in schema.items():
        if k in ANNOTATION_KEYS:
            continue
        if k not in _MODELED:
            return False
        if k == "properties":
            if not isinstance(v, dict) or not all(_fully_modeled(sub, depth + 1) for sub in v.values()):
                return False
        elif k == "additionalProperties":
            # schema-valued AP is not captured by the summary domain
            if not isinstance(v, bool):
                return False
        elif k == "allOf":
            if not isinstance(v, list) or not all(_fully_modeled(sub, depth + 1) for sub in v):
                return False
    return True


def includes(sup: Any, sub: Any, depth: int = 0) -> Optional[bool]:
    """Structural proof that every ``sub``-valid instance is
    ``sup``-valid.  True = proven, False = refuted by the decidable
    enum-enumeration case, None = unknown.

    Soundness: ``summarize(sub)`` *over*-approximates sub, so showing
    the summary's instance set sits inside sup's exact semantics
    (available because sup is ``_fully_modeled``) proves inclusion.
    """
    if depth > 8:
        return None
    if is_top(sup):
        return True
    if sub is False:
        return True
    if sup is False:
        return None  # sub could itself be empty; leave to witnesses
    if not _fully_modeled(sup):
        return None

    a = summarize(sub)  # over-approximation of sub's valid set
    b = summarize(sup)

    # Decidable finite case: sub is an enum/const -- enumerate.
    if a.values is not None:
        try:
            nv_sub = NaiveValidator(sub)
            nv_sup = NaiveValidator(sup)
            live = [v for v in a.values if nv_sub.is_valid(v)]
            return all(nv_sup.is_valid(v) for v in live)
        except Exception:
            return None

    # sup constrains to a finite value set but sub is not finite:
    # no containment proof possible from the summary domain.
    if b.values is not None:
        return None

    if not a.types <= b.types:
        return None
    if _types_touch(a, ("number", "integer")):
        if a.num_lo < b.num_lo or (a.num_lo == b.num_lo and b.num_lo_excl and not a.num_lo_excl):
            return None
        if a.num_hi > b.num_hi or (a.num_hi == b.num_hi and b.num_hi_excl and not a.num_hi_excl):
            return None
    if _types_touch(a, ("string",)) and (a.str_min < b.str_min or a.str_max > b.str_max):
        return None
    if _types_touch(a, ("array",)) and (a.arr_min < b.arr_min or a.arr_max > b.arr_max):
        return None
    if _types_touch(a, ("object",)):
        if a.obj_min < b.obj_min or a.obj_max > b.obj_max:
            return None
        if not b.required <= a.required:
            return None
        if b.closed:
            if not a.closed or a.closed_props is None or b.closed_props is None:
                return None
            if not a.closed_props <= b.closed_props:
                return None
        # per-key: sup's property schemas must admit whatever sub can
        # put at each key sup constrains
        sup_props = _props_of(sup)
        sub_props = _props_of(sub)
        for key, sup_sub in sup_props.items():
            if is_top(sup_sub):
                continue
            if a.closed and a.closed_props is not None and key not in a.closed_props:
                continue  # sub never materializes `key`
            if key in sub_props:
                if includes(sup_sub, sub_props[key], depth + 1) is not True:
                    return None
            else:
                # sub leaves the value unconstrained; sup_sub is not TOP
                return None
    return True


def _types_touch(s: Summary, kinds: Tuple[str, ...]) -> bool:
    return any(k in s.types for k in kinds)


def _props_of(schema: Any) -> Dict[str, Any]:
    """Effective per-key property schemas, folding nested allOf."""
    if not isinstance(schema, dict):
        return {}
    out: Dict[str, Any] = {}

    def fold(node: Any) -> None:
        if not isinstance(node, dict):
            return
        props = node.get("properties")
        if isinstance(props, dict):
            for k, v in props.items():
                out[k] = {"allOf": [out[k], v]} if k in out else v
        members = node.get("allOf")
        if isinstance(members, list):
            for m in members:
                fold(m)

    fold(schema)
    return out


# ---------------------------------------------------------------------------
# Verdict assembly
# ---------------------------------------------------------------------------


def compare(
    old: Any,
    new: Any,
    *,
    old_hash: Optional[str] = None,
    new_hash: Optional[str] = None,
) -> SubsumptionResult:
    """Classify ``new`` against serving ``old`` on the verdict lattice."""
    oh = old_hash or structural_hash(old)
    nh = new_hash or structural_hash(new)
    if oh == nh:
        return SubsumptionResult(EQUIVALENT, notes=("canonical-hash match",))

    try:
        nv_old = NaiveValidator(old)
        nv_new = NaiveValidator(new)
    except Exception as exc:  # pragma: no cover - defensive
        return SubsumptionResult(UNKNOWN, notes=(f"oracle construction failed: {exc}",))

    # Witness sweep: probe both oracles on boundary instances of both
    # schemas; disagreements refute one inclusion direction each.
    new_not_old: List[Any] = []  # refutes new <= old (widening witnesses)
    old_not_new: List[Any] = []  # refutes old <= new (narrowing witnesses)
    for probe in schema_probes(old) + schema_probes(new):
        try:
            vo = nv_old.is_valid(probe)
            vn = nv_new.is_valid(probe)
        except Exception:
            continue
        if vn and not vo and len(new_not_old) < 4:
            new_not_old.append(probe)
        if vo and not vn and len(old_not_new) < 4:
            old_not_new.append(probe)

    if new_not_old and old_not_new:
        return SubsumptionResult(
            INCOMPARABLE,
            witnesses=tuple(new_not_old[:2] + old_not_new[:2]),
            notes=("witnesses refute both inclusion directions",),
        )

    old_in_new = includes(new, old)  # old <= new
    new_in_old = includes(old, new)  # new <= old
    if new_not_old:
        new_in_old = False
    if old_not_new:
        old_in_new = False

    if old_in_new is True and new_in_old is True:
        return SubsumptionResult(EQUIVALENT, notes=("structural inclusion both directions",))
    if old_in_new is True and new_in_old is False:
        return SubsumptionResult(
            WIDENED,
            witnesses=tuple(new_not_old[:4]),
            notes=("old included in new; reverse refuted",),
        )
    if new_in_old is True and old_in_new is False:
        return SubsumptionResult(
            NARROWED,
            witnesses=tuple(old_not_new[:4]),
            notes=("new included in old; reverse refuted",),
        )
    return SubsumptionResult(UNKNOWN)
