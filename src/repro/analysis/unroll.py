"""Per-schema ``unroll_depth`` sizing from the compiled label graph.

The tape builder unrolls recursive ``$ref`` labels ``unroll_depth``
times under a node budget (core/tape.py).  A single global default
wastes budget on linear recursion (one self-jump per level: depth 4
costs 4x the body) and blows the budget on branching recursion (a
binary tree schema at depth 4 costs 2^4 bodies).  The analyzer walks
the compiled instruction tree, measures each label's body size and
jump fan-out, and recommends the deepest uniform unroll whose
worst-case clone count stays inside the node budget.

The recommendation only ever *shrinks* below the caller's default --
deep unrolling of branching recursion is the failure mode; linear
recursion keeps the default and still fits.
"""

from __future__ import annotations

from typing import Dict, Optional

from ..core.compiler import CompiledSchema
from ..core.instructions import ControlJump, walk
from ..core.tape import DEFAULT_UNROLL_DEPTH, DEFAULT_UNROLL_NODE_BUDGET

__all__ = ["recommend_unroll_depth"]


def recommend_unroll_depth(
    compiled: CompiledSchema,
    *,
    default: int = DEFAULT_UNROLL_DEPTH,
    node_budget: int = DEFAULT_UNROLL_NODE_BUDGET,
) -> int:
    """Recommend an unroll depth for ``compiled`` given the budget.

    Returns ``default`` for non-recursive schemas and for linear
    recursion; returns a smaller depth (>= 1) when the label graph's
    branching factor would exhaust ``node_budget`` before ``default``
    levels.
    """
    if not compiled.labels:
        return default

    # Per-label body size and outgoing-jump fan-out (jumps anywhere in
    # the body count: each one clones a whole target body per level).
    body_size: Dict[int, int] = {}
    fan_out: Dict[int, int] = {}
    for label, body in compiled.labels.items():
        n = 0
        jumps = 0
        for inst in walk(body):
            n += 1
            if isinstance(inst, ControlJump):
                jumps += 1
        body_size[label] = max(1, n)
        fan_out[label] = jumps

    root_jumps = sum(1 for inst in walk(compiled.instructions) if isinstance(inst, ControlJump))
    branching = max(fan_out.values(), default=0)
    if branching <= 1:
        # linear (or no) recursion: each extra level adds one body
        # copy per jump site -- the builder's own budget guard handles
        # pathological body sizes, keep the global default
        return default

    # Worst-case clone growth: every level multiplies live jump sites
    # by the max fan-out, each cloning the largest body.
    biggest = max(body_size.values())
    live = max(1, root_jumps)
    total = sum(body_size.values()) + len(list(compiled.instructions))
    depth = 0
    while depth < default:
        grown = total + live * biggest
        if grown > node_budget:
            break
        total = grown
        live *= branching
        depth += 1
    return max(1, depth)
