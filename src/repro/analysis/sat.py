"""Conservative satisfiability summaries for prune proofs.

A :class:`Summary` is an *over-approximation* of a schema's set of
valid instances built from the keywords the analyzer understands
(type sets, numeric/length intervals, required keys, closed-object
vocabularies, enum/const candidates).  Keywords the analyzer does not
model are simply ignored, which keeps the over-approximation sound:
the true valid set is always a subset of what the summary admits.

Because the summary over-approximates, **emptiness of the summary is
a proof of unsatisfiability of the schema** -- that is the only
direction the pruner ever uses.  The converse (a non-empty summary)
proves nothing, and callers must treat it as "unknown => keep".
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, FrozenSet, List, Optional, Tuple

from ..core.doc_model import json_equal

__all__ = ["Summary", "summarize", "conjoin", "is_empty", "is_top", "ALL_TYPES"]

ALL_TYPES = frozenset({"null", "boolean", "number", "integer", "string", "object", "array"})

# Keys that never constrain validation (annotations / identifiers).
ANNOTATION_KEYS = frozenset(
    {
        "title",
        "description",
        "default",
        "examples",
        "example",
        "$comment",
        "deprecated",
        "readOnly",
        "writeOnly",
        "$schema",
        "$id",
        "id",
        "$anchor",
        "$defs",
        "definitions",
        "format",  # annotation-only in every dialect this repo compiles
        "contentMediaType",
        "contentEncoding",
    }
)

_INF = math.inf


@dataclass(frozen=True)
class Summary:
    """Abstract domain element: conjunction of interval / set facts."""

    types: FrozenSet[str] = ALL_TYPES
    num_lo: float = -_INF
    num_lo_excl: bool = False
    num_hi: float = _INF
    num_hi_excl: bool = False
    str_min: int = 0
    str_max: float = _INF
    arr_min: int = 0
    arr_max: float = _INF
    obj_min: int = 0
    obj_max: float = _INF
    required: FrozenSet[str] = frozenset()
    closed: bool = False
    # property vocabulary when closed (only meaningful without
    # patternProperties, which the summarizer checks before setting it)
    closed_props: Optional[FrozenSet[str]] = None
    # property names whose subschema is literally unsatisfiable
    false_props: FrozenSet[str] = frozenset()
    # enum/const candidates (None = unconstrained)
    values: Optional[Tuple[Any, ...]] = None


TOP = Summary()


def _as_num(v: Any) -> Optional[float]:
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return float(v)


def summarize(schema: Any) -> Summary:
    """Build the over-approximating summary for one schema node.

    Only conjunctive keywords at this node (plus ``allOf`` members,
    recursively) are folded in; disjunctions (``anyOf``/``oneOf``),
    negation, conditionals and references are ignored -- ignoring a
    constraint only enlarges the summary, never shrinks it.
    """
    if schema is True:
        return TOP
    if schema is False:
        return replace(TOP, types=frozenset())
    if not isinstance(schema, dict):
        return TOP

    s = TOP

    t = schema.get("type")
    if isinstance(t, str):
        s = replace(s, types=_expand_types(frozenset({t})))
    elif isinstance(t, list) and all(isinstance(x, str) for x in t):
        s = replace(s, types=_expand_types(frozenset(t)))

    lo = _as_num(schema.get("minimum"))
    hi = _as_num(schema.get("maximum"))
    xlo = schema.get("exclusiveMinimum")
    xhi = schema.get("exclusiveMaximum")
    if lo is not None:
        # draft-04 boolean form: exclusiveMinimum: true modifies minimum
        excl = xlo is True
        s = _meet_lo(s, lo, excl)
    if isinstance(xlo, (int, float)) and not isinstance(xlo, bool):
        s = _meet_lo(s, float(xlo), True)
    if hi is not None:
        excl = xhi is True
        s = _meet_hi(s, hi, excl)
    if isinstance(xhi, (int, float)) and not isinstance(xhi, bool):
        s = _meet_hi(s, float(xhi), True)

    def _nat(key: str) -> Optional[int]:
        v = schema.get(key)
        if isinstance(v, bool) or not isinstance(v, int):
            return None
        return v

    if (v := _nat("minLength")) is not None:
        s = replace(s, str_min=max(s.str_min, v))
    if (v := _nat("maxLength")) is not None:
        s = replace(s, str_max=min(s.str_max, v))
    if (v := _nat("minItems")) is not None:
        s = replace(s, arr_min=max(s.arr_min, v))
    if (v := _nat("maxItems")) is not None:
        s = replace(s, arr_max=min(s.arr_max, v))
    if (v := _nat("minProperties")) is not None:
        s = replace(s, obj_min=max(s.obj_min, v))
    if (v := _nat("maxProperties")) is not None:
        s = replace(s, obj_max=min(s.obj_max, v))

    req = schema.get("required")
    if isinstance(req, list) and all(isinstance(k, str) for k in req):
        s = replace(s, required=s.required | frozenset(req))

    props = schema.get("properties")
    if isinstance(props, dict):
        falsy = frozenset(k for k, sub in props.items() if sub is False)
        if falsy:
            s = replace(s, false_props=s.false_props | falsy)
    if schema.get("additionalProperties") is False and "patternProperties" not in schema:
        vocab = frozenset(props.keys()) if isinstance(props, dict) else frozenset()
        s = replace(s, closed=True, closed_props=vocab)

    if "enum" in schema and isinstance(schema["enum"], list):
        s = _meet_values(s, tuple(schema["enum"]))
    if "const" in schema:
        s = _meet_values(s, (schema["const"],))

    subs = schema.get("allOf")
    if isinstance(subs, list):
        for sub in subs:
            s = conjoin(s, summarize(sub))

    return s


def _expand_types(types: FrozenSet[str]) -> FrozenSet[str]:
    # "number" admits integers too; keep "integer" alongside so
    # intersections with {"integer"} stay non-trivial.
    if "number" in types:
        return types | {"integer"}
    return types


def _meet_lo(s: Summary, lo: float, excl: bool) -> Summary:
    if lo > s.num_lo or (lo == s.num_lo and excl):
        return replace(s, num_lo=lo, num_lo_excl=excl)
    return s


def _meet_hi(s: Summary, hi: float, excl: bool) -> Summary:
    if hi < s.num_hi or (hi == s.num_hi and excl):
        return replace(s, num_hi=hi, num_hi_excl=excl)
    return s


def _meet_values(s: Summary, vals: Tuple[Any, ...]) -> Summary:
    if s.values is None:
        return replace(s, values=vals)
    kept = tuple(v for v in s.values if any(json_equal(v, w) for w in vals))
    return replace(s, values=kept)


def conjoin(a: Summary, b: Summary) -> Summary:
    """Meet of two summaries: over-approximates the intersection."""
    types = frozenset(a.types & b.types)
    s = Summary(
        types=types,
        num_lo=max(a.num_lo, b.num_lo),
        num_lo_excl=(a.num_lo_excl if a.num_lo >= b.num_lo else False)
        or (b.num_lo_excl if b.num_lo >= a.num_lo else False),
        num_hi=min(a.num_hi, b.num_hi),
        num_hi_excl=(a.num_hi_excl if a.num_hi <= b.num_hi else False)
        or (b.num_hi_excl if b.num_hi <= a.num_hi else False),
        str_min=max(a.str_min, b.str_min),
        str_max=min(a.str_max, b.str_max),
        arr_min=max(a.arr_min, b.arr_min),
        arr_max=min(a.arr_max, b.arr_max),
        obj_min=max(a.obj_min, b.obj_min),
        obj_max=min(a.obj_max, b.obj_max),
        required=a.required | b.required,
        closed=a.closed or b.closed,
        false_props=a.false_props | b.false_props,
    )
    if a.closed_props is not None and b.closed_props is not None:
        s = replace(s, closed_props=a.closed_props & b.closed_props)
    elif a.closed_props is not None or b.closed_props is not None:
        s = replace(s, closed_props=a.closed_props if a.closed_props is not None else b.closed_props)
    if a.values is not None:
        s = _meet_values(s, a.values)
    if b.values is not None:
        s = _meet_values(s, b.values)
    return s


def _int_interval_empty(s: Summary) -> bool:
    lo, hi = s.num_lo, s.num_hi
    if math.isfinite(lo):
        if s.num_lo_excl and float(lo).is_integer():
            lo += 1
        lo = math.ceil(lo)
    if math.isfinite(hi):
        if s.num_hi_excl and float(hi).is_integer():
            hi -= 1
        hi = math.floor(hi)
    return lo > hi


def _type_satisfiable(s: Summary, t: str) -> bool:
    if t in ("null", "boolean"):
        return True
    if t == "number":
        if s.num_lo > s.num_hi:
            return False
        if s.num_lo == s.num_hi and (s.num_lo_excl or s.num_hi_excl):
            return False
        return True
    if t == "integer":
        return _type_satisfiable(s, "number") and not _int_interval_empty(s)
    if t == "string":
        return s.str_min <= s.str_max
    if t == "array":
        return s.arr_min <= s.arr_max
    if t == "object":
        if s.obj_min > s.obj_max:
            return False
        if len(s.required) > s.obj_max:
            return False
        if s.required & s.false_props:
            return False
        if s.closed and s.closed_props is not None:
            if not s.required <= s.closed_props:
                return False
            usable = s.closed_props - s.false_props
            if len(usable) < s.obj_min:
                return False
        return True
    return True


def _value_type(v: Any) -> str:
    if v is None:
        return "null"
    if isinstance(v, bool):
        return "boolean"
    if isinstance(v, int):
        return "integer"
    if isinstance(v, float):
        return "integer" if v.is_integer() else "number"
    if isinstance(v, str):
        return "string"
    if isinstance(v, list):
        return "array"
    return "object"


def _value_ok(s: Summary, v: Any) -> bool:
    """Does candidate value ``v`` pass every fact the summary tracks?"""
    t = _value_type(v)
    if t == "integer":
        if "integer" not in s.types and "number" not in s.types:
            return False
    elif t not in s.types:
        return False
    if t in ("integer", "number"):
        x = float(v)
        if x < s.num_lo or (x == s.num_lo and s.num_lo_excl):
            return False
        if x > s.num_hi or (x == s.num_hi and s.num_hi_excl):
            return False
    elif t == "string":
        if not (s.str_min <= len(v) <= s.str_max):
            return False
    elif t == "array":
        if not (s.arr_min <= len(v) <= s.arr_max):
            return False
    elif t == "object":
        if not (s.obj_min <= len(v) <= s.obj_max):
            return False
        if not s.required <= frozenset(v.keys()):
            return False
        if s.closed and s.closed_props is not None and not frozenset(v.keys()) <= s.closed_props:
            return False
        if frozenset(v.keys()) & s.false_props:
            return False
    return True


def is_empty(s: Summary) -> Optional[str]:
    """Return a human-readable proof tag when the summary admits no
    instance, else None.  Emptiness of the over-approximation proves
    the schema unsatisfiable."""
    if s.values is not None:
        if not s.values:
            return "empty enum/const intersection"
        if not any(_value_ok(s, v) for v in s.values):
            return "no enum/const candidate satisfies conjoined constraints"
        return None
    if not s.types:
        return "empty type intersection"
    for t in sorted(s.types):
        if _type_satisfiable(s, t):
            return None
    return "every admitted type has contradictory bounds"


def is_top(schema: Any) -> bool:
    """Syntactic proof that a schema accepts every instance."""
    if schema is True:
        return True
    if isinstance(schema, dict):
        return all(k in ANNOTATION_KEYS for k in schema)
    return False
