"""Static invariant checker for LocationTape / LinkedTape.

Every tape transform in this repo (build, unroll, circuit wiring,
segment + relink) must preserve the layout contracts the batched
executor compiles against.  ``lint_tape`` re-derives each contract
from the raw arrays and reports violations as human-readable strings;
``assert_tape`` raises :class:`TapeLintError` on the first dirty tape.

Invariants checked (DESIGN.md §15):

- array shape consistency across the prop/psort/loc/asrt/circ tables
  and their provenance sidecars;
- owner-sorted CSR windows: per-location ``loc_asrt_start/len`` are
  contiguous, disjoint, cover exactly the real assertion rows, agree
  with ``asrt_owner``, keep AND rows (group 0) ahead of contiguous
  OR-groups, and ``max_rows_per_loc`` equals the widest window;
- psort segment integrity: the hash-sorted view is a permutation of
  the property table (via ``psort_orig_row``), lanes sort
  lexicographically *within* each member segment, equal-hash run
  lengths are correct and never span members, and ``max_hash_run``
  matches;
- location DAG: every transition edge (property child, addl, item,
  prefix) points strictly forward (acyclic by construction), depth DP
  reproduces ``max_loc_depth``, and sentinel domains hold;
- frontier consistency: no edge targets a ``loc_frontier`` location
  (all were snapped to the ``LOC_FRONTIER`` sentinel at build time);
- circuits: parents-first storage (``circ_parent[c] < c``), owners in
  range, recomputed bottom-up levels match ``circ_level`` and
  ``max_circ_depth``, leaf wiring ids in range;
- required-slot masks: every mask bit is backed by a property row
  carrying that slot, slots < 32;
- linked tapes: member offsets strictly monotonic and consistent with
  per-member counts, ``roots``/``member_prop_start`` mirror the
  offset tables, per-member horizons reproduce from the DAG, and
  per-member frontier/circuit counts add up.

CLI::

    python -m repro.analysis.lint_tape --presets   # registry presets + linked group tapes
"""

from __future__ import annotations

import sys
from typing import List, Optional

import numpy as np

from ..core.tape import LOC_FRONTIER, LOC_INVALID, LOC_UNTRACKED, LocationTape

__all__ = ["TapeLintError", "lint_tape", "assert_tape", "main"]

_SENTINELS = (-1, LOC_UNTRACKED, LOC_INVALID, LOC_FRONTIER)


class TapeLintError(AssertionError):
    """A tape violates a layout invariant the executor relies on."""


def assert_tape(tape: LocationTape, *, label: str = "") -> None:
    problems = lint_tape(tape)
    if problems:
        prefix = f"[{label}] " if label else ""
        raise TapeLintError(prefix + "; ".join(problems))


def lint_tape(tape: LocationTape) -> List[str]:
    """Return every invariant violation found (empty list = clean)."""
    out: List[str] = []
    say = out.append

    L = tape.n_locations
    M = tape.n_props
    A = tape.n_assertions
    C = tape.n_circuits

    # ---- shapes --------------------------------------------------------
    for name, arr, want in (
        ("prop_owner", tape.prop_owner, M),
        ("prop_child_loc", tape.prop_child_loc, M),
        ("prop_required_slot", tape.prop_required_slot, M),
        ("psort_owner", tape.psort_owner, M),
        ("psort_child_loc", tape.psort_child_loc, M),
        ("psort_required_slot", tape.psort_required_slot, M),
        ("psort_orig_row", tape.psort_orig_row, M),
        ("psort_run_len", tape.psort_run_len, M),
        ("loc_closed", tape.loc_closed, L),
        ("loc_addl", tape.loc_addl, L),
        ("loc_item", tape.loc_item, L),
        ("loc_item_start", tape.loc_item_start, L),
        ("loc_prefix_start", tape.loc_prefix_start, L),
        ("loc_prefix_len", tape.loc_prefix_len, L),
        ("loc_required_mask", tape.loc_required_mask, L),
        ("loc_asrt_start", tape.loc_asrt_start, L),
        ("loc_asrt_len", tape.loc_asrt_len, L),
        ("asrt_op", tape.asrt_op, A),
        ("asrt_group", tape.asrt_group, A),
        ("asrt_f0", tape.asrt_f0, A),
        ("asrt_i0", tape.asrt_i0, A),
        ("asrt_i1", tape.asrt_i1, A),
        ("asrt_u0", tape.asrt_u0, A),
        ("asrt_u1", tape.asrt_u1, A),
        ("asrt_circ", tape.asrt_circ, A),
        ("loc_frontier", tape.loc_frontier, L),
        ("circ_kind", tape.circ_kind, C),
        ("circ_parent", tape.circ_parent, C),
        ("circ_owner", tape.circ_owner, C),
        ("circ_level", tape.circ_level, C),
    ):
        if arr is None or len(arr) != want:
            say(f"shape: {name} has {0 if arr is None else len(arr)} rows, want {want}")
    if tape.prop_hash.shape != (M, 8):
        say(f"shape: prop_hash {tape.prop_hash.shape} != ({M}, 8)")
    if tape.psort_hash.shape != (M, 8):
        say(f"shape: psort_hash {tape.psort_hash.shape} != ({M}, 8)")
    if tape.asrt_hash.shape != (A, 8):
        say(f"shape: asrt_hash {tape.asrt_hash.shape} != ({A}, 8)")
    if tape.asrt_path is not None and len(tape.asrt_path) != A:
        say(f"shape: asrt_path has {len(tape.asrt_path)} entries, want {A}")
    if tape.loc_closed_path is not None and len(tape.loc_closed_path) != L:
        say(f"shape: loc_closed_path has {len(tape.loc_closed_path)} entries, want {L}")
    if tape.loc_required_info is not None and len(tape.loc_required_info) != L:
        say(f"shape: loc_required_info has {len(tape.loc_required_info)} entries, want {L}")
    if tape.circ_path is not None and len(tape.circ_path) != C:
        say(f"shape: circ_path has {len(tape.circ_path)} entries, want {C}")
    if out:
        return out  # downstream checks index by these shapes

    linked = tape.roots is not None and len(tape.roots) > 1
    S = tape.n_members if tape.roots is not None else 1

    # member location ranges (single tape: one member spanning all)
    if tape.roots is not None:
        loc_off = np.asarray(tape.roots, np.int64)
    else:
        loc_off = np.zeros(1, np.int64)
    loc_end = np.concatenate([loc_off[1:], [L]])

    real_a = tape.asrt_owner >= 0
    nA = int(np.count_nonzero(real_a))
    real_p = tape.prop_owner >= 0
    nM = int(np.count_nonzero(real_p))

    # ---- owner-sorted CSR windows --------------------------------------
    pos = 0
    for l in range(L):
        start = int(tape.loc_asrt_start[l])
        ln = int(tape.loc_asrt_len[l])
        if ln < 0:
            say(f"csr: negative window length at loc {l}")
            break
        if ln and start != pos:
            say(f"csr: window at loc {l} starts at {start}, expected {pos} (gap/overlap)")
            break
        if ln:
            if start + ln > nA:
                say(f"csr: window at loc {l} overruns real rows ({start}+{ln} > {nA})")
                break
            owners = tape.asrt_owner[start : start + ln]
            if not np.all(owners == l):
                say(f"csr: rows in loc {l}'s window owned by {set(owners.tolist()) - {l}}")
            groups = tape.asrt_group[start : start + ln]
            if np.any(np.diff(groups) < 0):
                say(f"csr: OR-groups not contiguous/sorted in loc {l}'s window")
            pos = start + ln
    else:
        if pos != nA:
            say(f"csr: windows cover {pos} rows, tape has {nA} real rows")
    want_ahat = int(tape.loc_asrt_len.max()) if L else 0
    if tape.max_rows_per_loc != want_ahat:
        say(f"csr: max_rows_per_loc {tape.max_rows_per_loc} != widest window {want_ahat}")

    # ---- psort permutation + segment integrity -------------------------
    if linked or tape.member_prop_start is not None:
        seg_start = np.asarray(tape.member_prop_start, np.int64)
        seg_len = np.asarray(tape.member_prop_len, np.int64)
    else:
        seg_start = np.zeros(1, np.int64)
        seg_len = np.array([nM], np.int64)
    if int(seg_len.sum()) != nM:
        say(f"psort: member segments cover {int(seg_len.sum())} rows, tape has {nM}")
    if tape.max_member_props is not None and len(seg_len) and int(seg_len.max()) != int(tape.max_member_props):
        say(f"psort: max_member_props {tape.max_member_props} != widest segment {int(seg_len.max())}")
    orig = tape.psort_orig_row
    if nM:
        if sorted(orig[:nM].tolist()) != list(range(nM)):
            say("psort: psort_orig_row is not a permutation of the real property rows")
        else:
            if not np.array_equal(tape.psort_owner[:nM], tape.prop_owner[orig[:nM]]):
                say("psort: psort_owner disagrees with prop_owner[psort_orig_row]")
            if not np.array_equal(tape.psort_hash[:nM], tape.prop_hash[orig[:nM]]):
                say("psort: psort_hash disagrees with prop_hash[psort_orig_row]")
            if not np.array_equal(tape.psort_child_loc[:nM], tape.prop_child_loc[orig[:nM]]):
                say("psort: psort_child_loc disagrees with prop_child_loc[psort_orig_row]")
            if not np.array_equal(tape.psort_required_slot[:nM], tape.prop_required_slot[orig[:nM]]):
                say("psort: psort_required_slot disagrees with prop_required_slot[psort_orig_row]")
    max_run = 0
    for s in range(len(seg_start)):
        a, b = int(seg_start[s]), int(seg_start[s] + seg_len[s])
        if b > nM or a > b:
            say(f"psort: member {s} segment [{a}, {b}) outside real rows [0, {nM})")
            continue
        lanes = tape.psort_hash[a:b]
        if len(lanes) > 1:
            flat = [tuple(int(x) for x in row) for row in lanes]
            if flat != sorted(flat):
                say(f"psort: member {s} lanes not lexicographically sorted")
        if len(lanes):
            run_id = np.zeros(len(lanes), np.int64)
            for r in range(1, len(lanes)):
                run_id[r] = run_id[r - 1] + (0 if np.array_equal(lanes[r], lanes[r - 1]) else 1)
            sizes = np.bincount(run_id)
            want = sizes[run_id]
            got = tape.psort_run_len[a:b]
            if not np.array_equal(got, want):
                say(f"psort: member {s} run lengths wrong")
            max_run = max(max_run, int(sizes.max()))
        if tape.psort_member is not None:
            if not np.all(tape.psort_member[a:b] == s):
                say(f"psort: psort_member mislabels member {s}'s segment")
    if tape.max_hash_run != max_run:
        say(f"psort: max_hash_run {tape.max_hash_run} != observed {max_run}")

    # ---- location DAG / sentinels / frontier ---------------------------
    frontier = np.asarray(tape.loc_frontier, bool)

    def check_targets(name: str, owners: np.ndarray, targets: np.ndarray) -> None:
        for owner, tgt in zip(owners.tolist(), targets.tolist()):
            if tgt in _SENTINELS:
                continue
            if not (0 <= tgt < L):
                say(f"dag: {name} target {tgt} outside locations and sentinel domain")
            elif frontier[tgt]:
                say(f"dag: {name} edge {owner}->{tgt} targets a frontier location (unsnapped)")
            elif tgt <= owner:
                say(f"dag: {name} edge {owner}->{tgt} not strictly forward")

    check_targets("prop", tape.prop_owner[real_p], tape.prop_child_loc[real_p])
    loc_ids = np.arange(L)
    check_targets("addl", loc_ids, tape.loc_addl)
    check_targets("item", loc_ids, tape.loc_item)
    n_pfx_real = int(tape.loc_prefix_len.sum())
    ppos = 0
    for l in range(L):
        a = int(tape.loc_prefix_start[l])
        n = int(tape.loc_prefix_len[l])
        if n < 0 or (n and a != ppos):
            say(f"dag: prefix window at loc {l} not contiguous")
            break
        if n:
            if a + n > len(tape.prefix_loc):
                say(f"dag: prefix window at loc {l} overruns prefix_loc")
                break
            check_targets("prefix", np.full(n, l), tape.prefix_loc[a : a + n])
            ppos = a + n
    else:
        if ppos != n_pfx_real:
            say(f"dag: prefix windows cover {ppos} rows, table declares {n_pfx_real}")

    # depth DP reproduction: collect every real forward edge, then one
    # ascending pass (edges only point forward, so dist[u] is final
    # before any edge out of u is relaxed)
    all_edges = [
        (int(o), int(t))
        for o, t in zip(tape.prop_owner[real_p], tape.prop_child_loc[real_p])
        if 0 <= t < L and t > o and not frontier[t]
    ]
    for u in range(L):
        for v in (int(tape.loc_addl[u]), int(tape.loc_item[u])):
            if 0 <= v < L and v > u and not frontier[v]:
                all_edges.append((u, v))
        a, n = int(tape.loc_prefix_start[u]), int(tape.loc_prefix_len[u])
        for v in tape.prefix_loc[a : a + n].tolist():
            if 0 <= v < L and v > u and not frontier[v]:
                all_edges.append((u, v))
    dist = np.zeros(max(1, L), np.int64)
    for u, v in sorted(all_edges):
        dist[v] = max(dist[v], dist[u] + 1)
    want_depth = int(dist.max()) if L else 0
    if tape.max_loc_depth != want_depth:
        say(f"dag: max_loc_depth {tape.max_loc_depth} != recomputed {want_depth}")
    if tape.member_horizons is not None:
        for s in range(S):
            seg = slice(int(loc_off[s]), int(loc_end[s]))
            member_depth = int(dist[seg].max()) if loc_end[s] > loc_off[s] else 0
            if int(tape.member_horizons[s]) != member_depth + 1:
                say(
                    f"linked: member {s} horizon {int(tape.member_horizons[s])}"
                    f" != recomputed {member_depth + 1}"
                )
    if bool(frontier.any()) and tape.unroll_depth < 1:
        say("dag: frontier locations present but unroll_depth < 1")

    # ---- required-slot masks -------------------------------------------
    if np.any(tape.prop_required_slot[real_p] >= 32):
        say("required: property slot >= 32 overflows the uint32 mask")
    slot_index = {}
    for o, sl in zip(tape.prop_owner[real_p].tolist(), tape.prop_required_slot[real_p].tolist()):
        if sl >= 0:
            slot_index.setdefault(o, set()).add(sl)
    for l in range(L):
        mask = int(tape.loc_required_mask[l])
        bit = 0
        while mask:
            if mask & 1 and bit not in slot_index.get(l, ()):
                say(f"required: loc {l} mask bit {bit} has no backing property row")
            mask >>= 1
            bit += 1

    # ---- circuits ------------------------------------------------------
    if C:
        for c in range(C):
            p = int(tape.circ_parent[c])
            if p != -1 and not (0 <= p < c):
                say(f"circ: node {c} parent {p} violates parents-first storage")
            o = int(tape.circ_owner[c])
            if not (0 <= o < L):
                say(f"circ: node {c} owner {o} out of range")
        level = np.zeros(C, np.int64)
        for c in range(C - 1, -1, -1):
            p = int(tape.circ_parent[c])
            if 0 <= p < c and level[p] <= level[c]:
                level[p] = level[c] + 1
        if not np.array_equal(level, np.asarray(tape.circ_level, np.int64)):
            say("circ: circ_level disagrees with recomputed bottom-up levels")
        want_cd = int(level.max())
        if tape.max_circ_depth != want_cd:
            say(f"circ: max_circ_depth {tape.max_circ_depth} != recomputed {want_cd}")
    elif tape.max_circ_depth != 0:
        say("circ: max_circ_depth nonzero without circuit nodes")
    bad_circ = [
        int(x) for x in tape.asrt_circ[real_a].tolist() if x != -1 and not (0 <= x < C)
    ]
    if bad_circ:
        say(f"circ: asrt_circ leaf ids {bad_circ[:4]} out of range [0, {C})")

    # ---- linked-tape member bookkeeping --------------------------------
    if tape.roots is not None:
        if int(loc_off[0]) != 0 or (S > 1 and bool(np.any(np.diff(loc_off) <= 0))):
            say("linked: loc_offsets not strictly increasing from 0")
        mnl = getattr(tape, "member_n_locations", None)
        if mnl is not None and not np.array_equal(
            np.asarray(mnl, np.int64), loc_end - loc_off
        ):
            say("linked: member_n_locations disagrees with loc_offsets")
        lofs = getattr(tape, "loc_offsets", None)
        if lofs is not None and not np.array_equal(np.asarray(lofs, np.int64), loc_off):
            say("linked: roots disagree with loc_offsets")
        pofs = getattr(tape, "prop_offsets", None)
        if pofs is not None and tape.member_prop_start is not None and not np.array_equal(
            np.asarray(pofs, np.int64), np.asarray(tape.member_prop_start, np.int64)
        ):
            say("linked: member_prop_start disagrees with prop_offsets")
        aofs = getattr(tape, "asrt_offsets", None)
        if aofs is not None and S and len(aofs) == S:
            # each member's assertion rows sit in [aofs[s], aofs[s+1])
            a_end = np.concatenate([np.asarray(aofs, np.int64)[1:], [nA]])
            for s in range(S):
                seg = tape.asrt_owner[int(aofs[s]) : int(a_end[s])]
                if len(seg) and (
                    int(seg.min()) < int(loc_off[s]) or int(seg.max()) >= int(loc_end[s])
                ):
                    say(f"linked: member {s} assertion owners stray outside its locations")
        mnf = getattr(tape, "member_n_frontier", None)
        if mnf is not None and len(mnf) == S:
            for s in range(S):
                cnt = int(np.count_nonzero(frontier[int(loc_off[s]) : int(loc_end[s])]))
                if cnt != int(mnf[s]):
                    say(f"linked: member {s} frontier count {int(mnf[s])} != {cnt}")
        mnc = getattr(tape, "member_n_circuits", None)
        if mnc is not None and len(mnc) == S and int(np.sum(mnc)) != C:
            say(f"linked: member_n_circuits sums to {int(np.sum(mnc))}, tape has {C}")

    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _lint_presets(verbose: bool = True) -> int:
    """Build every registry preset tape plus the linked group tapes and
    lint each; returns a process exit code."""
    from ..registry.presets import GATEWAY_SCHEMAS
    from ..registry.registry import SchemaRegistry

    failures = 0
    reg = SchemaRegistry()
    for name, schema in GATEWAY_SCHEMAS.items():
        reg.register(name, schema)
    for name in GATEWAY_SCHEMAS:
        entry = reg.get(name)
        if entry.tape is None:
            if verbose:
                print(f"  - {name}: not batchable ({entry.stats.fallback_reason}); skipped")
            continue
        problems = lint_tape(entry.tape)
        status = "ok" if not problems else "FAIL"
        if verbose or problems:
            print(f"  - {name} (v{entry.version}): {status}")
        for p in problems:
            failures += 1
            print(f"      {p}")
    for group in sorted(reg.groups(), key=lambda g: g.label):
        problems = lint_tape(group.tape)
        status = "ok" if not problems else "FAIL"
        if verbose or problems:
            print(f"  - group {group.label} {list(group.members)}: {status}")
        for p in problems:
            failures += 1
            print(f"      {p}")
    legacy = reg.linked_tape()
    if legacy is not None:
        problems = lint_tape(legacy)
        if verbose or problems:
            print(f"  - legacy linked tape: {'ok' if not problems else 'FAIL'}")
        for p in problems:
            failures += 1
            print(f"      {p}")
    return 1 if failures else 0


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="repro.analysis.lint_tape", description=__doc__)
    ap.add_argument("--presets", action="store_true", help="lint registry preset + linked group tapes")
    ap.add_argument("-q", "--quiet", action="store_true", help="only print failures")
    args = ap.parse_args(argv)
    if not args.presets:
        ap.error("nothing to lint: pass --presets")
    print("tape lint: registry presets")
    rc = _lint_presets(verbose=not args.quiet)
    print("tape lint:", "clean" if rc == 0 else "violations found")
    return rc


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
