"""A correct, non-compiling JSON Schema validator (the comparison baseline).

This walks the raw schema dictionary for every document, resolving ``$ref``
at validation time -- representative of interpreting validators such as
Python ``jsonschema`` (Table 4: AOT = no).  It intentionally performs none
of Blaze's compile-time work: no keyword tiering, no hashing, no regex
specialization, no reordering.  It shares no code with the compiled
executor, which also makes it an independent oracle for differential
testing (tests/test_differential.py).
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Set, Tuple

from .doc_model import has_type, json_equal
from .schema_resolver import Dialect, SchemaResolver

__all__ = ["NaiveValidator"]


class NaiveValidator:
    """Direct schema interpretation, resolving keywords per document."""

    def __init__(self, schema: Any, resources: Optional[Dict[str, Any]] = None):
        self.schema = schema
        self.resolver = SchemaResolver(schema, resources)
        self.dialect = self.resolver.dialect

    def is_valid(self, instance: Any) -> bool:
        valid, _, _ = self._validate(self.schema, instance, self.resolver.root_base, 0)
        return valid

    # ------------------------------------------------------------------

    def _validate(
        self, schema: Any, instance: Any, base: str, depth: int
    ) -> Tuple[bool, Set[str], Set[int]]:
        """Returns (valid, evaluated property names, evaluated item indices)."""
        if depth > 512:
            raise RecursionError("schema recursion limit")
        if schema is True or schema == {}:
            return True, set(), set()
        if schema is False:
            return False, set(), set()
        s: Dict[str, Any] = schema

        from urllib.parse import urljoin

        sid = s.get("$id")
        if isinstance(sid, str) and sid:
            base = urljoin(base, sid)

        eval_props: Set[str] = set()
        eval_items: Set[int] = set()

        # --- references ---------------------------------------------------
        for kw in ("$ref", "$dynamicRef", "$recursiveRef"):
            ref = s.get(kw)
            if not isinstance(ref, str):
                continue
            if kw == "$ref":
                r = self.resolver.resolve(ref, base)
            elif kw == "$dynamicRef":
                r = self.resolver.resolve_dynamic(ref, base)
            else:
                r = self.resolver.resolve_recursive(base)
            ok, ep, ei = self._validate(r.schema, instance, r.base_uri, depth + 1)
            if not ok:
                return False, set(), set()
            eval_props |= ep
            eval_items |= ei

        # --- type/const/enum -----------------------------------------------
        t = s.get("type")
        if isinstance(t, str):
            if not has_type(instance, t):
                return False, set(), set()
        elif isinstance(t, list):
            if not any(has_type(instance, x) for x in t):
                return False, set(), set()
        if "const" in s and not json_equal(instance, s["const"]):
            return False, set(), set()
        if "enum" in s and not any(json_equal(instance, v) for v in s["enum"]):
            return False, set(), set()

        # --- numbers ---------------------------------------------------------
        if isinstance(instance, (int, float)) and not isinstance(instance, bool):
            if not self._check_number(s, instance):
                return False, set(), set()

        # --- strings ---------------------------------------------------------
        if isinstance(instance, str):
            if "minLength" in s and len(instance) < s["minLength"]:
                return False, set(), set()
            if "maxLength" in s and len(instance) > s["maxLength"]:
                return False, set(), set()
            if "pattern" in s and re.search(s["pattern"], instance, re.DOTALL) is None:
                return False, set(), set()

        # --- objects ----------------------------------------------------------
        if isinstance(instance, dict):
            ok, ep = self._check_object(s, instance, base, depth)
            if not ok:
                return False, set(), set()
            eval_props |= ep

        # --- arrays ------------------------------------------------------------
        if isinstance(instance, list):
            ok, ei = self._check_array(s, instance, base, depth)
            if not ok:
                return False, set(), set()
            eval_items |= ei

        # --- logical ---------------------------------------------------------
        for sub in s.get("allOf") or []:
            ok, ep, ei = self._validate(sub, instance, base, depth + 1)
            if not ok:
                return False, set(), set()
            eval_props |= ep
            eval_items |= ei
        any_of = s.get("anyOf")
        if isinstance(any_of, list):
            hit = False
            for sub in any_of:
                ok, ep, ei = self._validate(sub, instance, base, depth + 1)
                if ok:
                    hit = True
                    eval_props |= ep
                    eval_items |= ei
            if not hit:
                return False, set(), set()
        one_of = s.get("oneOf")
        if isinstance(one_of, list):
            passed = 0
            for sub in one_of:
                ok, ep, ei = self._validate(sub, instance, base, depth + 1)
                if ok:
                    passed += 1
                    eval_props |= ep
                    eval_items |= ei
            if passed != 1:
                return False, set(), set()
        if "not" in s:
            ok, _, _ = self._validate(s["not"], instance, base, depth + 1)
            if ok:
                return False, set(), set()
        if "if" in s and self.dialect not in (Dialect.DRAFT4, Dialect.DRAFT6):
            ok, ep, ei = self._validate(s["if"], instance, base, depth + 1)
            branch = s.get("then") if ok else s.get("else")
            if ok:
                eval_props |= ep
                eval_items |= ei
            if branch is not None:
                bok, ep2, ei2 = self._validate(branch, instance, base, depth + 1)
                if not bok:
                    return False, set(), set()
                eval_props |= ep2
                eval_items |= ei2

        # --- dependent schemas -------------------------------------------------
        if isinstance(instance, dict):
            for key, sub in self._dependent_schemas(s):
                if key in instance:
                    ok, ep, ei = self._validate(sub, instance, base, depth + 1)
                    if not ok:
                        return False, set(), set()
                    eval_props |= ep
                    eval_items |= ei

        # --- unevaluated* (after everything else) -------------------------------
        if self.dialect in (Dialect.DRAFT2019, Dialect.DRAFT2020):
            if isinstance(instance, dict) and "unevaluatedProperties" in s:
                sub = s["unevaluatedProperties"]
                for key in instance:
                    if key in eval_props or self._directly_evaluated(s, key):
                        continue
                    ok, _, _ = self._validate(sub, instance[key], base, depth + 1)
                    if not ok:
                        return False, set(), set()
                    eval_props.add(key)
                eval_props = set(instance.keys())
            if isinstance(instance, list) and "unevaluatedItems" in s:
                sub = s["unevaluatedItems"]
                for i, item in enumerate(instance):
                    if i in eval_items or i < self._direct_prefix(s):
                        continue
                    ok, _, _ = self._validate(sub, item, base, depth + 1)
                    if not ok:
                        return False, set(), set()
                eval_items = set(range(len(instance)))
        return True, eval_props, eval_items

    # ------------------------------------------------------------------

    def _check_number(self, s: Dict[str, Any], v: float) -> bool:
        if self.dialect is Dialect.DRAFT4:
            if "minimum" in s:
                if s.get("exclusiveMinimum") is True:
                    if not v > s["minimum"]:
                        return False
                elif not v >= s["minimum"]:
                    return False
            if "maximum" in s:
                if s.get("exclusiveMaximum") is True:
                    if not v < s["maximum"]:
                        return False
                elif not v <= s["maximum"]:
                    return False
        else:
            if "minimum" in s and not v >= s["minimum"]:
                return False
            if "maximum" in s and not v <= s["maximum"]:
                return False
            em = s.get("exclusiveMinimum")
            if isinstance(em, (int, float)) and not isinstance(em, bool) and not v > em:
                return False
            eM = s.get("exclusiveMaximum")
            if isinstance(eM, (int, float)) and not isinstance(eM, bool) and not v < eM:
                return False
        if "multipleOf" in s:
            from .executor import _divisible

            # shared spec-exact check: decimal multipleOf (0.01) must
            # accept decimal multiples (19.99) despite binary floats
            if not _divisible(v, s["multipleOf"]):
                return False
        return True

    def _check_object(
        self, s: Dict[str, Any], obj: Dict[str, Any], base: str, depth: int
    ) -> Tuple[bool, Set[str]]:
        evaluated: Set[str] = set()
        req = s.get("required")
        if isinstance(req, list):
            for key in req:
                if key not in obj:
                    return False, evaluated
        if "minProperties" in s and len(obj) < s["minProperties"]:
            return False, evaluated
        if "maxProperties" in s and len(obj) > s["maxProperties"]:
            return False, evaluated
        for key, deps in self._dependent_required(s):
            if key in obj:
                for d in deps:
                    if d not in obj:
                        return False, evaluated
        props = s.get("properties") or {}
        pat_props = s.get("patternProperties") or {}
        addl = s.get("additionalProperties")
        for key, value in obj.items():
            matched = False
            if key in props:
                matched = True
                ok, _, _ = self._validate(props[key], value, base, depth + 1)
                if not ok:
                    return False, evaluated
            for pat, sub in pat_props.items():
                if re.search(pat, key, re.DOTALL) is not None:
                    matched = True
                    ok, _, _ = self._validate(sub, value, base, depth + 1)
                    if not ok:
                        return False, evaluated
            if matched:
                evaluated.add(key)
            elif addl is not None:
                if addl is False:
                    return False, evaluated
                ok, _, _ = self._validate(addl, value, base, depth + 1)
                if not ok:
                    return False, evaluated
                evaluated.add(key)
        if "propertyNames" in s:
            for key in obj:
                ok, _, _ = self._validate(s["propertyNames"], key, base, depth + 1)
                if not ok:
                    return False, evaluated
        return True, evaluated

    def _check_array(
        self, s: Dict[str, Any], arr: List[Any], base: str, depth: int
    ) -> Tuple[bool, Set[int]]:
        evaluated: Set[int] = set()
        if "minItems" in s and len(arr) < s["minItems"]:
            return False, evaluated
        if "maxItems" in s and len(arr) > s["maxItems"]:
            return False, evaluated
        if s.get("uniqueItems") is True:
            for i in range(len(arr)):
                for j in range(i + 1, len(arr)):
                    if json_equal(arr[i], arr[j]):
                        return False, evaluated
        prefix, tail = self._split_items(s)
        for i, sub in enumerate(prefix):
            if i >= len(arr):
                break
            ok, _, _ = self._validate(sub, arr[i], base, depth + 1)
            if not ok:
                return False, evaluated
            evaluated.add(i)
        if tail is not None:
            for i in range(len(prefix), len(arr)):
                if tail is False:
                    return False, evaluated
                ok, _, _ = self._validate(tail, arr[i], base, depth + 1)
                if not ok:
                    return False, evaluated
                evaluated.add(i)
        if "contains" in s and self.dialect is not Dialect.DRAFT4:
            min_c = s.get("minContains", 1)
            max_c = s.get("maxContains")
            if self.dialect in (Dialect.DRAFT6, Dialect.DRAFT7):
                min_c, max_c = 1, None
            count = 0
            for i, item in enumerate(arr):
                ok, _, _ = self._validate(s["contains"], item, base, depth + 1)
                if ok:
                    count += 1
                    evaluated.add(i)
            if count < min_c or (max_c is not None and count > max_c):
                return False, evaluated
        return True, evaluated

    # ------------------------------------------------------------------

    def _split_items(self, s: Dict[str, Any]):
        if self.dialect in (Dialect.DRAFT2019, Dialect.DRAFT2020):
            prefix = s.get("prefixItems") or []
            items = s.get("items")
            if self.dialect is Dialect.DRAFT2019 and isinstance(items, list):
                return items, s.get("additionalItems")
            return list(prefix), items
        items = s.get("items")
        if isinstance(items, list):
            return items, s.get("additionalItems")
        return [], items

    def _dependent_required(self, s: Dict[str, Any]):
        out = []
        dr = s.get("dependentRequired")
        if isinstance(dr, dict):
            out.extend((k, v) for k, v in dr.items() if isinstance(v, list))
        legacy = s.get("dependencies")
        if isinstance(legacy, dict):
            out.extend((k, v) for k, v in legacy.items() if isinstance(v, list))
        return out

    def _dependent_schemas(self, s: Dict[str, Any]):
        out = []
        ds = s.get("dependentSchemas")
        if isinstance(ds, dict):
            out.extend(ds.items())
        legacy = s.get("dependencies")
        if isinstance(legacy, dict):
            out.extend((k, v) for k, v in legacy.items() if not isinstance(v, list))
        return out

    def _directly_evaluated(self, s: Dict[str, Any], key: str) -> bool:
        if key in (s.get("properties") or {}):
            return True
        for pat in s.get("patternProperties") or {}:
            if re.search(pat, key, re.DOTALL) is not None:
                return True
        return "additionalProperties" in s

    def _direct_prefix(self, s: Dict[str, Any]) -> int:
        prefix, tail = self._split_items(s)
        if tail is not None:
            return 1 << 30
        return len(prefix)
