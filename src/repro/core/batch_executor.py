"""Batched, TPU-native schema validation over token tables.

Validates B documents against one compiled location tape in a handful of
large tensor ops:

1. **Location propagation** -- BFS-level loop (static, ``max_depth``
   iterations): every node's schema location derives from its parent's via
   the property-transition table (``hash_match`` kernel) or the
   item/prefix rules.  Unmatched properties map to the location's
   additionalProperties location, ``UNTRACKED`` (no constraints below) or
   ``INVALID`` (closed object).
2. **Required tracking** -- matched children scatter their required-slot
   bit into the parent's acquired mask; objects then check
   ``acquired & required == required``.
3. **Assertion evaluation** -- the ``assertion_eval`` kernel computes the
   (nodes x rows) pass matrix; ownership masking and enum OR-group
   reduction are fused selects around it.
4. **Reduce** -- AND over nodes per document.

The per-document fail-fast of the sequential engine becomes batch-level
work (§2.3 short-circuiting has no analogue across a converged batch); the
compile-time *reordering* optimizations still apply because they shrink
the tape itself.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tape import LOC_INVALID, LOC_UNTRACKED, LocationTape
from ..kernels import ops as kops

__all__ = ["BatchValidator"]

_T_OBJ = 6
_T_ARR = 5


def _tape_consts(tape: LocationTape) -> Dict[str, jnp.ndarray]:
    return {
        "prop_owner": jnp.asarray(tape.prop_owner),
        "prop_hash": jnp.asarray(tape.prop_hash),
        "prop_child_loc": jnp.asarray(tape.prop_child_loc),
        "prop_required_slot": jnp.asarray(tape.prop_required_slot),
        "loc_closed": jnp.asarray(tape.loc_closed),
        "loc_addl": jnp.asarray(tape.loc_addl),
        "loc_item": jnp.asarray(tape.loc_item),
        "loc_item_start": jnp.asarray(tape.loc_item_start),
        "loc_prefix_start": jnp.asarray(tape.loc_prefix_start),
        "loc_prefix_len": jnp.asarray(tape.loc_prefix_len),
        "prefix_loc": jnp.asarray(tape.prefix_loc),
        "loc_required_mask": jnp.asarray(tape.loc_required_mask.astype(np.int32)),
        "asrt_owner": jnp.asarray(tape.asrt_owner),
        "asrt_op": jnp.asarray(tape.asrt_op),
        "asrt_group": jnp.asarray(tape.asrt_group),
        "asrt_f0": jnp.asarray(tape.asrt_f0.astype(np.float32)),
        "asrt_i0": jnp.asarray(tape.asrt_i0),
        "asrt_i1": jnp.asarray(tape.asrt_i1),
        "asrt_u0": jnp.asarray(tape.asrt_u0),
        "asrt_u1": jnp.asarray(tape.asrt_u1),
        "asrt_hash": jnp.asarray(tape.asrt_hash),
    }


class BatchValidator:
    """Validates encoded token-table batches against one schema tape."""

    def __init__(
        self,
        tape: LocationTape,
        *,
        max_depth: int = 16,
        use_pallas: bool = True,
    ):
        self.tape = tape
        self.max_depth = max_depth
        self.use_pallas = use_pallas
        self._consts = _tape_consts(tape)
        self._fn = jax.jit(
            functools.partial(
                _validate_batch,
                consts=self._consts,
                max_depth=max_depth,
                use_pallas=use_pallas,
            )
        )

    def validate(self, table) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (valid, decided) boolean arrays of shape (B,).

        ``decided=False`` rows exceeded the encoder budget and must be
        routed to the sequential executor.
        """
        cols = {k: jnp.asarray(v) for k, v in table.columns().items()}
        valid = self._fn(cols)
        return np.asarray(valid), np.asarray(table.ok)


def _validate_batch(cols, *, consts, max_depth: int, use_pallas: bool):
    B, N = cols["node_type"].shape
    flat = lambda x: x.reshape((B * N,) + x.shape[2:])

    node_type = flat(cols["node_type"]).astype(jnp.int32)
    parent = flat(cols["parent"])  # int32, -1 root
    depth = flat(cols["depth"])
    idx_in_parent = flat(cols["idx_in_parent"])
    key_hash = flat(cols["key_hash"])
    size = flat(cols["size"])

    doc_base = jnp.repeat(jnp.arange(B, dtype=jnp.int32) * N, N)
    parent_flat = jnp.where(parent >= 0, doc_base + parent, 0)

    is_pad = node_type == 0

    # ---- 1. location propagation -------------------------------------------
    loc = jnp.where(
        jnp.arange(B * N, dtype=jnp.int32) % N == 0,
        jnp.int32(0),
        jnp.int32(-1),
    )
    acquired = jnp.zeros(B * N, jnp.int32)  # required-slot bits per object

    for d in range(1, max_depth + 1):
        at_depth = (depth == d) & ~is_pad & (parent >= 0)
        parent_loc = loc[parent_flat]
        parent_type = node_type[parent_flat]

        # -- object members: property-table match (hash_match kernel)
        is_member = at_depth & (parent_type == _T_OBJ)
        q_owner = jnp.where(is_member & (parent_loc >= 0), parent_loc, jnp.int32(-1))
        row = kops.hash_match(
            key_hash,
            q_owner,
            consts["prop_hash"],
            consts["prop_owner"],
            use_pallas=use_pallas,
        )
        matched = row >= 0
        safe_row = jnp.where(matched, row, 0)
        child_loc = jnp.where(
            matched, consts["prop_child_loc"][safe_row], jnp.int32(LOC_UNTRACKED)
        )
        # unmatched at a tracked object location: addl / closed / untracked
        p_loc_safe = jnp.where(parent_loc >= 0, parent_loc, 0)
        addl = consts["loc_addl"][p_loc_safe]
        closed = consts["loc_closed"][p_loc_safe]
        unmatched_loc = jnp.where(
            closed,
            jnp.int32(LOC_INVALID),
            jnp.where(addl >= 0, addl, jnp.int32(LOC_UNTRACKED)),
        )
        member_loc = jnp.where(matched, child_loc, unmatched_loc)
        member_loc = jnp.where(parent_loc >= 0, member_loc, parent_loc)

        # required bit scatter into the parent's acquired mask
        slot = jnp.where(matched, consts["prop_required_slot"][safe_row], -1)
        contrib = jnp.where(
            is_member & (slot >= 0),
            jnp.left_shift(jnp.int32(1), jnp.maximum(slot, 0)),
            0,
        )
        acquired = acquired.at[parent_flat].add(
            jnp.where(is_member, contrib, 0), mode="drop"
        )

        # -- array items: prefix / tail-items rules
        is_item = at_depth & (parent_type == _T_ARR)
        pfx_len = consts["loc_prefix_len"][p_loc_safe]
        pfx_start = consts["loc_prefix_start"][p_loc_safe]
        in_prefix = idx_in_parent < pfx_len
        pfx_idx = jnp.clip(pfx_start + idx_in_parent, 0, consts["prefix_loc"].shape[0] - 1)
        prefix_loc = consts["prefix_loc"][pfx_idx]
        item_loc = consts["loc_item"][p_loc_safe]
        item_start = consts["loc_item_start"][p_loc_safe]
        tail_loc = jnp.where(
            (item_loc >= 0) & (idx_in_parent >= item_start),
            item_loc,
            jnp.int32(LOC_UNTRACKED),
        )
        arr_loc = jnp.where(in_prefix, prefix_loc, tail_loc)
        arr_loc = jnp.where(parent_loc >= 0, arr_loc, parent_loc)

        new_loc = jnp.where(is_member, member_loc, jnp.where(is_item, arr_loc, loc))
        loc = jnp.where(at_depth, new_loc, loc)

    tracked = loc >= 0

    # ---- 2. required properties ----------------------------------------------
    loc_safe = jnp.where(tracked, loc, 0)
    required_mask = jnp.where(
        tracked & (node_type == _T_OBJ), consts["loc_required_mask"][loc_safe], 0
    )
    required_ok = (acquired & required_mask) == required_mask

    # ---- 3. assertion rows ------------------------------------------------------
    node_cols = {
        "type": node_type,
        "is_int": flat(cols["is_int"]),
        "num": flat(cols["num"]).astype(jnp.float32),
        "size": size,
        "str_hash": flat(cols["str_hash"]),
        "str_prefix": flat(cols["str_prefix"]),
    }
    asrt_cols = {
        "op": consts["asrt_op"],
        "f0": consts["asrt_f0"],
        "i0": consts["asrt_i0"],
        "i1": consts["asrt_i1"],
        "u0": consts["asrt_u0"],
        "u1": consts["asrt_u1"],
        "hash": consts["asrt_hash"],
    }
    passes = kops.assertion_eval(node_cols, asrt_cols, use_pallas=use_pallas).astype(
        bool
    )  # (B*N, A)
    applies = loc[:, None] == consts["asrt_owner"][None, :]  # (B*N, A)

    is_and_row = consts["asrt_group"] == 0
    and_ok = jnp.all(jnp.where(applies & is_and_row[None, :], passes, True), axis=1)

    # enum OR-groups: group passes iff it does not apply or any row matches
    groups = consts["asrt_group"]
    n_groups = int(self_max(groups)) + 1
    if n_groups > 1:
        onehot = (
            groups[None, :, None] == jnp.arange(1, n_groups, dtype=jnp.int32)[None, None, :]
        )  # (1, A, G-1)
        gm = jnp.any((applies & passes)[:, :, None] & onehot, axis=1)  # (B*N, G-1)
        ga = jnp.any(applies[:, :, None] & onehot, axis=1)
        or_ok = jnp.all(jnp.logical_or(~ga, gm), axis=1)
    else:
        or_ok = jnp.ones(B * N, bool)

    # ---- 4. reduce ---------------------------------------------------------------
    node_valid = (
        (loc != LOC_INVALID) & and_ok & or_ok & required_ok
    ) | is_pad
    return jnp.all(node_valid.reshape(B, N), axis=1)


def self_max(x: jnp.ndarray) -> int:
    """Static max of a tape-constant array (tape is host data)."""
    return int(np.asarray(x).max())
