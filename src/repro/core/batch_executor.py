"""Batched, TPU-native schema validation over token tables.

Validates B documents against one compiled location tape in a handful of
large tensor ops.  The tape may be a multi-member *linked* tape
(``registry/linker.py``): per-document ``schema_ids`` seed each root
from ``tape.roots`` and the hash pass becomes member-windowed, so one
kernel launch validates a heterogeneous (multi-schema) batch
bit-identically to per-schema dispatch (DESIGN.md §8).  The pipeline:

1. **Location propagation** -- one owner-blind ``hash_match`` pass over
   all B*N nodes finds each node's *candidate set*: the contiguous run of
   hash-sorted property rows sharing the node's key hash (<= K rows,
   K = ``tape.max_hash_run``).  The BFS-level loop (static, ``max_depth``
   iterations) then resolves each node's schema location from its
   parent's with a cheap owner-equality check over the K candidates --
   O(N*M + depth*N*K) instead of the historical O(depth*N*M) of running
   the full kernel every iteration.  Unmatched properties map to the
   location's additionalProperties location, ``UNTRACKED`` (no
   constraints below) or ``INVALID`` (closed object); array items follow
   the item/prefix rules.
2. **Required tracking** -- matched children scatter their required-slot
   bit into the parent's acquired mask; objects then check
   ``acquired & required == required``.
3. **Assertion evaluation** -- each node gathers only its own location's
   owner-sorted CSR window (<= A-hat rows, ``tape.max_rows_per_loc``) and
   the windowed ``assertion_eval`` kernel computes the (nodes x A-hat)
   pass matrix; enum OR-groups reduce with a segmented scan over the
   window (groups are contiguous by construction).  O(N*A-hat) memory and
   compute instead of the dense O(N*A) matrix plus a rank-3 (N, A, G)
   one-hot reduction.
3b. **Circuit reduce** (DESIGN.md §10) -- rows wired to logical-applicator
   circuits (``anyOf``/``oneOf``/``not``/``if`` over the scalar subset)
   are excluded from the plain AND/OR reduction; per-document *anchor*
   node indices (one masked reduction per circuit-relevant location)
   feed tiny (B, U) leaf gathers, and a statically-unrolled bottom-up
   pass (trace depth bounded by the tape's ``max_circ_depth``) reduces
   the circuit (AND/OR/XOR1-count/NOT), gating every node on its owner
   location's presence so absent targets stay vacuously true and other
   members' circuits are no-ops on a linked tape.  Root values AND into
   the verdict.
4. **Reduce** -- AND over nodes per document, plus a per-document
   ``decided`` flag: nodes deeper than the ``max_depth`` budget never
   receive a location, so their documents are flagged undecided and must
   be routed to the sequential executor (mirroring the encoder budget in
   ``TokenTable.ok``) instead of vacuously passing.  Documents whose
   recursion outran the tape's $ref-unroll budget carry ``LOC_FRONTIER``
   nodes and are likewise undecided (``validate_ex`` exposes the flag so
   callers can count those ``unroll_overflow`` fallbacks separately).

``layout="dense"`` keeps the historical full-matrix path (hash_match per
depth iteration + dense assertion matrix) for apples-to-apples
benchmarking; both layouts produce bit-identical (valid, decided).

The per-document fail-fast of the sequential engine becomes batch-level
work (§2.3 short-circuiting has no analogue across a converged batch); the
compile-time *reordering* optimizations still apply because they shrink
the tape itself.
"""

from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..obs.profile import phase as _phase
from ..obs.trace import span as _span, trace_point as _trace_point
from .explain import KIND_CIRCUIT, FailureSite, resolve_site
from .nodetypes import T_ARR as _T_ARR, T_OBJ as _T_OBJ
from .outcomes import fault_hook_armed, fault_point
from .tape import (
    CK_AND,
    CK_NOT,
    CK_OR,
    LOC_FRONTIER,
    LOC_INVALID,
    LOC_UNTRACKED,
    LocationTape,
)

__all__ = ["BatchValidator"]

_BIG = jnp.int32(2**30)
_CIRC_FLAG = 1 << 20  # packed circuit-membership bit in asrt_gcode


def _group_circ_map(tape: LocationTape) -> np.ndarray:
    """OR-group id -> owning circuit node (-1 for plain enum groups).

    All rows of one group share a circuit by construction (a group is
    emitted by a single enum lowering), so any row of the group may
    supply the mapping.
    """
    groups = np.asarray(tape.asrt_group)
    circ = np.asarray(tape.asrt_circ)
    n_groups = (int(groups.max()) + 1) if groups.size else 1
    out = np.full(max(1, n_groups), -1, np.int32)
    for g, c in zip(groups.tolist(), circ.tolist()):
        if g > 0 and c >= 0:
            out[g] = c
    return out


def _circuit_leaf_units(tape: LocationTape):
    """Static circuit-leaf wiring: which row/group feeds which node.

    Returns ``(and_units, group_units)``: AND units are
    ``(circ, owner_loc, row, window_slot)`` for plain circuit rows, group
    units ``(circ, owner_loc, group_id, window_slot_of_start)`` for
    circuit enum groups (rows are (owner, group)-sorted, so the first row
    of a group is its window start).  Everything here is compile-time.
    """
    owner = np.asarray(tape.asrt_owner)
    grp = np.asarray(tape.asrt_group)
    circ = np.asarray(tape.asrt_circ)
    start = np.asarray(tape.loc_asrt_start)
    and_units, group_units = [], []
    seen_groups = set()
    for r in range(len(owner)):
        c = int(circ[r])
        if c < 0:
            continue
        o = int(owner[r])
        g = int(grp[r])
        s = r - int(start[o])
        if g == 0:
            and_units.append((c, o, r, s))
        elif g not in seen_groups:
            seen_groups.add(g)
            group_units.append((c, o, g, s))
    return tuple(and_units), tuple(group_units)


def _circuit_static_wiring(tape: LocationTape):
    """All compile-time circuit metadata for the executor.

    Circuit work must not tax non-circuit traffic: every location a
    circuit touches (node owners + leaf-unit owners) gets a compact
    *anchor rank*, so the executor can resolve, per document, the single
    node at each such location (unique-path precondition) with ONE small
    scatter and evaluate leaves/presence as (B, U)/(B, C) gathers --
    never (B*N, U) masking over the whole batch.
    """
    and_units, group_units = _circuit_leaf_units(tape)
    circ_owner = np.asarray(tape.circ_owner, np.int32)
    unit_owners = [u[1] for u in and_units] + [u[1] for u in group_units]
    owner_locs = np.unique(
        np.concatenate([circ_owner, np.asarray(unit_owners, np.int32)])
    ) if (len(circ_owner) or unit_owners) else np.zeros(0, np.int32)
    rank_of = {l: r for r, l in enumerate(owner_locs.tolist())}
    return {
        "kind": np.asarray(tape.circ_kind, np.int32),
        "parent": np.asarray(tape.circ_parent, np.int32),
        "owner": circ_owner,
        # OR-group id -> owning circuit (-1 plain), for the dense
        # layout's group-level reduction (rows of one group share it)
        "group_circ": _group_circ_map(tape),
        "and_units": and_units,
        "group_units": group_units,
        "owner_locs": owner_locs,
        "circ_ranks": np.asarray([rank_of[int(l)] for l in circ_owner], np.int32),
        "and_ranks": np.asarray([rank_of[u[1]] for u in and_units], np.int32),
        "group_ranks": np.asarray([rank_of[u[1]] for u in group_units], np.int32),
    }


def _tape_consts(tape: LocationTape) -> Dict[str, jnp.ndarray]:
    # the packed gcode column reserves bit 20 for circuit membership: a
    # linked tape accumulating that many distinct OR-group ids must fail
    # loudly, never silently misdecode enum rows as circuit rows
    assert int(np.asarray(tape.asrt_group).max(initial=0)) < _CIRC_FLAG, (
        "OR-group id space exceeds the gcode circuit-flag bit"
    )
    return {
        "prop_owner": jnp.asarray(tape.prop_owner),
        "prop_hash": jnp.asarray(tape.prop_hash),
        "prop_child_loc": jnp.asarray(tape.prop_child_loc),
        "prop_required_slot": jnp.asarray(tape.prop_required_slot),
        "psort_hash": jnp.asarray(tape.psort_hash),
        "psort_owner": jnp.asarray(tape.psort_owner),
        "psort_child_loc": jnp.asarray(tape.psort_child_loc),
        "psort_required_slot": jnp.asarray(tape.psort_required_slot),
        "psort_orig_row": jnp.asarray(tape.psort_orig_row),
        "psort_run_len": jnp.asarray(tape.psort_run_len),
        "prefix_loc": jnp.asarray(tape.prefix_loc),
        # packed per-location structural row: one gather per depth
        # iteration instead of six (addl, closed, item, item_start,
        # prefix_start, prefix_len)
        "loc_struct": jnp.stack(
            [
                jnp.asarray(tape.loc_addl),
                jnp.asarray(tape.loc_closed.astype(np.int32)),
                jnp.asarray(tape.loc_item),
                jnp.asarray(tape.loc_item_start),
                jnp.asarray(tape.loc_prefix_start),
                jnp.asarray(tape.loc_prefix_len),
            ],
            axis=1,
        ),
        "loc_required_mask": jnp.asarray(tape.loc_required_mask.astype(np.int32)),
        "loc_asrt_start": jnp.asarray(tape.loc_asrt_start),
        "loc_asrt_len": jnp.asarray(tape.loc_asrt_len),
        "asrt_owner": jnp.asarray(tape.asrt_owner),
        "asrt_op": jnp.asarray(tape.asrt_op),
        "asrt_group": jnp.asarray(tape.asrt_group),
        "asrt_f0": jnp.asarray(tape.asrt_f0.astype(np.float32)),
        "asrt_i0": jnp.asarray(tape.asrt_i0),
        "asrt_i1": jnp.asarray(tape.asrt_i1),
        "asrt_u0": jnp.asarray(tape.asrt_u0),
        "asrt_u1": jnp.asarray(tape.asrt_u1),
        "asrt_hash": jnp.asarray(tape.asrt_hash),
        "asrt_circ": jnp.asarray(tape.asrt_circ),
        # group id + circuit-membership flag packed into one column so
        # the windowed path pays ONE gather for both (group ids stay far
        # below the flag bit)
        "asrt_gcode": jnp.asarray(
            (
                np.asarray(tape.asrt_group)
                + np.where(np.asarray(tape.asrt_circ) >= 0, _CIRC_FLAG, 0)
            ).astype(np.int32)
        ),
        "psort_member": jnp.asarray(tape.psort_member),
        # a frontier root (degenerate: the unroll budget died at the
        # root) must seed documents with the sentinel, not location 0
        "roots": jnp.asarray(
            np.where(tape.loc_frontier[tape.roots], LOC_FRONTIER, tape.roots).astype(
                np.int32
            )
        ),
        "member_horizons": jnp.asarray(tape.member_horizons),
        "member_prop_start": jnp.asarray(tape.member_prop_start),
        "member_prop_len": jnp.asarray(tape.member_prop_len),
    }


class BatchValidator:
    """Validates encoded token-table batches against one schema tape."""

    def __init__(
        self,
        tape: LocationTape,
        *,
        max_depth: int = 16,
        use_pallas: bool = True,
        layout: str = "csr",
        metrics=None,
    ):
        if layout not in ("csr", "dense"):
            raise ValueError(f"unknown layout {layout!r}")
        self.tape = tape
        self.max_depth = max_depth
        self.use_pallas = use_pallas
        self.layout = layout
        # optional MetricRegistry (obs/metrics.py): children are cached
        # here once so the per-launch hot path is attribute adds gated on
        # one ``is not None`` check (DESIGN.md §12)
        self.metrics = metrics
        if metrics is not None:
            self._m_launches = metrics.counter(
                "executor_launches_total", "batched kernel launches"
            )
            self._m_launch_seconds = metrics.counter(
                "executor_launch_seconds_total",
                "wall seconds inside batched launches (device sync included)",
            )
            self._m_recompiles = metrics.counter(
                "executor_recompiles_total",
                "distinct batch shapes seen (each costs one jit trace)",
            )
            self._m_bisect_depth = metrics.histogram(
                "executor_bisect_depth",
                "poison-isolation bisection depth per isolated validate",
                buckets=tuple(float(d) for d in range(13)),
            )
        self._seen_shapes: set = set()
        # compile-time window bounds (clamped: the kernels need >= 1 slot)
        self.n_window = max(1, tape.max_rows_per_loc)
        self.k_cand = max(1, tape.max_hash_run)
        self.m_hat = max(1, tape.max_member_props)
        # static: tapes without frontier locations skip the detection scan
        self.has_frontier = tape.n_frontier > 0
        # logical-applicator circuits (DESIGN.md §10): all wiring is
        # compile-time -- kept as host numpy so the per-level reduce can
        # slice/scatter with static indices.  Circuit-free tapes (the
        # common case) statically skip every circuit op.
        self.n_circuits = tape.n_circuits
        self._circuits = _circuit_static_wiring(tape)
        self._consts = _tape_consts(tape)
        self._fn = jax.jit(
            functools.partial(
                _validate_batch,
                consts=self._consts,
                max_depth=max_depth,
                max_loc_depth=tape.max_loc_depth,
                use_pallas=use_pallas,
                layout=layout,
                n_window=self.n_window,
                k_cand=self.k_cand,
                m_hat=self.m_hat,
                n_members=tape.n_members,
                has_frontier=self.has_frontier,
                circuits=self._circuits,
                n_circuits=self.n_circuits,
            )
        )
        self._explain_fn = None  # lazily jitted by explain_batch

    def validate(self, table, schema_ids=None) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (valid, decided) boolean arrays of shape (B,).

        ``schema_ids`` selects each document's member of a linked tape
        (``registry/linker.py``): document b's root node is seeded with
        ``tape.roots[schema_ids[b]]``.  Single-member tapes (the default)
        accept the implicit all-zeros vector.

        ``decided=False`` rows exceeded the encoder budget, contain
        nodes deeper than this validator's ``max_depth`` (which the
        location loop never reaches), *or* reached a ``LOC_FRONTIER``
        sentinel (the tape's $ref-unroll budget ran out below them); all
        must be routed to the sequential executor -- their ``valid``
        entry is meaningless.
        """
        valid, decided, _ = self.validate_ex(table, schema_ids)
        return valid, decided

    def validate_ex(
        self, table, schema_ids=None
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Like :meth:`validate` plus the per-doc ``frontier`` flag.

        ``frontier[b]`` is True when document b reached an unroll
        frontier -- one of the three undecided causes (the others being
        encoder oversize and the depth budget), kept separate so callers
        can count ``unroll_overflow`` fallbacks distinctly.
        """
        B = table.batch
        ids = self._normalize_ids(B, schema_ids)
        cols = {k: jnp.asarray(v) for k, v in table.columns().items()}
        # shape churn = jit re-traces: each new (B, N) pair re-traces the
        # launch function (the power-of-two padding upstream exists to
        # keep this set tiny).  Tracked unconditionally: the profiler's
        # compile-vs-execute split keys on the same first-call-under-new-
        # shape event whether or not metrics are attached.
        shape = (B, table.max_nodes)
        new_shape = shape not in self._seen_shapes
        if new_shape:
            self._seen_shapes.add(shape)
        m = self.metrics
        if m is not None:
            if new_shape:
                self._m_recompiles.inc()
                _trace_point("executor.recompile", shape=shape)
            t0 = time.perf_counter()
        # first call under a new shape pays the jit trace: attribute its
        # whole wall time to compile, steady-state launches to execute
        with _phase("executor.compile" if new_shape else "executor.execute"):
            with _span("executor.launch"):
                valid, in_depth, frontier = self._fn(cols, jnp.asarray(ids))
                valid = np.asarray(valid)  # forces device sync inside the span
                in_depth = np.asarray(in_depth)
                frontier = np.asarray(frontier)
        if m is not None:
            self._m_launches.inc()
            self._m_launch_seconds.inc(time.perf_counter() - t0)
        decided = in_depth & ~frontier & np.asarray(table.ok)
        return valid, decided, frontier & np.asarray(table.ok)

    def seen_shapes(self) -> set:
        """Snapshot of the (B, max_nodes) launch shapes already traced."""
        return set(self._seen_shapes)

    def warm(self, table, schema_ids=None) -> bool:
        """Pre-trace the launch for ``table``'s shape off the request
        path; returns True when a new shape was actually compiled.

        Streaming schedulers admit power-of-two buckets precisely so
        this set stays tiny; warming the expected buckets ahead of
        traffic keeps jit traces out of deadline-bounded drains.
        """
        if (table.batch, table.max_nodes) in self._seen_shapes:
            return False
        self.validate_ex(table, schema_ids)
        return True

    def _normalize_ids(self, B: int, schema_ids) -> np.ndarray:
        if schema_ids is None:
            if self.tape.n_members > 1:
                raise ValueError(
                    "linked tape: per-document schema_ids are required "
                    "(member 0 would otherwise be guessed silently)"
                )
            return np.zeros(B, np.int32)
        ids = np.asarray(schema_ids, np.int32)
        if ids.shape != (B,):
            raise ValueError(f"schema_ids shape {ids.shape} != ({B},)")
        if ids.size and (ids.min() < 0 or ids.max() >= self.tape.n_members):
            raise ValueError("schema_ids outside the tape's member range")
        return ids

    def validate_isolated(
        self, table, schema_ids=None, *, keys: Optional[Sequence[Any]] = None
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Dict[int, str]]:
        """:meth:`validate_ex` with per-document launch-fault containment.

        A launch that raises (device error, injected ``"launch"`` fault)
        is bisected: rows are split in half and relaunched recursively
        until the poison is cornered in a single-row launch, whose error
        is recorded in ``errors[row]``; every other row's verdict is
        bit-identical to a fault-free run (the batched executor is
        row-independent, so sub-batch launches reproduce full-batch
        results exactly).  Worst case P poisoned rows cost
        O(P·log B) extra launches; halving keeps sub-batch shapes to at
        most log2(B) distinct jit traces.  Rows already error-isolated
        at encode time (``table.errors``) launch as zeroed ok=False rows
        and keep their encode error.

        Returns ``(valid, decided, frontier, errors)``; ``errors`` rows
        are ERROR_ISOLATED -- callers must not route them to fallback.
        """
        B = table.batch
        ids = self._normalize_ids(B, schema_ids)
        row_keys = list(keys) if keys is not None else list(range(B))
        if len(row_keys) != B:
            raise ValueError(f"{len(row_keys)} keys for batch of {B}")
        valid = np.zeros(B, bool)
        decided = np.zeros(B, bool)
        frontier = np.zeros(B, bool)
        errors: Dict[int, str] = dict(table.errors)
        stack: List[Tuple[List[int], int]] = [(list(range(B)), 0)]
        max_bisect = 0  # deepest split reached while cornering poison
        while stack:
            rows, bdepth = stack.pop()
            full = len(rows) == B
            # the full-batch launch reuses the caller's table/ids objects:
            # a fresh ids copy per call would defeat the executor's
            # same-identity host->device transfer cache (~5% per launch)
            sub = table if full else table.take(rows)
            sub_ids = ids if full else ids[rows]
            try:
                if fault_hook_armed():  # skip the key tuple on the clean path
                    fault_point("launch", tuple(row_keys[i] for i in rows))
                v, d, f = self.validate_ex(sub, sub_ids)
            except Exception as exc:
                if len(rows) == 1:
                    errors[rows[0]] = f"launch: {type(exc).__name__}: {exc}"
                    continue
                mid = len(rows) // 2
                stack.append((rows[mid:], bdepth + 1))
                stack.append((rows[:mid], bdepth + 1))
                if bdepth + 1 > max_bisect:
                    max_bisect = bdepth + 1
                    _trace_point("executor.bisect", depth=max_bisect)
                continue
            if full:
                valid[:] = v
                decided[:] = d
                frontier[:] = f
            else:
                valid[rows] = v
                decided[rows] = d
                frontier[rows] = f
        if self.metrics is not None:
            self._m_bisect_depth.observe(float(max_bisect))
        for r in errors:
            decided[r] = False
            frontier[r] = False
        return valid, decided, frontier, errors

    def explain_batch(
        self, table, schema_ids=None, *, docs: Optional[Sequence[Any]] = None
    ) -> List[Optional[FailureSite]]:
        """Batched first-failure attribution (DESIGN.md §12).

        Returns one entry per document: a :class:`FailureSite` where the
        batched pipeline attributes a failure, ``None`` where it finds
        none (the document is valid -- callers gate on their own
        verdicts and must not call this for undecided rows).  ``docs``
        (the original parsed documents, encode order) enables instance
        JSON pointers; without them ``instance_path`` stays empty.

        Tie-break contract: lowest BFS node first; within a node
        assertion-row < missing-required < closed-object, and among
        assertion rows the lowest row wins; structural failures beat
        circuit failures anchored at the same node, and among circuits
        the lowest circuit id wins.  Opt-in by construction -- the
        explain launch is a separate jitted function, so ``explain=False``
        traffic never pays for it.
        """
        if self.layout != "csr":
            raise NotImplementedError("explain_batch requires the csr layout")
        B = table.batch
        ids = self._normalize_ids(B, schema_ids)
        if docs is not None and len(docs) != B:
            raise ValueError(f"{len(docs)} docs for batch of {B}")
        if self._explain_fn is None:
            self._explain_fn = jax.jit(
                functools.partial(
                    _explain_batch,
                    consts=self._consts,
                    max_depth=self.max_depth,
                    max_loc_depth=self.tape.max_loc_depth,
                    use_pallas=self.use_pallas,
                    n_window=self.n_window,
                    k_cand=self.k_cand,
                    m_hat=self.m_hat,
                    n_members=self.tape.n_members,
                    circuits=self._circuits,
                    n_circuits=self.n_circuits,
                )
            )
        cols = {k: jnp.asarray(v) for k, v in table.columns().items()}
        with _phase("executor.explain"), _span("executor.explain", batch=B):
            out = self._explain_fn(cols, jnp.asarray(ids))
        doc_key, bad_row, bad_loc, parent_loc, missing, root_fail, root_anchor = (
            np.asarray(x) for x in out
        )
        roots = _circuit_roots(self._circuits, self.n_circuits)
        big = int(_BIG)
        sites: List[Optional[FailureSite]] = []
        for b in range(B):
            doc = docs[b] if docs is not None else None
            skey = int(doc_key[b])  # structural pick: node*4 + kind
            ckey, circ = big, -1  # circuit pick: anchor*4 + KIND_CIRCUIT
            for j, r in enumerate(roots):
                if root_fail[b, j]:
                    anchor = int(root_anchor[b, j])
                    k = max(anchor, 0) * 4 + KIND_CIRCUIT
                    if k < ckey:
                        ckey, circ = k, r
            if skey >= big and ckey >= big:
                sites.append(None)
                continue
            if skey <= ckey:  # structural wins ties at the same node
                sites.append(
                    resolve_site(
                        self.tape,
                        kind=skey % 4,
                        node=skey // 4,
                        row=int(bad_row[b]),
                        loc=int(bad_loc[b]),
                        parent_loc=int(parent_loc[b]),
                        missing_mask=int(missing[b]) & 0xFFFFFFFF,
                        doc=doc,
                    )
                )
            else:
                sites.append(
                    resolve_site(
                        self.tape,
                        kind=KIND_CIRCUIT,
                        node=ckey // 4,
                        circ=circ,
                        doc=doc,
                    )
                )
        return sites


def _propagate_locations(
    cols,
    schema_ids,
    consts,
    *,
    loop_depth: int,
    use_pallas: bool,
    layout: str,
    k_cand: int,
    m_hat: int,
    n_members: int,
):
    """Assign every node a schema location; returns (loc, acquired, aux).

    ``aux`` carries the flat per-node columns reused by the caller.
    """
    B, N = cols["node_type"].shape
    flat = lambda x: x.reshape((B * N,) + x.shape[2:])

    node_type = flat(cols["node_type"]).astype(jnp.int32)
    parent = flat(cols["parent"])  # int32, -1 root
    depth = flat(cols["depth"])
    idx_in_parent = flat(cols["idx_in_parent"])
    key_hash = flat(cols["key_hash"])

    doc_base = jnp.repeat(jnp.arange(B, dtype=jnp.int32) * N, N)
    parent_flat = jnp.where(parent >= 0, doc_base + parent, 0)

    is_pad = node_type == 0

    # each document's root is its schema member's root location (plain
    # location 0 for single-member tapes)
    member = jnp.repeat(schema_ids.astype(jnp.int32), N)  # (B*N,)
    loc = jnp.where(
        jnp.arange(B * N, dtype=jnp.int32) % N == 0,
        consts["roots"][member],
        jnp.int32(-1),
    )
    acquired = jnp.zeros(B * N, jnp.int32)  # required-slot bits per object

    # loop-invariant node classification, shared by the hoisted hash pass
    # and the depth loop (one definition so they can never desynchronize)
    is_real = ~is_pad & (parent >= 0)
    parent_type = node_type[parent_flat]
    is_member_node = is_real & (parent_type == _T_OBJ)
    is_item_node = is_real & (parent_type == _T_ARR)

    if layout == "csr":
        # -- hoisted single hash pass: find each object-member node's
        # candidate-run start in its schema's hash-sorted property rows
        M = consts["psort_owner"].shape[0]
        if n_members == 1 or use_pallas:
            # hash_match kernel over the whole table, owner = the row's
            # member id (all zeros on a single tape): the kernel's minimal
            # matching row within the querying document's member is its
            # run start.  Streamed/blocked, so no giant gather -- the
            # right trade on the kernel path.  The empty-table placeholder
            # keeps owner -9 so all-zero key lanes cannot hit it
            t_owner0 = jnp.where(
                consts["psort_owner"] >= 0, consts["psort_member"], jnp.int32(-9)
            )
            q_owner0 = jnp.where(is_member_node, member, jnp.int32(-1))
            first = kops.hash_match(
                key_hash, q_owner0, consts["psort_hash"], t_owner0, use_pallas=use_pallas
            )
        else:
            # linked tape on the jnp path: member-windowed pass -- each
            # node scans only its member's psort segment (<= M-hat rows),
            # so per-node work tracks the *largest* member instead of the
            # member sum.  Runs never span members, so the minimal
            # matching row in the segment is the run start, exactly as
            # the kernel branch returns
            seg_start = consts["member_prop_start"][member]  # (BN,)
            seg_len = consts["member_prop_len"][member]
            m_idx = jnp.arange(m_hat, dtype=jnp.int32)[None, :]  # (1, Mh)
            seg_rows = jnp.clip(seg_start[:, None] + m_idx, 0, M - 1)  # (BN, Mh)
            row_ok = (m_idx < seg_len[:, None]) & is_member_node[:, None]
            lane_eq = jnp.all(
                key_hash[:, None, :] == consts["psort_hash"][seg_rows], axis=-1
            )
            row_masked = jnp.where(lane_eq & row_ok, seg_rows, _BIG)
            first_row = jnp.min(row_masked, axis=1)
            first = jnp.where(first_row < _BIG, first_row, jnp.int32(-1))
        has_cand = first >= 0
        safe_first = jnp.where(has_cand, first, 0)
        run_len = jnp.where(has_cand, consts["psort_run_len"][safe_first], 0)
        k_arange = jnp.arange(k_cand, dtype=jnp.int32)[None, :]  # (1, K)
        cand_rows = jnp.clip(safe_first[:, None] + k_arange, 0, M - 1)  # (BN, K)
        cand_valid = k_arange < run_len[:, None]
        cand_owner = jnp.where(cand_valid, consts["psort_owner"][cand_rows], -1)
        cand_child = consts["psort_child_loc"][cand_rows]
        cand_slot = consts["psort_required_slot"][cand_rows]
        cand_orig = consts["psort_orig_row"][cand_rows]

    # the required-bit contribution of every node is known the moment its
    # own depth iteration resolves it -- accumulate elementwise in the
    # loop and scatter ONCE afterwards instead of once per depth
    contrib_vec = jnp.zeros(B * N, jnp.int32)

    for d in range(1, loop_depth + 1):
        at_depth = depth == d
        parent_loc = loc[parent_flat]

        # -- object members: property-table match
        is_member = at_depth & is_member_node
        if layout == "csr":
            # owner-equality over the K pre-gathered candidates; ties
            # break to the minimal original row (dense-path semantics)
            m = cand_valid & (cand_owner == parent_loc[:, None])
            orig_masked = jnp.where(m, cand_orig, _BIG)
            best_k = jnp.argmin(orig_masked, axis=1)
            matched = jnp.min(orig_masked, axis=1) < _BIG
            child_loc_m = jnp.take_along_axis(cand_child, best_k[:, None], axis=1)[:, 0]
            slot_m = jnp.take_along_axis(cand_slot, best_k[:, None], axis=1)[:, 0]
        else:
            q_owner = jnp.where(is_member & (parent_loc >= 0), parent_loc, jnp.int32(-1))
            row = kops.hash_match(
                key_hash,
                q_owner,
                consts["prop_hash"],
                consts["prop_owner"],
                use_pallas=use_pallas,
            )
            matched = row >= 0
            safe_row = jnp.where(matched, row, 0)
            child_loc_m = consts["prop_child_loc"][safe_row]
            slot_m = consts["prop_required_slot"][safe_row]
        child_loc = jnp.where(matched, child_loc_m, jnp.int32(LOC_UNTRACKED))
        # one packed row gather for the parent's structural facts
        p_loc_safe = jnp.where(parent_loc >= 0, parent_loc, 0)
        ls = consts["loc_struct"][p_loc_safe]  # (BN, 6)
        addl, closed = ls[:, 0], ls[:, 1]
        item_loc, item_start = ls[:, 2], ls[:, 3]
        pfx_start, pfx_len = ls[:, 4], ls[:, 5]
        # unmatched at a tracked object location: addl / closed / untracked
        # (an addl slot may carry the LOC_FRONTIER sentinel: recursion
        # through additionalProperties past the unroll budget)
        unmatched_loc = jnp.where(
            closed != 0,
            jnp.int32(LOC_INVALID),
            jnp.where(
                (addl >= 0) | (addl == LOC_FRONTIER),
                addl,
                jnp.int32(LOC_UNTRACKED),
            ),
        )
        member_loc = jnp.where(matched, child_loc, unmatched_loc)
        member_loc = jnp.where(parent_loc >= 0, member_loc, parent_loc)

        # required bit: record the contribution at the node's own depth
        slot = jnp.where(matched, slot_m, -1)
        contrib = jnp.where(
            is_member & (slot >= 0),
            jnp.left_shift(jnp.int32(1), jnp.maximum(slot, 0)),
            0,
        )
        contrib_vec = jnp.where(is_member, contrib, contrib_vec)

        # -- array items: prefix / tail-items rules
        is_item = at_depth & is_item_node
        in_prefix = idx_in_parent < pfx_len
        pfx_idx = jnp.clip(pfx_start + idx_in_parent, 0, consts["prefix_loc"].shape[0] - 1)
        prefix_loc = consts["prefix_loc"][pfx_idx]
        tail_loc = jnp.where(
            ((item_loc >= 0) | (item_loc == LOC_FRONTIER))
            & (idx_in_parent >= item_start),
            item_loc,
            jnp.int32(LOC_UNTRACKED),
        )
        arr_loc = jnp.where(in_prefix, prefix_loc, tail_loc)
        arr_loc = jnp.where(parent_loc >= 0, arr_loc, parent_loc)

        loc = jnp.where(
            is_member, member_loc, jnp.where(is_item, arr_loc, loc)
        )

    acquired = acquired.at[parent_flat].add(contrib_vec, mode="drop")

    aux = {
        "node_type": node_type,
        "is_pad": is_pad,
        "flat": flat,
        "B": B,
        "N": N,
    }
    return loc, acquired, aux


def _segment_or_suffix(vals: jnp.ndarray, grp: jnp.ndarray) -> jnp.ndarray:
    """Segmented suffix-OR along axis 1.

    ``out[:, j] = OR(vals[:, k] for k >= j while grp stays equal)`` --
    groups are contiguous within a CSR window, so evaluating at each
    segment start yields the whole group's OR.  Implemented as an
    associative segmented scan (O(log W) depth, static shapes).
    """
    same_next = jnp.concatenate(
        [grp[:, :-1] == grp[:, 1:], jnp.zeros_like(grp[:, :1], bool)], axis=1
    )
    rv = jnp.flip(vals, axis=1)
    rc = jnp.flip(same_next, axis=1)

    def combine(a, b):
        av, ac = a
        bv, bc = b
        return (bv | (bc & av), ac & bc)

    out, _ = jax.lax.associative_scan(combine, (rv, rc), axis=1)
    return jnp.flip(out, axis=1)


def _assertions_csr(
    loc,
    node_cols,
    consts,
    *,
    use_pallas: bool,
    n_window: int,
    n_circuits: int,
    detail=None,
):
    """Windowed assertion evaluation + segmented OR-group reduction.

    Returns ``(asrt_ok, passes, seg_any)``: the per-node verdict over
    *plain* rows (rows wired to a circuit are excluded from the plain
    reduction), plus the raw window pass matrix and per-window segmented
    group OR for the caller's circuit-leaf gathers (None without
    circuits).  ``detail`` (a dict, explain path only) receives the
    per-window intermediates so the first-failure pass can argmax over
    them without recomputing.
    """
    A = consts["asrt_op"].shape[0]
    tracked = loc >= 0
    loc_safe = jnp.where(tracked, loc, 0)
    w_start = consts["loc_asrt_start"][loc_safe]
    w_len = jnp.where(tracked, consts["loc_asrt_len"][loc_safe], 0)
    slots = jnp.arange(n_window, dtype=jnp.int32)[None, :]  # (1, W)
    w_rows = jnp.clip(w_start[:, None] + slots, 0, A - 1)  # (BN, W)
    w_valid = slots < w_len[:, None]  # (BN, W) == "applies"
    w_cols = {
        "op": jnp.where(w_valid, consts["asrt_op"][w_rows], -1),
        "f0": consts["asrt_f0"][w_rows],
        "i0": consts["asrt_i0"][w_rows],
        "i1": consts["asrt_i1"][w_rows],
        "u0": consts["asrt_u0"][w_rows],
        "u1": consts["asrt_u1"][w_rows],
        "hash": consts["asrt_hash"][w_rows],
    }
    passes = kops.assertion_eval_window(
        node_cols, w_cols, use_pallas=use_pallas
    ).astype(bool)  # (BN, W)

    gcode = jnp.where(w_valid, consts["asrt_gcode"][w_rows], 0)
    grp = gcode & jnp.int32(_CIRC_FLAG - 1)
    in_circ = gcode >= _CIRC_FLAG  # constant-folds False on circuit-free tapes
    is_and = w_valid & (grp == 0) & ~in_circ
    and_ok = jnp.all(jnp.where(is_and, passes, True), axis=1)

    # enum OR-groups: group passes iff any of its (contiguous) rows passes
    pass_or = passes & w_valid & (grp > 0)
    seg_any = _segment_or_suffix(pass_or, grp)
    first_col = jnp.ones_like(grp[:, :1], bool)
    is_start = (grp > 0) & jnp.concatenate(
        [first_col, grp[:, 1:] != grp[:, :-1]], axis=1
    )
    or_ok = jnp.all(jnp.where(is_start & ~in_circ, seg_any, True), axis=1)
    asrt_ok = and_ok & or_ok

    if detail is not None:
        detail.update(
            w_rows=w_rows,
            passes=passes,
            in_circ=in_circ,
            is_and=is_and,
            is_start=is_start,
            seg_any=seg_any,
        )
    if not n_circuits:
        return asrt_ok, None, None
    return asrt_ok, passes, seg_any


def _circuit_anchors(loc, circuits, B: int, N: int):
    """(B, O) in-document node index at each circuit-relevant location.

    -1 where the document does not instantiate the location.  The
    unique-path precondition guarantees at most one node per (document,
    location), so a masked max-reduction per location resolves every
    anchor; all further circuit work is (B, U)-sized gathers.
    """
    owner_locs = circuits["owner_locs"]
    loc_r = loc.reshape(B, N)
    n_idx = jnp.arange(N, dtype=jnp.int32)[None, :]  # (1, N)
    # one masked max-reduction per circuit-relevant location (O is small,
    # and a static loop of reductions beats an XLA scatter by a lot on
    # CPU for these shapes)
    cols = [
        jnp.max(jnp.where(loc_r == int(o), n_idx, -1), axis=1)
        for o in owner_locs.tolist()
    ]
    return jnp.stack(cols, axis=1) if cols else jnp.zeros((B, 0), jnp.int32)


def _anchor_gather(node_at, mat, ranks, cols, B: int, N: int):
    """(B, U) values of static columns of ``mat`` at anchored nodes.

    ``mat`` is (B*N, cols); unit u reads ``mat[anchor, cols[u]]`` at its
    owner's anchor node, vacuous-true where the anchor is absent.
    """
    rows = node_at[:, np.asarray(ranks, np.int32)]  # (B, U)
    safe = jnp.maximum(rows, 0)
    flat = jnp.arange(B, dtype=jnp.int32)[:, None] * N + safe
    vals = mat[flat, jnp.asarray(cols, np.int32)[None, :]]
    return jnp.where(rows >= 0, vals, True)


def _leaf_values(node_at, circuits, B: int, N: int, *, and_mat, group_mat, and_cols, group_cols):
    """Per-document circuit-leaf values via anchored gathers.

    ``and_mat``/``group_mat`` are (B*N, cols) value matrices; each leaf
    unit reads one static column (``and_cols``/``group_cols``, per
    layout: window slot or row id / group verdict) at its owner
    location's anchor node.  Returns {circuit id: [(B,) bool, ...]}.
    """
    and_units, group_units = circuits["and_units"], circuits["group_units"]
    out = {}
    if and_units:
        v = _anchor_gather(node_at, and_mat, circuits["and_ranks"], and_cols, B, N)
        for u, unit in enumerate(and_units):
            out.setdefault(unit[0], []).append(v[:, u])
    if group_units:
        v = _anchor_gather(node_at, group_mat, circuits["group_ranks"], group_cols, B, N)
        for u, unit in enumerate(group_units):
            out.setdefault(unit[0], []).append(v[:, u])
    return out


def _circuit_presence(node_at, circuits):
    """(B, C) bool: does the document instantiate each circuit's owner
    location?  Gated circuits at absent locations are vacuously true
    (sequential engines skip instructions whose target is missing)."""
    return node_at[:, np.asarray(circuits["circ_ranks"], np.int32)] >= 0


def _reduce_circuits(leaf_vals, present, circuits, *, n_circuits: int, roots_out=None):
    """Bottom-up circuit reduce -> (B,) root conjunction.

    ``leaf_vals`` maps circuit ids to their per-document leaf values
    (from :func:`_leaf_values`).  All wiring (kinds, parents) is
    compile-time numpy, so the reduce unrolls into straight-line
    elementwise ops at trace time -- one AND/OR/count op per circuit
    edge, no gathers or scatters (XLA scatters are pathologically slow
    for this shape on CPU).  Children always have larger ids than their
    parent, so one descending pass evaluates the DAG in topological
    order; the tape's ``max_circ_depth`` bounds the dependency depth of
    the emitted ops at compile time.
    """
    kind = circuits["kind"]
    parent = circuits["parent"]
    B = present.shape[0]
    children = [[] for _ in range(n_circuits)]
    roots = []
    for c in range(n_circuits):
        p = int(parent[c])
        if p >= 0:
            children[p].append(c)
        else:
            roots.append(c)
    vals = [None] * n_circuits
    for c in range(n_circuits - 1, -1, -1):
        k = int(kind[c])
        ch = children[c]
        if k == CK_OR:
            v = jnp.zeros(B, bool)
            for d in ch:
                v = v | vals[d]
        elif k == CK_AND or k == CK_NOT:
            v = jnp.ones(B, bool)
            for lv in leaf_vals.get(c, ()):
                v = v & lv
            for d in ch:
                v = v & vals[d]
            if k == CK_NOT:
                v = ~v
        else:  # CK_XOR1: exactly one child true
            cnt = jnp.zeros(B, jnp.int32)
            for d in ch:
                cnt = cnt + vals[d].astype(jnp.int32)
            v = cnt == 1
        # presence gate: a circuit whose owner location has no node is
        # vacuously true (also makes other members' circuits no-ops on a
        # linked tape)
        vals[c] = v | ~present[:, c]
    ok = jnp.ones(B, bool)
    for r in roots:
        ok = ok & vals[r]
    if roots_out is not None:  # explain path: per-root gated values (B, R)
        roots_out.append(
            jnp.stack([vals[r] for r in roots], axis=1)
            if roots
            else jnp.zeros((B, 0), bool)
        )
    return ok


def _validate_batch(
    cols,
    schema_ids,
    *,
    consts,
    max_depth: int,
    max_loc_depth: int,
    use_pallas: bool,
    layout: str,
    n_window: int,
    k_cand: int,
    m_hat: int,
    n_members: int,
    has_frontier: bool = False,
    circuits=None,
    n_circuits: int = 0,
):
    # the tape caps trackable depth at compile time: below
    # max_loc_depth + 1 every location is untracked or under an invalid
    # ancestor, so the CSR loop stops there.  The dense layout keeps the
    # historical full-depth loop as the benchmark baseline (verdicts are
    # identical either way).
    tape_horizon = max_loc_depth + 1
    loop_depth = min(max_depth, tape_horizon) if layout == "csr" else max_depth
    loc, acquired, aux = _propagate_locations(
        cols,
        schema_ids,
        consts,
        loop_depth=loop_depth,
        use_pallas=use_pallas,
        layout=layout,
        k_cand=k_cand,
        m_hat=m_hat,
        n_members=n_members,
    )
    node_type = aux["node_type"]
    is_pad = aux["is_pad"]
    flat = aux["flat"]
    B, N = aux["B"], aux["N"]
    size = flat(cols["size"])

    tracked = loc >= 0

    # ---- 2. required properties --------------------------------------------
    loc_safe = jnp.where(tracked, loc, 0)
    required_mask = jnp.where(
        tracked & (node_type == _T_OBJ), consts["loc_required_mask"][loc_safe], 0
    )
    required_ok = (acquired & required_mask) == required_mask

    # ---- 3. assertion rows -------------------------------------------------
    node_cols = {
        "type": node_type,
        "is_int": flat(cols["is_int"]),
        "num": flat(cols["num"]).astype(jnp.float32),
        "size": size,
        "acquired": acquired,
        "str_hash": flat(cols["str_hash"]),
        "str_prefix": flat(cols["str_prefix"]),
    }
    leaf_args = None  # (and_mat, group_mat, and_cols, group_cols)
    if layout == "csr":
        asrt_ok, w_passes, w_seg_any = _assertions_csr(
            loc,
            node_cols,
            consts,
            use_pallas=use_pallas,
            n_window=n_window,
            n_circuits=n_circuits,
        )
        if n_circuits:
            leaf_args = (
                w_passes,
                w_seg_any,
                [u[3] for u in circuits["and_units"]],
                [u[3] for u in circuits["group_units"]],
            )
    else:
        asrt_cols = {
            "op": consts["asrt_op"],
            "f0": consts["asrt_f0"],
            "i0": consts["asrt_i0"],
            "i1": consts["asrt_i1"],
            "u0": consts["asrt_u0"],
            "u1": consts["asrt_u1"],
            "hash": consts["asrt_hash"],
        }
        passes = kops.assertion_eval(
            node_cols, asrt_cols, use_pallas=use_pallas
        ).astype(bool)  # (B*N, A)
        applies = loc[:, None] == consts["asrt_owner"][None, :]  # (B*N, A)

        in_circ_row = consts["asrt_circ"] >= 0  # (A,)
        is_and_row = (consts["asrt_group"] == 0) & ~in_circ_row
        and_ok = jnp.all(jnp.where(applies & is_and_row[None, :], passes, True), axis=1)

        # enum OR-groups: group passes iff it does not apply or any row matches
        groups = consts["asrt_group"]
        n_groups = int(np.asarray(groups).max()) + 1
        group_circ = circuits["group_circ"] if n_circuits else None
        if n_groups > 1:
            onehot = (
                groups[None, :, None]
                == jnp.arange(1, n_groups, dtype=jnp.int32)[None, None, :]
            )  # (1, A, G-1)
            gm = jnp.any((applies & passes)[:, :, None] & onehot, axis=1)  # (B*N, G-1)
            ga = jnp.any(applies[:, :, None] & onehot, axis=1)
            gval = jnp.logical_or(~ga, gm)  # (B*N, G-1) per-node group verdict
            if n_circuits:
                plain_g = jnp.asarray(group_circ[1:] < 0)[None, :]
                or_ok = jnp.all(gval | ~plain_g, axis=1)
            else:
                or_ok = jnp.all(gval, axis=1)
        else:
            or_ok = jnp.ones(B * N, bool)
        asrt_ok = and_ok & or_ok

        if n_circuits:
            # circuit-leaf sources, bit-identical to the CSR path: AND
            # leaf rows read their applied pass (the anchor node IS the
            # applying node), enum leaf groups their per-node group
            # verdict
            leaf_args = (
                passes,
                gval if n_groups > 1 else jnp.ones((B * N, 1), bool),
                [u[2] for u in circuits["and_units"]],
                [u[2] - 1 for u in circuits["group_units"]],
            )

    # ---- 4. reduce -----------------------------------------------------------
    node_valid = ((loc != LOC_INVALID) & asrt_ok & required_ok) | is_pad
    valid = jnp.all(node_valid.reshape(B, N), axis=1)

    # logical-applicator circuits (DESIGN.md §10): per-document leaves ->
    # bounded-depth reduce -> AND of gated root values into the verdict
    if n_circuits:
        node_at = _circuit_anchors(loc, circuits, B, N)
        and_mat, group_mat, and_cols, group_cols = leaf_args
        leaf_vals = _leaf_values(
            node_at,
            circuits,
            B,
            N,
            and_mat=and_mat,
            group_mat=group_mat,
            and_cols=and_cols,
            group_cols=group_cols,
        )
        present = _circuit_presence(node_at, circuits)
        valid = valid & _reduce_circuits(
            leaf_vals, present, circuits, n_circuits=n_circuits
        )

    # depth-budget coverage: a non-root, non-pad node that never received a
    # location sits below the max_depth horizon -- its document's verdict
    # is vacuous, flag it undecided (the silent-correctness fix).  When the
    # tape horizon fits inside the budget, deeper nodes are provably
    # unconstrained and every document is decided (statically).  On a
    # linked tape the global horizon is the member maximum, so documents
    # whose *own* member horizon fits the budget are still statically
    # decided -- keeping (valid, decided) bit-identical to dispatching
    # each document to its own single-member tape.
    if tape_horizon <= max_depth:
        in_depth = jnp.ones(B, bool)
    else:
        is_root = jnp.arange(B * N, dtype=jnp.int32) % N == 0
        unreached = ~is_pad & ~is_root & (loc == jnp.int32(-1))
        member_ok = consts["member_horizons"][schema_ids] <= max_depth  # (B,)
        in_depth = member_ok | ~jnp.any(unreached.reshape(B, N), axis=1)

    # $ref-unroll frontiers (DESIGN.md §9): transition edges past the
    # unroll budget carry LOC_FRONTIER, and the ordinary negative-parent
    # propagation spreads it down the subtree -- so one equality scan
    # finds every document whose recursion outran the tape.  Those
    # verdicts are vacuous: the caller must route them to the sequential
    # oracle (counted as ``unroll_overflow``, distinct from the depth
    # budget's ``undecided``).  Statically skipped for frontier-free
    # tapes (the overwhelming majority).
    if has_frontier:
        frontier = jnp.any((loc == jnp.int32(LOC_FRONTIER)).reshape(B, N), axis=1)
    else:
        frontier = jnp.zeros(B, bool)
    return valid, in_depth, frontier


def _circuit_roots(circuits, n_circuits: int) -> List[int]:
    """Root circuit ids in ascending order (compile-time)."""
    parent = circuits["parent"]
    return [c for c in range(n_circuits) if int(parent[c]) < 0]


def _explain_batch(
    cols,
    schema_ids,
    *,
    consts,
    max_depth: int,
    max_loc_depth: int,
    use_pallas: bool,
    n_window: int,
    k_cand: int,
    m_hat: int,
    n_members: int,
    circuits=None,
    n_circuits: int = 0,
):
    """Device half of batched first-failure attribution (DESIGN.md §12).

    Re-runs the CSR validation pipeline keeping the per-window
    intermediates, then reduces every document to ONE failure pick:

    - per node, the lowest failing assertion row (a failed AND row fails
      at its own row; a failed enum OR-group at its first window row);
    - per document, an argmin over packed ``node*4 + kind`` keys, so the
      lowest BFS node wins and, within a node, assertion (0) beats
      missing-required (1) beats closed-object (2);
    - circuit failures come back separately as per-root gated values +
      the root owner's anchor node; the host merges them in as kind 3.

    Returns ``(doc_key, bad_row, bad_loc, parent_loc, missing,
    root_fail, root_anchor)`` -- all small (B,)/(B, R) tensors; the
    provenance mapping happens on the host (``core/explain.py``).
    """
    tape_horizon = max_loc_depth + 1
    loop_depth = min(max_depth, tape_horizon)
    loc, acquired, aux = _propagate_locations(
        cols,
        schema_ids,
        consts,
        loop_depth=loop_depth,
        use_pallas=use_pallas,
        layout="csr",
        k_cand=k_cand,
        m_hat=m_hat,
        n_members=n_members,
    )
    node_type = aux["node_type"]
    is_pad = aux["is_pad"]
    flat = aux["flat"]
    B, N = aux["B"], aux["N"]

    tracked = loc >= 0
    loc_safe = jnp.where(tracked, loc, 0)
    required_mask = jnp.where(
        tracked & (node_type == _T_OBJ), consts["loc_required_mask"][loc_safe], 0
    )
    required_ok = (acquired & required_mask) == required_mask

    node_cols = {
        "type": node_type,
        "is_int": flat(cols["is_int"]),
        "num": flat(cols["num"]).astype(jnp.float32),
        "size": flat(cols["size"]),
        "acquired": acquired,
        "str_hash": flat(cols["str_hash"]),
        "str_prefix": flat(cols["str_prefix"]),
    }
    detail: Dict[str, Any] = {}
    _asrt_ok, w_passes, w_seg_any = _assertions_csr(
        loc,
        node_cols,
        consts,
        use_pallas=use_pallas,
        n_window=n_window,
        n_circuits=n_circuits,
        detail=detail,
    )

    # per-node first failing plain assertion row (global row id)
    fail_and = detail["is_and"] & ~detail["passes"]
    fail_or = detail["is_start"] & ~detail["in_circ"] & ~detail["seg_any"]
    row_masked = jnp.where(fail_and | fail_or, detail["w_rows"], _BIG)
    node_first_row = jnp.min(row_masked, axis=1)  # (BN,)
    has_row_fail = node_first_row < _BIG

    req_fail = tracked & ~required_ok
    closed_fail = loc == jnp.int32(LOC_INVALID)
    node_fail = ~is_pad & (has_row_fail | req_fail | closed_fail)
    kind = jnp.where(has_row_fail, 0, jnp.where(req_fail, 1, 2))

    # packed argmin: lowest BFS node, then kind priority within the node
    n_in_doc = jnp.arange(B * N, dtype=jnp.int32) % N
    key = jnp.where(node_fail, n_in_doc * 4 + kind, _BIG)
    doc_key = jnp.min(key.reshape(B, N), axis=1)  # (B,)

    picked = doc_key < _BIG
    node_pick = jnp.where(picked, doc_key // 4, 0)
    chosen_flat = jnp.arange(B, dtype=jnp.int32) * N + node_pick
    bad_row = jnp.where(picked, node_first_row[chosen_flat], -1)
    bad_loc = jnp.where(picked, loc[chosen_flat], -1)
    missing = jnp.where(picked, (required_mask & ~acquired)[chosen_flat], 0)
    parent = flat(cols["parent"])
    par = parent[chosen_flat]  # (B,) in-document parent index
    par_flat = jnp.where(par >= 0, jnp.arange(B, dtype=jnp.int32) * N + par, 0)
    parent_loc = jnp.where(picked & (par >= 0), loc[par_flat], -1)

    if n_circuits:
        node_at = _circuit_anchors(loc, circuits, B, N)
        leaf_vals = _leaf_values(
            node_at,
            circuits,
            B,
            N,
            and_mat=w_passes,
            group_mat=w_seg_any,
            and_cols=[u[3] for u in circuits["and_units"]],
            group_cols=[u[3] for u in circuits["group_units"]],
        )
        present = _circuit_presence(node_at, circuits)
        roots_out: List[Any] = []
        _reduce_circuits(
            leaf_vals,
            present,
            circuits,
            n_circuits=n_circuits,
            roots_out=roots_out,
        )
        root_fail = ~roots_out[0]  # (B, R): gated root value False = fail
        roots = _circuit_roots(circuits, n_circuits)
        rank_cols = np.asarray(
            [int(circuits["circ_ranks"][r]) for r in roots], np.int32
        )
        root_anchor = node_at[:, rank_cols]  # (B, R) in-doc anchor, -1 absent
    else:
        root_fail = jnp.zeros((B, 0), bool)
        root_anchor = jnp.zeros((B, 0), jnp.int32)
    return doc_key, bad_row, bad_loc, parent_loc, missing, root_fail, root_anchor
