"""Parsed-document model used by the sequential executor (Blaze §4.1/§4.5).

Keys are hashed *at parse time* (the paper stores the semi-perfect hash
while parsing) and objects are stored as a flat vector of entries rather
than a hash map: "documents generally have a small number [of] keys ...
looping over the small number of entries is more efficient than dealing
with the indirection inherent in hash tables" (§4.1).
"""

from __future__ import annotations

from typing import Any, Iterator, List, Optional, Tuple

from .hashing import is_short_hash, shash

__all__ = ["HashedObject", "parse_document", "json_type", "json_equal", "canonical"]

_MISS = object()


class HashedObject:
    """A JSON object as a vector of (hash, key, value) entries."""

    __slots__ = ("entries",)

    def __init__(self, entries: List[Tuple[int, str, Any]]):
        self.entries = entries

    # -- container protocol -------------------------------------------------

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self) -> Iterator[str]:
        return (k for _, k, _ in self.entries)

    def keys(self):
        return [k for _, k, _ in self.entries]

    def values(self):
        return [v for _, _, v in self.entries]

    def items(self):
        return [(k, v) for _, k, v in self.entries]

    # -- hash-accelerated lookup (Blaze §4.1) --------------------------------

    def get_hashed(self, key_hash: int, key: str, default: Any = None) -> Any:
        """Lookup by precomputed hash: short keys never compare strings."""
        if is_short_hash(key_hash):
            for h, _, v in self.entries:
                if h == key_hash:
                    return v
            return default
        for h, k, v in self.entries:
            if h == key_hash and k == key:
                return v
        return default

    def defines_hashed(self, key_hash: int, key: str) -> bool:
        return self.get_hashed(key_hash, key, _MISS) is not _MISS

    def get_item(self, key: str, default: Any = None) -> Any:
        """Plain-string lookup (used by generic instance-path resolution)."""
        return self.get_hashed(shash(key), key, default)

    def __repr__(self) -> str:
        return f"HashedObject({dict(self.items())!r})"


def parse_document(value: Any) -> Any:
    """Convert plain parsed JSON into the executor's document model.

    This is the parse stage: hashing happens here, once, not during
    validation (§4.1: "we store the hash of strings as part of the process
    of parsing documents").
    """
    if isinstance(value, dict):
        return HashedObject(
            [(shash(k), k, parse_document(v)) for k, v in value.items()]
        )
    if isinstance(value, list):
        return [parse_document(v) for v in value]
    return value


# ---------------------------------------------------------------------------
# JSON semantics helpers
# ---------------------------------------------------------------------------


def json_type(value: Any) -> str:
    """The JSON type name of a value ('integer' for whole numbers)."""
    if value is None:
        return "null"
    if isinstance(value, bool):
        return "boolean"
    if isinstance(value, int):
        return "integer"
    if isinstance(value, float):
        return "integer" if value.is_integer() else "number"
    if isinstance(value, str):
        return "string"
    if isinstance(value, list):
        return "array"
    return "object"


def has_type(value: Any, t: str) -> bool:
    """Type check per 2020-12 semantics (1.0 is an integer; bool is not)."""
    if t == "integer":
        if isinstance(value, bool):
            return False
        return isinstance(value, int) or (isinstance(value, float) and value.is_integer())
    if t == "number":
        return isinstance(value, (int, float)) and not isinstance(value, bool)
    if t == "string":
        return isinstance(value, str)
    if t == "object":
        return isinstance(value, (dict, HashedObject))
    if t == "array":
        return isinstance(value, list)
    if t == "boolean":
        return isinstance(value, bool)
    if t == "null":
        return value is None
    return False


def json_equal(a: Any, b: Any) -> bool:
    """Deep JSON equality: 1 == 1.0, but True != 1 and 0 != False."""
    if isinstance(a, bool) or isinstance(b, bool):
        return a is b if isinstance(a, bool) and isinstance(b, bool) else False
    if isinstance(a, (int, float)) and isinstance(b, (int, float)):
        return a == b
    if isinstance(a, str) and isinstance(b, str):
        return a == b
    if a is None or b is None:
        return a is None and b is None
    if isinstance(a, list) and isinstance(b, list):
        return len(a) == len(b) and all(json_equal(x, y) for x, y in zip(a, b))
    a_obj = isinstance(a, (dict, HashedObject))
    b_obj = isinstance(b, (dict, HashedObject))
    if a_obj and b_obj:
        a_items = a.items() if isinstance(a, HashedObject) else list(a.items())
        b_map = dict(b.items()) if isinstance(b, HashedObject) else b
        if len(a_items) != len(b_map):
            return False
        for k, v in a_items:
            if k not in b_map or not json_equal(v, b_map[k]):
                return False
        return True
    return False


def canonical(value: Any) -> Any:
    """Hashable canonical form (uniqueItems in O(n) via a set).

    Must agree with :func:`json_equal` pairwise semantics: numbers keep
    their native type (Python's cross-type ``==``/``hash`` already make
    ``1`` and ``1.0`` collide) instead of coercing through ``float``,
    which would merge distinct integers past 2**53 -- ``[2**53, 2**53+1]``
    has no duplicate.
    """
    if isinstance(value, bool):
        return ("b", value)
    if isinstance(value, (int, float)):
        return ("n", value)
    if isinstance(value, str):
        return ("s", value)
    if value is None:
        return ("z",)
    if isinstance(value, list):
        return ("a", tuple(canonical(v) for v in value))
    items = value.items() if isinstance(value, HashedObject) else value.items()
    return ("o", tuple(sorted((k, canonical(v)) for k, v in items)))
