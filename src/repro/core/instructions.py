"""The Blaze schema-validation DSL (paper §2).

Instructions are the compilation target for JSON Schema keywords.  Each
instruction carries:

* ``rel_path`` -- the instance location it applies to, *relative to its
  parent instruction* (§5.1);
* ``schema_path`` -- the keyword location in the source schema (error
  reporting / debugging only, never consulted during validation);
* instruction-specific operands.

Type *preconditions* are intrinsic to the instruction class: e.g.
``AssertionGreaterEqual`` silently passes for non-numeric targets, matching
the semantics of ``minimum``.  By convention instruction names start
uppercase while JSON Schema keywords are lowercase (§2).

The set below covers §2.1-2.5: basic assertions (Table 1), the five
property-loop variants + two item-loop variants + key loop + contains,
short-circuiting logical combinators, ControlLabel/ControlJump, and the
CISC-style fused variants (StringBounds / singleton Equals / Table 2
``When*`` conditions).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum, auto
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .json_pointer import InstancePath
from .regex_opt import RegexPlan

Instructions = Tuple["Instruction", ...]


class OpCode(IntEnum):
    """Stable opcode numbering shared with the tensorised tape (tape.py)."""

    # -- assertions: universal ------------------------------------------------
    FAIL = 0
    TYPE = auto()
    TYPE_ANY = auto()
    EQUAL = auto()
    EQUALS_ANY = auto()
    # -- assertions: object ---------------------------------------------------
    DEFINES = auto()
    DEFINES_ALL = auto()
    PROPERTY_DEPENDENCIES = auto()
    OBJECT_SIZE_GREATER = auto()
    OBJECT_SIZE_LESS = auto()
    PROPERTY_TYPE = auto()
    # -- assertions: string ---------------------------------------------------
    REGEX = auto()
    STRING_SIZE_GREATER = auto()
    STRING_SIZE_LESS = auto()
    STRING_BOUNDS = auto()
    STRING_TYPE = auto()
    # -- assertions: array ----------------------------------------------------
    UNIQUE = auto()
    ARRAY_SIZE_GREATER = auto()
    ARRAY_SIZE_LESS = auto()
    ARRAY_BOUNDS = auto()
    # -- assertions: number ---------------------------------------------------
    GREATER = auto()
    GREATER_EQUAL = auto()
    LESS = auto()
    LESS_EQUAL = auto()
    NUMBER_BOUNDS = auto()
    DIVISIBLE = auto()
    # -- loops ----------------------------------------------------------------
    LOOP_KEYS = auto()
    LOOP_PROPERTIES = auto()
    LOOP_PROPERTIES_EXCEPT = auto()
    LOOP_PROPERTIES_REGEX = auto()
    LOOP_PROPERTIES_MATCH = auto()
    LOOP_PROPERTIES_MATCH_CLOSED = auto()
    LOOP_ITEMS = auto()
    LOOP_ITEMS_FROM = auto()
    LOOP_CONTAINS = auto()
    ARRAY_PREFIX = auto()
    LOOP_UNEVALUATED_PROPERTIES = auto()
    LOOP_UNEVALUATED_ITEMS = auto()
    # -- logical ----------------------------------------------------------------
    AND = auto()
    OR = auto()
    XOR = auto()
    NOT = auto()
    CONDITION = auto()
    WHEN_TYPE = auto()
    WHEN_DEFINES = auto()
    WHEN_ARRAY_SIZE_GREATER = auto()
    WHEN_ARRAY_SIZE_EQUAL = auto()
    # -- control ----------------------------------------------------------------
    CONTROL_LABEL = auto()
    CONTROL_JUMP = auto()


# JSON type lattice.  "integer" is a refinement of "number"; per 2020-12 a
# float with zero fraction *is* an integer.
JSON_TYPES = ("null", "boolean", "object", "array", "number", "string", "integer")


@dataclass(frozen=True, slots=True)
class Instruction:
    rel_path: InstancePath = ()
    schema_path: str = ""

    op: OpCode = field(default=OpCode.FAIL, init=False, repr=False)

    def children_groups(self) -> Sequence[Instructions]:
        """All nested instruction sequences (for traversal/serialization)."""
        return ()

    def cost(self) -> int:
        """Static cost estimate used by §4.4 instruction reordering."""
        return 1 + sum(c.cost() for grp in self.children_groups() for c in grp)


# ---------------------------------------------------------------------------
# Universal assertions
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class AssertionFail(Instruction):
    """Unconditional failure -- the ``false`` schema."""

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.FAIL)


@dataclass(frozen=True, slots=True)
class AssertionType(Instruction):
    """Value must have exactly this JSON type (singleton CISC variant)."""

    type: str = "null"

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.TYPE)


@dataclass(frozen=True, slots=True)
class AssertionTypeAny(Instruction):
    """Value must have one of the given JSON types."""

    types: Tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.TYPE_ANY)


@dataclass(frozen=True, slots=True)
class AssertionEqual(Instruction):
    """Value equals a single constant (CISC variant of EqualsAny, §2.5)."""

    value: Any = None

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.EQUAL)


@dataclass(frozen=True, slots=True)
class AssertionEqualsAny(Instruction):
    """Value is one of a list of constants (``enum``)."""

    values: Tuple[Any, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.EQUALS_ANY)

    def cost(self) -> int:
        return 1 + len(self.values) // 4


# ---------------------------------------------------------------------------
# Object assertions (precondition: target is an object)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class AssertionDefines(Instruction):
    """Object defines a specific property (singleton ``required``)."""

    key: str = ""
    key_hash: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.DEFINES)


@dataclass(frozen=True, slots=True)
class AssertionDefinesAll(Instruction):
    """Object defines all listed properties (``required``)."""

    keys: Tuple[str, ...] = ()
    key_hashes: Tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.DEFINES_ALL)

    def cost(self) -> int:
        return 1 + len(self.keys) // 2


@dataclass(frozen=True, slots=True)
class AssertionPropertyDependencies(Instruction):
    """If a property exists, other properties must exist too
    (``dependentRequired`` / array-form ``dependencies``)."""

    # key -> (required keys, their hashes)
    dependencies: Tuple[Tuple[str, int, Tuple[str, ...], Tuple[int, ...]], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.PROPERTY_DEPENDENCIES)


@dataclass(frozen=True, slots=True)
class AssertionObjectSizeGreater(Instruction):
    """Object has at least ``bound`` properties (``minProperties``)."""

    bound: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.OBJECT_SIZE_GREATER)


@dataclass(frozen=True, slots=True)
class AssertionObjectSizeLess(Instruction):
    """Object has at most ``bound`` properties (``maxProperties``)."""

    bound: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.OBJECT_SIZE_LESS)


@dataclass(frozen=True, slots=True)
class AssertionPropertyType(Instruction):
    """Fused Defines+child-Type: object property has a specific type
    (Table 1 ``PropertyType``).  Property absent => pass."""

    key: str = ""
    key_hash: int = 0
    type: str = "null"

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.PROPERTY_TYPE)


# ---------------------------------------------------------------------------
# String assertions (precondition: target is a string)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class AssertionRegex(Instruction):
    """String matches a pattern (specialized via RegexPlan, §4.3)."""

    plan: Optional[RegexPlan] = None

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.REGEX)

    def cost(self) -> int:
        return 10 if (self.plan is None or self.plan.uses_engine) else 2


@dataclass(frozen=True, slots=True)
class AssertionStringSizeGreater(Instruction):
    """len(string) >= bound (``minLength``)."""

    bound: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.STRING_SIZE_GREATER)


@dataclass(frozen=True, slots=True)
class AssertionStringSizeLess(Instruction):
    """len(string) <= bound (``maxLength``)."""

    bound: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.STRING_SIZE_LESS)


@dataclass(frozen=True, slots=True)
class AssertionStringBounds(Instruction):
    """Fused Type+minLength+maxLength (CISC, §2.5).  Unlike the plain string
    assertions this *requires* the value to be a string."""

    min_len: int = 0
    max_len: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.STRING_BOUNDS)


@dataclass(frozen=True, slots=True)
class AssertionStringType(Instruction):
    """Complex string format (``format`` assertion: uri, uuid, ...)."""

    format: str = ""

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.STRING_TYPE)

    def cost(self) -> int:
        return 8


# ---------------------------------------------------------------------------
# Array assertions (precondition: target is an array)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class AssertionUnique(Instruction):
    """All array elements distinct (``uniqueItems``)."""

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.UNIQUE)

    def cost(self) -> int:
        return 12


@dataclass(frozen=True, slots=True)
class AssertionArraySizeGreater(Instruction):
    bound: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.ARRAY_SIZE_GREATER)


@dataclass(frozen=True, slots=True)
class AssertionArraySizeLess(Instruction):
    bound: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.ARRAY_SIZE_LESS)


@dataclass(frozen=True, slots=True)
class AssertionArrayBounds(Instruction):
    """Fused minItems+maxItems."""

    min_len: int = 0
    max_len: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.ARRAY_BOUNDS)


# ---------------------------------------------------------------------------
# Number assertions (precondition: target is a number)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class AssertionGreater(Instruction):
    """value > bound (``exclusiveMinimum``)."""

    bound: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.GREATER)


@dataclass(frozen=True, slots=True)
class AssertionGreaterEqual(Instruction):
    """value >= bound (``minimum``)."""

    bound: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.GREATER_EQUAL)


@dataclass(frozen=True, slots=True)
class AssertionLess(Instruction):
    """value < bound (``exclusiveMaximum``)."""

    bound: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LESS)


@dataclass(frozen=True, slots=True)
class AssertionLessEqual(Instruction):
    """value <= bound (``maximum``)."""

    bound: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LESS_EQUAL)


@dataclass(frozen=True, slots=True)
class AssertionNumberBounds(Instruction):
    """Fused min/max with per-end exclusivity (CISC)."""

    lo: Optional[float] = None
    lo_exclusive: bool = False
    hi: Optional[float] = None
    hi_exclusive: bool = False

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.NUMBER_BOUNDS)


@dataclass(frozen=True, slots=True)
class AssertionDivisible(Instruction):
    """value % divisor == 0 (``multipleOf``)."""

    divisor: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.DIVISIBLE)

    def cost(self) -> int:
        return 3


# ---------------------------------------------------------------------------
# Loops (§2.2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class LoopKeys(Instruction):
    """Validate every object *key* against child instructions
    (``propertyNames``)."""

    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_KEYS)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 4 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class LoopProperties(Instruction):
    """Validate every property value against one child sequence."""

    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_PROPERTIES)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 4 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class LoopPropertiesExcept(Instruction):
    """Validate property values whose keys match neither the static key set
    nor any exclusion pattern (``additionalProperties`` with adjacent
    ``properties``/``patternProperties``, resolved statically -- §3.2.1)."""

    exclude_keys: Tuple[str, ...] = ()
    exclude_hashes: Tuple[int, ...] = ()
    exclude_patterns: Tuple[RegexPlan, ...] = ()
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_PROPERTIES_EXCEPT)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 6 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class LoopPropertiesRegex(Instruction):
    """Validate property values whose keys match a pattern
    (``patternProperties``)."""

    plan: Optional[RegexPlan] = None
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_PROPERTIES_REGEX)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 6 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class LoopPropertiesMatch(Instruction):
    """Loop over the *instance* and look up per-key instruction groups
    (``properties`` when not unrolled)."""

    # key -> (hash, instruction group applying at the property's value)
    matches: Tuple[Tuple[str, int, Instructions], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_PROPERTIES_MATCH)

    def children_groups(self):
        return tuple(grp for _, _, grp in self.matches)

    def cost(self):
        return 4 + sum(c.cost() for grp in self.children_groups() for c in grp)


@dataclass(frozen=True, slots=True)
class LoopPropertiesMatchClosed(Instruction):
    """As LoopPropertiesMatch but *every* instance key must have a match
    (``additionalProperties: false``)."""

    matches: Tuple[Tuple[str, int, Instructions], ...] = ()
    # keys additionally tolerated via patternProperties (plans)
    tolerate_patterns: Tuple[RegexPlan, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_PROPERTIES_MATCH_CLOSED)

    def children_groups(self):
        return tuple(grp for _, _, grp in self.matches)

    def cost(self):
        return 4 + sum(c.cost() for grp in self.children_groups() for c in grp)


@dataclass(frozen=True, slots=True)
class LoopItems(Instruction):
    """Validate every array item (``items``)."""

    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_ITEMS)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 4 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class LoopItemsFrom(Instruction):
    """Validate array items from index ``start`` (``items`` adjacent to
    ``prefixItems`` -- first-level dependency resolved statically)."""

    start: int = 0
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_ITEMS_FROM)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 4 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class LoopContains(Instruction):
    """Count items matching child instructions; require count within
    [min_count, max_count] (``contains``/``minContains``/``maxContains``)."""

    children: Instructions = ()
    min_count: int = 1
    max_count: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_CONTAINS)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 5 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class ArrayPrefix(Instruction):
    """Validate the i-th item against the i-th instruction group
    (``prefixItems`` / draft-4..7 array-form ``items``)."""

    groups: Tuple[Instructions, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.ARRAY_PREFIX)

    def children_groups(self):
        return self.groups


@dataclass(frozen=True, slots=True)
class LoopUnevaluatedProperties(Instruction):
    """Second-level dependent ``unevaluatedProperties`` (dynamic residue).

    Static analysis (§3.2.2) removes this instruction whenever the evaluated
    set is statically known; the instruction remains only for schemas where
    branch outcomes decide evaluation.  ``branches`` holds
    (guard instructions, names, hashes, patterns, sees_all) tuples: when a
    guard validates, its names/patterns join the evaluated set; sees_all
    marks branches that evaluate *every* property (additionalProperties).
    """

    static_keys: Tuple[str, ...] = ()
    static_hashes: Tuple[int, ...] = ()
    static_patterns: Tuple[RegexPlan, ...] = ()
    branches: Tuple[
        Tuple[Instructions, Tuple[str, ...], Tuple[int, ...], Tuple[RegexPlan, ...], bool],
        ...,
    ] = ()
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_UNEVALUATED_PROPERTIES)

    def children_groups(self):
        groups = [self.children]
        groups.extend(guard for guard, *_ in self.branches)
        return tuple(groups)

    def cost(self):
        return 20 + sum(c.cost() for grp in self.children_groups() for c in grp)


@dataclass(frozen=True, slots=True)
class LoopUnevaluatedItems(Instruction):
    """Second-level dependent ``unevaluatedItems`` (dynamic residue).

    ``branches``: (guard instructions, covered_prefix, covers_all).
    """

    static_prefix: int = 0
    static_all: bool = False
    branches: Tuple[Tuple[Instructions, int, bool], ...] = ()
    # ``contains`` annotations: (branch guard, contains group) pairs.  When
    # the guard validates the whole array (empty guard = unconditional),
    # items matching the group are evaluated.  The guard gating matters:
    # a ``contains`` inside a *failed* anyOf/oneOf branch contributes no
    # annotations (2020-12 annotation semantics).
    contains_groups: Tuple[Tuple[Instructions, Instructions], ...] = ()
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.LOOP_UNEVALUATED_ITEMS)

    def children_groups(self):
        groups = [self.children]
        groups.extend(guard for guard, _, _ in self.branches)
        for guard, group in self.contains_groups:
            groups.append(guard)
            groups.append(group)
        return tuple(groups)

    def cost(self):
        return 20 + sum(c.cost() for grp in self.children_groups() for c in grp)


# ---------------------------------------------------------------------------
# Logical combinators (§2.3) + CISC conditions (Table 2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class LogicalAnd(Instruction):
    """All children must pass (``allOf``).  Short-circuits on failure."""

    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.AND)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class LogicalOr(Instruction):
    """At least one child group must pass (``anyOf``).  Short-circuits on
    first success."""

    groups: Tuple[Instructions, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.OR)

    def children_groups(self):
        return self.groups


@dataclass(frozen=True, slots=True)
class LogicalXor(Instruction):
    """Exactly one child group must pass (``oneOf``).  Short-circuits once a
    second group passes."""

    groups: Tuple[Instructions, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.XOR)

    def children_groups(self):
        return self.groups


@dataclass(frozen=True, slots=True)
class LogicalNot(Instruction):
    """Children must NOT all pass (``not``)."""

    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.NOT)

    def children_groups(self):
        return (self.children,)


@dataclass(frozen=True, slots=True)
class LogicalCondition(Instruction):
    """``if``/``then``/``else``: evaluate condition, branch accordingly."""

    condition: Instructions = ()
    then_children: Instructions = ()
    else_children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.CONDITION)

    def children_groups(self):
        return (self.condition, self.then_children, self.else_children)


@dataclass(frozen=True, slots=True)
class WhenType(Instruction):
    """Execute children only when target has a type (Table 2 CISC)."""

    type: str = "object"
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.WHEN_TYPE)

    def children_groups(self):
        return (self.children,)


@dataclass(frozen=True, slots=True)
class WhenDefines(Instruction):
    """Execute children only when target object defines a key
    (``dependentSchemas`` -- Table 2 CISC)."""

    key: str = ""
    key_hash: int = 0
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.WHEN_DEFINES)

    def children_groups(self):
        return (self.children,)


@dataclass(frozen=True, slots=True)
class WhenArraySizeGreater(Instruction):
    """Execute children only when array length > bound (Table 2 CISC)."""

    bound: int = 0
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.WHEN_ARRAY_SIZE_GREATER)

    def children_groups(self):
        return (self.children,)


@dataclass(frozen=True, slots=True)
class WhenArraySizeEqual(Instruction):
    """Execute children only when array length == bound (Table 2 CISC)."""

    bound: int = 0
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.WHEN_ARRAY_SIZE_EQUAL)

    def children_groups(self):
        return (self.children,)


# ---------------------------------------------------------------------------
# Control flow (§2.4)
# ---------------------------------------------------------------------------


@dataclass(frozen=True, slots=True)
class ControlLabel(Instruction):
    """Register children under a label, then execute them (first ``$ref``
    encounter of a shared/recursive destination, §3.3)."""

    label: int = 0
    children: Instructions = ()

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.CONTROL_LABEL)

    def children_groups(self):
        return (self.children,)

    def cost(self):
        return 2 + sum(c.cost() for c in self.children)


@dataclass(frozen=True, slots=True)
class ControlJump(Instruction):
    """Execute the instruction group registered under ``label``."""

    label: int = 0

    def __post_init__(self):
        object.__setattr__(self, "op", OpCode.CONTROL_JUMP)

    def cost(self):
        return 6  # jumps hurt cache locality (§3.3) -- bias reordering


def walk(instructions: Sequence[Instruction]):
    """Yield every instruction in a tree, depth first."""
    for inst in instructions:
        yield inst
        for grp in inst.children_groups():
            yield from walk(grp)
        if isinstance(inst, LoopUnevaluatedProperties):
            pass  # guards already covered by children_groups
