"""RFC 6901 JSON Pointers and relative instance locations.

Schema locations (for ``$ref`` resolution and error reporting) use standard
JSON Pointer strings.  Instance locations inside compiled instructions are
tuples of tokens (str for object keys, int for array indices) *relative to
the parent instruction* -- Blaze §5.1.
"""

from __future__ import annotations

from typing import Any, Iterable, Tuple, Union

Token = Union[str, int]
InstancePath = Tuple[Token, ...]

_MISSING = object()


def escape(token: str) -> str:
    """Escape a reference token per RFC 6901 (~ -> ~0, / -> ~1)."""
    return token.replace("~", "~0").replace("/", "~1")


def unescape(token: str) -> str:
    """Unescape a reference token per RFC 6901 (order matters: ~1 first)."""
    return token.replace("~1", "/").replace("~0", "~")


def parse_pointer(pointer: str) -> Tuple[str, ...]:
    """Split a JSON Pointer string into unescaped tokens."""
    if pointer == "":
        return ()
    if not pointer.startswith("/"):
        raise ValueError(f"invalid JSON pointer: {pointer!r}")
    return tuple(unescape(tok) for tok in pointer[1:].split("/"))


def format_pointer(tokens: Iterable[Token]) -> str:
    """Render tokens back into a JSON Pointer string."""
    return "".join("/" + escape(str(tok)) for tok in tokens)


def resolve_pointer(document: Any, pointer: str) -> Any:
    """Resolve a JSON Pointer against a plain-dict/list document.

    Raises ``KeyError`` when the pointer does not exist -- used for ``$ref``
    resolution where a dangling pointer is a schema bug.
    """
    node = document
    for tok in parse_pointer(pointer):
        if isinstance(node, dict):
            if tok not in node:
                raise KeyError(f"pointer token {tok!r} not found ({pointer!r})")
            node = node[tok]
        elif isinstance(node, list):
            try:
                idx = int(tok)
            except ValueError as exc:
                raise KeyError(f"non-integer index {tok!r} ({pointer!r})") from exc
            if not 0 <= idx < len(node):
                raise KeyError(f"index {idx} out of range ({pointer!r})")
            node = node[idx]
        else:
            raise KeyError(f"cannot descend into scalar at {tok!r} ({pointer!r})")
    return node


def get_instance(value: Any, path: InstancePath) -> Any:
    """Resolve a relative instance path; returns ``MISSING`` when absent.

    Instructions whose target is absent are skipped (vacuously true) --
    requiredness is asserted separately via ``AssertionDefines``.
    """
    node = value
    for tok in path:
        if isinstance(tok, str):
            # Instance objects are stored as HashedObject (vector of
            # entries) by the executor; support both plain dicts and the
            # executor's representation via duck typing.
            getter = getattr(node, "get_item", None)
            if getter is not None:
                node = getter(tok, _MISSING)
            elif isinstance(node, dict):
                node = node.get(tok, _MISSING)
            else:
                return _MISSING
            if node is _MISSING:
                return _MISSING
        else:
            if not isinstance(node, list) or not 0 <= tok < len(node):
                return _MISSING
            node = node[tok]
    return node


MISSING = _MISSING
