"""Blaze core: JSON Schema -> validation DSL compiler + executors.

The paper's primary contribution: schema compilation (compiler.py),
the validation DSL (instructions.py), the sequential fail-fast executor
(executor.py), and the TPU-native tensorised form (tape.py +
batch_executor.py).
"""

from .compiler import CompiledSchema, CompilerOptions, compile_schema
from .executor import Validator
from .interpreter import NaiveValidator
from .doc_model import parse_document
from .outcomes import (
    BreakerConfig,
    CircuitBreaker,
    DocumentDepthError,
    GuardLimits,
    InjectedFault,
    ValidationBudget,
    ValidationOutcome,
    ValidationTimeout,
    Verdict,
    fault_point,
    resource_guard,
    set_fault_hook,
)
from .schema_resolver import Dialect

__all__ = [
    "CompiledSchema",
    "CompilerOptions",
    "compile_schema",
    "Validator",
    "NaiveValidator",
    "parse_document",
    "Dialect",
    "BreakerConfig",
    "CircuitBreaker",
    "DocumentDepthError",
    "GuardLimits",
    "InjectedFault",
    "ValidationBudget",
    "ValidationOutcome",
    "ValidationTimeout",
    "Verdict",
    "fault_point",
    "resource_guard",
    "set_fault_hook",
]
