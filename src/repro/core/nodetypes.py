"""Canonical node-type codes for the columnar document encoding.

Single source of truth for the integer codes shared by the token-table
encoder (``data.doc_table``), the batched executor
(``core.batch_executor``), the tape builder (``core.tape``) and both
assertion kernels (``kernels.assertion_eval`` / ``kernels.ref``).  These
used to be mirrored as private constants in each module; keeping them here
means the codes cannot drift.

The codes double as bit positions in the TYPE_MASK assertion op:
``type_bit(t) = 1 << code(t)``.
"""

from __future__ import annotations

__all__ = [
    "T_PAD",
    "T_NULL",
    "T_BOOL",
    "T_NUM",
    "T_STR",
    "T_ARR",
    "T_OBJ",
    "TYPE_CODES",
    "TYPE_BIT",
]

T_PAD = 0
T_NULL = 1
T_BOOL = 2
T_NUM = 3
T_STR = 4
T_ARR = 5
T_OBJ = 6

# name -> code, as stored in TokenTable.node_type
TYPE_CODES = {
    "pad": T_PAD,
    "null": T_NULL,
    "boolean": T_BOOL,
    "number": T_NUM,
    "string": T_STR,
    "array": T_ARR,
    "object": T_OBJ,
}

# name -> TYPE_MASK bit (JSON types only; no bit for padding)
TYPE_BIT = {
    "null": 1 << T_NULL,
    "boolean": 1 << T_BOOL,
    "number": 1 << T_NUM,
    "string": 1 << T_STR,
    "array": 1 << T_ARR,
    "object": 1 << T_OBJ,
}
