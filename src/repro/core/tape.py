"""Instruction DSL -> tensorised *location tape* (the TPU-native schema form).

The sequential executor walks instructions per document.  The batched
executor instead assigns every document node a **schema location id** by
propagating locations down the BFS-ordered token table (property matching =
the ``hash_match`` kernel), then evaluates a flat table of per-location
assertion rows over all nodes at once (the ``assertion_eval`` kernel).

The tape supports the *structural subset* of the DSL that dominates API
payload validation: types, numeric/string/array/object bounds, specialized
regexes, scalar const/enum, required, (closed) properties, nested
objects/arrays, prefixItems/items, and -- since the bounded-unrolling
change (DESIGN.md §9) -- shared and **recursive** ``$ref`` labels.
``ControlLabel``/``ControlJump`` cycles are unrolled into the flat
location tape up to a compile-time depth budget (``unroll_depth``); the
locations where the budget ran out are *frontier* locations, and every
transition edge into them carries the :data:`LOC_FRONTIER` sentinel so
the batched executor can flag any document that reaches one as
**undecided** (routed to the sequential oracle, never vacuously valid).
Instructions outside the subset still raise :class:`UnsupportedForBatch`,
and callers fall back to the sequential executor -- the classic
fast-path/slow-path split.  Coverage over the benchmark corpus is
reported in EXPERIMENTS.md.

Layout (DESIGN.md §4-§5): assertion rows are stored **owner-sorted** as
CSR windows (``loc_asrt_start``/``loc_asrt_len``, bounded by the static
``max_rows_per_loc`` = A-hat) so each node evaluates only its own
location's rows; the property table additionally carries a
**hash-sorted** view (``psort_*``, runs bounded by ``max_hash_run`` = K)
so location propagation needs only one owner-blind hash pass; and
``max_loc_depth`` records the location DAG's depth so the executor can
truncate its propagation loop at compile-time-known horizons.

Multi-tenancy (DESIGN.md §8): a single-schema tape is the one-member
degenerate case of a *linked* tape; ``registry/linker.py`` relocates
and concatenates N member tapes so one batch can mix schemas, with
per-document roots (``roots[schema_id]``) and per-member psort segments
(``member_prop_start/len``, ``psort_member``).

Assertion-row mini-ISA (column ``asrt_op``; operands: f0 float, i0/i1
int32, u0/u1 uint32, plus 8 uint32 hash lanes per row):

====  ==============  =======================================================
code  name            semantics (precondition in parentheses)
====  ==============  =======================================================
0     TYPE_MASK       node type bit (1 << type code) in mask i0;
                      i1=1 -> numbers must be integers
1     NUM_GE          (number)  num >= f0
2     NUM_GT          (number)  num >  f0
3     NUM_LE          (number)  num <= f0
4     NUM_LT          (number)  num <  f0
5     NUM_MULTIPLE    (number)  num divisible by f0 (f0 != 0); evaluated
                      with a relative tolerance on the quotient (decimal
                      ``multipleOf`` like 0.01 has no exact binary form,
                      so exact f32 remainders would reject 19.99 % 0.01)
6     STR_MINLEN      (string)  size >= i0
7     STR_MAXLEN      (string)  size <= i0
8     ARR_MINLEN      (array)   size >= i0
9     ARR_MAXLEN      (array)   size <= i0
10    OBJ_MINPROPS    (object)  size >= i0
11    OBJ_MAXPROPS    (object)  size <= i0
12    STR_PREFIX      (string)  first i0 (<=8) bytes equal u0,u1 (big-endian)
13    STR_EQ          exact string equality via hash lanes (non-strings fail)
14    CONST_NULL      value is null
15    CONST_BOOL      value is boolean f0
16    CONST_NUM       value is number f0
17    STR_EQ_PRE      (string)  equality via hash lanes (non-strings pass)
====  ==============  =======================================================

Rows sharing a nonzero ``asrt_group`` form an OR-group (``enum``); rows with
group 0 are ANDed individually with precondition semantics.  Within a CSR
window the AND rows come first and each OR-group is contiguous (the
executor's segmented-scan reduction relies on this).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .compiler import CompiledSchema
from .instructions import Instruction, Instructions, OpCode
from .nodetypes import TYPE_BIT
from .regex_opt import RegexKind

__all__ = [
    "LocationTape",
    "UnsupportedForBatch",
    "build_tape",
    "try_build_tape",
    "AOP",
    "LOC_FRONTIER",
    "DEFAULT_UNROLL_DEPTH",
    "DEFAULT_UNROLL_NODE_BUDGET",
]


class UnsupportedForBatch(ValueError):
    """Schema uses DSL features outside the tensorised subset."""


# assertion op codes (mini-ISA)
class AOP:
    TYPE_MASK = 0
    NUM_GE = 1
    NUM_GT = 2
    NUM_LE = 3
    NUM_LT = 4
    NUM_MULTIPLE = 5
    STR_MINLEN = 6
    STR_MAXLEN = 7
    ARR_MINLEN = 8
    ARR_MAXLEN = 9
    OBJ_MINPROPS = 10
    OBJ_MAXPROPS = 11
    STR_PREFIX = 12
    STR_EQ = 13
    CONST_NULL = 14
    CONST_BOOL = 15
    CONST_NUM = 16
    STR_EQ_PRE = 17


# special location ids
LOC_UNTRACKED = -2  # no constraints below this point
LOC_INVALID = -3  # reaching this location fails the document
LOC_FRONTIER = -4  # the unroll budget ran out here: document undecided

# $ref-recursion unrolling budgets (DESIGN.md §9): levels of label
# re-expansion beyond the first, and a cap on total locations so
# branching recursion (trees with many recursive children) cannot blow
# the tape up exponentially -- the budget simply converts into earlier
# frontiers, i.e. more sequential-oracle routing, never wrong verdicts.
DEFAULT_UNROLL_DEPTH = 4
DEFAULT_UNROLL_NODE_BUDGET = 4096

# type code bits (shared canonical codes, see core.nodetypes)
_TYPE_BIT = TYPE_BIT


@dataclass
class _Loc:
    """Mutable per-location build state."""

    index: int
    props: Dict[str, int] = field(default_factory=dict)  # key -> prop row
    closed: bool = False
    addl_loc: int = -1  # location for unmatched properties (-1: none)
    item_loc: int = -1
    item_start: int = 0
    prefix_locs: List[int] = field(default_factory=list)
    required_slots: Dict[str, int] = field(default_factory=dict)
    frontier: bool = False  # a label expansion ran out of budget here


@dataclass
class LocationTape:
    """Flat tensor form of a compiled (structural-subset) schema.

    Assertion rows are stored **owner-sorted** (by ``(owner, group)``):
    each location's rows occupy the contiguous CSR window
    ``[loc_asrt_start[l], loc_asrt_start[l] + loc_asrt_len[l])``, with the
    AND rows (group 0) first and each enum OR-group contiguous after them.
    ``max_rows_per_loc`` (compile-time constant, "A-hat") bounds every
    window, so the batched executor can evaluate a dense (nodes x A-hat)
    gather instead of the full (nodes x A) matrix.

    The property-transition table additionally carries a **hash-sorted
    view** (``psort_*``): rows sorted lexicographically by their 8 hash
    lanes, so all rows sharing one key hash form a contiguous run.  One
    owner-blind ``hash_match`` per node finds the run start; the run is at
    most ``max_hash_run`` (K) rows, and per-depth location propagation
    reduces to an owner-equality check over those K candidates.
    """

    n_locations: int
    max_loc_depth: int  # longest root path in the location DAG
    # property transition rows (original emission order)
    prop_owner: np.ndarray  # int32 (M,)
    prop_hash: np.ndarray  # uint32 (M, 8)
    prop_child_loc: np.ndarray  # int32 (M,)
    prop_required_slot: np.ndarray  # int32 (M,)  -1 = not required
    # hash-sorted view of the property table (candidate-set hashing)
    psort_hash: np.ndarray  # uint32 (M, 8) lexicographically sorted lanes
    psort_owner: np.ndarray  # int32 (M,)
    psort_child_loc: np.ndarray  # int32 (M,)
    psort_required_slot: np.ndarray  # int32 (M,)
    psort_orig_row: np.ndarray  # int32 (M,) original row index (tie-break)
    psort_run_len: np.ndarray  # int32 (M,) length of the equal-hash run
    max_hash_run: int  # K: max rows sharing one key hash
    # per-location
    loc_closed: np.ndarray  # bool (L,)
    loc_addl: np.ndarray  # int32 (L,)  unmatched-property location / -1
    loc_item: np.ndarray  # int32 (L,)
    loc_item_start: np.ndarray  # int32 (L,)
    loc_prefix_start: np.ndarray  # int32 (L,)
    loc_prefix_len: np.ndarray  # int32 (L,)
    prefix_loc: np.ndarray  # int32 (P,)
    loc_required_mask: np.ndarray  # uint32 (L,)
    # assertion rows, owner-sorted CSR (see class docstring)
    loc_asrt_start: np.ndarray  # int32 (L,) window start per location
    loc_asrt_len: np.ndarray  # int32 (L,) window length per location
    max_rows_per_loc: int  # A-hat: max window length over locations
    asrt_owner: np.ndarray  # int32 (A,)
    asrt_op: np.ndarray  # int32 (A,)
    asrt_group: np.ndarray  # int32 (A,)  0 = AND row, else OR-group id
    asrt_f0: np.ndarray  # float64 (A,)
    asrt_i0: np.ndarray  # int32 (A,)
    asrt_i1: np.ndarray  # int32 (A,)
    asrt_u0: np.ndarray  # uint32 (A,)
    asrt_u1: np.ndarray  # uint32 (A,)
    asrt_hash: np.ndarray  # uint32 (A, 8)
    # -- multi-tenant linking (registry/linker.py) ----------------------
    # A single-schema tape is the one-member degenerate case: member 0,
    # root location 0.  A *linked* tape concatenates S relocated member
    # tapes; ``roots[s]`` seeds each document's root location from its
    # schema id, and the hash-sorted property view keeps per-member
    # segments (``member_prop_start/len``; rows tagged ``psort_member``
    # for introspection) so the executor's hash pass never matches
    # across members (runs never span members by construction).
    # ``member_horizons[s]`` keeps each member's own propagation horizon
    # (max_loc_depth + 1) so per-document ``decided`` stays bit-identical
    # to single-tape dispatch even when members disagree on depth.
    psort_member: Optional[np.ndarray] = None  # int32 (M,)
    roots: Optional[np.ndarray] = None  # int32 (S,)
    member_horizons: Optional[np.ndarray] = None  # int32 (S,)
    # per-member psort segment windows: member s's hash-sorted rows are
    # [member_prop_start[s], member_prop_start[s] + member_prop_len[s]).
    # ``max_member_props`` (M-hat) bounds them, so the linked executor's
    # hash pass scans the largest member, not the member *sum*.
    member_prop_start: Optional[np.ndarray] = None  # int32 (S,)
    member_prop_len: Optional[np.ndarray] = None  # int32 (S,)
    max_member_props: Optional[int] = None  # M-hat
    # -- $ref-recursion unrolling (DESIGN.md §9) ------------------------
    # ``loc_frontier[l]`` marks locations where the unroll budget ran
    # out; every transition edge into them already carries the
    # LOC_FRONTIER sentinel (so the executor needs no extra gather), the
    # bool array is kept for introspection, linking and static skips.
    loc_frontier: Optional[np.ndarray] = None  # bool (L,)
    unroll_depth: int = 0  # budget used at build time (0: no labels)

    def __post_init__(self) -> None:
        if self.psort_member is None:
            self.psort_member = np.zeros(len(self.psort_owner), np.int32)
        if self.roots is None:
            self.roots = np.zeros(1, np.int32)
        if self.member_horizons is None:
            self.member_horizons = np.array([self.max_loc_depth + 1], np.int32)
        if self.member_prop_start is None:
            self.member_prop_start = np.zeros(len(self.roots), np.int32)
        if self.member_prop_len is None:
            n_real = int(np.count_nonzero(self.prop_owner >= 0))
            self.member_prop_len = np.full(len(self.roots), n_real, np.int32)
        if self.max_member_props is None:
            self.max_member_props = int(self.member_prop_len.max()) if len(self.member_prop_len) else 0
        if self.loc_frontier is None:
            self.loc_frontier = np.zeros(len(self.loc_closed), bool)

    @property
    def n_props(self) -> int:
        return len(self.prop_owner)

    @property
    def n_assertions(self) -> int:
        return len(self.asrt_owner)

    @property
    def n_members(self) -> int:
        return len(self.roots)

    @property
    def n_frontier(self) -> int:
        return int(np.count_nonzero(self.loc_frontier))


class _TapeBuilder:
    def __init__(
        self,
        labels: Optional[Dict[int, Instructions]] = None,
        *,
        unroll_depth: int = DEFAULT_UNROLL_DEPTH,
        unroll_node_budget: int = DEFAULT_UNROLL_NODE_BUDGET,
    ) -> None:
        self.locs: List[_Loc] = []
        self.prop_rows: List[Tuple[int, np.ndarray, int, int]] = []
        self.asrt_rows: List[dict] = []
        self._group_counter = 0
        self.labels: Dict[int, Instructions] = dict(labels or {})
        self.unroll_depth = max(1, int(unroll_depth))
        self.unroll_node_budget = int(unroll_node_budget)
        # active expansions per label along the current lowering path --
        # the cycle detector.  A label already on the stack more than
        # ``unroll_depth`` times stops expanding and marks a frontier.
        self._label_stack: Dict[int, int] = {}

    # -- label unrolling (DESIGN.md §9) --------------------------------

    def expand_label(self, label: int, loc: _Loc) -> None:
        """Expand ``label``'s body at ``loc``, bounded by the budgets.

        Each re-expansion along one lowering path clones the label's
        location subgraph one level deeper (property-transition rows of
        level *d* wire to the level *d+1* clones because every
        ``child_for_key`` call allocates fresh locations).  When either
        budget runs out, ``loc`` becomes a *frontier* location instead:
        documents reaching it are undecided, never vacuously valid.
        """
        children = self.labels.get(label)
        if children is None:
            raise UnsupportedForBatch(f"jump to unknown label {label}")
        depth = self._label_stack.get(label, 0)
        if depth > self.unroll_depth or len(self.locs) >= self.unroll_node_budget:
            loc.frontier = True
            return
        self._label_stack[label] = depth + 1
        try:
            self.add_group(children, loc)
        finally:
            self._label_stack[label] = depth

    # -- locations -----------------------------------------------------

    def new_loc(self) -> _Loc:
        loc = _Loc(index=len(self.locs))
        self.locs.append(loc)
        return loc

    def child_for_key(self, loc: _Loc, key: str) -> _Loc:
        if key in loc.props:
            row = loc.props[key]
            child_idx = self.prop_rows[row][2]
            if child_idx >= 0:
                return self.locs[child_idx]
            # upgrade an untracked (required-only) row to a real location
            child = self.new_loc()
            owner, lanes, _, slot = self.prop_rows[row]
            self.prop_rows[row] = (owner, lanes, child.index, slot)
            return child
        from ..data.doc_table import key_lanes

        child = self.new_loc()
        row = len(self.prop_rows)
        self.prop_rows.append((loc.index, key_lanes(key), child.index, -1))
        loc.props[key] = row
        return child

    def require_key(self, loc: _Loc, key: str) -> None:
        if key in loc.required_slots:
            return
        slot = len(loc.required_slots)
        if slot >= 32:
            raise UnsupportedForBatch(">32 required properties at one location")
        loc.required_slots[key] = slot
        if key in loc.props:
            row = loc.props[key]
            owner, lanes, child, _ = self.prop_rows[row]
            self.prop_rows[row] = (owner, lanes, child, slot)
        else:
            from ..data.doc_table import key_lanes

            row = len(self.prop_rows)
            self.prop_rows.append((loc.index, key_lanes(key), LOC_UNTRACKED, slot))
            loc.props[key] = row

    # -- assertion rows ---------------------------------------------------

    def row(self, loc: _Loc, op: int, *, f0=0.0, i0=0, i1=0, u0=0, u1=0, lanes=None, group=0):
        self.asrt_rows.append(
            dict(
                owner=loc.index,
                op=op,
                group=group,
                f0=float(f0),
                i0=int(i0),
                i1=int(i1),
                u0=int(u0),
                u1=int(u1),
                lanes=np.zeros(8, np.uint32) if lanes is None else lanes,
            )
        )

    def next_group(self) -> int:
        self._group_counter += 1
        return self._group_counter

    # -- instruction lowering -----------------------------------------------

    def add_group(self, instructions: Instructions, loc: _Loc) -> None:
        for inst in instructions:
            self.add(inst, loc)

    def descend(self, loc: _Loc, rel_path) -> _Loc:
        for tok in rel_path:
            if not isinstance(tok, str):
                raise UnsupportedForBatch("integer instance paths not batchable")
            loc = self.child_for_key(loc, tok)
        return loc

    def add(self, inst: Instruction, loc: _Loc) -> None:
        target = self.descend(loc, inst.rel_path)
        op = inst.op
        handler = _HANDLERS.get(op)
        if handler is None:
            raise UnsupportedForBatch(f"instruction {op.name} not batchable")
        handler(self, inst, target)

    # -- finalize ------------------------------------------------------------

    def build(self) -> LocationTape:
        L = len(self.locs)
        # frontier locations (unroll budget exhausted): every transition
        # edge INTO one is snapped to the LOC_FRONTIER sentinel, so the
        # executor's ordinary negative-location propagation carries the
        # "undecided" mark down the whole subtree for free and the
        # frontier location itself (with its partial constraints) is
        # never entered.  Frontier subtrees are likewise excluded from
        # the depth DP, keeping the horizon tight.
        frontier_mask = np.array([l.frontier for l in self.locs] or [False], bool)

        def _snap(child: int) -> int:
            if child >= 0 and frontier_mask[child]:
                return LOC_FRONTIER
            return child

        prefix_loc: List[int] = []
        loc_prefix_start = np.zeros(L, np.int32)
        loc_prefix_len = np.zeros(L, np.int32)
        for loc in self.locs:
            loc_prefix_start[loc.index] = len(prefix_loc)
            loc_prefix_len[loc.index] = len(loc.prefix_locs)
            prefix_loc.extend(_snap(p) for p in loc.prefix_locs)
        M = max(1, len(self.prop_rows))
        prop_owner = np.full(M, -1, np.int32)
        prop_hash = np.zeros((M, 8), np.uint32)
        prop_child = np.full(M, LOC_UNTRACKED, np.int32)
        prop_slot = np.full(M, -1, np.int32)
        for r, (owner, lanes, child, slot) in enumerate(self.prop_rows):
            prop_owner[r] = owner
            prop_hash[r] = lanes
            prop_child[r] = _snap(child)
            prop_slot[r] = slot

        # hash-sorted view: rows sorted lexicographically by lanes so equal
        # key hashes form contiguous runs (candidate sets for the single
        # owner-blind hash_match pass).  Lane 0 is the primary sort key.
        if self.prop_rows:
            order = np.lexsort(tuple(prop_hash[:, k] for k in range(7, -1, -1)))
            order = order.astype(np.int32)
            psort_hash = prop_hash[order]
            new_run = np.ones(M, bool)
            new_run[1:] = np.any(psort_hash[1:] != psort_hash[:-1], axis=1)
            run_id = np.cumsum(new_run) - 1
            run_sizes = np.bincount(run_id)
            psort_run_len = run_sizes[run_id].astype(np.int32)
            max_hash_run = int(run_sizes.max())
        else:
            order = np.zeros(1, np.int32)
            psort_hash = prop_hash
            psort_run_len = np.zeros(M, np.int32)
            max_hash_run = 0

        # longest root path in the location DAG: all transition edges point
        # to later-created locations, so one ascending DP pass suffices.
        # Nodes deeper than max_loc_depth + 1 can only be untracked or
        # under an already-invalid ancestor -- the executor truncates its
        # propagation loop there (compile-time depth knowledge).
        dist = np.zeros(max(1, L), np.int64)
        children: List[List[int]] = [[] for _ in range(L)]
        for owner, _lanes, child, _slot in self.prop_rows:
            if child >= 0 and not frontier_mask[child]:
                children[owner].append(child)
        for loc in self.locs:
            for v in (loc.addl_loc, loc.item_loc):
                if v >= 0 and not frontier_mask[v]:
                    children[loc.index].append(v)
            children[loc.index].extend(
                p for p in loc.prefix_locs if not frontier_mask[p]
            )
        for u in range(L):
            for v in children[u]:
                if v > u:
                    dist[v] = max(dist[v], dist[u] + 1)
        max_loc_depth = int(dist.max())

        # owner-sorted CSR assertion windows: stable sort by (owner, group)
        # keeps AND rows (group 0) first and every OR-group contiguous
        asrt_rows = self.asrt_rows
        if asrt_rows:
            a_owner = np.array([r["owner"] for r in asrt_rows], np.int32)
            a_group = np.array([r["group"] for r in asrt_rows], np.int32)
            a_order = np.lexsort((a_group, a_owner))
            asrt_rows = [asrt_rows[i] for i in a_order]
            sorted_owner = a_owner[a_order]
            loc_asrt_len = np.bincount(sorted_owner, minlength=L).astype(np.int32)
            loc_asrt_start = np.concatenate(
                [[0], np.cumsum(loc_asrt_len[:-1])]
            ).astype(np.int32)
            max_rows_per_loc = int(loc_asrt_len.max())
        else:
            loc_asrt_len = np.zeros(max(1, L), np.int32)
            loc_asrt_start = np.zeros(max(1, L), np.int32)
            max_rows_per_loc = 0

        tape = LocationTape(
            n_locations=L,
            max_loc_depth=max_loc_depth,
            prop_owner=prop_owner,
            prop_hash=prop_hash,
            prop_child_loc=prop_child,
            prop_required_slot=prop_slot,
            psort_hash=psort_hash,
            psort_owner=prop_owner[order],
            psort_child_loc=prop_child[order],
            psort_required_slot=prop_slot[order],
            psort_orig_row=order,
            psort_run_len=psort_run_len,
            max_hash_run=max_hash_run,
            loc_asrt_start=loc_asrt_start,
            loc_asrt_len=loc_asrt_len,
            max_rows_per_loc=max_rows_per_loc,
            loc_closed=np.array([l.closed for l in self.locs] or [False], bool),
            loc_addl=np.array(
                [_snap(l.addl_loc) for l in self.locs] or [-1], np.int32
            ),
            loc_item=np.array(
                [_snap(l.item_loc) for l in self.locs] or [-1], np.int32
            ),
            loc_item_start=np.array([l.item_start for l in self.locs] or [0], np.int32),
            loc_prefix_start=loc_prefix_start if L else np.zeros(1, np.int32),
            loc_prefix_len=loc_prefix_len if L else np.zeros(1, np.int32),
            prefix_loc=np.array(prefix_loc or [-1], np.int32),
            loc_required_mask=np.array(
                [
                    sum(1 << s for s in l.required_slots.values())
                    for l in self.locs
                ]
                or [0],
                np.uint32,
            ),
            asrt_owner=np.array([r["owner"] for r in asrt_rows] or [-1], np.int32),
            asrt_op=np.array([r["op"] for r in asrt_rows] or [0], np.int32),
            asrt_group=np.array([r["group"] for r in asrt_rows] or [0], np.int32),
            asrt_f0=np.array([r["f0"] for r in asrt_rows] or [0.0], np.float64),
            asrt_i0=np.array([r["i0"] for r in asrt_rows] or [0], np.int32),
            asrt_i1=np.array([r["i1"] for r in asrt_rows] or [0], np.int32),
            asrt_u0=np.array([r["u0"] for r in asrt_rows] or [0], np.uint32),
            asrt_u1=np.array([r["u1"] for r in asrt_rows] or [0], np.uint32),
            asrt_hash=np.stack([r["lanes"] for r in asrt_rows] or [np.zeros(8, np.uint32)]),
            loc_frontier=frontier_mask,
            unroll_depth=self.unroll_depth if self.labels else 0,
        )
        return tape


# ---------------------------------------------------------------------------
# Per-instruction lowering handlers
# ---------------------------------------------------------------------------


def _type_row(b: _TapeBuilder, loc: _Loc, types: Tuple[str, ...]) -> None:
    mask = 0
    for t in types:
        if t == "integer":
            mask |= _TYPE_BIT["number"]
        else:
            mask |= _TYPE_BIT[t]
    ints_only = "integer" in types and "number" not in types
    b.row(loc, AOP.TYPE_MASK, i0=mask, i1=1 if ints_only else 0)


def _h_type(b, inst, loc):
    _type_row(b, loc, (inst.type,))


def _h_type_any(b, inst, loc):
    _type_row(b, loc, inst.types)


def _scalar_const_row(b: _TapeBuilder, loc: _Loc, value: Any, group: int) -> None:
    from ..data.doc_table import key_lanes

    if value is None:
        b.row(loc, AOP.CONST_NULL, group=group)
    elif isinstance(value, bool):
        b.row(loc, AOP.CONST_BOOL, f0=1.0 if value else 0.0, group=group)
    elif isinstance(value, (int, float)):
        b.row(loc, AOP.CONST_NUM, f0=float(value), group=group)
    elif isinstance(value, str):
        b.row(loc, AOP.STR_EQ, lanes=key_lanes(value), group=group)
    else:
        raise UnsupportedForBatch("const/enum of arrays/objects not batchable")


def _h_equal(b, inst, loc):
    group = b.next_group()
    _scalar_const_row(b, loc, inst.value, group)


def _h_equals_any(b, inst, loc):
    group = b.next_group()
    for v in inst.values:
        _scalar_const_row(b, loc, v, group)


def _h_fail(b, inst, loc):
    # an impossible assertion: type in empty mask
    b.row(loc, AOP.TYPE_MASK, i0=0)


def _h_number(b, inst, loc):
    op = inst.op
    if op is OpCode.GREATER:
        b.row(loc, AOP.NUM_GT, f0=inst.bound)
    elif op is OpCode.GREATER_EQUAL:
        b.row(loc, AOP.NUM_GE, f0=inst.bound)
    elif op is OpCode.LESS:
        b.row(loc, AOP.NUM_LT, f0=inst.bound)
    elif op is OpCode.LESS_EQUAL:
        b.row(loc, AOP.NUM_LE, f0=inst.bound)
    elif op is OpCode.DIVISIBLE:
        b.row(loc, AOP.NUM_MULTIPLE, f0=inst.divisor)
    elif op is OpCode.NUMBER_BOUNDS:
        if inst.lo is not None:
            b.row(loc, AOP.NUM_GT if inst.lo_exclusive else AOP.NUM_GE, f0=inst.lo)
        if inst.hi is not None:
            b.row(loc, AOP.NUM_LT if inst.hi_exclusive else AOP.NUM_LE, f0=inst.hi)


def _h_string_size(b, inst, loc):
    if inst.op is OpCode.STRING_SIZE_GREATER:
        b.row(loc, AOP.STR_MINLEN, i0=inst.bound)
    else:
        b.row(loc, AOP.STR_MAXLEN, i0=inst.bound)


def _h_string_bounds(b, inst, loc):
    b.row(loc, AOP.STR_MINLEN, i0=inst.min_len)
    if inst.max_len is not None:
        b.row(loc, AOP.STR_MAXLEN, i0=inst.max_len)


def _h_regex(b, inst, loc):
    plan = inst.plan
    if plan.kind is RegexKind.ALL:
        return
    if plan.kind is RegexKind.NON_EMPTY:
        b.row(loc, AOP.STR_MINLEN, i0=1)
        return
    if plan.kind is RegexKind.LENGTH_RANGE:
        b.row(loc, AOP.STR_MINLEN, i0=plan.min_len)
        if plan.max_len is not None:
            b.row(loc, AOP.STR_MAXLEN, i0=plan.max_len)
        return
    if plan.kind is RegexKind.EXACT:
        from ..data.doc_table import key_lanes

        # preconditioned form: non-strings skip (pattern semantics)
        b.row(loc, AOP.STR_EQ_PRE, lanes=key_lanes(plan.literal))
        return
    if plan.kind is RegexKind.PREFIX:
        data = plan.literal.encode("utf-8")
        if len(data) > 8:
            raise UnsupportedForBatch("prefix >8 bytes not batchable")
        padded = data.ljust(8, b"\x00")
        b.row(
            loc,
            AOP.STR_PREFIX,
            i0=len(data),
            u0=int.from_bytes(padded[:4], "big"),
            u1=int.from_bytes(padded[4:], "big"),
        )
        return
    raise UnsupportedForBatch(f"regex kind {plan.kind} not batchable")


def _h_array_size(b, inst, loc):
    if inst.op is OpCode.ARRAY_SIZE_GREATER:
        b.row(loc, AOP.ARR_MINLEN, i0=inst.bound)
    else:
        b.row(loc, AOP.ARR_MAXLEN, i0=inst.bound)


def _h_array_bounds(b, inst, loc):
    b.row(loc, AOP.ARR_MINLEN, i0=inst.min_len)
    if inst.max_len is not None:
        b.row(loc, AOP.ARR_MAXLEN, i0=inst.max_len)


def _h_object_size(b, inst, loc):
    if inst.op is OpCode.OBJECT_SIZE_GREATER:
        b.row(loc, AOP.OBJ_MINPROPS, i0=inst.bound)
    else:
        b.row(loc, AOP.OBJ_MAXPROPS, i0=inst.bound)


def _h_defines(b, inst, loc):
    b.require_key(loc, inst.key)


def _h_defines_all(b, inst, loc):
    for key in inst.keys:
        b.require_key(loc, key)


def _h_property_type(b, inst, loc):
    b.require_key(loc, inst.key)
    child = b.child_for_key(loc, inst.key)
    _type_row(b, child, (inst.type,))


def _h_loop_properties_match(b, inst, loc, closed=False):
    if closed and getattr(inst, "tolerate_patterns", ()):  # patterns need key text
        for p in inst.tolerate_patterns:
            raise UnsupportedForBatch("patternProperties tolerance not batchable")
    for key, _h, group in inst.matches:
        child = b.child_for_key(loc, key)
        b.add_group(group, child)
    if closed:
        loc.closed = True


def _h_loop_properties_match_closed(b, inst, loc):
    _h_loop_properties_match(b, inst, loc, closed=True)


def _h_loop_properties(b, inst, loc):
    # every property validates against children: model as the addl location
    if loc.addl_loc >= 0:
        addl = b.locs[loc.addl_loc]
    else:
        addl = b.new_loc()
        loc.addl_loc = addl.index
    b.add_group(inst.children, addl)


def _h_loop_properties_except(b, inst, loc):
    if inst.exclude_patterns:
        raise UnsupportedForBatch("patternProperties exclusion not batchable")
    # excluded keys must exist as prop rows so unmatched -> addl
    for key in inst.exclude_keys:
        b.child_for_key(loc, key)
    addl = b.new_loc()
    if loc.addl_loc >= 0:
        raise UnsupportedForBatch("multiple additionalProperties scopes")
    loc.addl_loc = addl.index
    b.add_group(inst.children, addl)


def _h_loop_items(b, inst, loc):
    if loc.item_loc >= 0:
        item = b.locs[loc.item_loc]
    else:
        item = b.new_loc()
        loc.item_loc = item.index
        loc.item_start = 0
    b.add_group(inst.children, item)


def _h_loop_items_from(b, inst, loc):
    if loc.item_loc >= 0:
        raise UnsupportedForBatch("conflicting items scopes")
    item = b.new_loc()
    loc.item_loc = item.index
    loc.item_start = inst.start
    b.add_group(inst.children, item)


def _h_array_prefix(b, inst, loc):
    if loc.prefix_locs:
        raise UnsupportedForBatch("conflicting prefixItems scopes")
    for group in inst.groups:
        child = b.new_loc()
        loc.prefix_locs.append(child.index)
        b.add_group(group, child)


def _h_control_label(b, inst, loc):
    # shared/recursive definitions: the body expands in place, and any
    # jumps back to this label re-expand through the bounded unroller
    b.labels.setdefault(inst.label, inst.children)
    b.expand_label(inst.label, loc)


def _h_control_jump(b, inst, loc):
    b.expand_label(inst.label, loc)


_HANDLERS = {
    OpCode.FAIL: _h_fail,
    OpCode.TYPE: _h_type,
    OpCode.TYPE_ANY: _h_type_any,
    OpCode.EQUAL: _h_equal,
    OpCode.EQUALS_ANY: _h_equals_any,
    OpCode.GREATER: _h_number,
    OpCode.GREATER_EQUAL: _h_number,
    OpCode.LESS: _h_number,
    OpCode.LESS_EQUAL: _h_number,
    OpCode.NUMBER_BOUNDS: _h_number,
    OpCode.DIVISIBLE: _h_number,
    OpCode.STRING_SIZE_GREATER: _h_string_size,
    OpCode.STRING_SIZE_LESS: _h_string_size,
    OpCode.STRING_BOUNDS: _h_string_bounds,
    OpCode.REGEX: _h_regex,
    OpCode.ARRAY_SIZE_GREATER: _h_array_size,
    OpCode.ARRAY_SIZE_LESS: _h_array_size,
    OpCode.ARRAY_BOUNDS: _h_array_bounds,
    OpCode.OBJECT_SIZE_GREATER: _h_object_size,
    OpCode.OBJECT_SIZE_LESS: _h_object_size,
    OpCode.DEFINES: _h_defines,
    OpCode.DEFINES_ALL: _h_defines_all,
    OpCode.PROPERTY_TYPE: _h_property_type,
    OpCode.LOOP_PROPERTIES_MATCH: _h_loop_properties_match,
    OpCode.LOOP_PROPERTIES_MATCH_CLOSED: _h_loop_properties_match_closed,
    OpCode.LOOP_PROPERTIES: _h_loop_properties,
    OpCode.LOOP_PROPERTIES_EXCEPT: _h_loop_properties_except,
    OpCode.LOOP_ITEMS: _h_loop_items,
    OpCode.LOOP_ITEMS_FROM: _h_loop_items_from,
    OpCode.ARRAY_PREFIX: _h_array_prefix,
    OpCode.CONTROL_LABEL: _h_control_label,
    OpCode.CONTROL_JUMP: _h_control_jump,
}


def build_tape(
    compiled: CompiledSchema,
    *,
    unroll_depth: int = DEFAULT_UNROLL_DEPTH,
    unroll_node_budget: int = DEFAULT_UNROLL_NODE_BUDGET,
) -> LocationTape:
    """Lower a compiled schema to the tensor tape; raises
    :class:`UnsupportedForBatch` outside the structural subset.

    Shared and recursive ``$ref`` labels (``ControlLabel``/``ControlJump``)
    are unrolled into the flat tape up to ``unroll_depth`` re-expansions
    per label (and ``unroll_node_budget`` total locations); past the
    budget the lowering marks *frontier* locations whose documents the
    batched executor flags undecided (DESIGN.md §9).
    """
    b = _TapeBuilder(
        compiled.labels,
        unroll_depth=unroll_depth,
        unroll_node_budget=unroll_node_budget,
    )
    root = b.new_loc()
    b.add_group(compiled.instructions, root)
    return b.build()


def try_build_tape(
    compiled: CompiledSchema,
    *,
    unroll_depth: int = DEFAULT_UNROLL_DEPTH,
    unroll_node_budget: int = DEFAULT_UNROLL_NODE_BUDGET,
) -> Tuple[Optional[LocationTape], str]:
    """Build the tape or report why the schema is not batchable."""
    try:
        return (
            build_tape(
                compiled,
                unroll_depth=unroll_depth,
                unroll_node_budget=unroll_node_budget,
            ),
            "",
        )
    except UnsupportedForBatch as exc:
        return None, str(exc)
