"""Instruction DSL -> tensorised *location tape* (the TPU-native schema form).

The sequential executor walks instructions per document.  The batched
executor instead assigns every document node a **schema location id** by
propagating locations down the BFS-ordered token table (property matching =
the ``hash_match`` kernel), then evaluates a flat table of per-location
assertion rows over all nodes at once (the ``assertion_eval`` kernel).

The tape supports the *structural subset* of the DSL that dominates API
payload validation: types, numeric/string/array/object bounds, specialized
regexes, scalar const/enum, required, (closed) properties, nested
objects/arrays, prefixItems/items, and -- since the bounded-unrolling
change (DESIGN.md §9) -- shared and **recursive** ``$ref`` labels.
``ControlLabel``/``ControlJump`` cycles are unrolled into the flat
location tape up to a compile-time depth budget (``unroll_depth``); the
locations where the budget ran out are *frontier* locations, and every
transition edge into them carries the :data:`LOC_FRONTIER` sentinel so
the batched executor can flag any document that reaches one as
**undecided** (routed to the sequential oracle, never vacuously valid).
Instructions outside the subset still raise :class:`UnsupportedForBatch`,
and callers fall back to the sequential executor -- the classic
fast-path/slow-path split.  Coverage over the benchmark corpus is
reported in EXPERIMENTS.md.

Layout (DESIGN.md §4-§5): assertion rows are stored **owner-sorted** as
CSR windows (``loc_asrt_start``/``loc_asrt_len``, bounded by the static
``max_rows_per_loc`` = A-hat) so each node evaluates only its own
location's rows; the property table additionally carries a
**hash-sorted** view (``psort_*``, runs bounded by ``max_hash_run`` = K)
so location propagation needs only one owner-blind hash pass; and
``max_loc_depth`` records the location DAG's depth so the executor can
truncate its propagation loop at compile-time-known horizons.

Multi-tenancy (DESIGN.md §8): a single-schema tape is the one-member
degenerate case of a *linked* tape; ``registry/linker.py`` relocates
and concatenates N member tapes so one batch can mix schemas, with
per-document roots (``roots[schema_id]``) and per-member psort segments
(``member_prop_start/len``, ``psort_member``).

Assertion-row mini-ISA (column ``asrt_op``; operands: f0 float, i0/i1
int32, u0/u1 uint32, plus 8 uint32 hash lanes per row):

====  ==============  =======================================================
code  name            semantics (precondition in parentheses)
====  ==============  =======================================================
0     TYPE_MASK       node type bit (1 << type code) in mask i0;
                      i1=1 -> numbers must be integers
1     NUM_GE          (number)  num >= f0
2     NUM_GT          (number)  num >  f0
3     NUM_LE          (number)  num <= f0
4     NUM_LT          (number)  num <  f0
5     NUM_MULTIPLE    (number)  num divisible by f0 (f0 != 0); evaluated
                      with a relative tolerance on the quotient (decimal
                      ``multipleOf`` like 0.01 has no exact binary form,
                      so exact f32 remainders would reject 19.99 % 0.01)
6     STR_MINLEN      (string)  size >= i0
7     STR_MAXLEN      (string)  size <= i0
8     ARR_MINLEN      (array)   size >= i0
9     ARR_MAXLEN      (array)   size <= i0
10    OBJ_MINPROPS    (object)  size >= i0
11    OBJ_MAXPROPS    (object)  size <= i0
12    STR_PREFIX      (string)  first i0 (<=8) bytes equal u0,u1 (big-endian)
13    STR_EQ          exact string equality via hash lanes (non-strings fail)
14    CONST_NULL      value is null
15    CONST_BOOL      value is boolean f0
16    CONST_NUM       value is number f0
17    STR_EQ_PRE      (string)  equality via hash lanes (non-strings pass)
18    OBJ_HAS_SLOT    (object)  required-slot bit i0 is acquired, i.e. the
                      object defines the property wired to that slot
                      (conditional ``required`` inside logical applicators)
====  ==============  =======================================================

Rows sharing a nonzero ``asrt_group`` form an OR-group (``enum``); rows with
group 0 are ANDed individually with precondition semantics.  Within a CSR
window the AND rows come first and each OR-group is contiguous (the
executor's segmented-scan reduction relies on this).

Logical applicators (DESIGN.md §10): ``anyOf``/``oneOf``/``not``/``if``
(and the CISC ``When*`` conditions) over the scalar-assertion subset lower
into a per-tape **boolean group circuit**.  Each circuit node has a kind
(:data:`CK_AND`/:data:`CK_OR`/:data:`CK_XOR1`/:data:`CK_NOT`), an owner
location, and an optional parent node; assertion rows carry ``asrt_circ``
(-1 for plain rows) wiring them as leaves of their circuit node.  The
batched executor aggregates leaf rows per document (vacuously true when
the leaf's location has no node -- the tensor form of "absent target =>
instruction skipped"), reduces the circuit bottom-up with a bounded-depth
level sweep (``max_circ_depth`` levels, compile-time constant), gates
every node on its owner location's presence, and ANDs root-node values
into the document verdict.  Soundness requires each circuit-owning
location to be instantiated at most once per document, so circuits are
only lowered at *unique-path* locations (reached from the root purely via
property edges); applicators under ``items``/``additionalProperties``/
``prefixItems`` still raise :class:`UnsupportedForBatch`.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from .compiler import CompiledSchema
from .instructions import Instruction, Instructions, OpCode
from .nodetypes import TYPE_BIT
from .regex_opt import RegexKind

__all__ = [
    "LocationTape",
    "UnsupportedForBatch",
    "build_tape",
    "try_build_tape",
    "AOP",
    "LOC_FRONTIER",
    "DEFAULT_UNROLL_DEPTH",
    "DEFAULT_UNROLL_NODE_BUDGET",
    "CK_AND",
    "CK_OR",
    "CK_XOR1",
    "CK_NOT",
]


class UnsupportedForBatch(ValueError):
    """Schema uses DSL features outside the tensorised subset."""


# assertion op codes (mini-ISA)
class AOP:
    TYPE_MASK = 0
    NUM_GE = 1
    NUM_GT = 2
    NUM_LE = 3
    NUM_LT = 4
    NUM_MULTIPLE = 5
    STR_MINLEN = 6
    STR_MAXLEN = 7
    ARR_MINLEN = 8
    ARR_MAXLEN = 9
    OBJ_MINPROPS = 10
    OBJ_MAXPROPS = 11
    STR_PREFIX = 12
    STR_EQ = 13
    CONST_NULL = 14
    CONST_BOOL = 15
    CONST_NUM = 16
    STR_EQ_PRE = 17
    OBJ_HAS_SLOT = 18


# circuit-node kinds (DESIGN.md §10)
CK_AND = 0  # all leaves and children true (a branch conjunction)
CK_OR = 1  # any child true (anyOf)
CK_XOR1 = 2  # exactly one child true (oneOf)
CK_NOT = 3  # negation of the conjunction of leaves and children (not)


# special location ids
LOC_UNTRACKED = -2  # no constraints below this point
LOC_INVALID = -3  # reaching this location fails the document
LOC_FRONTIER = -4  # the unroll budget ran out here: document undecided

# $ref-recursion unrolling budgets (DESIGN.md §9): levels of label
# re-expansion beyond the first, and a cap on total locations so
# branching recursion (trees with many recursive children) cannot blow
# the tape up exponentially -- the budget simply converts into earlier
# frontiers, i.e. more sequential-oracle routing, never wrong verdicts.
DEFAULT_UNROLL_DEPTH = 4
DEFAULT_UNROLL_NODE_BUDGET = 4096

# type code bits (shared canonical codes, see core.nodetypes)
_TYPE_BIT = TYPE_BIT


@dataclass
class _Loc:
    """Mutable per-location build state."""

    index: int
    props: Dict[str, int] = field(default_factory=dict)  # key -> prop row
    closed: bool = False
    addl_loc: int = -1  # location for unmatched properties (-1: none)
    item_loc: int = -1
    item_start: int = 0
    prefix_locs: List[int] = field(default_factory=list)
    # key -> acquired-bit slot.  A slot exists for every key whose presence
    # is *observed* (hard ``required`` or conditional requiredness inside a
    # circuit); only ``hard_keys`` enter ``loc_required_mask``.
    required_slots: Dict[str, int] = field(default_factory=dict)
    hard_keys: Set[str] = field(default_factory=set)
    frontier: bool = False  # a label expansion ran out of budget here
    # instantiated at most once per document (root, or reached purely via
    # property edges) -- the soundness precondition for circuit owners
    unique: bool = True
    # property-routing scopes, enforced at build() time (exempt keys keep
    # their route; other keys snap to LOC_INVALID under a closed object,
    # or must re-route to / raise against an additionalProperties scope)
    closed_exempt: Optional[Set[str]] = None
    addl_exempt: Optional[Set[str]] = None
    # provenance for first-failure attribution (DESIGN.md §12):
    # key -> source schema path of the requiring keyword, and the path of
    # the closing (additionalProperties: false) scope
    required_paths: Dict[str, str] = field(default_factory=dict)
    closed_path: str = ""


@dataclass
class LocationTape:
    """Flat tensor form of a compiled (structural-subset) schema.

    Assertion rows are stored **owner-sorted** (by ``(owner, group)``):
    each location's rows occupy the contiguous CSR window
    ``[loc_asrt_start[l], loc_asrt_start[l] + loc_asrt_len[l])``, with the
    AND rows (group 0) first and each enum OR-group contiguous after them.
    ``max_rows_per_loc`` (compile-time constant, "A-hat") bounds every
    window, so the batched executor can evaluate a dense (nodes x A-hat)
    gather instead of the full (nodes x A) matrix.

    The property-transition table additionally carries a **hash-sorted
    view** (``psort_*``): rows sorted lexicographically by their 8 hash
    lanes, so all rows sharing one key hash form a contiguous run.  One
    owner-blind ``hash_match`` per node finds the run start; the run is at
    most ``max_hash_run`` (K) rows, and per-depth location propagation
    reduces to an owner-equality check over those K candidates.
    """

    n_locations: int
    max_loc_depth: int  # longest root path in the location DAG
    # property transition rows (original emission order)
    prop_owner: np.ndarray  # int32 (M,)
    prop_hash: np.ndarray  # uint32 (M, 8)
    prop_child_loc: np.ndarray  # int32 (M,)
    prop_required_slot: np.ndarray  # int32 (M,)  -1 = not required
    # hash-sorted view of the property table (candidate-set hashing)
    psort_hash: np.ndarray  # uint32 (M, 8) lexicographically sorted lanes
    psort_owner: np.ndarray  # int32 (M,)
    psort_child_loc: np.ndarray  # int32 (M,)
    psort_required_slot: np.ndarray  # int32 (M,)
    psort_orig_row: np.ndarray  # int32 (M,) original row index (tie-break)
    psort_run_len: np.ndarray  # int32 (M,) length of the equal-hash run
    max_hash_run: int  # K: max rows sharing one key hash
    # per-location
    loc_closed: np.ndarray  # bool (L,)
    loc_addl: np.ndarray  # int32 (L,)  unmatched-property location / -1
    loc_item: np.ndarray  # int32 (L,)
    loc_item_start: np.ndarray  # int32 (L,)
    loc_prefix_start: np.ndarray  # int32 (L,)
    loc_prefix_len: np.ndarray  # int32 (L,)
    prefix_loc: np.ndarray  # int32 (P,)
    loc_required_mask: np.ndarray  # uint32 (L,)
    # assertion rows, owner-sorted CSR (see class docstring)
    loc_asrt_start: np.ndarray  # int32 (L,) window start per location
    loc_asrt_len: np.ndarray  # int32 (L,) window length per location
    max_rows_per_loc: int  # A-hat: max window length over locations
    asrt_owner: np.ndarray  # int32 (A,)
    asrt_op: np.ndarray  # int32 (A,)
    asrt_group: np.ndarray  # int32 (A,)  0 = AND row, else OR-group id
    asrt_f0: np.ndarray  # float64 (A,)
    asrt_i0: np.ndarray  # int32 (A,)
    asrt_i1: np.ndarray  # int32 (A,)
    asrt_u0: np.ndarray  # uint32 (A,)
    asrt_u1: np.ndarray  # uint32 (A,)
    asrt_hash: np.ndarray  # uint32 (A, 8)
    # -- multi-tenant linking (registry/linker.py) ----------------------
    # A single-schema tape is the one-member degenerate case: member 0,
    # root location 0.  A *linked* tape concatenates S relocated member
    # tapes; ``roots[s]`` seeds each document's root location from its
    # schema id, and the hash-sorted property view keeps per-member
    # segments (``member_prop_start/len``; rows tagged ``psort_member``
    # for introspection) so the executor's hash pass never matches
    # across members (runs never span members by construction).
    # ``member_horizons[s]`` keeps each member's own propagation horizon
    # (max_loc_depth + 1) so per-document ``decided`` stays bit-identical
    # to single-tape dispatch even when members disagree on depth.
    psort_member: Optional[np.ndarray] = None  # int32 (M,)
    roots: Optional[np.ndarray] = None  # int32 (S,)
    member_horizons: Optional[np.ndarray] = None  # int32 (S,)
    # per-member psort segment windows: member s's hash-sorted rows are
    # [member_prop_start[s], member_prop_start[s] + member_prop_len[s]).
    # ``max_member_props`` (M-hat) bounds them, so the linked executor's
    # hash pass scans the largest member, not the member *sum*.
    member_prop_start: Optional[np.ndarray] = None  # int32 (S,)
    member_prop_len: Optional[np.ndarray] = None  # int32 (S,)
    max_member_props: Optional[int] = None  # M-hat
    # -- $ref-recursion unrolling (DESIGN.md §9) ------------------------
    # ``loc_frontier[l]`` marks locations where the unroll budget ran
    # out; every transition edge into them already carries the
    # LOC_FRONTIER sentinel (so the executor needs no extra gather), the
    # bool array is kept for introspection, linking and static skips.
    loc_frontier: Optional[np.ndarray] = None  # bool (L,)
    unroll_depth: int = 0  # budget used at build time (0: no labels)
    # -- logical-applicator circuits (DESIGN.md §10) --------------------
    # ``asrt_circ[a]`` wires assertion row ``a`` to a circuit node as a
    # leaf (-1: plain row).  Circuit nodes are stored parents-first
    # (``circ_parent[c] < c`` for non-roots); ``circ_level`` is the
    # bottom-up evaluation level (leaf-only nodes at level 0), bounded by
    # the compile-time ``max_circ_depth``.
    asrt_circ: Optional[np.ndarray] = None  # int32 (A,)
    circ_kind: Optional[np.ndarray] = None  # int32 (C,)  CK_* codes
    circ_parent: Optional[np.ndarray] = None  # int32 (C,)  -1 = root
    circ_owner: Optional[np.ndarray] = None  # int32 (C,)  owner location
    circ_level: Optional[np.ndarray] = None  # int32 (C,)
    max_circ_depth: int = 0
    # -- provenance sidecars for first-failure attribution (DESIGN.md §12)
    # Host-side only (tuples, never shipped to the device): the source
    # schema path per assertion row (aligned with the owner-sorted order),
    # per-location required-slot provenance ((slot, key, path) triples),
    # the path of the closing scope per location, and the path of the
    # originating applicator per circuit node.
    asrt_path: Optional[Tuple[str, ...]] = None  # (A,)
    loc_required_info: Optional[Tuple[Tuple[Tuple[int, str, str], ...], ...]] = None  # (L,)
    loc_closed_path: Optional[Tuple[str, ...]] = None  # (L,)
    circ_path: Optional[Tuple[str, ...]] = None  # (C,)

    def __post_init__(self) -> None:
        if self.psort_member is None:
            self.psort_member = np.zeros(len(self.psort_owner), np.int32)
        if self.roots is None:
            self.roots = np.zeros(1, np.int32)
        if self.member_horizons is None:
            self.member_horizons = np.array([self.max_loc_depth + 1], np.int32)
        if self.member_prop_start is None:
            self.member_prop_start = np.zeros(len(self.roots), np.int32)
        if self.member_prop_len is None:
            n_real = int(np.count_nonzero(self.prop_owner >= 0))
            self.member_prop_len = np.full(len(self.roots), n_real, np.int32)
        if self.max_member_props is None:
            self.max_member_props = int(self.member_prop_len.max()) if len(self.member_prop_len) else 0
        if self.loc_frontier is None:
            self.loc_frontier = np.zeros(len(self.loc_closed), bool)
        if self.asrt_circ is None:
            self.asrt_circ = np.full(len(self.asrt_owner), -1, np.int32)
        if self.circ_kind is None:
            self.circ_kind = np.zeros(0, np.int32)
        if self.circ_parent is None:
            self.circ_parent = np.zeros(0, np.int32)
        if self.circ_owner is None:
            self.circ_owner = np.zeros(0, np.int32)
        if self.circ_level is None:
            self.circ_level = np.zeros(0, np.int32)
        if self.asrt_path is None:
            self.asrt_path = ("",) * len(self.asrt_owner)
        if self.loc_required_info is None:
            self.loc_required_info = ((),) * len(self.loc_closed)
        if self.loc_closed_path is None:
            self.loc_closed_path = ("",) * len(self.loc_closed)
        if self.circ_path is None:
            self.circ_path = ("",) * len(self.circ_kind)

    @property
    def n_props(self) -> int:
        return len(self.prop_owner)

    @property
    def n_assertions(self) -> int:
        return len(self.asrt_owner)

    @property
    def n_members(self) -> int:
        return len(self.roots)

    @property
    def n_frontier(self) -> int:
        return int(np.count_nonzero(self.loc_frontier))

    @property
    def n_circuits(self) -> int:
        return len(self.circ_kind)


class _TapeBuilder:
    def __init__(
        self,
        labels: Optional[Dict[int, Instructions]] = None,
        *,
        unroll_depth: int = DEFAULT_UNROLL_DEPTH,
        unroll_node_budget: int = DEFAULT_UNROLL_NODE_BUDGET,
    ) -> None:
        self.locs: List[_Loc] = []
        self.prop_rows: List[Tuple[int, np.ndarray, int, int]] = []
        self.asrt_rows: List[dict] = []
        self._group_counter = 0
        self.labels: Dict[int, Instructions] = dict(labels or {})
        self.unroll_depth = max(1, int(unroll_depth))
        self.unroll_node_budget = int(unroll_node_budget)
        # active expansions per label along the current lowering path --
        # the cycle detector.  A label already on the stack more than
        # ``unroll_depth`` times stops expanding and marks a frontier.
        self._label_stack: Dict[int, int] = {}
        # logical-applicator circuit nodes (DESIGN.md §10); rows emitted
        # while ``_circ_ctx >= 0`` become leaves of that circuit node
        self.circ_kind: List[int] = []
        self.circ_parent: List[int] = []
        self.circ_owner: List[int] = []
        self.circ_path: List[str] = []
        self._circ_ctx: int = -1
        # source schema path of the instruction currently lowering --
        # synthesized instructions (empty schema_path) inherit the
        # enclosing applicator's path (DESIGN.md §12)
        self._cur_path: str = ""

    # -- circuits (DESIGN.md §10) --------------------------------------

    def new_circ(self, kind: int, loc: _Loc, parent: Optional[int] = None) -> int:
        cid = len(self.circ_kind)
        self.circ_kind.append(kind)
        self.circ_parent.append(self._circ_ctx if parent is None else parent)
        self.circ_owner.append(loc.index)
        self.circ_path.append(self._cur_path)
        return cid

    def circuit_group(self, instructions: Instructions, loc: _Loc, node: int) -> None:
        """Lower ``instructions`` at ``loc`` as inputs of circuit ``node``."""
        prev = self._circ_ctx
        self._circ_ctx = node
        try:
            self.add_group(instructions, loc)
        finally:
            self._circ_ctx = prev

    def check_circuit_site(self, loc: _Loc, kw: str) -> None:
        if not loc.unique:
            raise UnsupportedForBatch(
                f"{kw} under items/prefixItems/additionalProperties not "
                "batchable (owner location is not a unique instance path)"
            )

    def lower_condition(
        self,
        loc: _Loc,
        condition: Instructions,
        then_children: Instructions,
        else_children: Instructions,
    ) -> None:
        """``if c then t else e`` == OR(AND(c, t), AND(NOT(c), e))."""
        node = self.new_circ(CK_OR, loc)
        then_branch = self.new_circ(CK_AND, loc, parent=node)
        self.circuit_group(condition, loc, then_branch)
        self.circuit_group(then_children, loc, then_branch)
        else_branch = self.new_circ(CK_AND, loc, parent=node)
        negated = self.new_circ(CK_NOT, loc, parent=else_branch)
        self.circuit_group(condition, loc, negated)
        self.circuit_group(else_children, loc, else_branch)

    # -- label unrolling (DESIGN.md §9) --------------------------------

    def expand_label(self, label: int, loc: _Loc) -> None:
        """Expand ``label``'s body at ``loc``, bounded by the budgets.

        Each re-expansion along one lowering path clones the label's
        location subgraph one level deeper (property-transition rows of
        level *d* wire to the level *d+1* clones because every
        ``child_for_key`` call allocates fresh locations).  When either
        budget runs out, ``loc`` becomes a *frontier* location instead:
        documents reaching it are undecided, never vacuously valid.
        """
        children = self.labels.get(label)
        if children is None:
            raise UnsupportedForBatch(f"jump to unknown label {label}")
        depth = self._label_stack.get(label, 0)
        if depth > self.unroll_depth or len(self.locs) >= self.unroll_node_budget:
            loc.frontier = True
            return
        self._label_stack[label] = depth + 1
        try:
            self.add_group(children, loc)
        finally:
            self._label_stack[label] = depth

    # -- locations -----------------------------------------------------

    def new_loc(self, *, unique: bool = True) -> _Loc:
        loc = _Loc(index=len(self.locs), unique=unique)
        self.locs.append(loc)
        return loc

    def child_for_key(self, loc: _Loc, key: str) -> _Loc:
        if key in loc.props:
            row = loc.props[key]
            child_idx = self.prop_rows[row][2]
            if child_idx >= 0:
                return self.locs[child_idx]
            # upgrade an untracked (required-only) row to a real location
            child = self.new_loc(unique=loc.unique)
            owner, lanes, _, slot = self.prop_rows[row]
            self.prop_rows[row] = (owner, lanes, child.index, slot)
            return child
        from ..data.doc_table import key_lanes

        child = self.new_loc(unique=loc.unique)
        row = len(self.prop_rows)
        self.prop_rows.append((loc.index, key_lanes(key), child.index, -1))
        loc.props[key] = row
        return child

    def require_key(self, loc: _Loc, key: str, *, hard: bool = True) -> int:
        """Allocate (or look up) the key's acquired-bit slot.

        ``hard`` marks the key unconditionally required (it enters
        ``loc_required_mask``); conditional requiredness inside circuits
        only needs the slot so :data:`AOP.OBJ_HAS_SLOT` can observe it.
        """
        if hard:
            loc.hard_keys.add(key)
        loc.required_paths.setdefault(key, self._cur_path)
        if key in loc.required_slots:
            return loc.required_slots[key]
        slot = len(loc.required_slots)
        if slot >= 32:
            raise UnsupportedForBatch(">32 required properties at one location")
        loc.required_slots[key] = slot
        if key in loc.props:
            row = loc.props[key]
            owner, lanes, child, _ = self.prop_rows[row]
            self.prop_rows[row] = (owner, lanes, child, slot)
        else:
            from ..data.doc_table import key_lanes

            row = len(self.prop_rows)
            self.prop_rows.append((loc.index, key_lanes(key), LOC_UNTRACKED, slot))
            loc.props[key] = row
        return slot

    # -- assertion rows ---------------------------------------------------

    def row(self, loc: _Loc, op: int, *, f0=0.0, i0=0, i1=0, u0=0, u1=0, lanes=None, group=0):
        self.asrt_rows.append(
            dict(
                owner=loc.index,
                op=op,
                group=group,
                circ=self._circ_ctx,
                f0=float(f0),
                i0=int(i0),
                i1=int(i1),
                u0=int(u0),
                u1=int(u1),
                lanes=np.zeros(8, np.uint32) if lanes is None else lanes,
                path=self._cur_path,
            )
        )

    def next_group(self) -> int:
        self._group_counter += 1
        return self._group_counter

    # -- instruction lowering -----------------------------------------------

    def add_group(self, instructions: Instructions, loc: _Loc) -> None:
        for inst in instructions:
            self.add(inst, loc)

    def descend(self, loc: _Loc, rel_path) -> _Loc:
        for tok in rel_path:
            if not isinstance(tok, str):
                raise UnsupportedForBatch("integer instance paths not batchable")
            loc = self.child_for_key(loc, tok)
        return loc

    def add(self, inst: Instruction, loc: _Loc) -> None:
        target = self.descend(loc, inst.rel_path)
        op = inst.op
        if self._circ_ctx >= 0 and op not in _CIRCUIT_OPS:
            raise UnsupportedForBatch(
                f"instruction {op.name} inside a logical applicator not batchable"
            )
        handler = _HANDLERS.get(op)
        if handler is None:
            raise UnsupportedForBatch(f"instruction {op.name} not batchable")
        prev_path = self._cur_path
        if inst.schema_path:
            self._cur_path = inst.schema_path
        try:
            handler(self, inst, target)
        finally:
            self._cur_path = prev_path

    # -- finalize ------------------------------------------------------------

    def _note_closed(self, loc: _Loc, keys) -> None:
        ks = set(keys)
        loc.closed_exempt = ks if loc.closed_exempt is None else (loc.closed_exempt & ks)
        loc.closed = True
        if not loc.closed_path:
            loc.closed_path = self._cur_path

    def _note_addl_exempt(self, loc: _Loc, keys) -> None:
        ks = set(keys)
        loc.addl_exempt = ks if loc.addl_exempt is None else (loc.addl_exempt & ks)

    def _enforce_property_scopes(self) -> None:
        """Reconcile per-key routes with closed/additionalProperties scopes.

        A property row routes its key *away* from the location's unmatched
        rule, which is only sound for the keys the enclosing scope exempts
        (the adjacent ``properties``).  Rows that merely *observe* a key
        (required-only, ``LOC_UNTRACKED`` child) re-route to the scope's
        own rule: ``LOC_INVALID`` under a closed object (the key's very
        presence fails), the additionalProperties location otherwise.
        Rows with real child constraints under an additionalProperties
        scope would need the key validated against BOTH locations --
        inexpressible on the tape, so they fall back.  Runs before the
        frontier snap / depth DP: it is pure route rewriting.
        """
        for loc in self.locs:
            if loc.closed:
                exempt = loc.closed_exempt or set()
                for key, row in loc.props.items():
                    if key in exempt:
                        # a coexisting additionalProperties SCHEMA (e.g.
                        # allOf of a closed object and an addl scope)
                        # must also validate this key unless it exempts
                        # it too -- dual routing, inexpressible
                        if loc.addl_exempt is not None and key not in loc.addl_exempt:
                            raise UnsupportedForBatch(
                                f"property {key!r} is tolerated by a closed object "
                                "but also falls under an additionalProperties "
                                "schema (dual routing not batchable)"
                            )
                        continue
                    owner, lanes, _child, slot = self.prop_rows[row]
                    self.prop_rows[row] = (owner, lanes, LOC_INVALID, slot)
            elif loc.addl_loc >= 0 and loc.addl_exempt is not None:
                for key, row in loc.props.items():
                    if key in loc.addl_exempt:
                        continue
                    owner, lanes, child, slot = self.prop_rows[row]
                    if child == LOC_UNTRACKED:
                        self.prop_rows[row] = (owner, lanes, loc.addl_loc, slot)
                    elif child != loc.addl_loc:
                        raise UnsupportedForBatch(
                            f"property {key!r} has its own constraints while an "
                            "additionalProperties scope also applies to it "
                            "(dual routing not batchable)"
                        )

    def build(self) -> LocationTape:
        L = len(self.locs)
        self._enforce_property_scopes()
        # frontier locations (unroll budget exhausted): every transition
        # edge INTO one is snapped to the LOC_FRONTIER sentinel, so the
        # executor's ordinary negative-location propagation carries the
        # "undecided" mark down the whole subtree for free and the
        # frontier location itself (with its partial constraints) is
        # never entered.  Frontier subtrees are likewise excluded from
        # the depth DP, keeping the horizon tight.
        frontier_mask = np.array([l.frontier for l in self.locs] or [False], bool)

        def _snap(child: int) -> int:
            if child >= 0 and frontier_mask[child]:
                return LOC_FRONTIER
            return child

        prefix_loc: List[int] = []
        loc_prefix_start = np.zeros(L, np.int32)
        loc_prefix_len = np.zeros(L, np.int32)
        for loc in self.locs:
            loc_prefix_start[loc.index] = len(prefix_loc)
            loc_prefix_len[loc.index] = len(loc.prefix_locs)
            prefix_loc.extend(_snap(p) for p in loc.prefix_locs)
        M = max(1, len(self.prop_rows))
        prop_owner = np.full(M, -1, np.int32)
        prop_hash = np.zeros((M, 8), np.uint32)
        prop_child = np.full(M, LOC_UNTRACKED, np.int32)
        prop_slot = np.full(M, -1, np.int32)
        for r, (owner, lanes, child, slot) in enumerate(self.prop_rows):
            prop_owner[r] = owner
            prop_hash[r] = lanes
            prop_child[r] = _snap(child)
            prop_slot[r] = slot

        # hash-sorted view: rows sorted lexicographically by lanes so equal
        # key hashes form contiguous runs (candidate sets for the single
        # owner-blind hash_match pass).  Lane 0 is the primary sort key.
        if self.prop_rows:
            order = np.lexsort(tuple(prop_hash[:, k] for k in range(7, -1, -1)))
            order = order.astype(np.int32)
            psort_hash = prop_hash[order]
            new_run = np.ones(M, bool)
            new_run[1:] = np.any(psort_hash[1:] != psort_hash[:-1], axis=1)
            run_id = np.cumsum(new_run) - 1
            run_sizes = np.bincount(run_id)
            psort_run_len = run_sizes[run_id].astype(np.int32)
            max_hash_run = int(run_sizes.max())
        else:
            order = np.zeros(1, np.int32)
            psort_hash = prop_hash
            psort_run_len = np.zeros(M, np.int32)
            max_hash_run = 0

        # longest root path in the location DAG: all transition edges point
        # to later-created locations, so one ascending DP pass suffices.
        # Nodes deeper than max_loc_depth + 1 can only be untracked or
        # under an already-invalid ancestor -- the executor truncates its
        # propagation loop there (compile-time depth knowledge).
        dist = np.zeros(max(1, L), np.int64)
        children: List[List[int]] = [[] for _ in range(L)]
        for owner, _lanes, child, _slot in self.prop_rows:
            if child >= 0 and not frontier_mask[child]:
                children[owner].append(child)
        for loc in self.locs:
            for v in (loc.addl_loc, loc.item_loc):
                if v >= 0 and not frontier_mask[v]:
                    children[loc.index].append(v)
            children[loc.index].extend(
                p for p in loc.prefix_locs if not frontier_mask[p]
            )
        for u in range(L):
            for v in children[u]:
                if v > u:
                    dist[v] = max(dist[v], dist[u] + 1)
        max_loc_depth = int(dist.max())

        # owner-sorted CSR assertion windows: stable sort by (owner, group)
        # keeps AND rows (group 0) first and every OR-group contiguous
        asrt_rows = self.asrt_rows
        if asrt_rows:
            a_owner = np.array([r["owner"] for r in asrt_rows], np.int32)
            a_group = np.array([r["group"] for r in asrt_rows], np.int32)
            a_order = np.lexsort((a_group, a_owner))
            asrt_rows = [asrt_rows[i] for i in a_order]
            sorted_owner = a_owner[a_order]
            loc_asrt_len = np.bincount(sorted_owner, minlength=L).astype(np.int32)
            loc_asrt_start = np.concatenate(
                [[0], np.cumsum(loc_asrt_len[:-1])]
            ).astype(np.int32)
            max_rows_per_loc = int(loc_asrt_len.max())
        else:
            loc_asrt_len = np.zeros(max(1, L), np.int32)
            loc_asrt_start = np.zeros(max(1, L), np.int32)
            max_rows_per_loc = 0

        # circuit-node levels, bottom-up (a child always has a larger id
        # than its parent, so one descending pass finalizes every level)
        C = len(self.circ_kind)
        circ_level = np.zeros(C, np.int32)
        for c in range(C - 1, -1, -1):
            p = self.circ_parent[c]
            if p >= 0 and circ_level[p] <= circ_level[c]:
                circ_level[p] = circ_level[c] + 1

        tape = LocationTape(
            n_locations=L,
            max_loc_depth=max_loc_depth,
            prop_owner=prop_owner,
            prop_hash=prop_hash,
            prop_child_loc=prop_child,
            prop_required_slot=prop_slot,
            psort_hash=psort_hash,
            psort_owner=prop_owner[order],
            psort_child_loc=prop_child[order],
            psort_required_slot=prop_slot[order],
            psort_orig_row=order,
            psort_run_len=psort_run_len,
            max_hash_run=max_hash_run,
            loc_asrt_start=loc_asrt_start,
            loc_asrt_len=loc_asrt_len,
            max_rows_per_loc=max_rows_per_loc,
            loc_closed=np.array([l.closed for l in self.locs] or [False], bool),
            loc_addl=np.array(
                [_snap(l.addl_loc) for l in self.locs] or [-1], np.int32
            ),
            loc_item=np.array(
                [_snap(l.item_loc) for l in self.locs] or [-1], np.int32
            ),
            loc_item_start=np.array([l.item_start for l in self.locs] or [0], np.int32),
            loc_prefix_start=loc_prefix_start if L else np.zeros(1, np.int32),
            loc_prefix_len=loc_prefix_len if L else np.zeros(1, np.int32),
            prefix_loc=np.array(prefix_loc or [-1], np.int32),
            loc_required_mask=np.array(
                [
                    sum(1 << l.required_slots[k] for k in l.hard_keys)
                    for l in self.locs
                ]
                or [0],
                np.uint32,
            ),
            asrt_owner=np.array([r["owner"] for r in asrt_rows] or [-1], np.int32),
            asrt_op=np.array([r["op"] for r in asrt_rows] or [0], np.int32),
            asrt_group=np.array([r["group"] for r in asrt_rows] or [0], np.int32),
            asrt_f0=np.array([r["f0"] for r in asrt_rows] or [0.0], np.float64),
            asrt_i0=np.array([r["i0"] for r in asrt_rows] or [0], np.int32),
            asrt_i1=np.array([r["i1"] for r in asrt_rows] or [0], np.int32),
            asrt_u0=np.array([r["u0"] for r in asrt_rows] or [0], np.uint32),
            asrt_u1=np.array([r["u1"] for r in asrt_rows] or [0], np.uint32),
            asrt_hash=np.stack([r["lanes"] for r in asrt_rows] or [np.zeros(8, np.uint32)]),
            asrt_circ=np.array([r["circ"] for r in asrt_rows] or [-1], np.int32),
            circ_kind=np.asarray(self.circ_kind, dtype=np.int32),
            circ_parent=np.asarray(self.circ_parent, dtype=np.int32),
            circ_owner=np.asarray(self.circ_owner, dtype=np.int32),
            circ_level=circ_level,
            max_circ_depth=int(circ_level.max()) if C else 0,
            loc_frontier=frontier_mask,
            unroll_depth=self.unroll_depth if self.labels else 0,
            # provenance sidecars (DESIGN.md §12); ``asrt_rows`` is already
            # in the owner-sorted order, so the path tuple aligns with the
            # CSR row arrays
            asrt_path=tuple(r.get("path", "") for r in asrt_rows) or ("",),
            loc_required_info=tuple(
                tuple(
                    sorted(
                        (slot, key, l.required_paths.get(key, ""))
                        for key, slot in l.required_slots.items()
                    )
                )
                for l in self.locs
            )
            or ((),),
            loc_closed_path=tuple(l.closed_path for l in self.locs) or ("",),
            circ_path=tuple(self.circ_path),
        )
        return tape


# ---------------------------------------------------------------------------
# Per-instruction lowering handlers
# ---------------------------------------------------------------------------


def _type_row(b: _TapeBuilder, loc: _Loc, types: Tuple[str, ...]) -> None:
    mask = 0
    for t in types:
        if t == "integer":
            mask |= _TYPE_BIT["number"]
        else:
            mask |= _TYPE_BIT[t]
    ints_only = "integer" in types and "number" not in types
    b.row(loc, AOP.TYPE_MASK, i0=mask, i1=1 if ints_only else 0)


def _h_type(b, inst, loc):
    _type_row(b, loc, (inst.type,))


def _h_type_any(b, inst, loc):
    _type_row(b, loc, inst.types)


def _scalar_const_row(b: _TapeBuilder, loc: _Loc, value: Any, group: int) -> None:
    from ..data.doc_table import key_lanes

    if value is None:
        b.row(loc, AOP.CONST_NULL, group=group)
    elif isinstance(value, bool):
        b.row(loc, AOP.CONST_BOOL, f0=1.0 if value else 0.0, group=group)
    elif isinstance(value, (int, float)):
        b.row(loc, AOP.CONST_NUM, f0=float(value), group=group)
    elif isinstance(value, str):
        b.row(loc, AOP.STR_EQ, lanes=key_lanes(value), group=group)
    else:
        raise UnsupportedForBatch("const/enum of arrays/objects not batchable")


def _h_equal(b, inst, loc):
    group = b.next_group()
    _scalar_const_row(b, loc, inst.value, group)


def _h_equals_any(b, inst, loc):
    group = b.next_group()
    for v in inst.values:
        _scalar_const_row(b, loc, v, group)


def _h_fail(b, inst, loc):
    # an impossible assertion: type in empty mask
    b.row(loc, AOP.TYPE_MASK, i0=0)


def _h_number(b, inst, loc):
    op = inst.op
    if op is OpCode.GREATER:
        b.row(loc, AOP.NUM_GT, f0=inst.bound)
    elif op is OpCode.GREATER_EQUAL:
        b.row(loc, AOP.NUM_GE, f0=inst.bound)
    elif op is OpCode.LESS:
        b.row(loc, AOP.NUM_LT, f0=inst.bound)
    elif op is OpCode.LESS_EQUAL:
        b.row(loc, AOP.NUM_LE, f0=inst.bound)
    elif op is OpCode.DIVISIBLE:
        b.row(loc, AOP.NUM_MULTIPLE, f0=inst.divisor)
    elif op is OpCode.NUMBER_BOUNDS:
        if inst.lo is not None:
            b.row(loc, AOP.NUM_GT if inst.lo_exclusive else AOP.NUM_GE, f0=inst.lo)
        if inst.hi is not None:
            b.row(loc, AOP.NUM_LT if inst.hi_exclusive else AOP.NUM_LE, f0=inst.hi)


def _h_string_size(b, inst, loc):
    if inst.op is OpCode.STRING_SIZE_GREATER:
        b.row(loc, AOP.STR_MINLEN, i0=inst.bound)
    else:
        b.row(loc, AOP.STR_MAXLEN, i0=inst.bound)


def _h_string_bounds(b, inst, loc):
    b.row(loc, AOP.STR_MINLEN, i0=inst.min_len)
    if inst.max_len is not None:
        b.row(loc, AOP.STR_MAXLEN, i0=inst.max_len)


def _h_regex(b, inst, loc):
    plan = inst.plan
    if plan.kind is RegexKind.ALL:
        return
    if plan.kind is RegexKind.NON_EMPTY:
        b.row(loc, AOP.STR_MINLEN, i0=1)
        return
    if plan.kind is RegexKind.LENGTH_RANGE:
        b.row(loc, AOP.STR_MINLEN, i0=plan.min_len)
        if plan.max_len is not None:
            b.row(loc, AOP.STR_MAXLEN, i0=plan.max_len)
        return
    if plan.kind is RegexKind.EXACT:
        from ..data.doc_table import key_lanes

        # preconditioned form: non-strings skip (pattern semantics)
        b.row(loc, AOP.STR_EQ_PRE, lanes=key_lanes(plan.literal))
        return
    if plan.kind is RegexKind.PREFIX:
        data = plan.literal.encode("utf-8")
        if len(data) > 8:
            raise UnsupportedForBatch("prefix >8 bytes not batchable")
        padded = data.ljust(8, b"\x00")
        b.row(
            loc,
            AOP.STR_PREFIX,
            i0=len(data),
            u0=int.from_bytes(padded[:4], "big"),
            u1=int.from_bytes(padded[4:], "big"),
        )
        return
    raise UnsupportedForBatch(f"regex kind {plan.kind} not batchable")


def _h_array_size(b, inst, loc):
    if inst.op is OpCode.ARRAY_SIZE_GREATER:
        b.row(loc, AOP.ARR_MINLEN, i0=inst.bound)
    else:
        b.row(loc, AOP.ARR_MAXLEN, i0=inst.bound)


def _h_array_bounds(b, inst, loc):
    b.row(loc, AOP.ARR_MINLEN, i0=inst.min_len)
    if inst.max_len is not None:
        b.row(loc, AOP.ARR_MAXLEN, i0=inst.max_len)


def _h_object_size(b, inst, loc):
    if inst.op is OpCode.OBJECT_SIZE_GREATER:
        b.row(loc, AOP.OBJ_MINPROPS, i0=inst.bound)
    else:
        b.row(loc, AOP.OBJ_MAXPROPS, i0=inst.bound)


def _require_row(b, loc, key):
    """Lower one requiredness fact: a hard required-slot bit outside
    circuits, an :data:`AOP.OBJ_HAS_SLOT` leaf row inside them."""
    if b._circ_ctx >= 0:
        slot = b.require_key(loc, key, hard=False)
        b.row(loc, AOP.OBJ_HAS_SLOT, i0=slot)
    else:
        b.require_key(loc, key)


def _h_defines(b, inst, loc):
    _require_row(b, loc, inst.key)


def _h_defines_all(b, inst, loc):
    for key in inst.keys:
        _require_row(b, loc, key)


def _h_property_type(b, inst, loc):
    _require_row(b, loc, inst.key)
    child = b.child_for_key(loc, inst.key)
    _type_row(b, child, (inst.type,))


def _h_loop_properties_match(b, inst, loc, closed=False):
    if closed and b._circ_ctx >= 0:
        raise UnsupportedForBatch(
            "additionalProperties: false inside a logical applicator not batchable"
        )
    if closed and getattr(inst, "tolerate_patterns", ()):  # patterns need key text
        for p in inst.tolerate_patterns:
            raise UnsupportedForBatch("patternProperties tolerance not batchable")
    for key, _h, group in inst.matches:
        child = b.child_for_key(loc, key)
        b.add_group(group, child)
    if closed:
        b._note_closed(loc, (key for key, _h, _grp in inst.matches))


def _h_loop_properties_match_closed(b, inst, loc):
    _h_loop_properties_match(b, inst, loc, closed=True)


def _h_loop_properties(b, inst, loc):
    # every property validates against children: model as the addl location
    if loc.addl_loc >= 0:
        addl = b.locs[loc.addl_loc]
    else:
        addl = b.new_loc(unique=False)
        loc.addl_loc = addl.index
    # no key is exempt from this scope: every property row at this
    # location must reconcile with it (enforced at build())
    b._note_addl_exempt(loc, ())
    b.add_group(inst.children, addl)


def _h_loop_properties_except(b, inst, loc):
    if inst.exclude_patterns:
        raise UnsupportedForBatch("patternProperties exclusion not batchable")
    # excluded keys must exist as prop rows so unmatched -> addl
    for key in inst.exclude_keys:
        b.child_for_key(loc, key)
    addl = b.new_loc(unique=False)
    if loc.addl_loc >= 0:
        raise UnsupportedForBatch("multiple additionalProperties scopes")
    loc.addl_loc = addl.index
    b._note_addl_exempt(loc, inst.exclude_keys)
    b.add_group(inst.children, addl)


def _h_loop_items(b, inst, loc):
    if loc.item_loc >= 0:
        item = b.locs[loc.item_loc]
    else:
        item = b.new_loc(unique=False)
        loc.item_loc = item.index
        loc.item_start = 0
    b.add_group(inst.children, item)


def _h_loop_items_from(b, inst, loc):
    if loc.item_loc >= 0:
        raise UnsupportedForBatch("conflicting items scopes")
    item = b.new_loc(unique=False)
    loc.item_loc = item.index
    loc.item_start = inst.start
    b.add_group(inst.children, item)


def _h_array_prefix(b, inst, loc):
    if loc.prefix_locs:
        raise UnsupportedForBatch("conflicting prefixItems scopes")
    for group in inst.groups:
        child = b.new_loc(unique=False)
        loc.prefix_locs.append(child.index)
        b.add_group(group, child)


def _h_control_label(b, inst, loc):
    # shared/recursive definitions: the body expands in place, and any
    # jumps back to this label re-expand through the bounded unroller
    b.labels.setdefault(inst.label, inst.children)
    b.expand_label(inst.label, loc)


def _h_control_jump(b, inst, loc):
    b.expand_label(inst.label, loc)


# -- logical applicators -> circuit nodes (DESIGN.md §10) -------------------


def _h_logical_and(b, inst, loc):
    # allOf == splice: same conjunction context, no new node needed
    b.add_group(inst.children, loc)


def _h_logical_or(b, inst, loc):
    b.check_circuit_site(loc, "anyOf")
    node = b.new_circ(CK_OR, loc)
    for group in inst.groups:
        branch = b.new_circ(CK_AND, loc, parent=node)
        b.circuit_group(group, loc, branch)


def _h_logical_xor(b, inst, loc):
    b.check_circuit_site(loc, "oneOf")
    node = b.new_circ(CK_XOR1, loc)
    for group in inst.groups:
        branch = b.new_circ(CK_AND, loc, parent=node)
        b.circuit_group(group, loc, branch)


def _h_logical_not(b, inst, loc):
    b.check_circuit_site(loc, "not")
    node = b.new_circ(CK_NOT, loc)
    b.circuit_group(inst.children, loc, node)


def _h_logical_condition(b, inst, loc):
    b.check_circuit_site(loc, "if")
    b.lower_condition(loc, inst.condition, inst.then_children, inst.else_children)


def _h_when_type(b, inst, loc):
    from .instructions import AssertionType

    b.check_circuit_site(loc, "if")
    b.lower_condition(loc, (AssertionType(type=inst.type),), inst.children, ())


def _h_when_defines(b, inst, loc):
    from .instructions import AssertionDefines, AssertionType

    b.check_circuit_site(loc, "dependentSchemas")
    condition = (
        AssertionType(type="object"),
        AssertionDefines(key=inst.key, key_hash=inst.key_hash),
    )
    b.lower_condition(loc, condition, inst.children, ())


def _h_when_array_size_greater(b, inst, loc):
    from .instructions import AssertionArraySizeGreater, AssertionType

    b.check_circuit_site(loc, "if")
    condition = (
        AssertionType(type="array"),
        AssertionArraySizeGreater(bound=inst.bound + 1),
    )
    b.lower_condition(loc, condition, inst.children, ())


def _h_when_array_size_equal(b, inst, loc):
    from .instructions import (
        AssertionArraySizeGreater,
        AssertionArraySizeLess,
        AssertionType,
    )

    b.check_circuit_site(loc, "if")
    condition = (
        AssertionType(type="array"),
        AssertionArraySizeGreater(bound=inst.bound),
        AssertionArraySizeLess(bound=inst.bound),
    )
    b.lower_condition(loc, condition, inst.children, ())


_HANDLERS = {
    OpCode.FAIL: _h_fail,
    OpCode.TYPE: _h_type,
    OpCode.TYPE_ANY: _h_type_any,
    OpCode.EQUAL: _h_equal,
    OpCode.EQUALS_ANY: _h_equals_any,
    OpCode.GREATER: _h_number,
    OpCode.GREATER_EQUAL: _h_number,
    OpCode.LESS: _h_number,
    OpCode.LESS_EQUAL: _h_number,
    OpCode.NUMBER_BOUNDS: _h_number,
    OpCode.DIVISIBLE: _h_number,
    OpCode.STRING_SIZE_GREATER: _h_string_size,
    OpCode.STRING_SIZE_LESS: _h_string_size,
    OpCode.STRING_BOUNDS: _h_string_bounds,
    OpCode.REGEX: _h_regex,
    OpCode.ARRAY_SIZE_GREATER: _h_array_size,
    OpCode.ARRAY_SIZE_LESS: _h_array_size,
    OpCode.ARRAY_BOUNDS: _h_array_bounds,
    OpCode.OBJECT_SIZE_GREATER: _h_object_size,
    OpCode.OBJECT_SIZE_LESS: _h_object_size,
    OpCode.DEFINES: _h_defines,
    OpCode.DEFINES_ALL: _h_defines_all,
    OpCode.PROPERTY_TYPE: _h_property_type,
    OpCode.LOOP_PROPERTIES_MATCH: _h_loop_properties_match,
    OpCode.LOOP_PROPERTIES_MATCH_CLOSED: _h_loop_properties_match_closed,
    OpCode.LOOP_PROPERTIES: _h_loop_properties,
    OpCode.LOOP_PROPERTIES_EXCEPT: _h_loop_properties_except,
    OpCode.LOOP_ITEMS: _h_loop_items,
    OpCode.LOOP_ITEMS_FROM: _h_loop_items_from,
    OpCode.ARRAY_PREFIX: _h_array_prefix,
    OpCode.CONTROL_LABEL: _h_control_label,
    OpCode.CONTROL_JUMP: _h_control_jump,
    OpCode.AND: _h_logical_and,
    OpCode.OR: _h_logical_or,
    OpCode.XOR: _h_logical_xor,
    OpCode.NOT: _h_logical_not,
    OpCode.CONDITION: _h_logical_condition,
    OpCode.WHEN_TYPE: _h_when_type,
    OpCode.WHEN_DEFINES: _h_when_defines,
    OpCode.WHEN_ARRAY_SIZE_GREATER: _h_when_array_size_greater,
    OpCode.WHEN_ARRAY_SIZE_EQUAL: _h_when_array_size_equal,
}

# instructions lowerable INSIDE a circuit branch: scalar assertion rows
# (possibly at property-descended child locations), conditional
# requiredness, per-key property groups, and nested logical applicators.
# Anything else (item loops, additionalProperties scopes, $ref labels,
# propertyNames, contains, uniqueItems, ...) raises with a precise reason
# so `fallback_reasons()` can name the offending construct.
_CIRCUIT_OPS = frozenset(
    {
        OpCode.FAIL,
        OpCode.TYPE,
        OpCode.TYPE_ANY,
        OpCode.EQUAL,
        OpCode.EQUALS_ANY,
        OpCode.GREATER,
        OpCode.GREATER_EQUAL,
        OpCode.LESS,
        OpCode.LESS_EQUAL,
        OpCode.NUMBER_BOUNDS,
        OpCode.DIVISIBLE,
        OpCode.STRING_SIZE_GREATER,
        OpCode.STRING_SIZE_LESS,
        OpCode.STRING_BOUNDS,
        OpCode.REGEX,
        OpCode.ARRAY_SIZE_GREATER,
        OpCode.ARRAY_SIZE_LESS,
        OpCode.ARRAY_BOUNDS,
        OpCode.OBJECT_SIZE_GREATER,
        OpCode.OBJECT_SIZE_LESS,
        OpCode.DEFINES,
        OpCode.DEFINES_ALL,
        OpCode.PROPERTY_TYPE,
        OpCode.LOOP_PROPERTIES_MATCH,
        # handler raises its own precise "additionalProperties: false
        # inside a logical applicator" reason
        OpCode.LOOP_PROPERTIES_MATCH_CLOSED,
        OpCode.AND,
        OpCode.OR,
        OpCode.XOR,
        OpCode.NOT,
        OpCode.CONDITION,
        OpCode.WHEN_TYPE,
        OpCode.WHEN_DEFINES,
        OpCode.WHEN_ARRAY_SIZE_GREATER,
        OpCode.WHEN_ARRAY_SIZE_EQUAL,
    }
)


def build_tape(
    compiled: CompiledSchema,
    *,
    unroll_depth: int = DEFAULT_UNROLL_DEPTH,
    unroll_node_budget: int = DEFAULT_UNROLL_NODE_BUDGET,
) -> LocationTape:
    """Lower a compiled schema to the tensor tape; raises
    :class:`UnsupportedForBatch` outside the structural subset.

    Shared and recursive ``$ref`` labels (``ControlLabel``/``ControlJump``)
    are unrolled into the flat tape up to ``unroll_depth`` re-expansions
    per label (and ``unroll_node_budget`` total locations); past the
    budget the lowering marks *frontier* locations whose documents the
    batched executor flags undecided (DESIGN.md §9).
    """
    b = _TapeBuilder(
        compiled.labels,
        unroll_depth=unroll_depth,
        unroll_node_budget=unroll_node_budget,
    )
    root = b.new_loc()
    b.add_group(compiled.instructions, root)
    tape = b.build()
    if os.environ.get("REPRO_LINT_TAPES"):
        # structural-invariant linter (DESIGN.md §15); lazy import --
        # analysis sits above core in the layering
        from ..analysis.lint_tape import assert_tape

        assert_tape(tape, label="build_tape")
    return tape


def try_build_tape(
    compiled: CompiledSchema,
    *,
    unroll_depth: int = DEFAULT_UNROLL_DEPTH,
    unroll_node_budget: int = DEFAULT_UNROLL_NODE_BUDGET,
) -> Tuple[Optional[LocationTape], str]:
    """Build the tape or report why the schema is not batchable."""
    try:
        return (
            build_tape(
                compiled,
                unroll_depth=unroll_depth,
                unroll_node_budget=unroll_node_budget,
            ),
            "",
        )
    except UnsupportedForBatch as exc:
        return None, str(exc)
