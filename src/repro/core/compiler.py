"""JSON Schema -> validation-DSL compiler (Blaze §3, optimizations §4).

The compiler walks a schema once per reachable subschema and emits
instruction sequences.  Keywords are handled by tier:

* **independent** keywords (assertions + independent applicators, §3.1)
  compile in isolation and are then *reordered* cheapest-first (§4.4 -- the
  fail-fast ordering);
* **first-level dependent** keywords (``additionalProperties``, ``items``)
  have their dependencies on adjacent keywords resolved *statically* so the
  emitted instructions are again order-free (§3.2.1);
* **second-level dependent** keywords (``unevaluatedProperties`` /
  ``unevaluatedItems``) get a static coverage analysis that eliminates the
  annotation machinery whenever the evaluated set is statically determined
  (§3.2.2); only genuinely branch-dependent schemas keep a dynamic residue
  instruction, and those are pinned to the end of the sequence.

Optimizations implemented with the paper's exact heuristics:

* unrolling: properties unroll when <=5 properties or >=1/4 required, and
  always directly under ``oneOf``/``anyOf`` (§4.2);
* reference inlining: non-recursive ``$ref`` destinations used <=5 times are
  inlined, others get ControlLabel/ControlJump (§3.3/§4.2);
* regex specialization (§4.3, see regex_opt.py);
* instruction reordering by static cost (§4.4);
* CISC fusion: StringBounds/NumberBounds/ArrayBounds, singleton
  Equals/Type/Defines, ``When*`` condition variants (§2.5, Table 2);
* static elision of assertions made redundant by ``type`` (§3.1.1) and of
  no-op applicators (``contains`` with ``minContains: 0``, boolean
  ``additionalProperties: true``, ...).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from .hashing import shash
from .instructions import (
    ArrayPrefix,
    AssertionArrayBounds,
    AssertionArraySizeGreater,
    AssertionArraySizeLess,
    AssertionDefines,
    AssertionDefinesAll,
    AssertionDivisible,
    AssertionEqual,
    AssertionEqualsAny,
    AssertionFail,
    AssertionGreater,
    AssertionGreaterEqual,
    AssertionLess,
    AssertionLessEqual,
    AssertionNumberBounds,
    AssertionObjectSizeGreater,
    AssertionObjectSizeLess,
    AssertionPropertyDependencies,
    AssertionPropertyType,
    AssertionRegex,
    AssertionStringBounds,
    AssertionStringSizeGreater,
    AssertionStringSizeLess,
    AssertionStringType,
    AssertionType,
    AssertionTypeAny,
    AssertionUnique,
    ControlJump,
    ControlLabel,
    Instruction,
    Instructions,
    LogicalAnd,
    LogicalCondition,
    LogicalNot,
    LogicalOr,
    LogicalXor,
    LoopContains,
    LoopItems,
    LoopItemsFrom,
    LoopKeys,
    LoopProperties,
    LoopPropertiesExcept,
    LoopPropertiesMatch,
    LoopPropertiesMatchClosed,
    LoopPropertiesRegex,
    LoopUnevaluatedItems,
    LoopUnevaluatedProperties,
    WhenArraySizeEqual,
    WhenArraySizeGreater,
    WhenDefines,
    WhenType,
)
from .json_pointer import InstancePath, escape
from .regex_opt import RegexKind, RegexPlan, analyze_pattern
from .schema_resolver import Dialect, SchemaResolver

__all__ = ["CompilerOptions", "CompiledSchema", "compile_schema", "SchemaCompileError"]


class SchemaCompileError(ValueError):
    pass


@dataclass(frozen=True)
class CompilerOptions:
    """Optimization switches (all on by default; the ablation benchmark of
    §6.2.3 turns them off one at a time)."""

    unroll: bool = True
    regex_specialize: bool = True
    reorder: bool = True
    cisc: bool = True
    elide: bool = True
    inline_ref_limit: int = 5
    unroll_property_limit: int = 5
    unroll_required_fraction: float = 0.25
    format_assertion: bool = False


@dataclass
class CompiledSchema:
    """The compilation artifact: a flat instruction sequence + label table."""

    instructions: Instructions
    labels: Dict[int, Instructions]
    options: CompilerOptions
    dialect: Dialect
    source: Any = None
    # label id -> resolved $ref key, for diagnostics (tape unrolling
    # reports and fallback reasons name the offending definition)
    label_names: Dict[int, str] = field(default_factory=dict)

    def instruction_count(self) -> int:
        from .instructions import walk

        seen = list(walk(self.instructions))
        for group in self.labels.values():
            seen.extend(walk(group))
        return len(seen)


# JSON types asserted by each keyword, for §3.1.1 static elision.
_NUMERIC = frozenset(("number", "integer"))
_TYPES_ALL = frozenset(("null", "boolean", "object", "array", "number", "integer", "string"))


def _json_types_of_const(value: Any) -> FrozenSet[str]:
    if value is None:
        return frozenset(("null",))
    if isinstance(value, bool):
        return frozenset(("boolean",))
    if isinstance(value, int):
        return frozenset(("integer", "number"))
    if isinstance(value, float):
        return frozenset(("number", "integer")) if value.is_integer() else frozenset(("number",))
    if isinstance(value, str):
        return frozenset(("string",))
    if isinstance(value, list):
        return frozenset(("array",))
    return frozenset(("object",))


@dataclass
class _Coverage:
    """Static property-coverage analysis result for unevaluatedProperties."""

    names: Set[str] = field(default_factory=set)
    patterns: List[RegexPlan] = field(default_factory=list)
    sees_all: bool = False
    # (guard schema chain, names, patterns, sees_all)
    branches: List[Tuple[Tuple[Any, ...], Set[str], List[RegexPlan], bool]] = field(
        default_factory=list
    )


@dataclass
class _ItemCoverage:
    """Static item-coverage analysis for unevaluatedItems."""

    prefix: int = 0
    sees_all: bool = False
    branches: List[Tuple[Tuple[Any, ...], int, bool]] = field(default_factory=list)
    # (guard schema chain, contains schema): annotations from a ``contains``
    # nested in a branch apply only when that branch validates
    contains_schemas: List[Tuple[Tuple[Any, ...], Any]] = field(default_factory=list)


class _Compiler:
    def __init__(self, resolver: SchemaResolver, options: CompilerOptions):
        self.resolver = resolver
        self.options = options
        self.dialect = resolver.dialect
        self.labels: Dict[int, Instructions] = {}
        self._label_ids: Dict[str, int] = {}
        self._label_done: Set[str] = set()
        self._ref_stack: List[str] = []
        self._ref_uses: Dict[str, int] = {}
        self._recursive_refs: Set[str] = set()
        self._analyze_refs()

    # ------------------------------------------------------------------
    # Reference analysis (§3.3): count uses, find cycles.
    # ------------------------------------------------------------------

    def _analyze_refs(self) -> None:
        stack: List[str] = []
        visited: Set[int] = set()

        def visit(schema: Any, base: str) -> None:
            if not isinstance(schema, (dict, list)):
                return
            if isinstance(schema, list):
                for item in schema:
                    visit(item, base)
                return
            sid = schema.get("$id")
            if isinstance(sid, str) and sid:
                from urllib.parse import urljoin

                base = urljoin(base, sid)
            for kw in ("$ref", "$dynamicRef", "$recursiveRef"):
                ref = schema.get(kw)
                if not isinstance(ref, str):
                    continue
                try:
                    if kw == "$ref":
                        resolved = self.resolver.resolve(ref, base)
                    elif kw == "$dynamicRef":
                        resolved = self.resolver.resolve_dynamic(ref, base)
                    else:
                        resolved = self.resolver.resolve_recursive(base)
                except KeyError:
                    continue
                self._ref_uses[resolved.key] = self._ref_uses.get(resolved.key, 0) + 1
                if resolved.key in stack:
                    # every destination on the current chain participates in
                    # the cycle and needs a label
                    for k in stack[stack.index(resolved.key):]:
                        self._recursive_refs.add(k)
                    self._recursive_refs.add(resolved.key)
                    continue
                marker = id(resolved.schema)
                stack.append(resolved.key)
                if (marker, resolved.key) not in self._seen_pairs:
                    self._seen_pairs.add((marker, resolved.key))
                    visit(resolved.schema, resolved.base_uri)
                stack.pop()
            for key, value in schema.items():
                if key in ("enum", "const", "default", "examples"):
                    continue
                visit(value, base)

        self._seen_pairs: Set[Tuple[int, str]] = set()
        visit(self.resolver.root, self.resolver.root_base)

    def _needs_label(self, key: str) -> bool:
        if key in self._recursive_refs:
            return True
        limit = self.options.inline_ref_limit if self.options.unroll else 0
        return self._ref_uses.get(key, 0) > limit

    def _label_id(self, key: str) -> int:
        if key not in self._label_ids:
            self._label_ids[key] = len(self._label_ids) + 1
        return self._label_ids[key]

    # ------------------------------------------------------------------
    # Entry point
    # ------------------------------------------------------------------

    def compile_root(self) -> Instructions:
        return tuple(
            self.compile(self.resolver.root, self.resolver.root_base, "", in_disjunction=False)
        )

    # ------------------------------------------------------------------
    # Subschema compilation
    # ------------------------------------------------------------------

    def compile(
        self,
        schema: Any,
        base: str,
        schema_path: str,
        *,
        in_disjunction: bool = False,
    ) -> List[Instruction]:
        """Compile one subschema into an instruction list (rel_path = ())."""
        if schema is True or schema == {}:
            return []
        if schema is False:
            return [AssertionFail(schema_path=schema_path)]
        if not isinstance(schema, dict):
            raise SchemaCompileError(f"schema must be bool or object at {schema_path!r}")

        from urllib.parse import urljoin

        sid = schema.get("$id")
        if isinstance(sid, str) and sid:
            base = urljoin(base, sid)

        opts = self.options
        out: List[Instruction] = []
        pinned_last: List[Instruction] = []  # second-level dependents

        allowed = self._allowed_types(schema)

        # --- references -------------------------------------------------
        for kw in ("$ref", "$dynamicRef", "$recursiveRef"):
            ref = schema.get(kw)
            if not isinstance(ref, str):
                continue
            out.extend(self._compile_ref(kw, ref, base, f"{schema_path}/{kw}"))

        # --- type / const / enum ----------------------------------------
        out.extend(self._compile_type(schema, schema_path, allowed))
        if "const" in schema:
            out.append(AssertionEqual(value=schema["const"], schema_path=f"{schema_path}/const"))
        if "enum" in schema:
            values = schema["enum"]
            if opts.cisc and len(values) == 1:
                out.append(AssertionEqual(value=values[0], schema_path=f"{schema_path}/enum"))
            else:
                out.append(
                    AssertionEqualsAny(values=tuple(values), schema_path=f"{schema_path}/enum")
                )

        # --- independent assertions per type -----------------------------
        out.extend(self._compile_number(schema, schema_path, allowed))
        out.extend(self._compile_string(schema, schema_path, allowed))
        out.extend(self._compile_object_assertions(schema, schema_path, allowed))
        out.extend(self._compile_array_assertions(schema, schema_path, allowed))

        # --- applicators --------------------------------------------------
        out.extend(self._compile_object_applicators(schema, base, schema_path, allowed, in_disjunction))
        out.extend(self._compile_array_applicators(schema, base, schema_path, allowed))
        out.extend(self._compile_logical(schema, base, schema_path, in_disjunction))
        out.extend(self._compile_conditionals(schema, base, schema_path))

        # --- second-level dependents (always last, §3.2.2) ----------------
        pinned_last.extend(self._compile_unevaluated_properties(schema, base, schema_path))
        pinned_last.extend(self._compile_unevaluated_items(schema, base, schema_path))

        if opts.reorder:
            out.sort(key=lambda inst: inst.cost())
        return out + pinned_last

    # ------------------------------------------------------------------
    # References
    # ------------------------------------------------------------------

    def _compile_ref(self, kw: str, ref: str, base: str, schema_path: str) -> List[Instruction]:
        if kw == "$ref":
            resolved = self.resolver.resolve(ref, base)
        elif kw == "$dynamicRef":
            resolved = self.resolver.resolve_dynamic(ref, base)
        else:
            resolved = self.resolver.resolve_recursive(base)
        key = resolved.key
        if not self._needs_label(key):
            if key in self._ref_stack:  # safety net: inline recursion guard
                self._recursive_refs.add(key)
            else:
                self._ref_stack.append(key)
                try:
                    return self.compile(resolved.schema, resolved.base_uri, schema_path)
                finally:
                    self._ref_stack.pop()
        label = self._label_id(key)
        if key in self._label_done or key in self._ref_stack:
            return [ControlJump(label=label, schema_path=schema_path)]
        self._label_done.add(key)
        self._ref_stack.append(key)
        try:
            children = tuple(self.compile(resolved.schema, resolved.base_uri, schema_path))
        finally:
            self._ref_stack.pop()
        self.labels[label] = children
        return [ControlLabel(label=label, children=children, schema_path=schema_path)]

    # ------------------------------------------------------------------
    # type / allowed-type lattice
    # ------------------------------------------------------------------

    def _allowed_types(self, schema: Dict[str, Any]) -> FrozenSet[str]:
        """Types a value may have and still satisfy this schema level --
        used for §3.1.1 elision of redundant assertions."""
        if not self.options.elide:
            return _TYPES_ALL
        allowed: FrozenSet[str] = _TYPES_ALL
        t = schema.get("type")
        if isinstance(t, str):
            allowed = frozenset((t,))
        elif isinstance(t, list):
            allowed = frozenset(t)
        if "integer" in allowed and "number" not in allowed:
            pass  # integers only
        elif "number" in allowed:
            allowed = allowed | frozenset(("integer",))
        if "const" in schema:
            allowed = allowed & _json_types_of_const(schema["const"])
        elif "enum" in schema:
            enum_types: FrozenSet[str] = frozenset()
            for v in schema["enum"]:
                enum_types = enum_types | _json_types_of_const(v)
            allowed = allowed & enum_types
        return allowed

    def _compile_type(
        self, schema: Dict[str, Any], schema_path: str, allowed: FrozenSet[str]
    ) -> List[Instruction]:
        t = schema.get("type")
        path = f"{schema_path}/type"
        if isinstance(t, str):
            return [AssertionType(type=t, schema_path=path)]
        if isinstance(t, list):
            if self.options.cisc and len(t) == 1:
                return [AssertionType(type=t[0], schema_path=path)]
            if t:
                return [AssertionTypeAny(types=tuple(t), schema_path=path)]
        return []

    # ------------------------------------------------------------------
    # Numbers
    # ------------------------------------------------------------------

    def _compile_number(
        self, schema: Dict[str, Any], schema_path: str, allowed: FrozenSet[str]
    ) -> List[Instruction]:
        if self.options.elide and not (allowed & _NUMERIC):
            return []  # §3.1.1: numeric assertions are redundant
        out: List[Instruction] = []
        lo: Optional[float] = None
        lo_exc = False
        hi: Optional[float] = None
        hi_exc = False
        if self.dialect is Dialect.DRAFT4:
            if "minimum" in schema:
                lo = schema["minimum"]
                lo_exc = schema.get("exclusiveMinimum") is True
            if "maximum" in schema:
                hi = schema["maximum"]
                hi_exc = schema.get("exclusiveMaximum") is True
        else:
            if "minimum" in schema:
                lo, lo_exc = schema["minimum"], False
            if isinstance(schema.get("exclusiveMinimum"), (int, float)) and not isinstance(
                schema.get("exclusiveMinimum"), bool
            ):
                em = schema["exclusiveMinimum"]
                if lo is None or em >= lo:
                    lo, lo_exc = em, True
            if "maximum" in schema:
                hi, hi_exc = schema["maximum"], False
            if isinstance(schema.get("exclusiveMaximum"), (int, float)) and not isinstance(
                schema.get("exclusiveMaximum"), bool
            ):
                eM = schema["exclusiveMaximum"]
                if hi is None or eM <= hi:
                    hi, hi_exc = eM, True

        if lo is not None and hi is not None and self.options.cisc:
            out.append(
                AssertionNumberBounds(
                    lo=lo, lo_exclusive=lo_exc, hi=hi, hi_exclusive=hi_exc, schema_path=schema_path
                )
            )
        else:
            if lo is not None:
                cls = AssertionGreater if lo_exc else AssertionGreaterEqual
                out.append(cls(bound=lo, schema_path=f"{schema_path}/minimum"))
            if hi is not None:
                cls = AssertionLess if hi_exc else AssertionLessEqual
                out.append(cls(bound=hi, schema_path=f"{schema_path}/maximum"))
        if "multipleOf" in schema:
            out.append(
                AssertionDivisible(
                    divisor=schema["multipleOf"], schema_path=f"{schema_path}/multipleOf"
                )
            )
        return out

    # ------------------------------------------------------------------
    # Strings
    # ------------------------------------------------------------------

    def _compile_string(
        self, schema: Dict[str, Any], schema_path: str, allowed: FrozenSet[str]
    ) -> List[Instruction]:
        if self.options.elide and "string" not in allowed:
            return []
        out: List[Instruction] = []
        min_len = schema.get("minLength")
        max_len = schema.get("maxLength")
        only_string = allowed == frozenset(("string",))
        if (
            self.options.cisc
            and only_string
            and min_len is not None
            and max_len is not None
        ):
            # StringBounds fuses the type check (§2.5); the separate
            # AssertionType emitted for "type" stays (it is the actual type
            # assertion); the fusion here avoids two separate length ops.
            out.append(
                AssertionStringBounds(min_len=min_len, max_len=max_len, schema_path=schema_path)
            )
        else:
            if min_len is not None:
                out.append(
                    AssertionStringSizeGreater(bound=min_len, schema_path=f"{schema_path}/minLength")
                )
            if max_len is not None:
                out.append(
                    AssertionStringSizeLess(bound=max_len, schema_path=f"{schema_path}/maxLength")
                )
        if "pattern" in schema:
            plan = analyze_pattern(schema["pattern"], enabled=self.options.regex_specialize)
            inst = self._pattern_assertion(plan, f"{schema_path}/pattern")
            if inst is not None:
                out.append(inst)
        if self.options.format_assertion and isinstance(schema.get("format"), str):
            out.append(
                AssertionStringType(format=schema["format"], schema_path=f"{schema_path}/format")
            )
        return out

    def _pattern_assertion(self, plan: RegexPlan, schema_path: str) -> Optional[Instruction]:
        if plan.kind is RegexKind.ALL:
            return None  # §4.3: .* accepts everything -- drop the check
        if plan.kind is RegexKind.NON_EMPTY:
            return AssertionStringSizeGreater(bound=1, schema_path=schema_path)
        if plan.kind is RegexKind.LENGTH_RANGE:
            if plan.max_len is None:
                return AssertionStringSizeGreater(bound=plan.min_len, schema_path=schema_path)
            return AssertionStringBounds(
                min_len=plan.min_len, max_len=plan.max_len, schema_path=schema_path
            )
        return AssertionRegex(plan=plan, schema_path=schema_path)

    # ------------------------------------------------------------------
    # Object assertions
    # ------------------------------------------------------------------

    def _compile_object_assertions(
        self, schema: Dict[str, Any], schema_path: str, allowed: FrozenSet[str]
    ) -> List[Instruction]:
        if self.options.elide and "object" not in allowed:
            return []
        out: List[Instruction] = []
        required = schema.get("required")
        if isinstance(required, list) and required:
            if self.options.cisc and len(required) == 1:
                key = required[0]
                # PropertyType fusion: required + properties.<key>.type only
                child = schema.get("properties", {}).get(key) if isinstance(
                    schema.get("properties"), dict
                ) else None
                if (
                    isinstance(child, dict)
                    and set(child.keys()) == {"type"}
                    and isinstance(child["type"], str)
                ):
                    out.append(
                        AssertionPropertyType(
                            key=key,
                            key_hash=shash(key),
                            type=child["type"],
                            schema_path=f"{schema_path}/required",
                        )
                    )
                    # NOTE: marks the property as handled for 'properties'
                    self._fused_property_types.add((id(schema), key))
                else:
                    out.append(
                        AssertionDefines(
                            key=key, key_hash=shash(key), schema_path=f"{schema_path}/required"
                        )
                    )
            else:
                keys = tuple(dict.fromkeys(required))
                out.append(
                    AssertionDefinesAll(
                        keys=keys,
                        key_hashes=tuple(shash(k) for k in keys),
                        schema_path=f"{schema_path}/required",
                    )
                )
        if "minProperties" in schema:
            out.append(
                AssertionObjectSizeGreater(
                    bound=schema["minProperties"], schema_path=f"{schema_path}/minProperties"
                )
            )
        if "maxProperties" in schema:
            out.append(
                AssertionObjectSizeLess(
                    bound=schema["maxProperties"], schema_path=f"{schema_path}/maxProperties"
                )
            )
        deps = self._dependent_required(schema)
        if deps:
            out.append(
                AssertionPropertyDependencies(
                    dependencies=tuple(
                        (k, shash(k), tuple(v), tuple(shash(x) for x in v)) for k, v in deps
                    ),
                    schema_path=f"{schema_path}/dependentRequired",
                )
            )
        return out

    def _dependent_required(self, schema: Dict[str, Any]) -> List[Tuple[str, List[str]]]:
        out: List[Tuple[str, List[str]]] = []
        dr = schema.get("dependentRequired")
        if isinstance(dr, dict):
            out.extend((k, list(v)) for k, v in dr.items() if isinstance(v, list))
        legacy = schema.get("dependencies")
        if isinstance(legacy, dict):
            out.extend((k, list(v)) for k, v in legacy.items() if isinstance(v, list))
        return out

    def _dependent_schemas(self, schema: Dict[str, Any]) -> List[Tuple[str, Any]]:
        out: List[Tuple[str, Any]] = []
        ds = schema.get("dependentSchemas")
        if isinstance(ds, dict):
            out.extend(ds.items())
        legacy = schema.get("dependencies")
        if isinstance(legacy, dict):
            out.extend((k, v) for k, v in legacy.items() if not isinstance(v, list))
        return out

    # ------------------------------------------------------------------
    # Array assertions
    # ------------------------------------------------------------------

    def _compile_array_assertions(
        self, schema: Dict[str, Any], schema_path: str, allowed: FrozenSet[str]
    ) -> List[Instruction]:
        if self.options.elide and "array" not in allowed:
            return []
        out: List[Instruction] = []
        min_items = schema.get("minItems")
        max_items = schema.get("maxItems")
        if self.options.cisc and min_items is not None and max_items is not None:
            out.append(
                AssertionArrayBounds(min_len=min_items, max_len=max_items, schema_path=schema_path)
            )
        else:
            if min_items is not None:
                out.append(
                    AssertionArraySizeGreater(bound=min_items, schema_path=f"{schema_path}/minItems")
                )
            if max_items is not None:
                out.append(
                    AssertionArraySizeLess(bound=max_items, schema_path=f"{schema_path}/maxItems")
                )
        if schema.get("uniqueItems") is True:
            out.append(AssertionUnique(schema_path=f"{schema_path}/uniqueItems"))
        return out

    # ------------------------------------------------------------------
    # Object applicators (properties / patternProperties /
    # additionalProperties / propertyNames / dependentSchemas)
    # ------------------------------------------------------------------

    _fused_property_types: Set[Tuple[int, str]] = set()

    def _compile_object_applicators(
        self,
        schema: Dict[str, Any],
        base: str,
        schema_path: str,
        allowed: FrozenSet[str],
        in_disjunction: bool,
    ) -> List[Instruction]:
        if self.options.elide and "object" not in allowed:
            return []
        out: List[Instruction] = []
        opts = self.options

        props: Dict[str, Any] = schema.get("properties") or {}
        pat_props: Dict[str, Any] = schema.get("patternProperties") or {}
        addl = schema.get("additionalProperties")
        if self.dialect in (Dialect.DRAFT4, Dialect.DRAFT6, Dialect.DRAFT7):
            pass  # same keyword names apply

        pattern_plans = {
            pat: analyze_pattern(pat, enabled=opts.regex_specialize) for pat in pat_props
        }

        # patternProperties -> one loop per pattern
        for pat, subschema in pat_props.items():
            children = tuple(
                self.compile(subschema, base, f"{schema_path}/patternProperties/{escape(pat)}")
            )
            plan = pattern_plans[pat]
            if not children:
                continue
            if plan.kind is RegexKind.ALL:
                out.append(
                    LoopProperties(children=children, schema_path=f"{schema_path}/patternProperties")
                )
            else:
                out.append(
                    LoopPropertiesRegex(
                        plan=plan,
                        children=children,
                        schema_path=f"{schema_path}/patternProperties/{escape(pat)}",
                    )
                )

        required = set(schema.get("required") or ())
        prop_items: List[Tuple[str, Any]] = [
            (k, v)
            for k, v in props.items()
            if (id(schema), k) not in self._fused_property_types
        ]

        closed = addl is False

        if closed:
            # LoopPropertiesMatchClosed: every instance key must match.
            matches = tuple(
                (
                    k,
                    shash(k),
                    tuple(self.compile(v, base, f"{schema_path}/properties/{escape(k)}")),
                )
                for k, v in props.items()
            )
            out.append(
                LoopPropertiesMatchClosed(
                    matches=matches,
                    tolerate_patterns=tuple(pattern_plans.values()),
                    schema_path=f"{schema_path}/additionalProperties",
                )
            )
        elif prop_items:
            unrolled = opts.unroll and (
                in_disjunction
                or len(prop_items) <= opts.unroll_property_limit
                or (len(prop_items) > 0 and len(required & set(props)) / len(prop_items) >= opts.unroll_required_fraction)
            )
            if unrolled:
                for k, v in prop_items:
                    children = self.compile(v, base, f"{schema_path}/properties/{escape(k)}")
                    out.extend(_prefix(children, (k,)))
            else:
                matches = tuple(
                    (
                        k,
                        shash(k),
                        tuple(self.compile(v, base, f"{schema_path}/properties/{escape(k)}")),
                    )
                    for k, v in prop_items
                )
                out.append(
                    LoopPropertiesMatch(matches=matches, schema_path=f"{schema_path}/properties")
                )

        # additionalProperties as a schema (not boolean)
        if isinstance(addl, dict) or addl is True:
            if addl is not True:  # `true` -> no instructions (§3.2.1)
                children = tuple(
                    self.compile(addl, base, f"{schema_path}/additionalProperties")
                )
                if children:
                    if props or pat_props:
                        keys = tuple(props.keys())
                        out.append(
                            LoopPropertiesExcept(
                                exclude_keys=keys,
                                exclude_hashes=tuple(shash(k) for k in keys),
                                exclude_patterns=tuple(pattern_plans.values()),
                                children=children,
                                schema_path=f"{schema_path}/additionalProperties",
                            )
                        )
                    else:
                        out.append(
                            LoopProperties(
                                children=children,
                                schema_path=f"{schema_path}/additionalProperties",
                            )
                        )

        # propertyNames
        pn = schema.get("propertyNames")
        if pn is not None:
            children = tuple(self.compile(pn, base, f"{schema_path}/propertyNames"))
            if pn is False:
                out.append(
                    AssertionObjectSizeLess(bound=0, schema_path=f"{schema_path}/propertyNames")
                )
            elif children:
                out.append(LoopKeys(children=children, schema_path=f"{schema_path}/propertyNames"))

        # dependentSchemas (+ legacy schema-form dependencies) -> WhenDefines
        for key, subschema in self._dependent_schemas(schema):
            children = tuple(
                self.compile(subschema, base, f"{schema_path}/dependentSchemas/{escape(key)}")
            )
            if not children:
                continue
            if opts.cisc:
                out.append(
                    WhenDefines(
                        key=key,
                        key_hash=shash(key),
                        children=children,
                        schema_path=f"{schema_path}/dependentSchemas/{escape(key)}",
                    )
                )
            else:
                out.append(
                    LogicalCondition(
                        condition=(
                            AssertionType(type="object"),
                            AssertionDefines(key=key, key_hash=shash(key)),
                        ),
                        then_children=children,
                        schema_path=f"{schema_path}/dependentSchemas/{escape(key)}",
                    )
                )
        return out

    # ------------------------------------------------------------------
    # Array applicators (prefixItems / items / contains)
    # ------------------------------------------------------------------

    def _compile_array_applicators(
        self, schema: Dict[str, Any], base: str, schema_path: str, allowed: FrozenSet[str]
    ) -> List[Instruction]:
        if self.options.elide and "array" not in allowed:
            return []
        out: List[Instruction] = []
        prefix_schemas, items_schema = self._split_items(schema)

        n_prefix = len(prefix_schemas)
        if prefix_schemas:
            groups = tuple(
                tuple(self.compile(s, base, f"{schema_path}/prefixItems/{i}"))
                for i, s in enumerate(prefix_schemas)
            )
            if any(groups):
                out.append(ArrayPrefix(groups=groups, schema_path=f"{schema_path}/prefixItems"))

        if items_schema is not None and items_schema is not True:
            if items_schema is False:
                # only the prefix may exist -> pure length check (elision)
                out.append(
                    AssertionArraySizeLess(bound=n_prefix, schema_path=f"{schema_path}/items")
                )
            else:
                children = tuple(self.compile(items_schema, base, f"{schema_path}/items"))
                if children:
                    if n_prefix:
                        out.append(
                            LoopItemsFrom(
                                start=n_prefix,
                                children=children,
                                schema_path=f"{schema_path}/items",
                            )
                        )
                    else:
                        out.append(LoopItems(children=children, schema_path=f"{schema_path}/items"))

        out.extend(self._compile_contains(schema, base, schema_path))
        return out

    def _split_items(self, schema: Dict[str, Any]) -> Tuple[List[Any], Any]:
        """Normalize dialect differences: returns (prefix schemas, tail schema)."""
        if self.dialect in (Dialect.DRAFT2019, Dialect.DRAFT2020):
            prefix = schema.get("prefixItems") or []
            items = schema.get("items")
            if self.dialect is Dialect.DRAFT2019 and isinstance(items, list):
                # 2019-09 still used array-form items
                return items, schema.get("additionalItems")
            return list(prefix), items
        items = schema.get("items")
        if isinstance(items, list):
            return items, schema.get("additionalItems")
        return [], items

    def _compile_contains(
        self, schema: Dict[str, Any], base: str, schema_path: str
    ) -> List[Instruction]:
        if "contains" not in schema:
            return []
        if self.dialect in (Dialect.DRAFT4,):
            return []  # contains introduced in draft 6
        sub = schema["contains"]
        min_c = schema.get("minContains", 1)
        max_c = schema.get("maxContains")
        if self.dialect in (Dialect.DRAFT6, Dialect.DRAFT7):
            min_c, max_c = 1, None  # min/maxContains are 2019-09+
        out: List[Instruction] = []
        if self.options.elide and min_c == 0 and max_c is None:
            return []  # §3.1.2: nothing to validate
        if self.options.elide and (sub is True or sub == {}):
            # §3.1.2: contains:true degenerates to array size checks
            if min_c > 0:
                out.append(
                    AssertionArraySizeGreater(bound=min_c, schema_path=f"{schema_path}/minContains")
                )
            if max_c is not None:
                out.append(
                    AssertionArraySizeLess(bound=max_c, schema_path=f"{schema_path}/maxContains")
                )
            return out
        children = tuple(self.compile(sub, base, f"{schema_path}/contains"))
        out.append(
            LoopContains(
                children=children,
                min_count=min_c,
                max_count=max_c,
                schema_path=f"{schema_path}/contains",
            )
        )
        return out

    # ------------------------------------------------------------------
    # Logical applicators
    # ------------------------------------------------------------------

    def _compile_logical(
        self, schema: Dict[str, Any], base: str, schema_path: str, in_disjunction: bool
    ) -> List[Instruction]:
        out: List[Instruction] = []
        all_of = schema.get("allOf")
        if isinstance(all_of, list):
            # AND of subschemas == splice inline (short-circuit preserved;
            # gives §4.4 reordering a flat view across branch boundaries)
            if self.options.cisc:
                for i, sub in enumerate(all_of):
                    out.extend(self.compile(sub, base, f"{schema_path}/allOf/{i}"))
            else:
                groups = [
                    tuple(self.compile(sub, base, f"{schema_path}/allOf/{i}"))
                    for i, sub in enumerate(all_of)
                ]
                out.append(
                    LogicalAnd(
                        children=tuple(itertools.chain.from_iterable(groups)),
                        schema_path=f"{schema_path}/allOf",
                    )
                )
        any_of = schema.get("anyOf")
        if isinstance(any_of, list):
            groups = tuple(
                tuple(self.compile(sub, base, f"{schema_path}/anyOf/{i}", in_disjunction=True))
                for i, sub in enumerate(any_of)
            )
            if self.options.reorder:
                groups = tuple(sorted(groups, key=_group_cost))
            if any(len(g) == 0 for g in groups):
                pass  # a `true` branch makes anyOf vacuous (§3.1.1 elision)
            else:
                out.append(LogicalOr(groups=groups, schema_path=f"{schema_path}/anyOf"))
        one_of = schema.get("oneOf")
        if isinstance(one_of, list):
            groups = tuple(
                tuple(self.compile(sub, base, f"{schema_path}/oneOf/{i}", in_disjunction=True))
                for i, sub in enumerate(one_of)
            )
            out.append(LogicalXor(groups=groups, schema_path=f"{schema_path}/oneOf"))
        not_schema = schema.get("not")
        if not_schema is not None:
            children = tuple(self.compile(not_schema, base, f"{schema_path}/not"))
            if not children:  # not:true / not:{} -> always fails
                out.append(AssertionFail(schema_path=f"{schema_path}/not"))
            elif len(children) == 1 and isinstance(children[0], AssertionFail):
                pass  # not:false -> always passes
            else:
                out.append(LogicalNot(children=children, schema_path=f"{schema_path}/not"))
        return out

    # ------------------------------------------------------------------
    # if / then / else
    # ------------------------------------------------------------------

    def _compile_conditionals(
        self, schema: Dict[str, Any], base: str, schema_path: str
    ) -> List[Instruction]:
        if self.dialect in (Dialect.DRAFT4, Dialect.DRAFT6):
            return []
        if "if" not in schema:
            return []  # then/else are ignored without if
        if_schema = schema["if"]
        then_schema = schema.get("then")
        else_schema = schema.get("else")
        if then_schema is None and else_schema is None:
            return []  # no effect (§3.1.2 minor optimization)
        then_children = (
            tuple(self.compile(then_schema, base, f"{schema_path}/then"))
            if then_schema is not None
            else ()
        )
        else_children = (
            tuple(self.compile(else_schema, base, f"{schema_path}/else"))
            if else_schema is not None
            else ()
        )
        condition = tuple(self.compile(if_schema, base, f"{schema_path}/if"))
        if not condition:  # if:true -> then applies unconditionally
            return list(then_children)
        if not then_children and not else_children:
            return []

        # Table 2 CISC specializations of LogicalCondition
        if self.options.cisc and isinstance(if_schema, dict):
            keys = set(if_schema.keys())
            if keys == {"type"} and isinstance(if_schema["type"], str) and not else_children:
                return [
                    WhenType(
                        type=if_schema["type"],
                        children=then_children,
                        schema_path=f"{schema_path}/if",
                    )
                ]
            if (
                keys == {"required"}
                and isinstance(if_schema["required"], list)
                and len(if_schema["required"]) == 1
                and not else_children
            ):
                key = if_schema["required"][0]
                return [
                    WhenDefines(
                        key=key,
                        key_hash=shash(key),
                        children=then_children,
                        schema_path=f"{schema_path}/if",
                    )
                ]
            if keys == {"minItems"} and not else_children:
                return [
                    WhenArraySizeGreater(
                        bound=if_schema["minItems"] - 1,
                        children=then_children,
                        schema_path=f"{schema_path}/if",
                    )
                ]
            if (
                keys == {"minItems", "maxItems"}
                and if_schema["minItems"] == if_schema["maxItems"]
                and not else_children
            ):
                return [
                    WhenArraySizeEqual(
                        bound=if_schema["minItems"],
                        children=then_children,
                        schema_path=f"{schema_path}/if",
                    )
                ]
        return [
            LogicalCondition(
                condition=condition,
                then_children=then_children,
                else_children=else_children,
                schema_path=f"{schema_path}/if",
            )
        ]

    # ------------------------------------------------------------------
    # unevaluatedProperties (§3.2.2)
    # ------------------------------------------------------------------

    def _collect_coverage(
        self,
        schema: Any,
        base: str,
        cov: _Coverage,
        guards: Tuple[Any, ...],
        seen: Set[int],
    ) -> None:
        """Static pass: which properties does ``schema`` evaluate?

        ``guards`` is the conjunction of branch schemas controlling whether
        this schema's annotations apply; empty = guaranteed.
        """
        if schema is True or schema is False or not isinstance(schema, dict):
            return
        if id(schema) in seen:
            return
        seen.add(id(schema))
        from urllib.parse import urljoin

        sid = schema.get("$id")
        if isinstance(sid, str) and sid:
            base = urljoin(base, sid)

        names: Set[str] = set(schema.get("properties", {}) or {})
        patterns = [
            analyze_pattern(p, enabled=self.options.regex_specialize)
            for p in (schema.get("patternProperties") or {})
        ]
        sees_all = (
            "additionalProperties" in schema or "unevaluatedProperties" in schema
        )
        if guards:
            if names or patterns or sees_all:
                cov.branches.append((guards, names, patterns, sees_all))
        else:
            cov.names |= names
            cov.patterns.extend(patterns)
            cov.sees_all = cov.sees_all or sees_all

        for kw in ("$ref", "$dynamicRef", "$recursiveRef"):
            ref = schema.get(kw)
            if isinstance(ref, str):
                try:
                    if kw == "$ref":
                        r = self.resolver.resolve(ref, base)
                    elif kw == "$dynamicRef":
                        r = self.resolver.resolve_dynamic(ref, base)
                    else:
                        r = self.resolver.resolve_recursive(base)
                    self._collect_coverage(r.schema, r.base_uri, cov, guards, seen)
                except KeyError:
                    pass
        for sub in schema.get("allOf") or []:
            self._collect_coverage(sub, base, cov, guards, seen)
        for sub in (schema.get("anyOf") or []) + (schema.get("oneOf") or []):
            self._collect_coverage(sub, base, cov, guards + (sub,), set(seen))
        if "if" in schema:
            if_s = schema["if"]
            self._collect_coverage(if_s, base, cov, guards + (if_s,), set(seen))
            if "then" in schema:
                self._collect_coverage(
                    schema["then"], base, cov, guards + (if_s,), set(seen)
                )
            if "else" in schema:
                self._collect_coverage(
                    schema["else"], base, cov, guards + ({"not": if_s},), set(seen)
                )
        for key, sub in self._dependent_schemas(schema):
            self._collect_coverage(
                sub, base, cov, guards + ({"required": [key]},), set(seen)
            )

    def _compile_unevaluated_properties(
        self, schema: Dict[str, Any], base: str, schema_path: str
    ) -> List[Instruction]:
        if self.dialect in (Dialect.DRAFT4, Dialect.DRAFT6, Dialect.DRAFT7):
            return []
        if "unevaluatedProperties" not in schema:
            return []
        sub = schema["unevaluatedProperties"]
        if sub is True or sub == {}:
            return []  # everything allowed -> no instructions (§3.2.2)

        cov = _Coverage()
        probe = dict(schema)
        probe.pop("unevaluatedProperties")
        self._collect_coverage(probe, base, cov, (), set())
        if cov.sees_all:
            return []  # statically: every property is evaluated

        children = tuple(
            self.compile(sub, base, f"{schema_path}/unevaluatedProperties")
        )
        if not children:
            return []

        spath = f"{schema_path}/unevaluatedProperties"
        if not cov.branches:
            # Fully static: compiles exactly like additionalProperties
            # against the statically-known evaluated set (§3.2.2).
            keys = tuple(sorted(cov.names))
            if not keys and not cov.patterns:
                return [LoopProperties(children=children, schema_path=spath)]
            return [
                LoopPropertiesExcept(
                    exclude_keys=keys,
                    exclude_hashes=tuple(shash(k) for k in keys),
                    exclude_patterns=tuple(cov.patterns),
                    children=children,
                    schema_path=spath,
                )
            ]
        # Dynamic residue: guards decide the evaluated set at runtime.
        branches = []
        for guards, names, patterns, sees_all in cov.branches:
            guard_instructions: List[Instruction] = []
            for g in guards:
                guard_instructions.extend(self.compile(g, base, spath + "/guard"))
            keys = tuple(sorted(names))
            branches.append(
                (
                    tuple(guard_instructions),
                    keys,
                    tuple(shash(k) for k in keys),
                    tuple(patterns),
                    sees_all,
                )
            )
        static_keys = tuple(sorted(cov.names))
        return [
            LoopUnevaluatedProperties(
                static_keys=static_keys,
                static_hashes=tuple(shash(k) for k in static_keys),
                static_patterns=tuple(cov.patterns),
                branches=tuple(branches),
                children=children,
                schema_path=spath,
            )
        ]

    # ------------------------------------------------------------------
    # unevaluatedItems (§3.2.2)
    # ------------------------------------------------------------------

    def _collect_item_coverage(
        self,
        schema: Any,
        base: str,
        cov: _ItemCoverage,
        guards: Tuple[Any, ...],
        seen: Set[int],
    ) -> None:
        if schema is True or schema is False or not isinstance(schema, dict):
            return
        if id(schema) in seen:
            return
        seen.add(id(schema))
        prefix_schemas, items_schema = self._split_items(schema)
        prefix = len(prefix_schemas)
        sees_all = items_schema is not None or "unevaluatedItems" in schema
        if "contains" in schema:
            cov.contains_schemas.append((guards, schema["contains"]))
        if guards:
            if prefix or sees_all:
                cov.branches.append((guards, prefix, sees_all))
        else:
            cov.prefix = max(cov.prefix, prefix)
            cov.sees_all = cov.sees_all or sees_all
        for kw in ("$ref", "$dynamicRef", "$recursiveRef"):
            ref = schema.get(kw)
            if isinstance(ref, str):
                try:
                    if kw == "$ref":
                        r = self.resolver.resolve(ref, base)
                    elif kw == "$dynamicRef":
                        r = self.resolver.resolve_dynamic(ref, base)
                    else:
                        r = self.resolver.resolve_recursive(base)
                    self._collect_item_coverage(r.schema, r.base_uri, cov, guards, seen)
                except KeyError:
                    pass
        for sub in schema.get("allOf") or []:
            self._collect_item_coverage(sub, base, cov, guards, seen)
        for sub in (schema.get("anyOf") or []) + (schema.get("oneOf") or []):
            self._collect_item_coverage(sub, base, cov, guards + (sub,), set(seen))
        if "if" in schema:
            if_s = schema["if"]
            self._collect_item_coverage(if_s, base, cov, guards + (if_s,), set(seen))
            if "then" in schema:
                self._collect_item_coverage(
                    schema["then"], base, cov, guards + (if_s,), set(seen)
                )
            if "else" in schema:
                self._collect_item_coverage(
                    schema["else"], base, cov, guards + ({"not": if_s},), set(seen)
                )

    def _compile_unevaluated_items(
        self, schema: Dict[str, Any], base: str, schema_path: str
    ) -> List[Instruction]:
        if self.dialect in (Dialect.DRAFT4, Dialect.DRAFT6, Dialect.DRAFT7):
            return []
        if "unevaluatedItems" not in schema:
            return []
        sub = schema["unevaluatedItems"]
        if sub is True or sub == {}:
            return []

        cov = _ItemCoverage()
        probe = dict(schema)
        probe.pop("unevaluatedItems")
        self._collect_item_coverage(probe, base, cov, (), set())
        if cov.sees_all:
            return []

        children = tuple(self.compile(sub, base, f"{schema_path}/unevaluatedItems"))
        if not children:
            return []
        spath = f"{schema_path}/unevaluatedItems"
        contains_groups = []
        for guards, cs in cov.contains_schemas:
            guard_instructions: List[Instruction] = []
            for g in guards:
                guard_instructions.extend(self.compile(g, base, spath + "/guard"))
            contains_groups.append(
                (tuple(guard_instructions), tuple(self.compile(cs, base, spath + "/contains")))
            )
        contains_groups = tuple(contains_groups)
        if not cov.branches and not contains_groups:
            # static residue == LoopItemsFrom (first-level-equivalent form)
            if cov.prefix == 0:
                return [LoopItems(children=children, schema_path=spath)]
            return [LoopItemsFrom(start=cov.prefix, children=children, schema_path=spath)]
        branches = []
        for guards, prefix, sees_all in cov.branches:
            guard_instructions: List[Instruction] = []
            for g in guards:
                guard_instructions.extend(self.compile(g, base, spath + "/guard"))
            branches.append((tuple(guard_instructions), prefix, sees_all))
        return [
            LoopUnevaluatedItems(
                static_prefix=cov.prefix,
                static_all=False,
                branches=tuple(branches),
                contains_groups=contains_groups,
                children=children,
                schema_path=spath,
            )
        ]


def _prefix(instructions: Sequence[Instruction], rel: InstancePath) -> List[Instruction]:
    """Prepend ``rel`` to the rel_path of top-level instructions."""
    return [replace(inst, rel_path=rel + inst.rel_path) for inst in instructions]


def _group_cost(group: Instructions) -> int:
    return sum(inst.cost() for inst in group)


def compile_schema(
    schema: Any,
    resources: Optional[Dict[str, Any]] = None,
    options: Optional[CompilerOptions] = None,
) -> CompiledSchema:
    """Compile a JSON Schema into the Blaze validation DSL."""
    options = options or CompilerOptions()
    resolver = SchemaResolver(schema, resources)
    compiler = _Compiler(resolver, options)
    compiler._fused_property_types = set()
    instructions = compiler.compile_root()
    return CompiledSchema(
        instructions=instructions,
        labels=compiler.labels,
        options=options,
        dialect=resolver.dialect,
        source=schema,
        label_names={v: k for k, v in compiler._label_ids.items()},
    )
