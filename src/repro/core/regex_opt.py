"""Regular-expression specialization (Blaze §4.3).

JSON Schema ``pattern`` / ``patternProperties`` use *search* (unanchored)
semantics.  Many real-world patterns are trivial and never need a regex
engine; we statically classify them into cheap forms:

* ``.*`` / ``^.*$`` / ``""``      -> ALL            (elide the check entirely)
* ``.+`` / ``^.+$``               -> NON_EMPTY      (length >= 1)
* ``^.{n,m}$`` / ``^.{n,}$`` ...  -> LENGTH_RANGE   (length bounds only)
* ``^lit``                        -> PREFIX         (paper's ``^x-`` case)
* ``lit$``                        -> SUFFIX         (beyond-paper, same spirit)
* ``^lit$``                       -> EXACT          (beyond-paper)
* ``lit``                         -> CONTAINS       (beyond-paper)
* anything else                   -> GENERIC        (engine fallback)

The paper chose ``.`` to match any character including newlines (the spec
leaves this open); we mirror that with ``re.DOTALL``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from enum import Enum
from typing import Optional


class RegexKind(Enum):
    ALL = "all"
    NON_EMPTY = "non_empty"
    LENGTH_RANGE = "length_range"
    PREFIX = "prefix"
    SUFFIX = "suffix"
    EXACT = "exact"
    CONTAINS = "contains"
    GENERIC = "generic"


# Characters that make a pattern fragment non-literal.
_META = set(".^$*+?()[]{}|\\")

_LENGTH_RANGE = re.compile(r"^\^\.\{(\d+)(,(\d*))?\}\$$")


@dataclass(frozen=True)
class RegexPlan:
    """Statically analysed pattern with a fast-path classification.

    ``risky`` marks GENERIC patterns whose shape can backtrack
    superlinearly (nested/adjacent quantified groups, quantified
    alternation) -- classified once at compile time, in the paper's
    spirit.  The bounded fallback executor refuses to run risky engine
    patterns under a deadline (``ValidationBudget.regex_gate``), because
    ``re`` cannot be preempted mid-match.
    """

    source: str
    kind: RegexKind
    literal: str = ""
    min_len: int = 0
    max_len: Optional[int] = None
    risky: bool = False

    def matches(self, value: str) -> bool:
        """Evaluate the plan against a string (search semantics)."""
        kind = self.kind
        if kind is RegexKind.ALL:
            return True
        if kind is RegexKind.NON_EMPTY:
            return len(value) >= 1
        if kind is RegexKind.LENGTH_RANGE:
            n = len(value)
            return n >= self.min_len and (self.max_len is None or n <= self.max_len)
        if kind is RegexKind.PREFIX:
            return value.startswith(self.literal)
        if kind is RegexKind.SUFFIX:
            return value.endswith(self.literal)
        if kind is RegexKind.EXACT:
            return value == self.literal
        if kind is RegexKind.CONTAINS:
            return self.literal in value
        return _engine(self.source).search(value) is not None

    @property
    def uses_engine(self) -> bool:
        return self.kind is RegexKind.GENERIC


_ENGINE_CACHE: dict = {}


def _engine(source: str) -> "re.Pattern[str]":
    """Compile-once regex engine fallback ('precompilation', §4.3)."""
    compiled = _ENGINE_CACHE.get(source)
    if compiled is None:
        compiled = re.compile(source, re.DOTALL)
        _ENGINE_CACHE[source] = compiled
    return compiled


def _is_literal(fragment: str) -> bool:
    return not any(ch in _META for ch in fragment)


# A quantified group whose body itself contains a quantifier or an
# alternation -- the classic exponential-backtracking shapes ((a+)+,
# (a|aa)*, (\d*)+...).  Conservative by construction: flagging a safe
# pattern only forces it onto the unbounded (non-deadline) path.
_NESTED_QUANT = re.compile(r"\((?:[^()\\]|\\.)*[*+|](?:[^()\\]|\\.)*\)\s*[*+{]")


def _backtracking_prone(source: str) -> bool:
    return _NESTED_QUANT.search(source) is not None


def analyze_pattern(source: str, *, enabled: bool = True) -> RegexPlan:
    """Classify ``source`` into a :class:`RegexPlan`.

    ``enabled=False`` forces the GENERIC engine path -- used by the §6.2.3
    ablation benchmark to disable this optimization wholesale.
    """
    if not enabled:
        plan = RegexPlan(source, RegexKind.GENERIC, risky=_backtracking_prone(source))
        _engine(source)  # precompile eagerly either way
        return plan

    if source in ("", ".*", "^.*$", ".*$", "^.*"):
        return RegexPlan(source, RegexKind.ALL)
    if source in (".+", "^.+$", ".+$", "^.+", "^.{1,}$"):
        return RegexPlan(source, RegexKind.NON_EMPTY)

    m = _LENGTH_RANGE.match(source)
    if m is not None:
        lo = int(m.group(1))
        if m.group(2) is None:  # ^.{n}$ -- exact length
            return RegexPlan(source, RegexKind.LENGTH_RANGE, min_len=lo, max_len=lo)
        hi = m.group(3)
        return RegexPlan(
            source,
            RegexKind.LENGTH_RANGE,
            min_len=lo,
            max_len=int(hi) if hi else None,
        )

    if len(source) >= 2 and source.startswith("^") and source.endswith("$"):
        body = source[1:-1]
        if _is_literal(body):
            return RegexPlan(source, RegexKind.EXACT, literal=body)
    if source.startswith("^") and _is_literal(source[1:]) and len(source) > 1:
        return RegexPlan(source, RegexKind.PREFIX, literal=source[1:])
    if source.endswith("$") and _is_literal(source[:-1]) and len(source) > 1:
        return RegexPlan(source, RegexKind.SUFFIX, literal=source[:-1])
    if source and _is_literal(source):
        return RegexPlan(source, RegexKind.CONTAINS, literal=source)

    _engine(source)  # precompile eagerly (Boost.Regex 'optimize' analogue)
    return RegexPlan(source, RegexKind.GENERIC, risky=_backtracking_prone(source))
