"""Fault-containment contract shared by every serving layer (DESIGN.md §11).

The gateway's degradation ladder -- batched launch -> bounded sequential
fallback -> guard-only reject -- is an engineered, observable contract,
not an accident of exception propagation.  This module owns the pieces
every layer agrees on:

- :class:`ValidationOutcome`: the terminal disposition of one request.
  Exactly one outcome per received document, so stats always reconcile
  (``received == sum(outcome counts)``).
- :class:`Verdict`: outcome + verdict + human-readable reason, the
  structured replacement for the old ``(request_id, error-string)``
  contract.
- :class:`GuardLimits` / :func:`resource_guard`: admission resource caps
  (payload bytes, nesting depth, node count) checked *before* any encode
  or parse work, with precise reject reasons.
- :class:`ValidationBudget`: per-document node/step budget + wall-clock
  deadline for the sequential fallback oracle (depth bombs and
  ReDoS-prone patterns return TIMED_OUT instead of stalling the engine).
- :class:`CircuitBreaker`: repeated fallback timeouts on an endpoint
  trip it into a degraded guard-only mode that recovers after cool-down
  (closed -> open -> half-open probe -> closed).
- :func:`fault_point` / :func:`set_fault_hook`: the seams the
  fault-injection harness (``serve/faults.py``) hooks into.  One global
  ``None`` check on the clean path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, Optional

__all__ = [
    "ValidationOutcome",
    "Verdict",
    "GuardLimits",
    "resource_guard",
    "ValidationBudget",
    "ValidationTimeout",
    "DocumentDepthError",
    "BreakerConfig",
    "CircuitBreaker",
    "InjectedFault",
    "fault_point",
    "fault_hook_armed",
    "set_fault_hook",
]


class ValidationOutcome(str, Enum):
    """Terminal disposition of one received document (exactly one each).

    ``ADMITTED``/``INVALID`` are definite schema verdicts (from either
    engine); the other four are the containment classes: rejected by a
    pre-validation guard, undecidable because the fallback rung is
    suspended, isolated after a per-document error, or over the fallback
    deadline/step budget.
    """

    ADMITTED = "admitted"
    INVALID = "invalid"
    REJECTED_GUARD = "rejected_guard"
    UNDECIDED_FALLBACK = "undecided_fallback"
    ERROR_ISOLATED = "error_isolated"
    TIMED_OUT = "timed_out"


@dataclass(frozen=True)
class Verdict:
    """Structured per-document admission result.

    ``site`` is populated only on INVALID verdicts from an
    ``explain=True`` admission: a ``core.explain.FailureSite`` naming
    the violated schema location, keyword, and instance JSON pointer
    (first failure under the tie-break contract of DESIGN.md §12).
    """

    outcome: ValidationOutcome
    valid: bool
    reason: str = ""
    engine: str = ""  # "batched" | "sequential" | "" (no engine ran)
    site: Any = None  # FailureSite | None (explain=True INVALID only)

    @property
    def admitted(self) -> bool:
        return self.outcome is ValidationOutcome.ADMITTED


# ---------------------------------------------------------------------------
# Admission resource guards (pre-encode, pre-parse)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GuardLimits:
    """Hard resource ceilings checked before any per-document work.

    Deliberately far above the *encode* budgets (``max_nodes``/
    ``max_depth`` of the token table): documents between the encode
    budget and these caps still take the sequential fallback; documents
    beyond them are rejected outright with a precise reason -- a depth
    bomb never reaches the tokenizer, the parser, or the oracle.
    """

    max_bytes: int = 4 << 20  # serialized payload (checked where raw bytes exist)
    max_depth: int = 128
    max_nodes: int = 65536


def resource_guard(doc: Any, limits: GuardLimits) -> str:
    """Return a precise reject reason, or ``""`` when within limits.

    One iterative traversal (explicit stack, no hashing, no recursion)
    with early exit the moment a cap is crossed -- strictly cheaper than
    the encode it protects.
    """
    nodes = 0
    stack = [(doc, 0)]
    max_depth = limits.max_depth
    max_nodes = limits.max_nodes
    while stack:
        value, depth = stack.pop()
        if depth > max_depth:
            return f"payload depth {depth} > guard cap {max_depth}"
        nodes += 1
        if nodes > max_nodes:
            return f"payload nodes > guard cap {max_nodes}"
        if type(value) is list:
            d = depth + 1
            for item in value:
                stack.append((item, d))
        elif type(value) is dict:
            d = depth + 1
            for item in value.values():
                stack.append((item, d))
        elif hasattr(value, "entries"):  # HashedObject
            d = depth + 1
            for _, _, item in value.entries:
                stack.append((item, d))
    return ""


# ---------------------------------------------------------------------------
# Bounded sequential fallback
# ---------------------------------------------------------------------------


class ValidationTimeout(Exception):
    """The bounded fallback ran out of steps, depth, or wall clock."""


class DocumentDepthError(ValueError):
    """A structured replacement for ``RecursionError`` on deep documents."""


class ValidationBudget:
    """Per-document step/depth budget + wall-clock deadline.

    ``tick()`` is called once per executed instruction; the wall clock is
    consulted every 128 steps (a ``time.monotonic`` call per instruction
    would dominate the work it meters).  ``enter_group``/``exit_group``
    bound the evaluation recursion explicitly, so depth bombs raise a
    structured :class:`ValidationTimeout` long before the interpreter
    stack overflows.
    """

    __slots__ = (
        "max_steps",
        "steps",
        "deadline",
        "clock",
        "max_eval_depth",
        "depth",
        "_next_check",
        "max_regex_chars",
    )

    _CHECK_EVERY = 128

    def __init__(
        self,
        *,
        max_steps: int = 500_000,
        deadline_s: Optional[float] = 0.25,
        max_eval_depth: int = 200,
        max_regex_chars: int = 1 << 16,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.max_steps = max_steps
        self.steps = 0
        self.clock = clock
        self.deadline = None if deadline_s is None else clock() + deadline_s
        self.max_eval_depth = max_eval_depth
        self.depth = 0
        self._next_check = self._CHECK_EVERY
        self.max_regex_chars = max_regex_chars

    def tick(self) -> None:
        self.steps += 1
        if self.steps >= self.max_steps:
            raise ValidationTimeout(
                f"step budget exhausted ({self.max_steps} instructions)"
            )
        if self.steps >= self._next_check:
            self._next_check = self.steps + self._CHECK_EVERY
            self.check_deadline()

    def check_deadline(self) -> None:
        if self.deadline is not None and self.clock() > self.deadline:
            raise ValidationTimeout("wall-clock deadline exceeded")

    def enter_group(self) -> None:
        self.depth += 1
        if self.depth > self.max_eval_depth:
            raise ValidationTimeout(
                f"evaluation depth {self.depth} > budget {self.max_eval_depth}"
            )

    def exit_group(self) -> None:
        self.depth -= 1

    def regex_gate(self, plan: Any, subject_len: int) -> None:
        """Engine regexes are not preemptible mid-match, so containment is
        decided *before* the call: patterns statically flagged as
        backtracking-prone (``regex_opt.analyze_pattern``) and oversized
        subjects are refused under a budget (DESIGN.md §11)."""
        if getattr(plan, "risky", False):
            raise ValidationTimeout(
                f"pattern {plan.source!r} is flagged backtracking-prone; "
                "refused under a fallback deadline"
            )
        if subject_len > self.max_regex_chars:
            raise ValidationTimeout(
                f"regex subject of {subject_len} chars > budget "
                f"{self.max_regex_chars}"
            )


# ---------------------------------------------------------------------------
# Circuit breaker (per-endpoint fallback health)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BreakerConfig:
    threshold: int = 3  # consecutive fallback timeouts that trip the breaker
    cooldown_s: float = 30.0


class CircuitBreaker:
    """Closed -> open (after N consecutive timeouts) -> half-open -> closed.

    While open, the endpoint's sequential-fallback rung is suspended
    (guard-only degraded mode); after ``cooldown_s`` one probe request is
    allowed through (half-open) -- success closes the breaker, another
    timeout re-opens it for a fresh cool-down.  Only *timeouts* count:
    schema-invalid documents and isolated errors are normal traffic.
    """

    __slots__ = ("cfg", "clock", "consecutive", "state", "open_until", "trips")

    def __init__(
        self,
        cfg: BreakerConfig = BreakerConfig(),
        *,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cfg = cfg
        self.clock = clock
        self.consecutive = 0
        self.state = "closed"  # closed | open | half_open
        self.open_until = 0.0
        self.trips = 0

    def allow(self) -> bool:
        """May a fallback validation run now?  (May transition to half-open.)"""
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() >= self.open_until:
                self.state = "half_open"
                return True  # one probe
            return False
        return False  # half_open: probe already in flight this window

    def record_timeout(self) -> None:
        self.consecutive += 1
        if self.state == "half_open" or self.consecutive >= self.cfg.threshold:
            self.state = "open"
            self.open_until = self.clock() + self.cfg.cooldown_s
            self.trips += 1
            self.consecutive = 0

    def record_success(self) -> None:
        self.consecutive = 0
        self.state = "closed"


# ---------------------------------------------------------------------------
# Fault-injection seams
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Raised by the fault-injection harness at an armed fault point."""


_FAULT_HOOK: Optional[Callable[[str, Any], None]] = None


def set_fault_hook(
    hook: Optional[Callable[[str, Any], None]]
) -> Optional[Callable[[str, Any], None]]:
    """Install (or clear) the process-wide fault hook; returns the prior one."""
    global _FAULT_HOOK
    prev = _FAULT_HOOK
    _FAULT_HOOK = hook
    return prev


def fault_hook_armed() -> bool:
    """True when a fault harness is armed -- lets hot paths skip building
    expensive fault-point keys (e.g. the per-launch key tuple)."""
    return _FAULT_HOOK is not None


def fault_point(point: str, key: Any = None) -> None:
    """Injectable failure seam: no-op unless a harness armed a hook.

    Points wired through the serve stack: ``"encode"`` (per document,
    inside DocTable tokenization), ``"launch"`` (per batched launch,
    ``key`` = tuple of document keys in the launch), ``"fallback"`` (per
    document, before the sequential oracle), ``"link"`` (per
    registration, before the trial tape link).
    """
    if _FAULT_HOOK is not None:
        _FAULT_HOOK(point, key)
