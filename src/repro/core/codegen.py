"""Closure compilation of the instruction DSL (beyond-paper optimization).

The paper's §8 names "precompiling the code necessary to validate each
schema" as future work; this module does it.  Each instruction compiles to
a specialized Python closure with every operand, hash, and type test
pre-bound -- eliminating opcode dispatch, dataclass attribute loads, and
precondition re-derivation from the per-document hot path.  Semantics are
identical to executor.py (differentially tested in tests/test_codegen.py).

Notes on specialization:
* exact ``type(x) is`` tests (the document model produces exact types;
  bool/int discrimination falls out for free);
* scalar const/enum tests split by type at compile time -- enum membership
  is one frozenset probe, no json_equal walk;
* property matching uses dicts keyed by the semi-perfect hash, built once.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

from .compiler import CompiledSchema
from .doc_model import HashedObject, canonical, json_equal
from .hashing import is_short_hash
from .instructions import Instruction, Instructions, OpCode
from .regex_opt import RegexKind

__all__ = ["compile_to_callable"]

Check = Callable[[Any], bool]

_MISS = object()


def _type_check(t: str) -> Check:
    if t == "string":
        return lambda v: type(v) is str
    if t == "integer":
        return lambda v: type(v) is int or (type(v) is float and v.is_integer())
    if t == "number":
        return lambda v: type(v) is int or type(v) is float
    if t == "object":
        return lambda v: type(v) is HashedObject
    if t == "array":
        return lambda v: type(v) is list
    if t == "boolean":
        return lambda v: type(v) is bool
    if t == "null":
        return lambda v: v is None
    return lambda v: False


def _const_check(value: Any) -> Check:
    if value is None:
        return lambda v: v is None
    if isinstance(value, bool):
        return lambda v: v is value
    if isinstance(value, str):
        return lambda v: type(v) is str and v == value
    if isinstance(value, (int, float)):
        f = float(value)
        return lambda v: (type(v) is int or type(v) is float) and v == f
    return lambda v: json_equal(v, value)


def _enum_check(values: Tuple[Any, ...]) -> Check:
    strs = frozenset(v for v in values if isinstance(v, str))
    nums = frozenset(
        float(v) for v in values if isinstance(v, (int, float)) and not isinstance(v, bool)
    )
    has_null = any(v is None for v in values)
    has_true = any(v is True for v in values)
    has_false = any(v is False for v in values)
    complex_vals = [v for v in values if isinstance(v, (list, dict))]

    def check(v):
        t = type(v)
        if t is str:
            return v in strs
        if t is bool:
            return has_true if v else has_false
        if t is int or t is float:
            return v in nums
        if v is None:
            return has_null
        return any(json_equal(v, c) for c in complex_vals)

    return check


class _Codegen:
    def __init__(self, compiled: CompiledSchema):
        self.compiled = compiled
        self.labels: Dict[int, Check] = {}

    # -- groups ---------------------------------------------------------------

    def group(self, instructions: Instructions) -> Check:
        fns = [self.one(i) for i in instructions]
        if not fns:
            return lambda v: True
        if len(fns) == 1:
            return fns[0]
        if len(fns) == 2:
            f0, f1 = fns
            return lambda v: f0(v) and f1(v)
        fns_t = tuple(fns)

        def check(v):
            for f in fns_t:
                if not f(v):
                    return False
            return True

        return check

    # -- per-instruction ---------------------------------------------------------

    def one(self, inst: Instruction) -> Check:
        inner = self.body(inst)
        if not inst.rel_path:
            return inner
        # fold relative resolution into the closure; hashes precomputed here
        from .hashing import shash

        path = tuple(
            (tok, shash(tok)) if isinstance(tok, str) else tok
            for tok in inst.rel_path
        )
        if len(path) == 1 and type(path[0]) is tuple:
            key, h = path[0]

            def resolved(v, _inner=inner, _k=key, _h=h):
                if type(v) is not HashedObject:
                    return True
                child = v.get_hashed(_h, _k, _MISS)
                if child is _MISS:
                    return True
                return _inner(child)

            return resolved

        def resolved_deep(v, _inner=inner, _path=path):
            node = v
            for tok in _path:
                if type(tok) is tuple:
                    if type(node) is not HashedObject:
                        return True
                    node = node.get_hashed(tok[1], tok[0], _MISS)
                    if node is _MISS:
                        return True
                else:
                    if type(node) is not list or not 0 <= tok < len(node):
                        return True
                    node = node[tok]
            return _inner(node)

        return resolved_deep

    def body(self, inst: Instruction) -> Check:  # noqa: C901 -- dispatch table
        op = inst.op
        if op is OpCode.FAIL:
            return lambda v: False
        if op is OpCode.TYPE:
            return _type_check(inst.type)
        if op is OpCode.TYPE_ANY:
            checks = tuple(_type_check(t) for t in inst.types)
            return lambda v: any(c(v) for c in checks)
        if op is OpCode.EQUAL:
            return _const_check(inst.value)
        if op is OpCode.EQUALS_ANY:
            return _enum_check(inst.values)

        if op is OpCode.DEFINES:
            k, h = inst.key, inst.key_hash
            return (
                lambda v: type(v) is not HashedObject
                or v.get_hashed(h, k, _MISS) is not _MISS
            )
        if op is OpCode.DEFINES_ALL:
            pairs = tuple(zip(inst.key_hashes, inst.keys))

            def defines_all(v):
                if type(v) is not HashedObject:
                    return True
                get = v.get_hashed
                for h, k in pairs:
                    if get(h, k, _MISS) is _MISS:
                        return False
                return True

            return defines_all
        if op is OpCode.PROPERTY_DEPENDENCIES:
            deps = tuple(
                (h, k, tuple(zip(dh, dk)))
                for k, h, dk, dh in inst.dependencies
            )

            def prop_deps(v):
                if type(v) is not HashedObject:
                    return True
                get = v.get_hashed
                for h, k, reqs in deps:
                    if get(h, k, _MISS) is not _MISS:
                        for dh, dk in reqs:
                            if get(dh, dk, _MISS) is _MISS:
                                return False
                return True

            return prop_deps
        if op is OpCode.OBJECT_SIZE_GREATER:
            b = inst.bound
            return lambda v: type(v) is not HashedObject or len(v.entries) >= b
        if op is OpCode.OBJECT_SIZE_LESS:
            b = inst.bound
            return lambda v: type(v) is not HashedObject or len(v.entries) <= b
        if op is OpCode.PROPERTY_TYPE:
            k, h = inst.key, inst.key_hash
            tcheck = _type_check(inst.type)

            def prop_type(v):
                if type(v) is not HashedObject:
                    return True
                child = v.get_hashed(h, k, _MISS)
                return child is not _MISS and tcheck(child)

            return prop_type

        if op is OpCode.REGEX:
            plan = inst.plan
            kind = plan.kind
            if kind is RegexKind.PREFIX:
                lit = plan.literal
                return lambda v: type(v) is not str or v.startswith(lit)
            if kind is RegexKind.SUFFIX:
                lit = plan.literal
                return lambda v: type(v) is not str or v.endswith(lit)
            if kind is RegexKind.EXACT:
                lit = plan.literal
                return lambda v: type(v) is not str or v == lit
            if kind is RegexKind.CONTAINS:
                lit = plan.literal
                return lambda v: type(v) is not str or lit in v
            if kind is RegexKind.NON_EMPTY:
                return lambda v: type(v) is not str or len(v) >= 1
            if kind is RegexKind.LENGTH_RANGE:
                lo, hi = plan.min_len, plan.max_len
                if hi is None:
                    return lambda v: type(v) is not str or len(v) >= lo
                return lambda v: type(v) is not str or lo <= len(v) <= hi
            if kind is RegexKind.ALL:
                return lambda v: True
            from .regex_opt import _engine

            rx = _engine(plan.source)
            return lambda v: type(v) is not str or rx.search(v) is not None
        if op is OpCode.STRING_SIZE_GREATER:
            b = inst.bound
            return lambda v: type(v) is not str or len(v) >= b
        if op is OpCode.STRING_SIZE_LESS:
            b = inst.bound
            return lambda v: type(v) is not str or len(v) <= b
        if op is OpCode.STRING_BOUNDS:
            lo, hi = inst.min_len, inst.max_len
            if hi is None:
                return lambda v: type(v) is not str or len(v) >= lo
            return lambda v: type(v) is not str or lo <= len(v) <= hi
        if op is OpCode.STRING_TYPE:
            from .executor import _check_format

            fmt = inst.format
            return lambda v: type(v) is not str or _check_format(fmt, v)

        if op is OpCode.UNIQUE:

            def unique(v):
                if type(v) is not list:
                    return True
                seen = set()
                for item in v:
                    c = canonical(item)
                    if c in seen:
                        return False
                    seen.add(c)
                return True

            return unique
        if op is OpCode.ARRAY_SIZE_GREATER:
            b = inst.bound
            return lambda v: type(v) is not list or len(v) >= b
        if op is OpCode.ARRAY_SIZE_LESS:
            b = inst.bound
            return lambda v: type(v) is not list or len(v) <= b
        if op is OpCode.ARRAY_BOUNDS:
            lo, hi = inst.min_len, inst.max_len
            if hi is None:
                return lambda v: type(v) is not list or len(v) >= lo
            return lambda v: type(v) is not list or lo <= len(v) <= hi

        if op is OpCode.GREATER:
            b = inst.bound
            return lambda v: (type(v) is not int and type(v) is not float) or v > b
        if op is OpCode.GREATER_EQUAL:
            b = inst.bound
            return lambda v: (type(v) is not int and type(v) is not float) or v >= b
        if op is OpCode.LESS:
            b = inst.bound
            return lambda v: (type(v) is not int and type(v) is not float) or v < b
        if op is OpCode.LESS_EQUAL:
            b = inst.bound
            return lambda v: (type(v) is not int and type(v) is not float) or v <= b
        if op is OpCode.NUMBER_BOUNDS:
            lo, lo_x, hi, hi_x = inst.lo, inst.lo_exclusive, inst.hi, inst.hi_exclusive

            def bounds(v):
                t = type(v)
                if t is not int and t is not float:
                    return True
                if lo is not None:
                    if lo_x:
                        if not v > lo:
                            return False
                    elif not v >= lo:
                        return False
                if hi is not None:
                    if hi_x:
                        if not v < hi:
                            return False
                    elif not v <= hi:
                        return False
                return True

            return bounds
        if op is OpCode.DIVISIBLE:
            d = inst.divisor
            from .executor import _divisible as _div

            def divisible(v):
                t = type(v)
                if t is not int and t is not float:
                    return True
                # shared spec-exact check (decimal re-check on inexact
                # float quotients) -- keeps codegen == interpreter
                return _div(v, d)

            return divisible

        # ---- loops -----------------------------------------------------------
        if op is OpCode.LOOP_KEYS:
            child = self.group(inst.children)

            def loop_keys(v):
                if type(v) is not HashedObject:
                    return True
                for _, key, _val in v.entries:
                    if not child(key):
                        return False
                return True

            return loop_keys
        if op is OpCode.LOOP_PROPERTIES:
            child = self.group(inst.children)

            def loop_props(v):
                if type(v) is not HashedObject:
                    return True
                for _, _, val in v.entries:
                    if not child(val):
                        return False
                return True

            return loop_props
        if op is OpCode.LOOP_PROPERTIES_EXCEPT:
            child = self.group(inst.children)
            excl: Dict[int, List[str]] = {}
            for k, h in zip(inst.exclude_keys, inst.exclude_hashes):
                excl.setdefault(h, []).append(k)
            plans = inst.exclude_patterns

            def loop_except(v):
                if type(v) is not HashedObject:
                    return True
                for h, key, val in v.entries:
                    cands = excl.get(h)
                    if cands is not None and (is_short_hash(h) or key in cands):
                        continue
                    if plans and any(p.matches(key) for p in plans):
                        continue
                    if not child(val):
                        return False
                return True

            return loop_except
        if op is OpCode.LOOP_PROPERTIES_REGEX:
            child = self.group(inst.children)
            plan = inst.plan

            def loop_regex(v):
                if type(v) is not HashedObject:
                    return True
                for _, key, val in v.entries:
                    if plan.matches(key) and not child(val):
                        return False
                return True

            return loop_regex
        if op in (OpCode.LOOP_PROPERTIES_MATCH, OpCode.LOOP_PROPERTIES_MATCH_CLOSED):
            table: Dict[int, List[Tuple[str, Check]]] = {}
            for key, h, grp in inst.matches:
                table.setdefault(h, []).append((key, self.group(grp)))
            closed = op is OpCode.LOOP_PROPERTIES_MATCH_CLOSED
            plans = getattr(inst, "tolerate_patterns", ())

            def loop_match(v):
                if type(v) is not HashedObject:
                    return True
                for h, key, val in v.entries:
                    cands = table.get(h)
                    fn = None
                    if cands is not None:
                        if is_short_hash(h):
                            fn = cands[0][1]
                        else:
                            for k2, f2 in cands:
                                if k2 == key:
                                    fn = f2
                                    break
                    if fn is None:
                        if closed:
                            if plans and any(p.matches(key) for p in plans):
                                continue
                            return False
                        continue
                    if not fn(val):
                        return False
                return True

            return loop_match
        if op is OpCode.LOOP_ITEMS:
            child = self.group(inst.children)

            def loop_items(v):
                if type(v) is not list:
                    return True
                for item in v:
                    if not child(item):
                        return False
                return True

            return loop_items
        if op is OpCode.LOOP_ITEMS_FROM:
            child = self.group(inst.children)
            start = inst.start

            def loop_items_from(v):
                if type(v) is not list:
                    return True
                for i in range(start, len(v)):
                    if not child(v[i]):
                        return False
                return True

            return loop_items_from
        if op is OpCode.LOOP_CONTAINS:
            child = self.group(inst.children)
            lo, hi = inst.min_count, inst.max_count

            def loop_contains(v):
                if type(v) is not list:
                    return True
                count = 0
                for item in v:
                    if child(item):
                        count += 1
                        if hi is not None and count > hi:
                            return False
                        if hi is None and count >= lo:
                            return True
                return count >= lo and (hi is None or count <= hi)

            return loop_contains
        if op is OpCode.ARRAY_PREFIX:
            groups = tuple(self.group(g) for g in inst.groups)

            def array_prefix(v):
                if type(v) is not list:
                    return True
                for i, fn in enumerate(groups):
                    if i >= len(v):
                        break
                    if not fn(v[i]):
                        return False
                return True

            return array_prefix
        if op is OpCode.LOOP_UNEVALUATED_PROPERTIES:
            child = self.group(inst.children)
            static_keys = frozenset(inst.static_keys)
            static_plans = inst.static_patterns
            branches = tuple(
                (self.group(guard), frozenset(keys), pats, sees_all)
                for guard, keys, _h, pats, sees_all in inst.branches
            )

            def uneval_props(v):
                if type(v) is not HashedObject:
                    return True
                names = set(static_keys)
                plans = list(static_plans)
                for guard, keys, pats, sees_all in branches:
                    if guard(v):
                        if sees_all:
                            return True
                        names |= keys
                        plans.extend(pats)
                for _, key, val in v.entries:
                    if key in names or any(p.matches(key) for p in plans):
                        continue
                    if not child(val):
                        return False
                return True

            return uneval_props
        if op is OpCode.LOOP_UNEVALUATED_ITEMS:
            child = self.group(inst.children)
            branches = tuple(
                (self.group(guard), prefix, sees_all)
                for guard, prefix, sees_all in inst.branches
            )
            contains = tuple(
                (self.group(guard) if guard else None, self.group(group))
                for guard, group in inst.contains_groups
            )
            static_prefix = inst.static_prefix

            def uneval_items(v):
                if type(v) is not list:
                    return True
                prefix = static_prefix
                for guard, bp, sees_all in branches:
                    if guard(v):
                        if sees_all:
                            return True
                        prefix = max(prefix, bp)
                # branch-gated contains annotations (guard None = unconditional)
                active = [g for guard, g in contains if guard is None or guard(v)]
                for i in range(prefix, len(v)):
                    item = v[i]
                    if active and any(g(item) for g in active):
                        continue
                    if not child(item):
                        return False
                return True

            return uneval_items

        # ---- logical -----------------------------------------------------------
        if op is OpCode.AND:
            return self.group(inst.children)
        if op is OpCode.OR:
            groups = tuple(self.group(g) for g in inst.groups)

            def logical_or(v):
                for fn in groups:
                    if fn(v):
                        return True
                return False

            return logical_or
        if op is OpCode.XOR:
            groups = tuple(self.group(g) for g in inst.groups)

            def logical_xor(v):
                passed = 0
                for fn in groups:
                    if fn(v):
                        passed += 1
                        if passed > 1:
                            return False
                return passed == 1

            return logical_xor
        if op is OpCode.NOT:
            child = self.group(inst.children)
            return lambda v: not child(v)
        if op is OpCode.CONDITION:
            cond = self.group(inst.condition)
            then_fn = self.group(inst.then_children)
            else_fn = self.group(inst.else_children)
            return lambda v: then_fn(v) if cond(v) else else_fn(v)
        if op is OpCode.WHEN_TYPE:
            tcheck = _type_check(inst.type)
            child = self.group(inst.children)
            return lambda v: child(v) if tcheck(v) else True
        if op is OpCode.WHEN_DEFINES:
            k, h = inst.key, inst.key_hash
            child = self.group(inst.children)
            return (
                lambda v: child(v)
                if type(v) is HashedObject and v.get_hashed(h, k, _MISS) is not _MISS
                else True
            )
        if op is OpCode.WHEN_ARRAY_SIZE_GREATER:
            b = inst.bound
            child = self.group(inst.children)
            return lambda v: child(v) if type(v) is list and len(v) > b else True
        if op is OpCode.WHEN_ARRAY_SIZE_EQUAL:
            b = inst.bound
            child = self.group(inst.children)
            return lambda v: child(v) if type(v) is list and len(v) == b else True

        # ---- control -------------------------------------------------------------
        if op is OpCode.CONTROL_LABEL:
            fn = self.group(inst.children)
            self.labels[inst.label] = fn
            return fn
        if op is OpCode.CONTROL_JUMP:
            labels = self.labels
            label = inst.label
            return lambda v: labels[label](v)

        raise AssertionError(f"codegen: unhandled opcode {op!r}")


def compile_to_callable(compiled: CompiledSchema) -> Check:
    """Compile a CompiledSchema into a single specialised closure."""
    gen = _Codegen(compiled)
    # labels referenced by jumps may be registered during group compilation;
    # compile label bodies first so forward jumps resolve
    for label, group in compiled.labels.items():
        if label not in gen.labels:
            gen.labels[label] = gen.group(group)
    return gen.group(compiled.instructions)
