"""Semi-perfect hashing of JSON object keys (Blaze §4.1).

The hash output is 256 bits (32 bytes).  For strings of at most 31 bytes the
hash *is* the string: byte 0 is zero and the remaining 31 bytes are the string
bytes (zero padded).  Two short strings are therefore equal iff their hashes
are equal -- no string comparison is ever needed.  For longer strings byte 0
is ``(len + first + last) % 255 + 1`` (guaranteed non-zero, computed in
constant time) and a hash match must be confirmed with a full comparison.

Representation choices:

* The sequential executor uses a single Python ``int`` packing the 32 bytes
  big-endian, so byte 0 is the most significant byte.  Python ints compare in
  a handful of ns -- the analogue of the paper's two 128-bit compares.
* The tensorised executor unpacks the same 32 bytes into eight little-endian
  ``uint32`` lanes (TPUs have no 64-bit vector lanes); see :func:`hash_lanes`.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "SHORT_LIMIT",
    "shash",
    "shash_bytes",
    "is_short_hash",
    "hashed_equal",
    "hash_lanes",
    "lanes_to_int",
]

# Strings with byte-length <= SHORT_LIMIT hash perfectly (one-to-one).
SHORT_LIMIT = 31
_HASH_BYTES = 32
_HASH_BITS = _HASH_BYTES * 8

# Mask that isolates byte 0 (the discriminator byte) of the packed integer.
_DISCRIMINATOR_SHIFT = (_HASH_BYTES - 1) * 8


def shash_bytes(data: bytes) -> int:
    """Hash raw bytes to a 256-bit integer per Blaze's semi-perfect scheme."""
    n = len(data)
    if n <= SHORT_LIMIT:
        # Byte 0 = 0, bytes 1..31 = the string itself (zero padded on the
        # right).  Packing big-endian keeps byte 0 most significant.
        return int.from_bytes(data.ljust(SHORT_LIMIT, b"\x00"), "big")
    # Long string: constant-time 1-byte digest in byte 0, rest zero.
    digest = (n + data[0] + data[-1]) % 255 + 1
    return digest << _DISCRIMINATOR_SHIFT


def shash(key: str) -> int:
    """Hash a JSON key (UTF-8 encoded) to its 256-bit semi-perfect hash."""
    return shash_bytes(key.encode("utf-8"))


def is_short_hash(h: int) -> bool:
    """True when the hash belongs to a short (<=31 byte) string."""
    return (h >> _DISCRIMINATOR_SHIFT) == 0


def hashed_equal(h_a: int, a: str, h_b: int, b: str) -> bool:
    """Equality test using hashes first (Blaze §4.1 comparison procedure).

    Short/short: hash equality is definitive.  Anything involving a long
    string needs the hash as a cheap filter followed by a real comparison.
    """
    if h_a != h_b:
        return False
    if is_short_hash(h_a):  # both short: perfect hash, no string compare
        return True
    return a == b


def hash_lanes(h: int) -> np.ndarray:
    """Unpack a 256-bit hash into eight uint32 lanes (TPU-friendly form).

    Lane 0 holds the most-significant 4 bytes (so the discriminator byte is
    the top byte of lane 0); comparing all eight lanes is equivalent to
    comparing the packed integer.
    """
    raw = h.to_bytes(_HASH_BYTES, "big")
    return np.frombuffer(raw, dtype=">u4").astype(np.uint32)


def lanes_to_int(lanes: np.ndarray) -> int:
    """Inverse of :func:`hash_lanes` (test helper)."""
    out = 0
    for lane in lanes:
        out = (out << 32) | int(lane)
    return out
