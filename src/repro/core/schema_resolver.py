"""``$ref`` / ``$id`` / ``$anchor`` / ``$dynamicRef`` resolution and dialect
detection (Blaze §3.3-§3.4).

The resolver indexes every embedded resource (``$id``), plain anchor and
dynamic anchor in the root schema plus any externally supplied resources,
then resolves reference URIs to (subschema, new base URI) pairs.  Dynamic
references with a *single* possible context are rewritten to static
references at resolution time (§3.4) -- zero validation-time cost.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Dict, List, Optional, Tuple
from urllib.parse import urldefrag, urljoin

from .json_pointer import resolve_pointer


class Dialect(Enum):
    DRAFT4 = "draft4"
    DRAFT6 = "draft6"
    DRAFT7 = "draft7"
    DRAFT2019 = "2019-09"
    DRAFT2020 = "2020-12"


_DIALECT_URIS = {
    "http://json-schema.org/draft-04/schema": Dialect.DRAFT4,
    "http://json-schema.org/draft-06/schema": Dialect.DRAFT6,
    "http://json-schema.org/draft-07/schema": Dialect.DRAFT7,
    "https://json-schema.org/draft/2019-09/schema": Dialect.DRAFT2019,
    "https://json-schema.org/draft/2020-12/schema": Dialect.DRAFT2020,
}


def detect_dialect(schema: Any, default: Dialect = Dialect.DRAFT2020) -> Dialect:
    if isinstance(schema, dict):
        uri = schema.get("$schema")
        if isinstance(uri, str):
            return _DIALECT_URIS.get(uri.rstrip("#"), default)
    return default


@dataclass
class ResolvedRef:
    """A resolved reference destination."""

    schema: Any
    base_uri: str
    key: str  # canonical identity used for use-counting / labels


class SchemaResolver:
    """Static index over a schema document (+ external resources)."""

    def __init__(self, root: Any, resources: Optional[Dict[str, Any]] = None):
        self.root = root
        self.dialect = detect_dialect(root)
        # canonical URI -> (schema fragment, base uri at that fragment)
        self._ids: Dict[str, Tuple[Any, str]] = {}
        self._anchors: Dict[str, Tuple[Any, str]] = {}
        # dynamic anchor name -> list of (schema, base uri) contexts
        self._dynamic: Dict[str, List[Tuple[Any, str]]] = {}
        self.root_base = ""
        if isinstance(root, dict):
            self.root_base = root.get("$id", "") or ""
        self._index(root, self.root_base)
        for uri, res in (resources or {}).items():
            base = res.get("$id", uri) if isinstance(res, dict) else uri
            self._ids.setdefault(uri.rstrip("#"), (res, base))
            self._index(res, base)

    # -- indexing -----------------------------------------------------------

    def _index(self, node: Any, base: str) -> None:
        if isinstance(node, dict):
            new_id = node.get("$id")
            if isinstance(new_id, str) and new_id:
                base = urljoin(base, new_id)
                self._ids[urldefrag(base)[0] or base] = (node, base)
            anchor = node.get("$anchor")
            if isinstance(anchor, str):
                self._anchors[urljoin(base, "#" + anchor)] = (node, base)
            dyn = node.get("$dynamicAnchor")
            if isinstance(dyn, str):
                self._dynamic.setdefault(dyn, []).append((node, base))
                # a $dynamicAnchor also behaves as a plain $anchor
                self._anchors.setdefault(urljoin(base, "#" + dyn), (node, base))
            if node.get("$recursiveAnchor") is True:
                self._dynamic.setdefault("", []).append((node, base))
            for key, value in node.items():
                if key in ("enum", "const", "default", "examples"):
                    continue  # instance data, not schemas
                self._index(value, base)
        elif isinstance(node, list):
            for item in node:
                self._index(item, base)

    # -- resolution ---------------------------------------------------------

    def resolve(self, ref: str, base: str) -> ResolvedRef:
        """Resolve ``$ref`` value ``ref`` against base URI ``base``."""
        target = urljoin(base, ref) if base or not ref.startswith("#") else ref
        uri, fragment = urldefrag(target)

        if not uri:  # same-document reference
            doc, doc_base = self.root, self.root_base
        elif uri in self._ids:
            doc, doc_base = self._ids[uri]
        elif uri == urldefrag(self.root_base)[0]:
            doc, doc_base = self.root, self.root_base
        else:
            raise KeyError(f"unresolvable $ref {ref!r} (base {base!r})")

        if not fragment:
            return ResolvedRef(doc, doc_base, key=uri or "#root")
        if fragment.startswith("/"):
            frag_schema = resolve_pointer(doc, fragment)
            # the fragment may itself re-declare $id; track base changes
            new_base = doc_base
            if isinstance(frag_schema, dict) and isinstance(frag_schema.get("$id"), str):
                new_base = urljoin(doc_base, frag_schema["$id"])
            return ResolvedRef(frag_schema, new_base, key=f"{uri}#{fragment}")
        # named anchor
        anchor_uri = urljoin(uri or doc_base or "#", "#" + fragment)
        if anchor_uri in self._anchors:
            schema, abase = self._anchors[anchor_uri]
            return ResolvedRef(schema, abase, key=anchor_uri)
        # anchors registered without base
        if "#" + fragment in self._anchors:
            schema, abase = self._anchors["#" + fragment]
            return ResolvedRef(schema, abase, key="#" + fragment)
        raise KeyError(f"unresolvable anchor {ref!r} (base {base!r})")

    def resolve_dynamic(self, ref: str, base: str) -> ResolvedRef:
        """Resolve ``$dynamicRef`` -- static rewrite for single contexts (§3.4).

        When the dynamic anchor has exactly one possible context across all
        known resources, the reference is replaced by a static one.  With
        multiple contexts we fall back to the lexically innermost definition
        (correct for schemas that never override the anchor; documented
        limitation for the general PSPACE-complete case).
        """
        _, fragment = urldefrag(ref)
        contexts = self._dynamic.get(fragment, [])
        if len(contexts) == 1:
            schema, cbase = contexts[0]
            return ResolvedRef(schema, cbase, key=f"dynamic:{fragment}")
        return self.resolve(ref, base)

    def resolve_recursive(self, base: str) -> ResolvedRef:
        """2019-09 ``$recursiveRef: "#"`` -- same single-context treatment."""
        contexts = self._dynamic.get("", [])
        if len(contexts) == 1:
            schema, cbase = contexts[0]
            return ResolvedRef(schema, cbase, key="recursive:#")
        return ResolvedRef(self.root, self.root_base, key="#root")
