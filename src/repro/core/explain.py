"""First-failure attribution for the batched path (DESIGN.md §12).

The batched executor computes per-row assertion failures anyway; the
opt-in explain pass argmaxes over them to emit one
``(schema location, keyword, instance JSON pointer)`` per invalid
document -- the batched counterpart of the sequential
``Validator.explain()``.  This module owns the *host-side* half:

- :class:`FailureSite`: the structured attribution record carried on
  ``Verdict.site`` (and rendered into ``Verdict.reason``).
- :func:`node_pointer`: BFS-order node index -> RFC 6901 JSON pointer,
  replaying exactly the deterministic traversal of
  ``data/doc_table.encode_document`` (queue pop-front, children
  appended in document order), so index ``i`` on the device maps back
  to a human-readable instance path without shipping strings to the
  accelerator.
- :func:`resolve_site`: tape provenance (``asrt_path`` /
  ``loc_required_info`` / ``loc_closed_path`` / ``circ_path``) +
  the explain launch's per-document picks -> a :class:`FailureSite`.

Tie-break contract (documented in DESIGN.md §12): the attributed
failure is the one at the lowest BFS node index (document order);
within one node, assertion-row failures beat missing-required beats
closed-object, and among assertion rows the **lowest assertion row
wins**; structural failures beat circuit (logical-applicator)
failures anchored at the same node, and among circuits the lowest
circuit id wins.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

__all__ = ["FailureSite", "node_pointer", "keyword_of", "resolve_site"]

# failure kinds, ordered by attribution priority within one node
KIND_ASSERTION = 0
KIND_REQUIRED = 1
KIND_CLOSED = 2
KIND_CIRCUIT = 3


@dataclass(frozen=True)
class FailureSite:
    """One attributed validation failure.

    ``schema_path`` is the keyword location in the source schema (the
    compiler's ``schema_path`` provenance, e.g.
    ``"/properties/a/minLength"``), ``keyword`` its final segment,
    ``instance_path`` an RFC 6901 JSON pointer into the document (empty
    = root, or when no document was supplied to reconstruct it).
    """

    schema_path: str
    keyword: str
    instance_path: str = ""
    detail: str = ""

    def render(self) -> str:
        """Human-readable one-liner for ``Verdict.reason``."""
        at = self.instance_path or "/"
        msg = f"schema validation failed at {at!r}: {self.keyword or 'schema'}"
        if self.schema_path:
            msg += f" ({self.schema_path})"
        if self.detail:
            msg += f" -- {self.detail}"
        return msg


def _escape(tok: str) -> str:
    return tok.replace("~", "~0").replace("/", "~1")


def keyword_of(schema_path: str) -> str:
    """Final path segment = the violated keyword (``/a/minLength`` ->
    ``minLength``); empty paths stay empty."""
    if not schema_path:
        return ""
    return schema_path.rsplit("/", 1)[-1]


def node_pointer(doc: Any, index: int) -> str:
    """JSON pointer of BFS node ``index`` in ``doc``.

    Replays ``encode_document``'s traversal order exactly: one queue,
    pop from the front, children appended in document order (object
    entries in insertion order, array items in index order).  Stops as
    soon as the target index is dequeued, so cost is O(index + queued).
    """
    from ..core.doc_model import HashedObject

    if index <= 0:
        return ""
    # queue of (value, pointer)
    queue: List[Tuple[Any, str]] = [(doc, "")]
    count = 0
    while queue:
        value, ptr = queue.pop(0)
        if count == index:
            return ptr
        count += 1
        if isinstance(value, list):
            for j, item in enumerate(value):
                queue.append((item, f"{ptr}/{j}"))
        elif isinstance(value, HashedObject):
            for _, k, v in value.entries:
                queue.append((v, f"{ptr}/{_escape(k)}"))
        elif isinstance(value, dict):
            for k, v in value.items():
                queue.append((v, f"{ptr}/{_escape(k)}"))
    return ""


def _required_site(tape, loc: int, missing_mask: int) -> Tuple[str, str, str]:
    """(schema_path, keyword, detail) for a missing-required failure.

    The lowest set bit of the missing mask wins (slot allocation order =
    source order of the requiring keywords).
    """
    info = ()
    if 0 <= loc < len(tape.loc_required_info):
        info = tape.loc_required_info[loc]
    if missing_mask:
        lowest = (missing_mask & -missing_mask).bit_length() - 1
        for slot, key, path in info:
            if slot == lowest:
                return path, "required", f"missing property {key!r}"
    return "", "required", "missing required property"


def resolve_site(
    tape,
    *,
    kind: int,
    node: int,
    row: int = -1,
    loc: int = -1,
    parent_loc: int = -1,
    missing_mask: int = 0,
    circ: int = -1,
    doc: Any = None,
) -> FailureSite:
    """Map one explain-launch pick onto tape provenance.

    ``node`` is the failing node's in-document BFS index; the remaining
    operands are kind-specific (assertion row id / owner location /
    parent location / missing-required bitmask / circuit id).
    """
    instance = node_pointer(doc, node) if doc is not None else ""
    if kind == KIND_ASSERTION:
        path = ""
        if 0 <= row < len(tape.asrt_path):
            path = tape.asrt_path[row]
        return FailureSite(path, keyword_of(path), instance)
    if kind == KIND_REQUIRED:
        path, kw, detail = _required_site(tape, loc, missing_mask)
        return FailureSite(path, kw, instance, detail)
    if kind == KIND_CLOSED:
        path = ""
        if 0 <= parent_loc < len(tape.loc_closed_path):
            path = tape.loc_closed_path[parent_loc]
        return FailureSite(
            path,
            keyword_of(path) or "additionalProperties",
            instance,
            "unexpected property (closed object)",
        )
    # KIND_CIRCUIT: the originating logical applicator
    path = ""
    if 0 <= circ < len(tape.circ_path):
        path = tape.circ_path[circ]
    return FailureSite(path, keyword_of(path), instance)
