"""The Blaze execution engine (paper §5) -- sequential, fail-fast.

The executor drives a loop over compiled instructions.  Per instruction it

1. resolves the target value via the instruction's *relative* instance
   location (absent target => the instruction is skipped, vacuously true);
2. checks the instruction's type *precondition* (wrong type => skipped --
   "validation does NOT fail if the precondition for an instruction is not
   met", §5.2);
3. evaluates the assertion / recurses into subinstructions, short-circuiting
   on the first failure (§2.3).

Evaluation state (label table, scratch) lives in a preallocated
:class:`EvalContext` reused across validations (§4.5 -- "we optimize for the
case of repeated evaluations of the same schema by preallocating a data
structure that can be reused for multiple validations").

``use_hashing=False`` switches property matching to raw string comparison
for the §6.2.3 hash ablation.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from .compiler import CompiledSchema
from .doc_model import (
    HashedObject,
    canonical,
    has_type,
    json_equal,
    parse_document,
)
from .instructions import Instruction, Instructions, OpCode
from .json_pointer import MISSING, get_instance
from .outcomes import DocumentDepthError, ValidationBudget, ValidationTimeout

__all__ = ["Validator", "EvalContext"]


class EvalContext:
    """Preallocated, reusable evaluation state (§4.5)."""

    __slots__ = (
        "labels",
        "use_hashing",
        "_match_cache",
        "_path_cache",
        "trace",
        "budget",
    )

    def __init__(self, labels: Dict[int, Instructions], use_hashing: bool = True):
        self.labels = labels
        self.use_hashing = use_hashing
        # per-instruction lazily built lookup tables (hash -> candidates);
        # lives for the lifetime of the validator, i.e. built once per
        # compiled schema, amortised across documents.
        self._match_cache: Dict[int, Dict] = {}
        # rel_path with schema-side key hashes precomputed: hashing happens
        # at compile/parse time, never during validation (§4.1)
        self._path_cache: Dict[int, tuple] = {}
        # failure trace (paper §8 "helpful error messages" option): None on
        # the hot path; a list during Validator.explain()
        self.trace = None
        # fallback deadline/step budget (DESIGN.md §11): None on the hot
        # path; a ValidationBudget during Validator.is_valid_bounded()
        self.budget = None


def _cached_path(inst: Instruction, ctx: "EvalContext") -> tuple:
    path = ctx._path_cache.get(id(inst))
    if path is None:
        from .hashing import shash

        path = tuple(
            (tok, shash(tok)) if isinstance(tok, str) else tok
            for tok in inst.rel_path
        )
        ctx._path_cache[id(inst)] = path
    return path


def _resolve(value: Any, path: tuple) -> Any:
    """Hash-accelerated relative instance resolution."""
    node = value
    for tok in path:
        if type(tok) is tuple:
            if not isinstance(node, HashedObject):
                return MISSING
            node = node.get_hashed(tok[1], tok[0], MISSING)
            if node is MISSING:
                return MISSING
        else:
            if not isinstance(node, list) or not 0 <= tok < len(node):
                return MISSING
            node = node[tok]
    return node


class Validator:
    """Executes a :class:`CompiledSchema` against parsed documents.

    ``engine="interpreter"`` is the paper-faithful instruction interpreter
    (§5); ``engine="codegen"`` is the beyond-paper closure compiler
    (core/codegen.py, the paper's §8 future work).
    """

    def __init__(
        self,
        compiled: CompiledSchema,
        *,
        use_hashing: bool = True,
        engine: str = "interpreter",
    ):
        self.compiled = compiled
        self.engine = engine
        self.ctx = EvalContext(compiled.labels, use_hashing=use_hashing)
        self._fn = None
        if engine == "codegen":
            from .codegen import compile_to_callable

            self._fn = compile_to_callable(compiled)

    # -- public API ----------------------------------------------------------

    def is_valid(self, document: Any, *, parsed: bool = False) -> bool:
        """Validate a document (a plain parsed-JSON value by default).

        Deeply nested documents raise a structured
        :class:`~repro.core.outcomes.DocumentDepthError` instead of an
        interpreter ``RecursionError`` (the same explicit bound the naive
        interpreter enforces at ``core/interpreter.py``) -- callers on
        the serving path convert it into a reject-with-reason.
        """
        try:
            doc = document if parsed else parse_document(document)
            if self._fn is not None:
                return self._fn(doc)
            return _eval_group(self.compiled.instructions, doc, self.ctx)
        except RecursionError:
            raise DocumentDepthError(
                "document nesting exceeds the evaluation stack"
            ) from None

    # paper terminology alias
    validate = is_valid

    def is_valid_bounded(
        self, document: Any, *, budget: ValidationBudget, parsed: bool = False
    ) -> bool:
        """Deadline/step-bounded validation for the fallback oracle.

        Raises :class:`~repro.core.outcomes.ValidationTimeout` when the
        document exhausts the budget's instruction steps, evaluation
        depth, or wall-clock deadline, and
        :class:`~repro.core.outcomes.DocumentDepthError` when parsing
        itself over-recurses -- depth bombs and pathological ``pattern``
        backtracking become structured rejects instead of a stalled
        engine.  Always runs the instruction interpreter: the codegen
        closures are the unmetered hot path, by design.
        """
        budget.check_deadline()
        try:
            doc = document if parsed else parse_document(document)
        except RecursionError:
            raise DocumentDepthError(
                "document nesting exceeds the parse stack"
            ) from None
        self.ctx.budget = budget
        try:
            return _eval_group(self.compiled.instructions, doc, self.ctx)
        except RecursionError:
            raise ValidationTimeout(
                "evaluation recursion exceeded the interpreter stack"
            ) from None
        finally:
            self.ctx.budget = None

    def explain(self, document: Any, *, parsed: bool = False):
        """Diagnostic validation (paper §8's error-message option).

        Returns (valid, trace) where ``trace`` is the failure chain of
        (schema keyword location, instruction name) pairs, innermost
        first.  Inside disjunctions the trace includes the failing
        candidates of every attempted branch -- exploratory entries are a
        feature for schema debugging, not an error.  Runs the interpreter
        engine regardless of the configured engine (the codegen closures
        do not carry locations, by design -- they are the hot path).
        """
        doc = document if parsed else parse_document(document)
        self.ctx.trace = []
        try:
            ok = _eval_group(self.compiled.instructions, doc, self.ctx)
            return ok, list(self.ctx.trace)
        finally:
            self.ctx.trace = None


# ---------------------------------------------------------------------------
# Core evaluation loop
# ---------------------------------------------------------------------------


def _eval_group(instructions: Instructions, value: Any, ctx: EvalContext) -> bool:
    """AND over a group; the loop terminates early on first failure (§5.1)."""
    budget = ctx.budget
    if budget is not None:
        # bounded fallback (DESIGN.md §11): meter instructions and bound
        # the evaluation recursion explicitly -- the clean path pays only
        # the None check above
        budget.enter_group()
        try:
            for inst in instructions:
                budget.tick()
                if not _eval_one(inst, value, ctx):
                    if ctx.trace is not None and inst.schema_path:
                        ctx.trace.append((inst.schema_path, type(inst).__name__))
                    return False
            return True
        finally:
            budget.exit_group()
    for inst in instructions:
        if not _eval_one(inst, value, ctx):
            if ctx.trace is not None and inst.schema_path:
                ctx.trace.append((inst.schema_path, type(inst).__name__))
            return False
    return True


def _eval_one(inst: Instruction, value: Any, ctx: EvalContext) -> bool:
    if inst.rel_path:
        target = _resolve(value, _cached_path(inst, ctx))
        if target is MISSING:
            return True  # absent location: skip (requiredness is Defines' job)
    else:
        target = value
    op = inst.op

    # ----- universal assertions ---------------------------------------------
    if op is OpCode.FAIL:
        return False
    if op is OpCode.TYPE:
        return has_type(target, inst.type)
    if op is OpCode.TYPE_ANY:
        return any(has_type(target, t) for t in inst.types)
    if op is OpCode.EQUAL:
        return json_equal(target, inst.value)
    if op is OpCode.EQUALS_ANY:
        return any(json_equal(target, v) for v in inst.values)

    # ----- object assertions (precondition: object) --------------------------
    if op is OpCode.DEFINES:
        if not isinstance(target, HashedObject):
            return True
        return _defines(target, inst.key_hash, inst.key, ctx)
    if op is OpCode.DEFINES_ALL:
        if not isinstance(target, HashedObject):
            return True
        for kh, k in zip(inst.key_hashes, inst.keys):
            if not _defines(target, kh, k, ctx):
                return False
        return True
    if op is OpCode.PROPERTY_DEPENDENCIES:
        if not isinstance(target, HashedObject):
            return True
        for key, kh, deps, dep_hashes in inst.dependencies:
            if _defines(target, kh, key, ctx):
                for dh, d in zip(dep_hashes, deps):
                    if not _defines(target, dh, d, ctx):
                        return False
        return True
    if op is OpCode.OBJECT_SIZE_GREATER:
        if not isinstance(target, HashedObject):
            return True
        return len(target) >= inst.bound
    if op is OpCode.OBJECT_SIZE_LESS:
        if not isinstance(target, HashedObject):
            return True
        return len(target) <= inst.bound
    if op is OpCode.PROPERTY_TYPE:
        if not isinstance(target, HashedObject):
            return True
        child = target.get_hashed(inst.key_hash, inst.key, MISSING)
        return child is not MISSING and has_type(child, inst.type)

    # ----- string assertions (precondition: string) ---------------------------
    if op is OpCode.REGEX:
        if not isinstance(target, str):
            return True
        if ctx.budget is not None and inst.plan.uses_engine:
            # engine regexes cannot be preempted mid-match: gate
            # backtracking-prone patterns / oversized subjects up front
            ctx.budget.regex_gate(inst.plan, len(target))
        return inst.plan.matches(target)
    if op is OpCode.STRING_SIZE_GREATER:
        if not isinstance(target, str):
            return True
        return len(target) >= inst.bound
    if op is OpCode.STRING_SIZE_LESS:
        if not isinstance(target, str):
            return True
        return len(target) <= inst.bound
    if op is OpCode.STRING_BOUNDS:
        if not isinstance(target, str):
            return True
        n = len(target)
        return n >= inst.min_len and (inst.max_len is None or n <= inst.max_len)
    if op is OpCode.STRING_TYPE:
        if not isinstance(target, str):
            return True
        return _check_format(inst.format, target)

    # ----- array assertions (precondition: array) ------------------------------
    if op is OpCode.UNIQUE:
        if not isinstance(target, list):
            return True
        seen = set()
        for item in target:
            c = canonical(item)
            if c in seen:
                return False
            seen.add(c)
        return True
    if op is OpCode.ARRAY_SIZE_GREATER:
        if not isinstance(target, list):
            return True
        return len(target) >= inst.bound
    if op is OpCode.ARRAY_SIZE_LESS:
        if not isinstance(target, list):
            return True
        return len(target) <= inst.bound
    if op is OpCode.ARRAY_BOUNDS:
        if not isinstance(target, list):
            return True
        n = len(target)
        return n >= inst.min_len and (inst.max_len is None or n <= inst.max_len)

    # ----- number assertions (precondition: number) ----------------------------
    if op is OpCode.GREATER:
        if isinstance(target, bool) or not isinstance(target, (int, float)):
            return True
        return target > inst.bound
    if op is OpCode.GREATER_EQUAL:
        if isinstance(target, bool) or not isinstance(target, (int, float)):
            return True
        return target >= inst.bound
    if op is OpCode.LESS:
        if isinstance(target, bool) or not isinstance(target, (int, float)):
            return True
        return target < inst.bound
    if op is OpCode.LESS_EQUAL:
        if isinstance(target, bool) or not isinstance(target, (int, float)):
            return True
        return target <= inst.bound
    if op is OpCode.NUMBER_BOUNDS:
        if isinstance(target, bool) or not isinstance(target, (int, float)):
            return True
        if inst.lo is not None:
            if inst.lo_exclusive:
                if not target > inst.lo:
                    return False
            elif not target >= inst.lo:
                return False
        if inst.hi is not None:
            if inst.hi_exclusive:
                if not target < inst.hi:
                    return False
            elif not target <= inst.hi:
                return False
        return True
    if op is OpCode.DIVISIBLE:
        if isinstance(target, bool) or not isinstance(target, (int, float)):
            return True
        return _divisible(target, inst.divisor)

    # ----- loops ------------------------------------------------------------
    if op is OpCode.LOOP_KEYS:
        if not isinstance(target, HashedObject):
            return True
        for _, key, _v in target.entries:
            if not _eval_group(inst.children, key, ctx):
                return False
        return True
    if op is OpCode.LOOP_PROPERTIES:
        if not isinstance(target, HashedObject):
            return True
        for _, _, v in target.entries:
            if not _eval_group(inst.children, v, ctx):
                return False
        return True
    if op is OpCode.LOOP_PROPERTIES_EXCEPT:
        if not isinstance(target, HashedObject):
            return True
        table = _except_table(inst, ctx)
        for h, key, v in target.entries:
            if _matches_static(table, h, key, ctx) or any(
                p.matches(key) for p in inst.exclude_patterns
            ):
                continue
            if not _eval_group(inst.children, v, ctx):
                return False
        return True
    if op is OpCode.LOOP_PROPERTIES_REGEX:
        if not isinstance(target, HashedObject):
            return True
        for _, key, v in target.entries:
            if inst.plan.matches(key) and not _eval_group(inst.children, v, ctx):
                return False
        return True
    if op is OpCode.LOOP_PROPERTIES_MATCH:
        if not isinstance(target, HashedObject):
            return True
        table = _match_table(inst, ctx)
        for h, key, v in target.entries:
            group = _lookup_match(table, h, key, ctx)
            if group is not None and not _eval_group(group, v, ctx):
                return False
        return True
    if op is OpCode.LOOP_PROPERTIES_MATCH_CLOSED:
        if not isinstance(target, HashedObject):
            return True
        table = _match_table(inst, ctx)
        for h, key, v in target.entries:
            group = _lookup_match(table, h, key, ctx)
            if group is None:
                # tolerated when a patternProperties pattern matches
                if any(p.matches(key) for p in inst.tolerate_patterns):
                    continue
                return False  # closed object: unknown property (§5.2)
            if not _eval_group(group, v, ctx):
                return False
        return True
    if op is OpCode.LOOP_ITEMS:
        if not isinstance(target, list):
            return True
        for item in target:
            if not _eval_group(inst.children, item, ctx):
                return False
        return True
    if op is OpCode.LOOP_ITEMS_FROM:
        if not isinstance(target, list):
            return True
        for i in range(inst.start, len(target)):
            if not _eval_group(inst.children, target[i], ctx):
                return False
        return True
    if op is OpCode.LOOP_CONTAINS:
        if not isinstance(target, list):
            return True
        count = 0
        max_c = inst.max_count
        for item in target:
            if _eval_group(inst.children, item, ctx):
                count += 1
                if max_c is not None and count > max_c:
                    return False  # early exit: already over the max
                if max_c is None and count >= inst.min_count:
                    return True  # early exit: satisfied, no upper bound
        return count >= inst.min_count and (max_c is None or count <= max_c)
    if op is OpCode.ARRAY_PREFIX:
        if not isinstance(target, list):
            return True
        for i, group in enumerate(inst.groups):
            if i >= len(target):
                break
            if not _eval_group(group, target[i], ctx):
                return False
        return True
    if op is OpCode.LOOP_UNEVALUATED_PROPERTIES:
        if not isinstance(target, HashedObject):
            return True
        return _eval_unevaluated_properties(inst, target, ctx)
    if op is OpCode.LOOP_UNEVALUATED_ITEMS:
        if not isinstance(target, list):
            return True
        return _eval_unevaluated_items(inst, target, ctx)

    # ----- logical ------------------------------------------------------------
    if op is OpCode.AND:
        return _eval_group(inst.children, target, ctx)
    if op is OpCode.OR:
        for group in inst.groups:
            if _eval_group(group, target, ctx):
                return True  # short-circuit on first success (§2.3)
        return False
    if op is OpCode.XOR:
        passed = 0
        for group in inst.groups:
            if _eval_group(group, target, ctx):
                passed += 1
                if passed > 1:
                    return False  # short-circuit: a second success decides
        return passed == 1
    if op is OpCode.NOT:
        return not _eval_group(inst.children, target, ctx)
    if op is OpCode.CONDITION:
        if _eval_group(inst.condition, target, ctx):
            return _eval_group(inst.then_children, target, ctx)
        return _eval_group(inst.else_children, target, ctx)
    if op is OpCode.WHEN_TYPE:
        if has_type(target, inst.type):
            return _eval_group(inst.children, target, ctx)
        return True
    if op is OpCode.WHEN_DEFINES:
        if isinstance(target, HashedObject) and _defines(target, inst.key_hash, inst.key, ctx):
            return _eval_group(inst.children, target, ctx)
        return True
    if op is OpCode.WHEN_ARRAY_SIZE_GREATER:
        if isinstance(target, list) and len(target) > inst.bound:
            return _eval_group(inst.children, target, ctx)
        return True
    if op is OpCode.WHEN_ARRAY_SIZE_EQUAL:
        if isinstance(target, list) and len(target) == inst.bound:
            return _eval_group(inst.children, target, ctx)
        return True

    # ----- control --------------------------------------------------------------
    if op is OpCode.CONTROL_LABEL:
        return _eval_group(inst.children, target, ctx)
    if op is OpCode.CONTROL_JUMP:
        return _eval_group(ctx.labels[inst.label], target, ctx)

    raise AssertionError(f"unhandled opcode {op!r}")


# ---------------------------------------------------------------------------
# Property matching helpers (hash fast path + string-compare ablation)
# ---------------------------------------------------------------------------


def _defines(obj: HashedObject, key_hash: int, key: str, ctx: EvalContext) -> bool:
    if ctx.use_hashing:
        return obj.defines_hashed(key_hash, key)
    return any(k == key for _, k, _ in obj.entries)


def _match_table(inst, ctx: EvalContext):
    """hash -> [(key, group)] built once per compiled instruction (§4.5)."""
    table = ctx._match_cache.get(id(inst))
    if table is None:
        if ctx.use_hashing:
            table = {}
            for key, h, group in inst.matches:
                table.setdefault(h, []).append((key, group))
        else:
            table = {key: group for key, _, group in inst.matches}
        ctx._match_cache[id(inst)] = table
    return table


def _lookup_match(table, h: int, key: str, ctx: EvalContext):
    from .hashing import is_short_hash

    if ctx.use_hashing:
        candidates = table.get(h)
        if not candidates:
            return None
        if is_short_hash(h):
            return candidates[0][1]  # perfect hash: no string compare (§4.1)
        for k, group in candidates:
            if k == key:
                return group
        return None
    return table.get(key)


def _except_table(inst, ctx: EvalContext):
    table = ctx._match_cache.get(id(inst))
    if table is None:
        if ctx.use_hashing:
            table = {}
            for key, h in zip(inst.exclude_keys, inst.exclude_hashes):
                table.setdefault(h, []).append(key)
        else:
            table = set(inst.exclude_keys)
        ctx._match_cache[id(inst)] = table
    return table


def _matches_static(table, h: int, key: str, ctx: EvalContext) -> bool:
    from .hashing import is_short_hash

    if ctx.use_hashing:
        candidates = table.get(h)
        if not candidates:
            return False
        if is_short_hash(h):
            return True
        return any(k == key for k in candidates)
    return key in table


# ---------------------------------------------------------------------------
# unevaluated* dynamic residues
# ---------------------------------------------------------------------------


def _eval_unevaluated_properties(inst, target: HashedObject, ctx: EvalContext) -> bool:
    names = set(inst.static_keys)
    patterns = list(inst.static_patterns)
    for guard, keys, _hashes, pats, sees_all in inst.branches:
        if _eval_group(guard, target, ctx):
            if sees_all:
                return True  # a validating branch evaluates everything
            names.update(keys)
            patterns.extend(pats)
    for _, key, v in target.entries:
        if key in names or any(p.matches(key) for p in patterns):
            continue
        if not _eval_group(inst.children, v, ctx):
            return False
    return True


def _eval_unevaluated_items(inst, target: list, ctx: EvalContext) -> bool:
    prefix = inst.static_prefix
    for guard, br_prefix, sees_all in inst.branches:
        if _eval_group(guard, target, ctx):
            if sees_all:
                return True
            prefix = max(prefix, br_prefix)
    # contains annotations apply only when their branch guard validates --
    # a contains inside a FAILED anyOf branch annotates nothing
    active_contains = [
        group
        for guard, group in inst.contains_groups
        if not guard or _eval_group(guard, target, ctx)
    ]
    for i in range(prefix, len(target)):
        item = target[i]
        if active_contains and any(
            _eval_group(g, item, ctx) for g in active_contains
        ):
            continue  # evaluated by contains (2020-12 annotation semantics)
        if not _eval_group(inst.children, item, ctx):
            return False
    return True


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def _divisible(value: float, divisor: float) -> bool:
    """Spec-exact ``multipleOf``.

    JSON numbers are decimal: ``19.99`` IS a multiple of ``0.01`` even
    though neither has an exact binary-float form and the float quotient
    comes out 1998.9999...  The float fast path decides the common case;
    inexact quotients are re-checked as exact rationals built from the
    shortest decimal representation (``repr`` round-trips floats, so
    this is the number the document actually wrote).
    """
    if divisor == 0:
        return False
    try:
        quotient = value / divisor
    except OverflowError:
        return False
    if quotient != quotient or quotient in (float("inf"), float("-inf")):
        return False
    # fast path only while floats still resolve integrality: at
    # |quotient| >= 2^53 every float is integral, so "looks integral"
    # proves nothing (1e30 is NOT a multiple of 7)
    if quotient == int(quotient) and abs(quotient) < 2.0**53:
        return True
    from fractions import Fraction

    try:
        return Fraction(repr(value)) % Fraction(repr(divisor)) == 0
    except (ValueError, ZeroDivisionError, OverflowError):
        return False


_FORMAT_CHECKS = {}


def _check_format(name: str, value: str) -> bool:
    """Light-weight `format` assertions (StringType, Table 1)."""
    import re as _re

    checks = _FORMAT_CHECKS
    if not checks:
        checks["uuid"] = _re.compile(
            r"^[0-9a-fA-F]{8}-[0-9a-fA-F]{4}-[0-9a-fA-F]{4}-[0-9a-fA-F]{4}-[0-9a-fA-F]{12}$"
        )
        checks["date"] = _re.compile(r"^\d{4}-\d{2}-\d{2}$")
        checks["date-time"] = _re.compile(
            r"^\d{4}-\d{2}-\d{2}[Tt]\d{2}:\d{2}:\d{2}(\.\d+)?([Zz]|[+-]\d{2}:\d{2})$"
        )
        checks["email"] = _re.compile(r"^[^@\s]+@[^@\s]+\.[^@\s]+$")
        checks["ipv4"] = _re.compile(
            r"^((25[0-5]|2[0-4]\d|1\d\d|[1-9]?\d)\.){3}(25[0-5]|2[0-4]\d|1\d\d|[1-9]?\d)$"
        )
        checks["uri"] = _re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")
    rx = checks.get(name)
    return True if rx is None else rx.match(value) is not None
