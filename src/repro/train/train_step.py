"""Sharded train / prefill / decode step builders.

``make_train_step`` returns a jitted function with explicit in/out
shardings and donated (params, opt_state) buffers.  Gradients inherit the
parameter sharding; XLA inserts the hierarchical (ICI-then-DCI) gradient
reduce-scatter/all-gather pairs implied by the FSDP specs, overlapping them
with the backward pass.

``make_dp_compressed_step`` is the pure-data-parallel variant built on
``shard_map`` with *explicit* collectives, enabling int8 gradient
compression with error feedback across the pod axis -- the
distributed-optimization trick for DCI-bound multi-pod deployments (tested
on CPU via host-device forks; see tests/test_distributed.py).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig
from ..models.model import Model
from ..sharding import activation_specs, cache_specs_tree, param_pspecs
from ..sharding.constraints import activation_sharding
from . import optimizer as opt

Params = Any


def _named(mesh: Mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def make_train_step(
    model: Model,
    ocfg: opt.OptimizerConfig,
    mesh: Mesh,
    *,
    batch: int,
    donate: bool = True,
    remat: bool = True,
):
    """Returns (step_fn, in_shardings, out_shardings) -- jit-wrapped."""
    cfg = model.cfg
    pspecs = param_pspecs(_abstract_params(model), mesh)
    acts = activation_specs(mesh, batch=batch, vocab=cfg.padded_vocab)

    def step(params, opt_state, batch_data):
        with activation_sharding(mesh, batch=batch, vocab=cfg.padded_vocab):
            def loss_fn(p):
                return model.loss(
                    p,
                    batch_data["tokens"],
                    batch_data["labels"],
                    batch_data.get("prefix"),
                    remat=remat,
                )

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_params, new_state, metrics = opt.update(ocfg, grads, opt_state, params)
            metrics = dict(metrics, loss=loss)
            return new_params, new_state, metrics

    params_sh = _named(mesh, pspecs)
    opt_sh = opt.OptState(
        step=NamedSharding(mesh, P()), m=params_sh, v=params_sh
    )
    batch_sh = {
        "tokens": NamedSharding(mesh, acts["tokens"]),
        "labels": NamedSharding(mesh, acts["labels"]),
    }
    if cfg.prefix_len:
        batch_sh["prefix"] = NamedSharding(mesh, acts["prefix"])
    metrics_sh = {
        k: NamedSharding(mesh, P()) for k in ("lr", "grad_norm", "loss")
    }
    jitted = jax.jit(
        step,
        in_shardings=(params_sh, opt_sh, batch_sh),
        out_shardings=(params_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, (params_sh, opt_sh, batch_sh), (params_sh, opt_sh, metrics_sh)


def _abstract_params(model: Model):
    """Shape-only params (no allocation) for sharding-rule resolution."""
    return jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def make_prefill_step(model: Model, mesh: Mesh, *, batch: int, max_len: int):
    cfg = model.cfg
    pspecs = param_pspecs(_abstract_params(model), mesh)
    acts = activation_specs(mesh, batch=batch, vocab=cfg.padded_vocab)
    params_sh = _named(mesh, pspecs)

    def prefill(params, tokens, prefix=None):
        with activation_sharding(mesh, batch=batch, vocab=cfg.padded_vocab):
            return model.prefill(params, tokens, max_len, prefix)

    abstract_cache = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    cache_specs = cache_specs_tree(abstract_cache, mesh, batch=batch, seq_sharded=False)
    out_sh = (
        NamedSharding(mesh, acts["logits"]),
        _named(mesh, cache_specs),
    )
    in_sh = [params_sh, NamedSharding(mesh, acts["tokens"])]
    if cfg.prefix_len:
        in_sh.append(NamedSharding(mesh, acts["prefix"]))
        return jax.jit(prefill, in_shardings=tuple(in_sh), out_shardings=out_sh), in_sh, out_sh
    fn = lambda params, tokens: prefill(params, tokens)
    return jax.jit(fn, in_shardings=tuple(in_sh), out_shardings=out_sh), in_sh, out_sh


def make_decode_step(
    model: Model, mesh: Mesh, *, batch: int, max_len: int, seq_sharded: bool = False
):
    """One-token serve_step against a (possibly sequence-sharded) cache."""
    cfg = model.cfg
    pspecs = param_pspecs(_abstract_params(model), mesh)
    params_sh = _named(mesh, pspecs)
    acts = activation_specs(mesh, batch=batch, vocab=cfg.padded_vocab)
    abstract_cache = jax.eval_shape(lambda: model.init_cache(batch, max_len))
    cache_specs = cache_specs_tree(abstract_cache, mesh, batch=batch, seq_sharded=seq_sharded)
    cache_sh = _named(mesh, cache_specs)
    token_sh = NamedSharding(mesh, acts["tokens"])

    def decode(params, token, cache, cache_len):
        with activation_sharding(mesh, batch=batch, vocab=cfg.padded_vocab):
            return model.decode_step(params, token, cache, cache_len)

    jitted = jax.jit(
        decode,
        in_shardings=(params_sh, token_sh, cache_sh, NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, acts["logits"]), cache_sh),
        donate_argnums=(2,),
    )
    return jitted, (params_sh, token_sh, cache_sh), cache_sh


# ---------------------------------------------------------------------------
# Pure-DP shard_map step with int8 gradient compression (pod axis)
# ---------------------------------------------------------------------------


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree: Params, axis: str) -> Params:
    """int8-quantized psum: quantize locally, sum int32, dequantize.

    Per-tensor scales are themselves psum-maxed so every shard dequantizes
    identically; the quantization error stays bounded by the max-scale.
    """

    def one(x):
        x32 = x.astype(jnp.float32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(x32)) / 127.0 + 1e-12, axis)
        q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis)
        return (total.astype(jnp.float32) * scale).astype(x.dtype)

    return jax.tree.map(one, tree)


def make_dp_compressed_step(
    model: Model,
    ocfg: opt.OptimizerConfig,
    mesh: Mesh,
    *,
    compress: bool = True,
    error_feedback: bool = True,
):
    """Data-parallel train step with explicit (optionally compressed)
    gradient all-reduce over every mesh axis.  Params are replicated;
    the batch is sharded over the leading axis."""
    from jax.experimental.shard_map import shard_map

    axes = mesh.axis_names
    batch_spec = P(axes)

    def step(params, opt_state, err, tokens, labels):
        def loss_fn(p):
            return model.loss(p, tokens, labels, remat=False)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        nd = 1
        for a in axes:
            nd *= mesh.shape[a]
        if compress:
            if error_feedback:
                grads = jax.tree.map(lambda g, e: g + e.astype(g.dtype), grads, err)
            summed = grads
            for a in axes:
                summed = compressed_psum(summed, a)
            mean = jax.tree.map(lambda g: g / nd, summed)
            # residual the compression error for the next step
            new_err = jax.tree.map(
                lambda g, s: (g - s / nd).astype(jnp.float32), grads, mean
            ) if error_feedback else err
            grads = mean
        else:
            for a in axes:
                grads = jax.tree.map(lambda g: jax.lax.pmean(g, a), grads)
            new_err = err
        loss = jax.lax.pmean(loss, axes[0]) if axes else loss
        new_params, new_state, metrics = opt.update(ocfg, grads, opt_state, params)
        return new_params, new_state, new_err, dict(metrics, loss=loss)

    rep = P()
    rep_tree = lambda tree: jax.tree.map(lambda _: rep, tree)
    abstract = _abstract_params(model)
    in_specs = (
        rep_tree(abstract),
        opt.OptState(step=rep, m=rep_tree(abstract), v=rep_tree(abstract)),
        rep_tree(abstract),
        batch_spec,
        batch_spec,
    )
    out_specs = (
        rep_tree(abstract),
        opt.OptState(step=rep, m=rep_tree(abstract), v=rep_tree(abstract)),
        rep_tree(abstract),
        {"lr": rep, "grad_norm": rep, "loss": rep},
    )
    mapped = shard_map(
        step, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
    return jax.jit(mapped)
