"""AdamW with global-norm clipping and warmup+cosine schedule (pure JAX).

Moment tensors inherit the parameter sharding (states are element-wise), so
FSDP-sharded parameters give ZeRO-sharded optimizer state for free.  Moment
dtype is configurable: the >300B MoE architectures keep m/v in bf16 to fit
v5e HBM (DESIGN.md §5).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_fraction: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * progress))
    floor = cfg.min_lr_fraction
    return cfg.learning_rate * warm * (floor + (1 - floor) * cosine)


def init(cfg: OptimizerConfig, params: Params) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def _decay_mask(path) -> bool:
    """Weight decay applies to matrices, not to norms/biases/scalars."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else ""
    return name not in ("scale", "decay_bias", "dt_bias", "conv_b", "bonus", "shift_mix")


def update(
    cfg: OptimizerConfig, grads: Params, state: OptState, params: Params
) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    dt = jnp.dtype(cfg.state_dtype)

    def upd(path, p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * g32
        v32 = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * jnp.square(g32)
        m_hat = m32 / (1 - cfg.beta1 ** step.astype(jnp.float32))
        v_hat = v32 / (1 - cfg.beta2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(dt), v32.astype(dt)

    flat = jax.tree_util.tree_map_with_path(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step=step, m=new_m, v=new_v), metrics
