"""Fault-tolerant sharded checkpointing.

Layout: one directory per step containing a msgpack manifest (pytree
structure, shapes, dtypes, crc32 per leaf) and one zstd-compressed raw
file per leaf.  Writes are atomic (tmp dir + rename) so a killed writer
never corrupts the `latest` pointer; saves can run asynchronously on a
background thread (training continues; the previous save is joined first).

Restore is *elastic*: leaves are loaded host-side and device_put with
whatever sharding the (possibly different-sized) restore mesh prescribes --
a 512-chip checkpoint restores onto 256 chips by resharding, which is the
node-failure recovery path exercised in tests/test_fault_tolerance.py.
"""

from __future__ import annotations

import json
import threading
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import msgpack
import numpy as np

try:  # optional: fall back to uncompressed leaves when unavailable
    import zstandard
except ImportError:  # pragma: no cover
    zstandard = None

Params = Any

_SEP = "\x1f"


def _flatten(tree: Params) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Params, *, block: bool = False) -> None:
        """Snapshot host-side, then write (optionally on a thread)."""
        self.wait()  # at most one in-flight save
        flat, _ = _flatten(tree)
        host = [(k, np.asarray(v)) for k, v in flat]  # device -> host copy

        def write():
            self._write(step, host)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]]) -> None:
        tmp = self.dir / f".tmp-{step}"
        final = self.dir / f"step_{step:010d}"
        if tmp.exists():
            import shutil

            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        cctx = zstandard.ZstdCompressor(level=3) if zstandard is not None else None
        manifest = {"step": step, "leaves": []}
        for i, (key, arr) in enumerate(host):
            raw = np.ascontiguousarray(arr).tobytes()
            payload = cctx.compress(raw) if cctx is not None else raw
            fname = f"leaf_{i:05d}.bin.zst" if cctx is not None else f"leaf_{i:05d}.bin"
            (tmp / fname).write_bytes(payload)
            manifest["leaves"].append(
                {
                    "key": key,
                    "file": fname,
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
                }
            )
        (tmp / "manifest.msgpack").write_bytes(msgpack.packb(manifest))
        if final.exists():
            import shutil

            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        (self.dir / "latest.tmp").write_text(final.name)
        (self.dir / "latest.tmp").rename(self.dir / "latest")

    def _gc(self) -> None:
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[: max(0, len(steps) - self.keep)]:
            import shutil

            shutil.rmtree(old, ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        pointer = self.dir / "latest"
        if not pointer.exists():
            return None
        name = pointer.read_text().strip()
        if not (self.dir / name).exists():
            return None
        return int(name.split("_")[1])

    def restore(
        self,
        like: Params,
        *,
        step: Optional[int] = None,
        shardings: Optional[Params] = None,
        strict_integrity: bool = True,
    ) -> Tuple[int, Params]:
        """Restore into the structure of ``like`` (shape/dtype template).

        ``shardings`` (a pytree of Sharding matching ``like``) places each
        leaf on the restore mesh -- elastic re-mesh is just a different
        shardings tree.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = self.dir / f"step_{step:010d}"
        manifest = msgpack.unpackb((path / "manifest.msgpack").read_bytes())
        by_key: Dict[str, dict] = {m["key"]: m for m in manifest["leaves"]}
        dctx = zstandard.ZstdDecompressor() if zstandard is not None else None

        flat, treedef = _flatten(like)
        shard_flat = None
        if shardings is not None:
            shard_flat = [s for _, s in _flatten(shardings)[0]]
        leaves = []
        for i, (key, template) in enumerate(flat):
            meta = by_key[key]
            payload = (path / meta["file"]).read_bytes()
            if meta["file"].endswith(".zst"):
                if dctx is None:
                    raise IOError(
                        "checkpoint uses zstd compression but zstandard is "
                        "not installed"
                    )
                raw = dctx.decompress(
                    payload,
                    max_output_size=int(np.prod(meta["shape"] or [1])) * 16 + 64,
                )
            else:
                raw = payload
            if strict_integrity and (zlib.crc32(raw) & 0xFFFFFFFF) != meta["crc32"]:
                raise IOError(f"checkpoint corruption in leaf {key} (crc mismatch)")
            arr = np.frombuffer(raw, dtype=meta["dtype"]).reshape(meta["shape"])
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jax.device_put(arr))
        return step, jax.tree_util.tree_unflatten(treedef, leaves)
