"""Fault-tolerant training supervision.

Wraps the jitted train step with the control-plane logic a 1000-node run
needs:

* periodic async checkpoints + resume-from-latest on (re)start;
* per-step NaN/Inf guard: a poisoned step rolls back to the last
  checkpoint and skips ahead past the offending data batch;
* bounded retry on transient step failures (device loss on real fleets);
* straggler watch: an EMA of step time flags slow steps and invokes a
  remesh callback (on real fleets: exclude the slow host and restore onto
  the smaller mesh -- the elastic path exercised by
  tests/test_fault_tolerance.py via CheckpointManager resharding);
* failure injection hooks so every path above is testable on CPU.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from .checkpoint import CheckpointManager

Params = Any


@dataclass
class SupervisorConfig:
    checkpoint_every: int = 100
    max_step_retries: int = 2
    straggler_factor: float = 3.0  # step slower than factor x EMA => flag
    straggler_ema: float = 0.9
    nan_rollback: bool = True


@dataclass
class StepRecord:
    step: int
    loss: float
    seconds: float
    retried: int = 0
    rolled_back: bool = False
    straggler: bool = False


class TrainSupervisor:
    def __init__(
        self,
        step_fn: Callable,
        ckpt: CheckpointManager,
        cfg: SupervisorConfig = SupervisorConfig(),
        *,
        on_straggler: Optional[Callable[[int], None]] = None,
        fault_injector: Optional[Callable[[int], None]] = None,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.cfg = cfg
        self.on_straggler = on_straggler
        self.fault_injector = fault_injector
        self.clock = clock  # injectable for deterministic straggler tests
        self.history: List[StepRecord] = []
        self._ema: Optional[float] = None

    # ------------------------------------------------------------------

    def resume_or_init(
        self, params: Params, opt_state: Params, shardings: Optional[Params] = None
    ) -> Tuple[int, Params, Params]:
        """Restore the latest checkpoint if one exists."""
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, params, opt_state
        bundle_like = {"params": params, "opt_state": opt_state}
        step, bundle = self.ckpt.restore(bundle_like, shardings=shardings)
        return step, bundle["params"], bundle["opt_state"]

    # ------------------------------------------------------------------

    def run(
        self,
        params: Params,
        opt_state: Params,
        batches: Iterator[Dict[str, Any]],
        *,
        start_step: int = 0,
        num_steps: int = 100,
    ) -> Tuple[Params, Params, List[StepRecord]]:
        step = start_step
        last_good = None
        for batch in batches:
            if step >= start_step + num_steps:
                break
            record = self._one_step(step, params, opt_state, batch)
            if record is None:  # NaN rollback: reload and skip this batch
                if last_good is None:
                    _, bundle = self.ckpt.restore(
                        {"params": params, "opt_state": opt_state}
                    )
                else:
                    bundle = last_good
                params, opt_state = bundle["params"], bundle["opt_state"]
                self.history.append(
                    StepRecord(step, float("nan"), 0.0, rolled_back=True)
                )
                step += 1
                continue
            params, opt_state, rec = record
            self.history.append(rec)
            if rec.straggler and self.on_straggler is not None:
                self.on_straggler(step)
            if self.cfg.checkpoint_every and (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, {"params": params, "opt_state": opt_state})
                last_good = {"params": params, "opt_state": opt_state}
            step += 1
        self.ckpt.wait()
        return params, opt_state, self.history

    # ------------------------------------------------------------------

    def _one_step(self, step: int, params, opt_state, batch):
        retries = 0
        while True:
            t0 = self.clock()
            try:
                if self.fault_injector is not None:
                    self.fault_injector(step)
                new_params, new_opt, metrics = self.step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
            except _InjectedFault:
                retries += 1
                if retries > self.cfg.max_step_retries:
                    raise
                continue
            dt = self.clock() - t0
            if self.cfg.nan_rollback and not np.isfinite(loss):
                return None
            straggler = False
            if self._ema is not None and dt > self.cfg.straggler_factor * self._ema:
                straggler = True
            a = self.cfg.straggler_ema
            self._ema = dt if self._ema is None else a * self._ema + (1 - a) * dt
            return new_params, new_opt, StepRecord(step, loss, dt, retries, False, straggler)


class _InjectedFault(RuntimeError):
    """Raised by test fault injectors to simulate transient device loss."""


def injected_fault() -> RuntimeError:
    return _InjectedFault("injected transient fault")
