"""granite-3-8b [dense]: GQA dense transformer.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155
[hf:ibm-granite/granite-3.0-2b-base; hf].
"""

from ..models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    period=(LayerSpec(mixer="attention", ffn="dense"),),
    supports_long_context=False,
    max_seq_len=32768,
)
