"""jamba-1.5-large-398b [hybrid]: Mamba+attention 1:7 interleave with MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf].  Period of 8 layers: one attention layer (index 4)
among seven Mamba layers; MoE replaces the dense FFN on every other layer.
Optimizer moments are kept in bf16 (398B params must fit v5e HBM;
DESIGN.md §5).
"""

from ..models.config import ArchConfig, LayerSpec, MoEConfig

_PERIOD = tuple(
    LayerSpec(
        mixer="attention" if i == 4 else "mamba",
        ffn="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    period=_PERIOD,
    moe=MoEConfig(num_experts=16, top_k=2),
    optimizer_state_dtype="bfloat16",
    supports_long_context=True,  # SSM-dominant: runs long_500k
    max_seq_len=524288,
)
