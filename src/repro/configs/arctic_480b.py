"""arctic-480b [moe]: 128-expert top-2 MoE with a parallel dense residual.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf].  Dense-MoE hybrid: every layer
evaluates a small dense SwiGLU in parallel with the MoE.  Optimizer
moments bf16 (480B total parameters; DESIGN.md §5).
"""

from ..models.config import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    period=(LayerSpec(mixer="attention", ffn="moe"),),
    moe=MoEConfig(num_experts=128, top_k=2, dense_residual_ff=4864),
    optimizer_state_dtype="bfloat16",
    supports_long_context=False,
    max_seq_len=32768,
)
