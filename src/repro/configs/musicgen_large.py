"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 = full MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf].  The EnCodec audio frontend is a stub: precomputed
frame embeddings arrive via ``prefix_embeddings`` (see launch/specs.py).
"""

from ..models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    period=(LayerSpec(mixer="attention", ffn="dense"),),
    prefix_len=256,  # EnCodec frame-embedding stub
    supports_long_context=False,  # pure full attention: skip long_500k
    max_seq_len=32768,
)
