"""moonshot-v1-16b-a3b [moe]: Moonlight 64-expert top-6 MoE.

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf].
"""

from ..models.config import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    period=(LayerSpec(mixer="attention", ffn="moe"),),
    moe=MoEConfig(num_experts=64, top_k=6),
    supports_long_context=False,
    max_seq_len=32768,
)
