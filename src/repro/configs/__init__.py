"""Assigned-architecture registry: ``get_config(name)`` / ``ARCHS``."""

from importlib import import_module
from typing import Dict, List

from ..models.config import ArchConfig

_MODULES = {
    "musicgen-large": "musicgen_large",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "arctic-480b": "arctic_480b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "internvl2-76b": "internvl2_76b",
    "qwen1.5-32b": "qwen1_5_32b",
    "starcoder2-7b": "starcoder2_7b",
    "granite-3-8b": "granite_3_8b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCHS: List[str] = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {name: get_config(name) for name in ARCHS}
