"""starcoder2-7b [dense]: GQA + RoPE code model.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152
[arXiv:2402.19173; hf].
"""

from ..models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    period=(LayerSpec(mixer="attention", ffn="dense"),),
    supports_long_context=False,
    max_seq_len=32768,
)
