"""qwen1.5-32b [dense]: QKV-bias dense transformer.

64L d_model=5120 40H (GQA kv=40 = MHA) d_ff=27392 vocab=152064
[hf:Qwen/Qwen1.5-0.5B; hf].
"""

from ..models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    period=(LayerSpec(mixer="attention", ffn="dense"),),
    qkv_bias=True,
    # full MHA (kv=40): the 32k decode cache is 21.5 GiB/chip in bf16 --
    # int8 KV quantization is what makes this arch servable on v5e
    kv_cache_dtype="int8",
    supports_long_context=False,
    max_seq_len=32768,
)
