"""internvl2-76b [vlm]: InternLM2-style dense backbone (InternViT stubbed).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified].  The ViT frontend is a stub: precomputed
patch embeddings arrive via ``prefix_embeddings``.
"""

from ..models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    period=(LayerSpec(mixer="attention", ffn="dense"),),
    prefix_len=256,  # ViT patch-embedding stub
    supports_long_context=False,
    max_seq_len=32768,
)
