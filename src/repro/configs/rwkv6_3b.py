"""rwkv6-3b [ssm]: Finch -- attention-free, data-dependent decay.

32L d_model=2560 (attn-free) d_ff=8960 vocab=65536 [arXiv:2404.05892; hf].
Time-mix (rwkv6) + channel-mix blocks; O(1)-state decode runs long_500k.
"""

from ..models.config import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # rwkv head count: d_model / 64
    n_kv_heads=40,
    d_ff=8960,
    vocab_size=65536,
    period=(LayerSpec(mixer="rwkv6", ffn="none"),),
    supports_long_context=True,
    max_seq_len=524288,
)
