"""Serving engine: Blaze admission on the request path + batched decode.

The paper's motivating deployment is an API gateway validating every
request before the expensive work.  Here the expensive work is LM
inference: ``submit`` validates the JSON request against the request
schema (compiled Blaze validator -- the latency-critical path the paper
measures), tokenizes the prompt, and assigns a batch slot; ``step``
prefills newly admitted requests and decodes one token for every active
slot.  Slot bookkeeping is a miniature continuous-batching scheduler.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import Validator, compile_schema
from ..data import tokenizer
from ..models.config import ArchConfig
from ..models.model import Model

REQUEST_SCHEMA: Dict[str, Any] = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "type": "object",
    "required": ["prompt"],
    "additionalProperties": False,
    "properties": {
        "prompt": {"type": "string", "minLength": 1, "maxLength": 65536},
        "max_tokens": {"type": "integer", "minimum": 1, "maximum": 4096},
        "temperature": {"type": "number", "minimum": 0, "maximum": 2},
        "top_k": {"type": "integer", "minimum": 1, "maximum": 1000},
        "stop": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
        "stream": {"type": "boolean"},
        "metadata": {
            "type": "object",
            "propertyNames": {"maxLength": 64},
            "additionalProperties": {"type": "string"},
        },
    },
}


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 512
    default_max_tokens: int = 32
    greedy: bool = True


@dataclass
class _Slot:
    request_id: int
    tokens: List[int]
    generated: List[int] = field(default_factory=list)
    max_tokens: int = 32
    length: int = 0
    done: bool = False


@dataclass
class ServeStats:
    received: int = 0
    rejected: int = 0
    admitted: int = 0
    completed: int = 0
    validation_seconds: float = 0.0
    decode_steps: int = 0


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        serve_cfg: ServeConfig = ServeConfig(),
        request_schema: Optional[Dict[str, Any]] = None,
    ):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.scfg = serve_cfg
        # compiled ONCE; validated per request -- the paper's AOT bet
        # (codegen engine: the fastest path on the request-critical path)
        self.validator = Validator(
            compile_schema(request_schema or REQUEST_SCHEMA), engine="codegen"
        )
        self.stats = ServeStats()
        self.slots: List[Optional[_Slot]] = [None] * serve_cfg.batch_slots
        self.queue: List[_Slot] = []
        self._next_id = 0
        self.results: Dict[int, str] = {}
        self._decode = jax.jit(self.model.decode_step)
        self._cache = None

    # -- admission ------------------------------------------------------------

    def submit(self, request_json: str) -> Tuple[Optional[int], str]:
        """Validate + enqueue a request.  Returns (request_id, error)."""
        self.stats.received += 1
        try:
            request = json.loads(request_json)
        except json.JSONDecodeError as exc:
            self.stats.rejected += 1
            return None, f"malformed JSON: {exc}"
        t0 = time.perf_counter()
        ok = self.validator.is_valid(request)
        self.stats.validation_seconds += time.perf_counter() - t0
        if not ok:
            self.stats.rejected += 1
            return None, "schema validation failed"
        slot = _Slot(
            request_id=self._next_id,
            tokens=tokenizer.encode(request["prompt"], eos=False),
            max_tokens=request.get("max_tokens", self.scfg.default_max_tokens),
        )
        self._next_id += 1
        self.queue.append(slot)
        self.stats.admitted += 1
        return slot.request_id, ""

    # -- execution ------------------------------------------------------------

    def _admit_to_slots(self) -> None:
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                slot = self.queue.pop(0)
                logits, cache = self.model.prefill(
                    self.params,
                    jnp.asarray([slot.tokens], jnp.int32),
                    max_len=self.scfg.max_len,
                )
                slot.length = len(slot.tokens)
                next_tok = int(jnp.argmax(logits[0, -1]))
                slot.generated.append(next_tok)
                if self._cache is None:
                    self._cache = self.model.init_cache(
                        self.scfg.batch_slots, self.scfg.max_len
                    )
                self._cache = _write_slot_cache(self._cache, cache, i)
                self.slots[i] = slot

    def step(self) -> int:
        """One engine tick: admit, decode one token for all active slots."""
        self._admit_to_slots()
        active = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        max_len_now = max(s.length + len(s.generated) for _, s in active)
        tokens = np.full((self.scfg.batch_slots, 1), tokenizer.PAD, np.int32)
        for i, s in active:
            tokens[i, 0] = s.generated[-1] if s.generated else s.tokens[-1]
        logits, self._cache = self._decode(
            self.params, jnp.asarray(tokens), self._cache, jnp.int32(max_len_now)
        )
        self.stats.decode_steps += 1
        for i, s in active:
            nxt = int(jnp.argmax(logits[i, 0]))
            s.generated.append(nxt)
            if nxt == tokenizer.EOS or len(s.generated) >= s.max_tokens:
                s.done = True
                self.results[s.request_id] = tokenizer.decode(s.generated)
                self.stats.completed += 1
                self.slots[i] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, str]:
        steps = 0
        while (any(self.slots) or self.queue) and steps < max_steps:
            self.step()
            steps += 1
        return dict(self.results)


def _write_slot_cache(batch_cache, slot_cache, slot_idx: int):
    """Copy a prefilled single-request cache into batch slot ``slot_idx``."""

    def write(dst, src):
        if dst.ndim >= 2 and src.shape[0] == dst.shape[0]:  # (periods, B, ...)
            if src.shape[1] == 1 and dst.shape[1] > 1:
                return dst.at[:, slot_idx].set(src[:, 0].astype(dst.dtype))
        return dst

    return jax.tree.map(write, batch_cache, slot_cache)
