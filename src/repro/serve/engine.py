"""Serving engine: Blaze admission on the request path + batched decode.

The paper's motivating deployment is an API gateway validating every
request before the expensive work.  Here the expensive work is LM
inference: ``submit`` validates the JSON request against its endpoint's
schema (compiled Blaze validator -- the latency-critical path the paper
measures), tokenizes the prompt, and assigns a batch slot; ``step``
prefills newly admitted requests and decodes one token for every active
slot.  Slot bookkeeping is a miniature continuous-batching scheduler.

Multi-tenant routing: the engine owns a
:class:`~repro.registry.SchemaRegistry` of per-endpoint request schemas
(endpoint ``"default"`` always exists).  ``submit`` validates one
request sequentially; ``submit_batch`` admits a mixed-endpoint burst in
one batched launch per link group (DESIGN.md §14), falling back to each
endpoint's sequential validator only for undecided rows and endpoints
outside the structural subset.

Streaming traffic goes through :meth:`ServeEngine.scheduler`
(``serve/scheduler.py``): a latency-budget micro-batcher that queues
individual requests per link group and drains them through the same
admission path, routing each drain batched-vs-sequential by a measured
cost model.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.outcomes import ValidationOutcome
from ..data import tokenizer
from ..models.config import ArchConfig
from ..models.model import Model
from ..obs.events import EventLog
from ..obs.metrics import DEFAULT_LATENCY_BUCKETS, Histogram, MetricRegistry
from ..obs.profile import phase as _phase
from ..obs.slo import SLObjective, slo_status
from ..obs.stats import RegistryBackedStats
from ..obs.trace import span as _span
from ..registry import SchemaRegistry
from ..registry.registry import RegistrationError

REQUEST_SCHEMA: Dict[str, Any] = {
    "$schema": "https://json-schema.org/draft/2020-12/schema",
    "type": "object",
    "required": ["prompt"],
    "additionalProperties": False,
    "properties": {
        "prompt": {"type": "string", "minLength": 1, "maxLength": 65536},
        "max_tokens": {"type": "integer", "minimum": 1, "maximum": 4096},
        "temperature": {"type": "number", "minimum": 0, "maximum": 2},
        "top_k": {"type": "integer", "minimum": 1, "maximum": 1000},
        "stop": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
        "stream": {"type": "boolean"},
        "metadata": {
            "type": "object",
            "propertyNames": {"maxLength": 64},
            "additionalProperties": {"type": "string"},
        },
    },
}


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 512
    default_max_tokens: int = 32
    greedy: bool = True
    admission_max_nodes: int = 128  # token-table budget for submit_batch


# default latency objective: 99% of requests within 100ms (a bucket edge
# is deliberately NOT required -- obs/slo.py interpolates; see §13)
DEFAULT_SLO = SLObjective(objective_s=0.1, target=0.99)


@dataclass
class _Slot:
    request_id: int
    tokens: List[int]
    generated: List[int] = field(default_factory=list)
    max_tokens: int = 32
    length: int = 0
    done: bool = False


class SubmitResult(tuple):
    """A ``(request_id, error)`` pair that also carries the structured
    :class:`ValidationOutcome`.

    Subclassing ``tuple`` keeps every existing call site working
    (``rid, err = engine.submit(...)``) while new code reads
    ``result.outcome`` instead of string-matching the error."""

    outcome: ValidationOutcome

    def __new__(
        cls, request_id: Optional[int], error: str, outcome: ValidationOutcome
    ) -> "SubmitResult":
        self = super().__new__(cls, (request_id, error))
        self.outcome = outcome
        return self

    @property
    def request_id(self) -> Optional[int]:
        return self[0]

    @property
    def error(self) -> str:
        return self[1]


class ServeStats(RegistryBackedStats):
    """Serving counters, registry-backed (DESIGN.md §12).

    The attribute API is unchanged (``stats.received``,
    ``stats.by_endpoint`` ...) but every field is now a live child of a
    :class:`~repro.obs.metrics.MetricRegistry` -- one
    ``render_prometheus()`` exports the whole serving surface.
    ``outcomes`` pre-populates every :class:`ValidationOutcome` key with
    0, so reconciliation (``received == sum(outcomes.values())``) reads
    the same whether or not an outcome has occurred yet.
    """

    PREFIX = "serve_"
    INT_FIELDS = (
        "received",
        "rejected",
        "admitted",
        "completed",
        "decode_steps",
        "batch_validated",  # verdicts from the linked-tape launch
        "fallback_validated",  # sequential (unbatchable or undecided)
        "validated_only",  # admitted without a decodable text field
        # why batchable rows fell back (distinct causes, never conflated):
        "undecided",  # executor depth budget
        "oversize",  # encoder node budget
        "unroll_overflow",  # $ref-unroll frontier reached
    )
    FLOAT_FIELDS = ("validation_seconds",)
    HELP = {
        "received": "requests received (exactly one outcome each)",
        "validation_seconds": "wall seconds inside admission validation",
    }

    def __init__(self, metrics: Optional[MetricRegistry] = None):
        super().__init__(metrics)
        # endpoint -> real try_build_tape failure reason (registration-
        # time info, not traffic): a plain dict that survives reset()
        self.fallback_reasons: Dict[str, str] = {}
        # terminal disposition per received document (DESIGN.md §11):
        # one ValidationOutcome value each -- pre-created so the view
        # always carries every key
        self._outcome_c = {
            o.value: self._track(
                self.metrics.counter(
                    "serve_outcomes_total",
                    "terminal dispositions by outcome",
                    outcome=o.value,
                )
            )
            for o in ValidationOutcome
        }
        self._ep_c: Dict[str, Dict[str, Any]] = {}

    @property
    def outcomes(self) -> Dict[str, int]:
        """outcome value -> count; all ValidationOutcome keys present."""
        return {k: int(c.value) for k, c in self._outcome_c.items()}

    @property
    def by_endpoint(self) -> Dict[str, Dict[str, int]]:
        return {
            e: {r: int(c.value) for r, c in per.items()}
            for e, per in self._ep_c.items()
        }

    def _ep(self, endpoint: str) -> Dict[str, Any]:
        per = self._ep_c.get(endpoint)
        if per is None:
            # both result labels exist from first touch, so the view
            # always shows {"admitted": n, "rejected": m}
            per = self._ep_c[endpoint] = {
                r: self._track(
                    self.metrics.counter(
                        "serve_endpoint_requests_total",
                        "per-endpoint admission results",
                        endpoint=endpoint,
                        result=r,
                    )
                )
                for r in ("admitted", "rejected")
            }
        return per

    def count(self, endpoint: str, key: str) -> None:
        self._ep(endpoint)[key].inc()

    def record_outcome(self, outcome: ValidationOutcome) -> None:
        self._outcome_c[outcome.value].inc()

    def snapshot(self) -> Dict[str, Any]:
        snap = super().snapshot()
        snap["outcomes"] = self.outcomes
        snap["by_endpoint"] = self.by_endpoint
        snap["fallback_reasons"] = dict(self.fallback_reasons)
        return snap


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params: Any,
        serve_cfg: ServeConfig = ServeConfig(),
        request_schema: Optional[Dict[str, Any]] = None,
        endpoint_schemas: Optional[Dict[str, Any]] = None,
        registry: Optional[SchemaRegistry] = None,
        events: Optional[EventLog] = None,
        slo: Optional[SLObjective] = None,
    ):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.scfg = serve_cfg
        # sampled request-event ring (obs/events.py); None = detached,
        # and the hot path pays exactly one None check per request
        self.events = events
        self._batch_seq = 0  # submit_batch launch counter -> batch ids
        # per-endpoint latency objectives (obs/slo.py); endpoints without
        # an override share the engine default
        self.slo_default = slo if slo is not None else DEFAULT_SLO
        self._slo: Dict[str, SLObjective] = {}
        # compiled ONCE per endpoint; validated per request -- the paper's
        # AOT bet (codegen engine on the request-critical path).  The
        # registry also links all batchable endpoint tapes for
        # submit_batch's single-launch mixed admission.
        self.registry = registry if registry is not None else SchemaRegistry()
        # one shared MetricRegistry across engine + registry + executor:
        # a single render_prometheus() exports the whole serving surface
        self.stats = ServeStats(self.registry.metrics)
        self._lat: Dict[str, Histogram] = {}
        if request_schema is not None or "default" not in self.registry:
            self.register_endpoint("default", request_schema or REQUEST_SCHEMA)
        for name, schema in (endpoint_schemas or {}).items():
            self.register_endpoint(name, schema)
        # endpoints already present on a caller-provided registry get
        # their fallback reasons surfaced too
        self.stats.fallback_reasons.update(self.registry.fallback_reasons())
        self.slots: List[Optional[_Slot]] = [None] * serve_cfg.batch_slots
        self.queue: List[_Slot] = []
        self._next_id = 0
        self.results: Dict[int, str] = {}
        self._decode = jax.jit(self.model.decode_step)
        self._cache = None

    # -- admission ------------------------------------------------------------

    def register_endpoint(self, endpoint: str, schema: Any):
        """Register (or hot-swap) an endpoint schema, surfacing the real
        tape-build outcome in the engine's stats: endpoints outside the
        structural subset record their ``try_build_tape`` reason string
        instead of a generic fallback flag.

        Hot-swap safety: the registry builds, smoke-verifies, and
        trial-links the new version *before* swapping.  A failed swap on
        an already-serving endpoint keeps the prior version serving and
        surfaces the failure in :meth:`endpoint_stats` (``last_swap_error``)
        rather than raising into the control plane; a failed *first*
        registration has no prior version to fall back to and re-raises.
        """
        try:
            entry = self.registry.register(endpoint, schema)
        except RegistrationError:
            if endpoint in self.registry:
                return self.registry.get(endpoint)  # prior version serves on
            raise
        if entry.stats.batchable:
            self.stats.fallback_reasons.pop(endpoint, None)
        else:
            self.stats.fallback_reasons[endpoint] = entry.stats.fallback_reason
        return entry

    def endpoint_stats(self) -> Dict[str, Dict[str, Any]]:
        """Per-endpoint serving view: admission counters merged with the
        registry's compile-time facts (batchable, fallback reason, tape
        shape, unroll budget/frontiers)."""
        out: Dict[str, Dict[str, Any]] = {}
        swap_failures = self.registry.swap_failures()
        swap_verdicts = self.registry.swap_verdicts()
        for endpoint in self.registry.endpoints():
            entry = self.registry.get(endpoint)
            per: Dict[str, Any] = dict(
                self.stats.by_endpoint.get(endpoint, {"admitted": 0, "rejected": 0})
            )
            per["version"] = entry.version
            per["batchable"] = entry.stats.batchable
            per["fallback_reason"] = entry.stats.fallback_reason
            # compiled tape shape (SchemaStats): the batched-cost model's
            # inputs -- window bound A-hat, hash-run bound K, location
            # horizon, circuit count, unroll budget, frontier count
            per["a_hat"] = entry.stats.a_hat
            per["k"] = entry.stats.k
            per["horizon"] = entry.stats.horizon
            per["n_circuits"] = entry.stats.n_circuits
            per["unroll_depth"] = entry.stats.unroll_depth
            per["n_frontier"] = entry.stats.n_frontier
            # link-group placement (DESIGN.md §14): the group-local
            # linked windows are what this endpoint actually pays per
            # launch -- compare with the solo a_hat/horizon above to
            # read the residual member-max inflation
            group = self.registry.group_of(endpoint)
            per["link_group"] = "" if group is None else group.label
            per["group_members"] = 0 if group is None else len(group.members)
            per["group_a_hat"] = (
                0 if group is None else int(group.tape.max_rows_per_loc)
            )
            per["group_m_hat"] = (
                0 if group is None else int(group.tape.max_member_props)
            )
            per["group_horizon"] = (
                0 if group is None else int(group.tape.max_loc_depth) + 1
            )
            per["last_swap_error"] = swap_failures.get(endpoint, "")
            # schema-algebra posture (DESIGN.md §15): what register()-time
            # analysis proved/rewrote for the serving version, plus the
            # subsumption verdict of the most recent hot-swap attempt
            per["analysis_normalized"] = entry.stats.normalized
            per["pruned_branches"] = entry.stats.pruned_branches
            per["folded_assertions"] = entry.stats.folded_assertions
            per["dedup_subgraphs"] = entry.stats.dedup_subgraphs
            per["analysis_failure"] = entry.stats.analysis_failure
            per["last_swap_subsumption"] = swap_verdicts.get(endpoint, "")
            breaker = self.registry.breaker(endpoint)
            per["breaker_state"] = breaker.state
            per["breaker_trips"] = breaker.trips
            per["slo"] = self.slo_status(endpoint)
            out[endpoint] = per
        return out

    def _latency(self, endpoint: str) -> Histogram:
        """Per-endpoint request-latency histogram (one observation per
        received request; unknown endpoints share ``__unknown__``)."""
        h = self._lat.get(endpoint)
        if h is None:
            h = self._lat[endpoint] = self.registry.metrics.histogram(
                "serve_request_seconds",
                "request wall time through submit/submit_batch",
                buckets=DEFAULT_LATENCY_BUCKETS,
                endpoint=endpoint,
            )
        return h

    # -- SLO tracking (obs/slo.py, DESIGN.md §13) -----------------------------

    def set_slo(self, endpoint: str, objective: SLObjective) -> None:
        """Override the latency objective for one endpoint."""
        self._slo[endpoint] = objective

    def slo_status(self, endpoint: str) -> Dict[str, Any]:
        """Cumulative SLO view of one endpoint, computed straight from
        its ``serve_request_seconds`` histogram -- no second measurement
        path.  Also refreshes the exported SLO gauges, so calling this
        (or :meth:`endpoint_stats`/:meth:`render_metrics`) keeps the
        Prometheus surface current."""
        objective = self._slo.get(endpoint, self.slo_default)
        st = slo_status(self._latency(endpoint), objective)
        m = self.registry.metrics
        m.gauge(
            "serve_slo_good_ratio",
            "fraction of requests within the endpoint's latency objective",
            endpoint=endpoint,
        ).set(st["good_ratio"])
        m.gauge(
            "serve_slo_burn_rate",
            "error-budget burn rate (1.0 = budget consumed exactly on time)",
            endpoint=endpoint,
        ).set(st["burn_rate"])
        return st

    # -- event log (obs/events.py, DESIGN.md §13) -----------------------------

    def attach_event_log(self, events: Optional[EventLog]) -> None:
        """Attach (or detach with None) the sampled request-event ring."""
        self.events = events

    def flush_events(self, dest) -> int:
        """Flush the attached event ring to ``dest`` (path or file
        object) as JSONL; returns the record count (0 when detached)."""
        if self.events is None:
            return 0
        return self.events.flush(dest)

    def metrics_snapshot(self) -> Dict[str, Any]:
        """JSON-ready snapshot of the shared metric registry."""
        return self.registry.metrics.snapshot()

    def render_metrics(self) -> str:
        """Prometheus exposition of the shared metric registry
        (SLO gauges refreshed first so they are never stale)."""
        for endpoint in self.registry.endpoints():
            self.slo_status(endpoint)
        return self.registry.metrics.render_prometheus()

    @property
    def validator(self):
        """The default endpoint's serving validator (hot-swap aware)."""
        return self.registry.get("default").validator

    def submit(
        self,
        request_json: str,
        endpoint: str = "default",
        *,
        explain: bool = False,
    ) -> SubmitResult:
        """Validate + enqueue one request.

        Returns a :class:`SubmitResult` -- unpackable as the historical
        ``(request_id, error)`` pair, with the structured
        ``ValidationOutcome`` on ``.outcome``.  Validation runs through
        the registry's containment ladder: resource guard, then the
        breaker-gated deadline-bounded sequential oracle.

        ``explain=True`` opts into first-failure attribution: INVALID
        rejects carry the attributed site in the error string instead of
        the generic message.  The default path is unchanged.
        """
        t_start = time.perf_counter()
        # per-stage timings flow into the sampled event record only when
        # a log is attached (stages=None keeps the hot path timer-free)
        stages: Optional[Dict[str, float]] = {} if self.events is not None else None
        result: Optional[SubmitResult] = None
        try:
            with _span("serve.submit", endpoint=endpoint):
                result = self._submit_one(request_json, endpoint, explain, stages)
                return result
        finally:
            label = endpoint if endpoint in self.registry else "__unknown__"
            latency = time.perf_counter() - t_start
            self._latency(label).observe(latency)
            ev = self.events
            if ev is not None and ev.want():
                ev.emit(
                    kind="submit",
                    endpoint=label,
                    request_id=None if result is None else result.request_id,
                    outcome="error" if result is None else result.outcome.value,
                    latency_s=latency,
                    stages=stages or {},
                )

    def _submit_one(
        self,
        request_json: str,
        endpoint: str,
        explain: bool,
        stages: Optional[Dict[str, float]] = None,
    ) -> SubmitResult:
        self.stats.received += 1
        serial = self.stats.received
        t0 = time.perf_counter()
        request, err = self._parse(request_json, endpoint)
        if stages is not None:
            stages["parse_s"] = time.perf_counter() - t0
        if err:
            return SubmitResult(None, err, ValidationOutcome.REJECTED_GUARD)
        t0 = time.perf_counter()
        with _span("serve.validate", endpoint=endpoint):
            verdict = self.registry.validate_one(
                endpoint, request, key=("submit", serial), explain=explain
            )
        dt = time.perf_counter() - t0
        if stages is not None:
            stages["validate_s"] = dt
        self.stats.validation_seconds += dt
        if verdict.outcome in (
            ValidationOutcome.ADMITTED,
            ValidationOutcome.INVALID,
        ):
            self.stats.fallback_validated += 1  # the sequential oracle ran
        return self._finish(endpoint, request, verdict)

    def _finish(self, endpoint: str, request: Any, verdict) -> SubmitResult:
        """One verdict -> one terminal :class:`SubmitResult`: outcome
        accounting, enqueue on admit, canonical error string on reject.
        Shared by ``submit``, ``submit_batch``, and the streaming
        scheduler so all three produce identical results for identical
        verdicts."""
        self.stats.record_outcome(verdict.outcome)
        if verdict.admitted:
            return SubmitResult(
                self._enqueue(request, endpoint), "", verdict.outcome
            )
        self.stats.rejected += 1
        self.stats.count(endpoint, "rejected")
        if verdict.outcome is ValidationOutcome.INVALID:
            err = verdict.reason if verdict.site is not None else (
                "schema validation failed"
            )
        else:
            err = f"{verdict.outcome.value}: {verdict.reason}"
        return SubmitResult(None, err, verdict.outcome)

    def submit_batch(
        self,
        requests: Sequence[Tuple[str, str]],
        *,
        explain: bool = False,
    ) -> List[SubmitResult]:
        """Admit a mixed-endpoint burst of (endpoint, request_json) pairs.

        All parseable requests are validated in one batched launch per
        link group (DESIGN.md §14); only undecided rows and endpoints
        outside the structural subset take the (bounded) sequential
        fallback.  Per-document faults are isolated: a poison row gets an
        ERROR_ISOLATED result while every other row's verdict is
        bit-identical to a fault-free batch.  Returns a
        :class:`SubmitResult` per input, in order.

        ``explain=True`` opts into batched first-failure attribution
        (one extra explain launch over the already-encoded table);
        INVALID results carry the attributed site in their error string.
        Latency accounting: exactly one ``serve_request_seconds``
        observation per received request -- the burst's validation wall
        time amortized evenly over its validated rows, and the *true*
        admission->verdict wall (batch entry to the parse/guard reject)
        for rows rejected before validation, so SLO burn rates never
        under-count rejected traffic.
        """
        batch_id = self._batch_seq
        self._batch_seq += 1
        t_batch = time.perf_counter()
        with _span("serve.submit_batch", batch=len(requests)):
            out: List[Optional[SubmitResult]] = [None] * len(requests)
            parsed: List[Tuple[int, str, Any, int]] = []
            guard_rejected: List[Tuple[int, str, float]] = []
            with _phase("serve.parse"):
                for i, (endpoint, request_json) in enumerate(requests):
                    self.stats.received += 1
                    serial = self.stats.received
                    request, err = self._parse(request_json, endpoint)
                    if err:
                        out[i] = SubmitResult(
                            None, err, ValidationOutcome.REJECTED_GUARD
                        )
                        guard_rejected.append(
                            (
                                i,
                                endpoint
                                if endpoint in self.registry
                                else "__unknown__",
                                time.perf_counter() - t_batch,
                            )
                        )
                    else:
                        parsed.append((i, endpoint, request, serial))
            if parsed:
                docs = [r for _, _, r, _ in parsed]
                endpoints = [e for _, e, _, _ in parsed]
                keys = [("batch", s) for _, _, _, s in parsed]
                t0 = time.perf_counter()
                with _phase("serve.validate"), _span(
                    "serve.validate", batch=len(parsed)
                ):
                    verdicts, counts = self.registry.admit_mixed_ex(
                        docs,
                        endpoints,
                        max_nodes=self.scfg.admission_max_nodes,
                        keys=keys,
                        explain=explain,
                    )
                dt = time.perf_counter() - t0
                self.stats.batch_validated += counts.batch_validated
                self.stats.fallback_validated += counts.fallback_validated
                self.stats.undecided += counts.undecided
                self.stats.oversize += counts.oversize
                self.stats.unroll_overflow += counts.unroll_overflow
                self.stats.validation_seconds += dt
                # amortized latency: dt/n per validated row, grouped per
                # endpoint so each histogram takes one observe_many call
                per_row = dt / len(parsed)
                ep_rows: Dict[str, int] = {}
                for _, endpoint, _, _ in parsed:
                    ep_rows[endpoint] = ep_rows.get(endpoint, 0) + 1
                for endpoint, n in ep_rows.items():
                    self._latency(endpoint).observe_many(per_row, n)
                ev = self.events
                with _phase("serve.dispatch"):
                    for (i, endpoint, request, serial), verdict in zip(
                        parsed, verdicts
                    ):
                        out[i] = self._finish(endpoint, request, verdict)
                        if ev is not None and ev.want():
                            ev.emit(
                                kind="batch",
                                batch_id=batch_id,
                                endpoint=endpoint,
                                request_id=out[i].request_id,
                                outcome=verdict.outcome.value,
                                latency_s=per_row,
                                stages={
                                    "validate_s": dt,
                                    "batch_rows": len(parsed),
                                },
                            )
            ev = self.events
            for i, label, lat in guard_rejected:
                # true wall from batch entry to the parse/guard verdict
                # (was a flat 0.0 observation before §14)
                self._latency(label).observe(lat)
                if ev is not None and ev.want():
                    ev.emit(
                        kind="batch",
                        batch_id=batch_id,
                        endpoint=label,
                        request_id=None,
                        outcome=ValidationOutcome.REJECTED_GUARD.value,
                        latency_s=lat,
                        stages={},
                    )
            return out  # type: ignore[return-value]

    def _parse(self, request_json: str, endpoint: str):
        """Pre-validation gate: endpoint membership, payload byte guard,
        JSON decode.  Every reject here is a REJECTED_GUARD outcome; any
        decodable JSON value (including non-object top-levels like
        ``"5"`` or ``"[]"``) flows through to the normal validator
        verdict and never raises."""
        # endpoint membership first: by_endpoint buckets exist only for
        # registered endpoints (unknown names are client-controlled and
        # must not grow the stats dict without bound)
        if endpoint not in self.registry:
            self.stats.rejected += 1
            self.stats.record_outcome(ValidationOutcome.REJECTED_GUARD)
            return None, f"unknown endpoint {endpoint!r}"
        limit = self.registry.guard.max_bytes
        if len(request_json) > limit:
            self.stats.rejected += 1
            self.stats.count(endpoint, "rejected")
            self.stats.record_outcome(ValidationOutcome.REJECTED_GUARD)
            return None, f"payload {len(request_json)} bytes > guard cap {limit}"
        try:
            with _span("serve.parse", bytes=len(request_json)):
                request = json.loads(request_json)
        except json.JSONDecodeError as exc:
            self.stats.rejected += 1
            self.stats.count(endpoint, "rejected")
            self.stats.record_outcome(ValidationOutcome.REJECTED_GUARD)
            return None, f"malformed JSON: {exc}"
        except RecursionError:
            # hostile nesting can exhaust json.loads's recursive decoder
            # before any schema ever sees the document
            self.stats.rejected += 1
            self.stats.count(endpoint, "rejected")
            self.stats.record_outcome(ValidationOutcome.REJECTED_GUARD)
            return None, "malformed JSON: nesting exceeds the decode limit"
        return request, ""

    def _enqueue(self, request: Any, endpoint: str) -> int:
        rid = self._next_id
        self._next_id += 1
        self.stats.admitted += 1
        self.stats.count(endpoint, "admitted")
        prompt = _extract_prompt(request)
        if prompt is None:
            # validation-only request (no decodable text field): ack
            # immediately, and count it so silently-dropped decodes are
            # observable rather than indistinguishable from completions
            self.results[rid] = ""
            self.stats.completed += 1
            self.stats.validated_only += 1
            return rid
        # endpoint schemas are tenant-supplied: an open schema may admit a
        # non-integer or absurd max_tokens, which must not poison the
        # shared decode loop -- sanitize and clamp to the slot budget
        max_tokens = request.get("max_tokens", self.scfg.default_max_tokens)
        if isinstance(max_tokens, bool) or not isinstance(max_tokens, int):
            max_tokens = self.scfg.default_max_tokens
        max_tokens = max(1, min(max_tokens, self.scfg.max_len))
        slot = _Slot(
            request_id=rid,
            tokens=tokenizer.encode(prompt, eos=False),
            max_tokens=max_tokens,
        )
        self.queue.append(slot)
        return rid

    # -- execution ------------------------------------------------------------

    def _admit_to_slots(self) -> None:
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                slot = self.queue.pop(0)
                logits, cache = self.model.prefill(
                    self.params,
                    jnp.asarray([slot.tokens], jnp.int32),
                    max_len=self.scfg.max_len,
                )
                slot.length = len(slot.tokens)
                next_tok = int(jnp.argmax(logits[0, -1]))
                slot.generated.append(next_tok)
                if self._cache is None:
                    self._cache = self.model.init_cache(
                        self.scfg.batch_slots, self.scfg.max_len
                    )
                self._cache = _write_slot_cache(self._cache, cache, i)
                self.slots[i] = slot

    def step(self) -> int:
        """One engine tick: admit, decode one token for all active slots."""
        self._admit_to_slots()
        active = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        max_len_now = max(s.length + len(s.generated) for _, s in active)
        tokens = np.full((self.scfg.batch_slots, 1), tokenizer.PAD, np.int32)
        for i, s in active:
            tokens[i, 0] = s.generated[-1] if s.generated else s.tokens[-1]
        logits, self._cache = self._decode(
            self.params, jnp.asarray(tokens), self._cache, jnp.int32(max_len_now)
        )
        self.stats.decode_steps += 1
        for i, s in active:
            nxt = int(jnp.argmax(logits[i, 0]))
            s.generated.append(nxt)
            if nxt == tokenizer.EOS or len(s.generated) >= s.max_tokens:
                s.done = True
                self.results[s.request_id] = tokenizer.decode(s.generated)
                self.stats.completed += 1
                self.slots[i] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> Dict[int, str]:
        steps = 0
        while (any(self.slots) or self.queue) and steps < max_steps:
            self.step()
            steps += 1
        return dict(self.results)

    # -- streaming runtime (serve/scheduler.py, DESIGN.md §14) ----------------

    def scheduler(self, scheduler_cfg=None, **kw) -> "StreamScheduler":
        """A streaming micro-batcher over this engine.

        Requests :meth:`~repro.serve.scheduler.StreamScheduler.offer`-ed
        to the scheduler queue per link group and drain through the same
        admission/verdict path as :meth:`submit_batch` (identical
        :class:`SubmitResult` per request), with queue delay included in
        ``serve_request_seconds``.  Keyword arguments build a
        :class:`~repro.serve.scheduler.SchedulerConfig`.
        """
        from .scheduler import SchedulerConfig, StreamScheduler

        cfg = scheduler_cfg if scheduler_cfg is not None else SchedulerConfig(**kw)
        return StreamScheduler(self, cfg)


def _extract_prompt(request: Any) -> Optional[str]:
    """Decode text for a request: prompt / input / chat messages."""
    if isinstance(request, dict):
        for key in ("prompt", "input"):
            value = request.get(key)
            if isinstance(value, str):
                return value
        messages = request.get("messages")
        if isinstance(messages, list):
            parts = [
                m["content"]
                for m in messages
                if isinstance(m, dict) and isinstance(m.get("content"), str)
            ]
            if parts:
                return "\n".join(parts)
    return None


def _write_slot_cache(batch_cache, slot_cache, slot_idx: int):
    """Copy a prefilled single-request cache into batch slot ``slot_idx``."""

    def write(dst, src):
        if dst.ndim >= 2 and src.shape[0] == dst.shape[0]:  # (periods, B, ...)
            if src.shape[1] == 1 and dst.shape[1] > 1:
                return dst.at[:, slot_idx].set(src[:, 0].astype(dst.dtype))
        return dst

    return jax.tree.map(write, batch_cache, slot_cache)
