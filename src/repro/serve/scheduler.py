"""Streaming serve runtime: latency-budget micro-batching per link group.

Production traffic arrives one request at a time, not as pre-formed
B=4096 batches -- and at small B the linked launch is slower per doc
than the sequential engine (``BENCH_registry.json``).  The scheduler
turns the synchronous :class:`~repro.serve.engine.ServeEngine` admission
path into a stream runtime (DESIGN.md §14):

- :meth:`StreamScheduler.offer` parses/guards one request immediately
  (a guard reject is terminal at offer time, billed its true wall) and
  queues the survivor on its **link group's lane** (sequential-only
  endpoints get per-endpoint ``seq:`` lanes, so a degraded or
  unbatchable endpoint never holds up anyone else's drains).
- a lane fires when its oldest request has waited ``max_delay_s`` (the
  admission deadline) or the lane holds ``max_batch`` requests;
  :meth:`StreamScheduler.drain` serves the ready lane with the oldest
  head -- earliest-deadline-first over lanes, FIFO within a lane, which
  is starvation-free by construction.
- each drain routes through a measured **cost model**: predicted
  batched cost (one pow2-bucketed group launch, amortizing its fixed
  cost over the riders) versus predicted sequential cost (per-doc
  bounded oracle).  Small or cold bursts go sequential, hot bursts ride
  the group's linked tape.  Priors are seeded from committed ``BENCH_*``
  measurements and updated online with per-(lane, bucket) EMAs; sampled
  drains arm the §13 phase profiler so the update reads *attributed*
  encode+launch (or fallback) time rather than drain bookkeeping.

Both routes produce verdicts through the registry's containment ladder
and finish through ``ServeEngine._finish`` -- a request's
:class:`~repro.serve.engine.SubmitResult` is identical to what
``submit_batch`` would have produced, and per-request outcomes are
independent of drain timing (isolation keys are per-request serials, so
batch composition never changes a verdict; differentially tested).

Latency accounting closes the §13 under-count: ``serve_request_seconds``
observes **admission -> verdict wall including queue delay**
(``completion - arrival``), and ``serve_queue_delay_seconds`` tracks the
queueing component alone.  ``serve_queue_depth`` and
``serve_group_occupancy`` gauges expose the instantaneous backlog.
"""

from __future__ import annotations

import json
import time
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Deque, Dict, List, Optional, Tuple

from ..core.outcomes import ValidationOutcome
from ..obs.metrics import DEFAULT_LATENCY_BUCKETS
from ..obs.profile import Profiler, profiler_armed, set_profiler
from ..obs.trace import span as _span
from .engine import SubmitResult

__all__ = [
    "SchedulerConfig",
    "CostModel",
    "Ticket",
    "DrainReport",
    "StreamScheduler",
    "seed_priors_from_bench",
]

_ROUTES = ("auto", "batched", "sequential")


@dataclass
class SchedulerConfig:
    """Micro-batcher knobs (DESIGN.md §14)."""

    max_delay_s: float = 0.002  # admission deadline per request
    max_batch: int = 256  # lane drain cap (pow2-bucketed downstream)
    route: str = "auto"  # "auto" | "batched" | "sequential" (pinned)
    explain: bool = False  # first-failure attribution on INVALID
    # cost-model priors (µs); overridden by seed_priors_from_bench and
    # then by online EMA measurement
    launch_fixed_us: float = 2500.0  # per-launch fixed cost (encode+dispatch)
    launch_us_per_doc: float = 100.0  # marginal batched cost per rider
    seq_us_per_doc: float = 25.0  # bounded sequential oracle per doc
    ema_alpha: float = 0.25  # online update weight
    profile_every: int = 16  # arm the §13 profiler every Nth drain (0=off)
    # "auto" = seed priors from results/BENCH_registry.json when present;
    # a path seeds from that file; None/"" keeps the config priors
    bench_priors: Optional[str] = "auto"
    # pow2 batch shapes to pre-trace per group at attach time, so
    # deadline-bounded drains never pay a jit trace (empty = skip)
    warm_shapes: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if self.route not in _ROUTES:
            raise ValueError(f"route {self.route!r} not in {_ROUTES}")


def _bucket(n: int) -> int:
    """Power-of-two launch bucket (matches admission padding)."""
    return 1 << (n - 1).bit_length() if n > 1 else 1


def seed_priors_from_bench(path: Any) -> Optional[Dict[str, float]]:
    """Derive cost-model priors from a committed ``BENCH_registry.json``.

    Fits ``launch(B) = fixed + slope*B`` through the two smallest-B
    throughput rows of the *end-to-end* batched cost (linked launch +
    encode, both paid by a drain), and takes the most conservative
    (slowest) measured sequential per-doc cost.  Returns None when the
    file is missing or shaped unexpectedly -- callers keep their
    defaults.
    """
    try:
        data = json.loads(Path(path).read_text())
        rows = sorted(data["throughput"], key=lambda r: r["batch"])[:2]
        (b1, b2) = (rows[0]["batch"], rows[1]["batch"])
        if b1 == b2:
            return None
        total = [
            r["batch"] * (r["linked_us_per_doc"] + r["encode_us_per_doc"])
            for r in rows
        ]
        slope = (total[1] - total[0]) / (b2 - b1)
        fixed = total[0] - slope * b1
        seq = max(float(r["sequential_us_per_doc"]) for r in data["throughput"])
        if slope <= 0 or seq <= 0:
            return None
        return {
            "launch_fixed_us": max(fixed, 0.0),
            "launch_us_per_doc": slope,
            "seq_us_per_doc": seq,
        }
    except Exception:
        return None


class CostModel:
    """Measured batched-vs-sequential router (per lane).

    Prediction: ``batched_us(lane, n)`` is the EMA of measured wall for
    this lane's pow2 bucket when one exists, else the linear prior
    ``fixed + slope * bucket(n)`` (the launch pays the padded bucket, not
    n).  ``sequential_us(lane, n)`` is ``n`` times the lane's measured
    per-doc EMA (prior until measured).  Update rule (per drain):
    ``ema <- (1-alpha)*ema + alpha*observation``, keyed per (lane,
    bucket) for batched drains and per lane for sequential drains, so a
    fat group's launch cost never pollutes a lean group's routing.
    """

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.launch_fixed_us = cfg.launch_fixed_us
        self.launch_us_per_doc = cfg.launch_us_per_doc
        self.seq_us_per_doc = cfg.seq_us_per_doc
        self._launch_ema: Dict[Tuple[str, int], float] = {}
        self._seq_ema: Dict[str, float] = {}

    def seed(self, priors: Optional[Dict[str, float]]) -> None:
        if priors:
            self.launch_fixed_us = priors["launch_fixed_us"]
            self.launch_us_per_doc = priors["launch_us_per_doc"]
            self.seq_us_per_doc = priors["seq_us_per_doc"]

    def batched_us(self, lane: str, n: int) -> float:
        b = _bucket(n)
        ema = self._launch_ema.get((lane, b))
        if ema is not None:
            return ema
        return self.launch_fixed_us + self.launch_us_per_doc * b

    def sequential_us(self, lane: str, n: int) -> float:
        return n * self._seq_ema.get(lane, self.seq_us_per_doc)

    def prefer_batched(self, lane: str, n: int) -> bool:
        return self.batched_us(lane, n) < self.sequential_us(lane, n)

    def observe(self, lane: str, route: str, n: int, wall_us: float) -> None:
        a = self.cfg.ema_alpha
        if route == "batched":
            key = (lane, _bucket(n))
            prev = self._launch_ema.get(key)
            self._launch_ema[key] = (
                wall_us if prev is None else (1 - a) * prev + a * wall_us
            )
        else:
            per_doc = wall_us / max(n, 1)
            prev = self._seq_ema.get(lane)
            self._seq_ema[lane] = (
                per_doc if prev is None else (1 - a) * prev + a * per_doc
            )

    def snapshot(self) -> Dict[str, Any]:
        return {
            "priors": {
                "launch_fixed_us": self.launch_fixed_us,
                "launch_us_per_doc": self.launch_us_per_doc,
                "seq_us_per_doc": self.seq_us_per_doc,
            },
            "launch_ema_us": {
                f"{lane}@{b}": round(v, 3)
                for (lane, b), v in sorted(self._launch_ema.items())
            },
            "seq_ema_us_per_doc": {
                lane: round(v, 3) for lane, v in sorted(self._seq_ema.items())
            },
        }


@dataclass
class Ticket:
    """One offered request's handle; terminal after its drain."""

    endpoint: str
    serial: int
    arrival: float
    label: str = ""
    result: Optional[SubmitResult] = None
    latency_s: float = 0.0  # admission -> verdict, queue delay included
    queue_delay_s: float = 0.0

    @property
    def done(self) -> bool:
        return self.result is not None


@dataclass
class DrainReport:
    """What one :meth:`StreamScheduler.drain` did."""

    lane: str
    route: str  # "batched" | "sequential"
    n: int
    wall_s: float
    predicted_batched_us: float
    predicted_sequential_us: float


@dataclass
class _Queued:
    ticket: Ticket
    request: Any  # parsed document


@dataclass
class SchedulerStats:
    offered: int = 0
    rejected_at_offer: int = 0
    drains: int = 0
    drained: int = 0
    routed: Dict[str, int] = field(default_factory=lambda: {"batched": 0, "sequential": 0})


class StreamScheduler:
    """Micro-batching front end over one :class:`ServeEngine`.

    Synchronous by design (the repo's engines are synchronous): callers
    drive time with :meth:`drain`/:meth:`pump`/:meth:`flush`, and may
    inject ``now`` everywhere -- the open-loop load harness runs the
    scheduler on a virtual clock, tests on a hand-cranked one.  Wall
    time *inside* a drain is always measured on the real clock and added
    to the caller's ``now``, so latency billing stays honest in both
    modes.
    """

    def __init__(self, engine, cfg: Optional[SchedulerConfig] = None):
        from .engine import ServeEngine  # circular-import guard

        assert isinstance(engine, ServeEngine)
        self.engine = engine
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.clock = engine.registry.clock
        self.cost = CostModel(self.cfg)
        if self.cfg.bench_priors:
            path = self.cfg.bench_priors
            if path == "auto":
                path = (
                    Path(__file__).resolve().parents[3]
                    / "results"
                    / "BENCH_registry.json"
                )
            self.cost.seed(seed_priors_from_bench(path))
        self.stats = SchedulerStats()
        self._lanes: Dict[str, Deque[_Queued]] = {}
        m = engine.registry.metrics
        self._g_depth = m.gauge(
            "serve_queue_depth", "arrived-but-unserved requests at launch time"
        )
        self._h_qdelay: Dict[str, Any] = {}
        self._m_drains = {
            route: m.counter(
                "serve_drains_total",
                "scheduler drains by route",
                route=route,
            )
            for route in ("batched", "sequential")
        }
        self.last_profile: Optional[Dict[str, Any]] = None
        if self.cfg.warm_shapes:
            engine.registry.warm_groups(
                self.cfg.warm_shapes,
                max_nodes=engine.scfg.admission_max_nodes,
            )

    # -- admission -------------------------------------------------------------

    def offer(
        self, endpoint: str, request_json: str, *, now: Optional[float] = None
    ) -> Ticket:
        """Accept one request into the stream.

        Parse + pre-validation guards run immediately (their rejects are
        terminal here, billed the true offer wall); everything else
        queues on its link group's lane until :meth:`drain`.
        """
        now = self.clock() if now is None else now
        eng = self.engine
        t0 = time.perf_counter()
        eng.stats.received += 1
        serial = eng.stats.received
        ticket = Ticket(endpoint=endpoint, serial=serial, arrival=now)
        request, err = eng._parse(request_json, endpoint)
        ticket.label = endpoint if endpoint in eng.registry else "__unknown__"
        self.stats.offered += 1
        if err:
            self.stats.rejected_at_offer += 1
            result = SubmitResult(None, err, ValidationOutcome.REJECTED_GUARD)
            self._complete(
                ticket,
                result,
                latency_s=time.perf_counter() - t0,
                queue_delay_s=0.0,
                stages={"route": "offer"},
            )
            return ticket
        group = eng.registry.group_of(endpoint)
        lane = group.label if group is not None else f"seq:{endpoint}"
        q = self._lanes.get(lane)
        if q is None:
            q = self._lanes[lane] = deque()
        q.append(_Queued(ticket=ticket, request=request))
        self._g_depth.set(self.depth())
        self._occupancy(lane, len(q))
        return ticket

    def depth(self) -> int:
        """Total queued (offered, not yet drained) requests."""
        return sum(len(q) for q in self._lanes.values())

    def next_fire_s(self, now: Optional[float] = None) -> Optional[float]:
        """When the earliest lane becomes drainable (None = all empty).

        Returns ``now`` when some lane is already past its deadline or
        full -- the open-loop harness uses this to decide whether the
        server sleeps or launches.
        """
        now = self.clock() if now is None else now
        deadline: Optional[float] = None
        for q in self._lanes.values():
            if not q:
                continue
            if len(q) >= self.cfg.max_batch:
                return now
            d = q[0].ticket.arrival + self.cfg.max_delay_s
            deadline = d if deadline is None else min(deadline, d)
        if deadline is None:
            return None
        return max(deadline, now) if deadline > now else now

    # -- draining --------------------------------------------------------------

    def drain(
        self, *, now: Optional[float] = None, force: bool = False
    ) -> Optional[DrainReport]:
        """Serve ONE ready lane (earliest-deadline head first).

        A lane is ready when its head has aged past ``max_delay_s`` or
        the lane is full; ``force=True`` also drains a not-yet-due lane
        (used by :meth:`flush` and end-of-stream).  Returns None when
        nothing drained.
        """
        now = self.clock() if now is None else now
        candidates = []
        for lane, q in self._lanes.items():
            if not q:
                continue
            ready = (
                len(q) >= self.cfg.max_batch
                or now >= q[0].ticket.arrival + self.cfg.max_delay_s
            )
            if ready or force:
                candidates.append((q[0].ticket.arrival, lane))
        if not candidates:
            return None
        _, lane = min(candidates)
        q = self._lanes[lane]
        items = [q.popleft() for _ in range(min(len(q), self.cfg.max_batch))]
        report = self._serve(lane, items, now)
        self._g_depth.set(self.depth())
        self._occupancy(lane, len(q))
        return report

    def pump(self, now: Optional[float] = None) -> List[DrainReport]:
        """Drain every lane that is due at ``now``."""
        reports = []
        while True:
            r = self.drain(now=now)
            if r is None:
                return reports
            reports.append(r)

    def flush(self, now: Optional[float] = None) -> List[DrainReport]:
        """Force-drain everything (end of stream / shutdown)."""
        reports = []
        while self.depth():
            r = self.drain(now=now, force=True)
            if r is None:  # pragma: no cover -- depth>0 implies a lane
                break
            reports.append(r)
        return reports

    def _route(self, lane: str, n: int) -> str:
        if lane.startswith("seq:"):
            return "sequential"
        if self.cfg.route != "auto":
            return self.cfg.route
        return "batched" if self.cost.prefer_batched(lane, n) else "sequential"

    def _serve(self, lane: str, items: List[_Queued], now: float) -> DrainReport:
        eng = self.engine
        n = len(items)
        route = self._route(lane, n)
        pred_b = self.cost.batched_us(lane, n)
        pred_s = self.cost.sequential_us(lane, n)
        docs = [it.request for it in items]
        endpoints = [it.ticket.endpoint for it in items]
        keys = [("stream", it.ticket.serial) for it in items]
        # sampled §13 attribution: every Nth drain arms the phase
        # profiler (unless someone else is measuring) so the cost-model
        # update reads attributed encode+launch / fallback time
        prof: Optional[Profiler] = None
        sample = (
            self.cfg.profile_every > 0
            and self.stats.drains % self.cfg.profile_every == 0
            and not profiler_armed()
        )
        if sample:
            prof = Profiler()
            set_profiler(prof)
        t0 = time.perf_counter()
        try:
            with _span("serve.drain", lane=lane, route=route, batch=n):
                if route == "batched":
                    verdicts, counts = eng.registry.admit_mixed_ex(
                        docs,
                        endpoints,
                        max_nodes=eng.scfg.admission_max_nodes,
                        keys=keys,
                        explain=self.cfg.explain,
                    )
                    eng.stats.batch_validated += counts.batch_validated
                    eng.stats.fallback_validated += counts.fallback_validated
                    eng.stats.undecided += counts.undecided
                    eng.stats.oversize += counts.oversize
                    eng.stats.unroll_overflow += counts.unroll_overflow
                else:
                    verdicts = []
                    for doc, endpoint, key in zip(docs, endpoints, keys):
                        v = eng.registry.validate_one(
                            endpoint, doc, key=key, explain=self.cfg.explain
                        )
                        if v.outcome in (
                            ValidationOutcome.ADMITTED,
                            ValidationOutcome.INVALID,
                        ):
                            eng.stats.fallback_validated += 1
                        verdicts.append(v)
        finally:
            wall = time.perf_counter() - t0
            if sample:
                set_profiler(None)
        eng.stats.validation_seconds += wall
        self._observe_cost(lane, route, n, wall, prof)
        self.stats.drains += 1
        self.stats.drained += n
        self.stats.routed[route] += 1
        self._m_drains[route].inc()
        completion = now + wall
        for it, verdict in zip(items, verdicts):
            result = eng._finish(it.ticket.endpoint, it.request, verdict)
            self._complete(
                it.ticket,
                result,
                latency_s=completion - it.ticket.arrival,
                queue_delay_s=now - it.ticket.arrival,
                stages={
                    "route": route,
                    "drain_rows": n,
                    "drain_wall_s": wall,
                },
            )
        return DrainReport(
            lane=lane,
            route=route,
            n=n,
            wall_s=wall,
            predicted_batched_us=pred_b,
            predicted_sequential_us=pred_s,
        )

    def _observe_cost(
        self,
        lane: str,
        route: str,
        n: int,
        wall_s: float,
        prof: Optional[Profiler],
    ) -> None:
        """Online cost-model update; attributed phase time when sampled.

        On sampled drains the observation is the profiler's
        encode+launch(+explain) total for batched routes, or the
        ``fallback.sequential`` total for sequential routes -- the part
        of the drain a *bigger batch would amortize* -- falling back to
        raw wall when the phases did not fire (e.g. everything
        guard-rejected).
        """
        us = wall_s * 1e6
        if prof is not None:
            stats = prof.stats()
            names = (
                ("admit.encode", "admit.launch", "admit.explain")
                if route == "batched"
                else ("fallback.sequential",)
            )
            attributed = sum(
                stats[p].total_ns for p in names if p in stats
            ) / 1e3
            if attributed > 0:
                us = attributed
            self.last_profile = {
                "lane": lane,
                "route": route,
                "n": n,
                "wall_us": round(wall_s * 1e6, 3),
                "attributed_us": round(attributed, 3),
                "phases": {k: v.as_dict() for k, v in stats.items()},
            }
        self.cost.observe(lane, route, n, us)

    # -- completion ------------------------------------------------------------

    def _complete(
        self,
        ticket: Ticket,
        result: SubmitResult,
        *,
        latency_s: float,
        queue_delay_s: float,
        stages: Dict[str, Any],
    ) -> None:
        ticket.result = result
        ticket.latency_s = latency_s
        ticket.queue_delay_s = queue_delay_s
        eng = self.engine
        # admission -> verdict including queue delay: the stream runtime
        # never observes a flat 0.0 (§14 satellite of the §13 SLO layer)
        eng._latency(ticket.label).observe(max(latency_s, 0.0))
        self._qdelay(ticket.label).observe(max(queue_delay_s, 0.0))
        ev = eng.events
        if ev is not None and ev.want():
            ev.emit(
                kind="stream",
                endpoint=ticket.label,
                request_id=result.request_id,
                outcome=result.outcome.value,
                latency_s=latency_s,
                stages={**stages, "queue_delay_s": queue_delay_s},
            )

    def _qdelay(self, endpoint: str):
        h = self._h_qdelay.get(endpoint)
        if h is None:
            h = self._h_qdelay[endpoint] = self.engine.registry.metrics.histogram(
                "serve_queue_delay_seconds",
                "scheduler queue wait (offer -> drain start)",
                buckets=DEFAULT_LATENCY_BUCKETS,
                endpoint=endpoint,
            )
        return h

    def _occupancy(self, lane: str, depth: int) -> None:
        self.engine.registry.metrics.gauge(
            "serve_group_occupancy",
            "queued requests per link-group lane",
            group=lane,
        ).set(depth)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready scheduler view (cost model included)."""
        return {
            "offered": self.stats.offered,
            "rejected_at_offer": self.stats.rejected_at_offer,
            "drains": self.stats.drains,
            "drained": self.stats.drained,
            "routed": dict(self.stats.routed),
            "depth": self.depth(),
            "lanes": {lane: len(q) for lane, q in self._lanes.items()},
            "cost_model": self.cost.snapshot(),
        }
