"""Serving: request admission (Blaze), prefill/decode engine, KV caching."""

from .engine import ServeConfig, ServeEngine, SubmitResult
from .faults import FaultInjector

__all__ = ["ServeConfig", "ServeEngine", "SubmitResult", "FaultInjector"]
