"""Serving: request admission (Blaze), prefill/decode engine, KV caching."""

from .engine import ServeConfig, ServeEngine

__all__ = ["ServeConfig", "ServeEngine"]
