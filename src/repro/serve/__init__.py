"""Serving: request admission (Blaze), prefill/decode engine, KV caching,
and the streaming micro-batch scheduler (DESIGN.md §14)."""

from .engine import ServeConfig, ServeEngine, SubmitResult
from .faults import FaultInjector
from .scheduler import (
    CostModel,
    DrainReport,
    SchedulerConfig,
    StreamScheduler,
    Ticket,
)

__all__ = [
    "ServeConfig",
    "ServeEngine",
    "SubmitResult",
    "FaultInjector",
    "SchedulerConfig",
    "StreamScheduler",
    "CostModel",
    "DrainReport",
    "Ticket",
]
