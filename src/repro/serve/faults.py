"""Fault-injection harness for the serve stack (DESIGN.md §11).

Chaos testing needs failures that are *injectable, seeded, and
deterministic*: the same seed and traffic always poison the same
documents, so degradation invariants (poison isolation, stats
reconciliation, breaker trips) are assertable bit-for-bit.

The production layers expose seams via
:func:`repro.core.outcomes.fault_point` -- ``"encode"``, ``"launch"``,
``"fallback"``, ``"link"`` -- each a single global ``None`` check when no
harness is armed.  :class:`FaultInjector` is a context manager that arms
those seams:

    inj = FaultInjector(seed=7).poison("encode", 3, 17).rate("fallback", 0.05)
    with inj:
        verdicts, counts = registry.admit_mixed_ex(docs, endpoints)
    assert inj.fired["encode"] == 2

Selection is by explicit key (``poison``) or by a seeded rate
(``rate``): a key is poisoned iff ``blake2b(seed:point:key)`` falls
under the rate -- stable across runs, processes, and machines (unlike
``hash()``, which is salted per process).  The ``"launch"`` point
receives the tuple of document keys in the launch and raises when ANY
poisoned key is aboard -- exactly the failure mode the bisecting
launch isolator (``BatchValidator.validate_isolated``) is built to
contain.
"""

from __future__ import annotations

import hashlib
from typing import Any, Dict, Set

from ..core.outcomes import InjectedFault, set_fault_hook

__all__ = ["FaultInjector", "InjectedFault"]


def _stable_unit(seed: int, point: str, key: Any) -> float:
    """Deterministic uniform-[0,1) draw for (seed, point, key)."""
    digest = hashlib.blake2b(
        f"{seed}:{point}:{key!r}".encode(), digest_size=8
    ).digest()
    return int.from_bytes(digest, "big") / 2.0**64


class FaultInjector:
    """Seeded, deterministic fault plan; arm with ``with injector:``."""

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._keys: Dict[str, Set[Any]] = {}
        self._rates: Dict[str, float] = {}
        self.fired: Dict[str, int] = {}
        self._prev = None
        self._armed = False

    # -- plan construction (chainable) ----------------------------------------

    def poison(self, point: str, *keys: Any) -> "FaultInjector":
        """Poison specific document keys at ``point``."""
        self._keys.setdefault(point, set()).update(keys)
        return self

    def rate(self, point: str, probability: float) -> "FaultInjector":
        """Poison a seeded-deterministic fraction of keys at ``point``."""
        if not 0.0 <= probability <= 1.0:
            raise ValueError(f"rate {probability} outside [0, 1]")
        self._rates[point] = probability
        return self

    def selected(self, point: str, key: Any) -> bool:
        if key in self._keys.get(point, ()):
            return True
        p = self._rates.get(point, 0.0)
        return p > 0.0 and _stable_unit(self.seed, point, key) < p

    def poisoned_keys(self, point: str, keys) -> list:
        """The subset of ``keys`` this plan poisons at ``point``."""
        return [k for k in keys if self.selected(point, k)]

    # -- the armed hook --------------------------------------------------------

    def __call__(self, point: str, key: Any) -> None:
        if point == "launch" and isinstance(key, tuple):
            hit = self.poisoned_keys(point, key)
            if hit:
                self.fired[point] = self.fired.get(point, 0) + 1
                raise InjectedFault(
                    f"injected launch fault (poison keys {hit[:4]}"
                    f"{'...' if len(hit) > 4 else ''} aboard)"
                )
            return
        if self.selected(point, key):
            self.fired[point] = self.fired.get(point, 0) + 1
            raise InjectedFault(f"injected {point} fault at key {key!r}")

    # -- arming ----------------------------------------------------------------

    def __enter__(self) -> "FaultInjector":
        if self._armed:
            raise RuntimeError("FaultInjector already armed")
        self._prev = set_fault_hook(self)
        self._armed = True
        return self

    def __exit__(self, *exc) -> None:
        set_fault_hook(self._prev)
        self._prev = None
        self._armed = False
