"""Tape linker: relocate and concatenate member tapes into one linked tape.

A real gateway hosts many endpoint schemas, but the batched executor
wants exactly one :class:`~repro.core.tape.LocationTape` per kernel
launch.  The linker turns N compiled member tapes into a single
**linked** tape whose location-id space is the disjoint union of the
members': member ``s``'s location ``l`` becomes global location
``loc_offsets[s] + l``.  Per-document roots are seeded from
``roots[schema_id]`` (each member's root is its local location 0), so a
heterogeneous batch validates in one launch, bit-identically to
dispatching per-schema sub-batches.

Relocation scheme (DESIGN.md §8):

- **location-valued columns** (``prop_child_loc``, ``loc_addl``,
  ``loc_item``, ``prefix_loc``, owners) shift by ``loc_offsets[s]``;
  the negative sentinels (``LOC_UNTRACKED``, ``LOC_INVALID``,
  ``LOC_FRONTIER``, ``-1``) are preserved untouched -- a member's
  $ref-unroll frontier edges stay frontier edges after relocation.
- **assertion rows** concatenate in member order.  Rows are owner-sorted
  within each member and member ``s``'s locations all precede member
  ``s+1``'s, so the concatenation stays *globally* owner-sorted and the
  CSR windows stay contiguous: ``loc_asrt_start`` shifts by the member's
  row offset, ``loc_asrt_len`` is untouched.
- **enum OR-group ids** shift by the running maximum so they stay
  globally unique (the dense layout reduces groups globally).
- **circuit nodes** (logical applicators, DESIGN.md §10) concatenate in
  member order: ``circ_parent`` and ``asrt_circ`` shift by the member's
  circuit offset (-1 sentinels preserved), ``circ_owner`` by its
  location offset, ``circ_level`` is untouched and ``max_circ_depth``
  recomputes as the member maximum.  Presence gating makes members'
  circuits no-ops for documents of other members (their owner locations
  are never instantiated), so no per-member masking is needed.
- the **hash-sorted property view** (``psort_*``) concatenates per-member
  sorted segments (``member_prop_start``/``member_prop_len``, each row
  tagged ``psort_member`` for introspection).  The executor's hash pass
  scans only the querying document's member segment, so candidate runs
  *never span members* -- K stays the member maximum instead of
  inflating on shared key names (two endpoints both using ``"name"``
  must not see each other's transition rows).
- ``max_rows_per_loc`` (A-hat), ``max_hash_run`` (K) and
  ``max_loc_depth`` recompute as member maxima; ``member_horizons``
  additionally keeps every member's own horizon so per-document
  ``decided`` does not inflate when members disagree on depth.

Segmenting (placeholder-stripping + array grabs) touches only one
member's arrays, so :class:`TapeSegment` objects are cacheable per
compiled schema version -- re-linking after a hot-swap is pure
concatenation over mostly-cached segments (the registry's incremental
re-link path).
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.tape import LocationTape

__all__ = [
    "LinkedTape",
    "TapeSegment",
    "segment_tape",
    "link_tapes",
    "pow2_class",
    "group_signature",
    "signature_label",
]


# ---------------------------------------------------------------------------
# Link-group signatures (DESIGN.md §14)
#
# Linking inflates every member to the group maxima: Â (assertion window
# per node), M̂ (the member-windowed hash pass scans the fattest member's
# property rows) and the horizon (depth-loop trip count) all recompute as
# maxima over the linked members (§8).  One fat member therefore taxes
# every other member's launches -- the `charge` tagged union raising the
# shared Â 3→6 / M̂ 4→8 is the motivating case.  The registry avoids
# this by partitioning members into **link groups** of compatible
# signatures and cutting one linked tape per group.
#
# Compatibility is an equivalence relation so the partition is
# deterministic and independent of registration order: each window
# dimension is bucketed into its power-of-two ceiling class, and members
# sharing the class triple `(Â-class, M̂-class, horizon-class)` link
# together.  Within a group every dimension's linked maximum is bounded
# by the class, and any member sits within 2x of the group maximum
# (members of one class c all lie in (c/2, c]) -- in practice far
# closer, because the group constant is the max over *actual* member
# values, not the class ceiling.
# ---------------------------------------------------------------------------


def pow2_class(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()


def group_signature(tape: LocationTape) -> Tuple[int, int, int]:
    """The tape's link-group compatibility class: power-of-two ceilings
    of (Â, M̂, horizon) -- the three launch-cost constants that linking
    inflates to member maxima (§8)."""
    return (
        pow2_class(tape.max_rows_per_loc),
        pow2_class(tape.n_props),
        pow2_class(tape.max_loc_depth + 1),
    )


def signature_label(key: Tuple[int, int, int]) -> str:
    """Stable human-readable label for a group key (metrics/label-safe)."""
    return f"a{key[0]}.m{key[1]}.h{key[2]}"


@dataclass
class LinkedTape(LocationTape):
    """A LocationTape linked from N relocated member tapes.

    Executes on the unmodified batched executor (it *is* a
    ``LocationTape``); the extra fields record the member layout for
    introspection, tests and incremental re-linking.
    """

    members: Tuple[str, ...] = ()  # member names (endpoint ids) in order
    loc_offsets: Optional[np.ndarray] = None  # int32 (S,) location-id offset
    prop_offsets: Optional[np.ndarray] = None  # int32 (S,) property-row offset
    asrt_offsets: Optional[np.ndarray] = None  # int32 (S,) assertion-row offset
    member_n_locations: Optional[np.ndarray] = None  # int32 (S,)
    # per-member $ref-unroll metadata: the depth budget each member tape
    # was built with and how many frontier locations it carries (0 for
    # non-recursive members)
    member_unroll_depths: Optional[np.ndarray] = None  # int32 (S,)
    member_n_frontier: Optional[np.ndarray] = None  # int32 (S,)
    # per-member circuit-node counts (logical applicators)
    member_n_circuits: Optional[np.ndarray] = None  # int32 (S,)

    def member_of_location(self, loc: int) -> int:
        """Member index owning global location id ``loc``."""
        if not (0 <= loc < self.n_locations):
            raise IndexError(f"location {loc} outside [0, {self.n_locations})")
        return int(np.searchsorted(self.loc_offsets, loc, side="right") - 1)


@dataclass(frozen=True)
class TapeSegment:
    """One member tape's relocatable arrays, placeholders stripped.

    All arrays are views/copies of the member's own tape only, so a
    segment can be prepared once per (schema, version) and cached; the
    linker consumes segments and never re-reads the member tapes.
    """

    n_locations: int
    max_loc_depth: int
    # real property-transition rows (emission order)
    prop_owner: np.ndarray
    prop_hash: np.ndarray
    prop_child_loc: np.ndarray
    prop_required_slot: np.ndarray
    # hash-sorted view (sorted within the member; runs intact)
    psort_hash: np.ndarray
    psort_owner: np.ndarray
    psort_child_loc: np.ndarray
    psort_required_slot: np.ndarray
    psort_orig_row: np.ndarray
    psort_run_len: np.ndarray
    max_hash_run: int
    # per-location structural facts
    loc_closed: np.ndarray
    loc_addl: np.ndarray
    loc_item: np.ndarray
    loc_item_start: np.ndarray
    loc_prefix_start: np.ndarray
    loc_prefix_len: np.ndarray
    prefix_loc: np.ndarray  # real rows only
    loc_required_mask: np.ndarray
    # owner-sorted CSR assertion rows (real rows only)
    loc_asrt_start: np.ndarray
    loc_asrt_len: np.ndarray
    max_rows_per_loc: int
    asrt_owner: np.ndarray
    asrt_op: np.ndarray
    asrt_group: np.ndarray
    asrt_f0: np.ndarray
    asrt_i0: np.ndarray
    asrt_i1: np.ndarray
    asrt_u0: np.ndarray
    asrt_u1: np.ndarray
    asrt_hash: np.ndarray
    max_group: int
    # $ref-unroll facts (frontier locations mark exhausted budgets)
    loc_frontier: np.ndarray
    unroll_depth: int
    # logical-applicator circuits (real rows carry relocatable ids)
    asrt_circ: np.ndarray
    circ_kind: np.ndarray
    circ_parent: np.ndarray
    circ_owner: np.ndarray
    circ_level: np.ndarray
    max_circ_depth: int
    # provenance sidecars for first-failure attribution (DESIGN.md §12);
    # host-side tuples, aligned with the real rows above
    asrt_path: Tuple[str, ...] = ()
    loc_required_info: Tuple[Tuple[Tuple[int, str, str], ...], ...] = ()
    loc_closed_path: Tuple[str, ...] = ()
    circ_path: Tuple[str, ...] = ()

    @property
    def n_circuits(self) -> int:
        return len(self.circ_kind)

    @property
    def n_props(self) -> int:
        return len(self.prop_owner)

    @property
    def n_assertions(self) -> int:
        return len(self.asrt_owner)

    @property
    def n_prefix(self) -> int:
        return len(self.prefix_loc)


def segment_tape(tape: LocationTape) -> TapeSegment:
    """Strip the empty-table placeholder rows and freeze a member's arrays."""
    if tape.n_locations < 1:
        raise ValueError("member tape has no locations")
    if tape.n_members != 1:
        raise ValueError("cannot segment an already-linked tape")
    real_p = tape.prop_owner >= 0  # placeholder row only when 0 real rows
    real_a = tape.asrt_owner >= 0
    n_pfx = int(tape.loc_prefix_len.sum())  # placeholder [-1] when 0 rows
    return TapeSegment(
        n_locations=tape.n_locations,
        max_loc_depth=tape.max_loc_depth,
        prop_owner=tape.prop_owner[real_p],
        prop_hash=tape.prop_hash[real_p],
        prop_child_loc=tape.prop_child_loc[real_p],
        prop_required_slot=tape.prop_required_slot[real_p],
        psort_hash=tape.psort_hash[real_p],
        psort_owner=tape.psort_owner[real_p],
        psort_child_loc=tape.psort_child_loc[real_p],
        psort_required_slot=tape.psort_required_slot[real_p],
        psort_orig_row=tape.psort_orig_row[real_p],
        psort_run_len=tape.psort_run_len[real_p],
        max_hash_run=tape.max_hash_run,
        loc_closed=tape.loc_closed,
        loc_addl=tape.loc_addl,
        loc_item=tape.loc_item,
        loc_item_start=tape.loc_item_start,
        loc_prefix_start=tape.loc_prefix_start,
        loc_prefix_len=tape.loc_prefix_len,
        prefix_loc=tape.prefix_loc[:n_pfx],
        loc_required_mask=tape.loc_required_mask,
        loc_asrt_start=tape.loc_asrt_start,
        loc_asrt_len=tape.loc_asrt_len,
        max_rows_per_loc=tape.max_rows_per_loc,
        asrt_owner=tape.asrt_owner[real_a],
        asrt_op=tape.asrt_op[real_a],
        asrt_group=tape.asrt_group[real_a],
        asrt_f0=tape.asrt_f0[real_a],
        asrt_i0=tape.asrt_i0[real_a],
        asrt_i1=tape.asrt_i1[real_a],
        asrt_u0=tape.asrt_u0[real_a],
        asrt_u1=tape.asrt_u1[real_a],
        asrt_hash=tape.asrt_hash[real_a],
        max_group=int(tape.asrt_group.max()) if len(tape.asrt_group) else 0,
        loc_frontier=tape.loc_frontier,
        unroll_depth=tape.unroll_depth,
        asrt_circ=tape.asrt_circ[real_a],
        circ_kind=tape.circ_kind,
        circ_parent=tape.circ_parent,
        circ_owner=tape.circ_owner,
        circ_level=tape.circ_level,
        max_circ_depth=tape.max_circ_depth,
        asrt_path=tuple(
            p for p, r in zip(tape.asrt_path, real_a) if r
        ),
        loc_required_info=tuple(tape.loc_required_info),
        loc_closed_path=tuple(tape.loc_closed_path),
        circ_path=tuple(tape.circ_path),
    )


def _reloc(a: np.ndarray, off: int) -> np.ndarray:
    """Shift location ids by ``off``, preserving negative sentinels."""
    return np.where(a >= 0, a + np.int32(off), a).astype(np.int32)


def _exclusive_cumsum(counts: List[int]) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(counts[:-1])]).astype(np.int32) if counts else np.zeros(0, np.int32)


def link_tapes(
    tapes: Optional[Sequence[LocationTape]] = None,
    *,
    names: Optional[Sequence[str]] = None,
    segments: Optional[Sequence[TapeSegment]] = None,
) -> LinkedTape:
    """Link member tapes (or pre-cut segments) into one LinkedTape.

    Pass ``tapes`` for the one-shot path or ``segments`` (from
    :func:`segment_tape`, cacheable) for the incremental path; ``names``
    labels the members (defaults to ``"member<i>"``).
    """
    if segments is None:
        if not tapes:
            raise ValueError("link_tapes needs at least one member tape")
        segments = [segment_tape(t) for t in tapes]
    segments = list(segments)
    if not segments:
        raise ValueError("link_tapes needs at least one member")
    if names is None:
        names = [f"member{i}" for i in range(len(segments))]
    if len(names) != len(segments):
        raise ValueError("names/segments length mismatch")

    loc_off = _exclusive_cumsum([s.n_locations for s in segments])
    prop_off = _exclusive_cumsum([s.n_props for s in segments])
    asrt_off = _exclusive_cumsum([s.n_assertions for s in segments])
    pfx_off = _exclusive_cumsum([s.n_prefix for s in segments])

    cat = np.concatenate

    def cat_loc(field: str) -> np.ndarray:  # plain per-location concat
        return cat([getattr(s, field) for s in segments])

    # property table + hash-sorted view: owners/children relocate by the
    # member's location offset, original-row tie-break indices by its
    # property-row offset, and every psort row is tagged with its member
    prop_owner = cat([s.prop_owner + lo for s, lo in zip(segments, loc_off)])
    prop_child = cat([_reloc(s.prop_child_loc, lo) for s, lo in zip(segments, loc_off)])
    psort_member = cat(
        [np.full(s.n_props, i, np.int32) for i, s in enumerate(segments)]
    ) if prop_owner.size else np.zeros(0, np.int32)

    # enum OR-group ids stay globally unique: shift nonzero groups by the
    # running per-member maximum
    grp_off = _exclusive_cumsum([s.max_group for s in segments])
    asrt_group = cat(
        [np.where(s.asrt_group > 0, s.asrt_group + go, 0) for s, go in zip(segments, grp_off)]
    ).astype(np.int32)

    # circuit nodes concatenate; leaf wiring and parent pointers shift by
    # the member's circuit offset, owners by its location offset
    circ_off = _exclusive_cumsum([s.n_circuits for s in segments])
    asrt_circ = cat(
        [_reloc(s.asrt_circ, co) for s, co in zip(segments, circ_off)]
    ).astype(np.int32)
    circ_kind = cat([s.circ_kind for s in segments]).astype(np.int32)
    circ_parent = cat(
        [_reloc(s.circ_parent, co) for s, co in zip(segments, circ_off)]
    ).astype(np.int32)
    circ_owner = cat(
        [s.circ_owner + lo for s, lo in zip(segments, loc_off)]
    ).astype(np.int32)
    circ_level = cat([s.circ_level for s in segments]).astype(np.int32)

    linked = dict(
        n_locations=int(loc_off[-1]) + segments[-1].n_locations,
        max_loc_depth=max(s.max_loc_depth for s in segments),
        prop_owner=prop_owner.astype(np.int32),
        prop_hash=cat([s.prop_hash for s in segments]) if prop_owner.size else np.zeros((0, 8), np.uint32),
        prop_child_loc=prop_child,
        prop_required_slot=cat([s.prop_required_slot for s in segments]).astype(np.int32) if prop_owner.size else np.zeros(0, np.int32),
        psort_hash=cat([s.psort_hash for s in segments]) if prop_owner.size else np.zeros((0, 8), np.uint32),
        psort_owner=cat([s.psort_owner + lo for s, lo in zip(segments, loc_off)]).astype(np.int32),
        psort_child_loc=cat([_reloc(s.psort_child_loc, lo) for s, lo in zip(segments, loc_off)]),
        psort_required_slot=cat([s.psort_required_slot for s in segments]).astype(np.int32) if prop_owner.size else np.zeros(0, np.int32),
        psort_orig_row=cat([s.psort_orig_row + po for s, po in zip(segments, prop_off)]).astype(np.int32),
        psort_run_len=cat([s.psort_run_len for s in segments]).astype(np.int32) if prop_owner.size else np.zeros(0, np.int32),
        max_hash_run=max(s.max_hash_run for s in segments),
        loc_closed=cat_loc("loc_closed"),
        loc_addl=cat([_reloc(s.loc_addl, lo) for s, lo in zip(segments, loc_off)]),
        loc_item=cat([_reloc(s.loc_item, lo) for s, lo in zip(segments, loc_off)]),
        loc_item_start=cat_loc("loc_item_start").astype(np.int32),
        loc_prefix_start=cat([s.loc_prefix_start + po for s, po in zip(segments, pfx_off)]).astype(np.int32),
        loc_prefix_len=cat_loc("loc_prefix_len").astype(np.int32),
        prefix_loc=cat([_reloc(s.prefix_loc, lo) for s, lo in zip(segments, loc_off)]),
        loc_required_mask=cat_loc("loc_required_mask").astype(np.uint32),
        loc_asrt_start=cat([s.loc_asrt_start + ao for s, ao in zip(segments, asrt_off)]).astype(np.int32),
        loc_asrt_len=cat_loc("loc_asrt_len").astype(np.int32),
        max_rows_per_loc=max(s.max_rows_per_loc for s in segments),
        asrt_owner=cat([s.asrt_owner + lo for s, lo in zip(segments, loc_off)]).astype(np.int32),
        asrt_op=cat([s.asrt_op for s in segments]).astype(np.int32),
        asrt_group=asrt_group,
        asrt_f0=cat([s.asrt_f0 for s in segments]).astype(np.float64),
        asrt_i0=cat([s.asrt_i0 for s in segments]).astype(np.int32),
        asrt_i1=cat([s.asrt_i1 for s in segments]).astype(np.int32),
        asrt_u0=cat([s.asrt_u0 for s in segments]).astype(np.uint32),
        asrt_u1=cat([s.asrt_u1 for s in segments]).astype(np.uint32),
        asrt_hash=cat([s.asrt_hash for s in segments]).astype(np.uint32),
        psort_member=psort_member,
        roots=loc_off.copy(),
        member_horizons=np.array([s.max_loc_depth + 1 for s in segments], np.int32),
        member_prop_start=prop_off.copy(),
        member_prop_len=np.array([s.n_props for s in segments], np.int32),
        max_member_props=max(s.n_props for s in segments),
        # per-location frontier flags concatenate in member order (no
        # relocation needed; LOC_FRONTIER sentinels in the location-
        # valued columns above pass through ``_reloc`` untouched)
        loc_frontier=cat([s.loc_frontier for s in segments]).astype(bool),
        unroll_depth=max(s.unroll_depth for s in segments),
        asrt_circ=asrt_circ,
        circ_kind=circ_kind,
        circ_parent=circ_parent,
        circ_owner=circ_owner,
        circ_level=circ_level,
        max_circ_depth=max(s.max_circ_depth for s in segments),
        # provenance sidecars concatenate alongside their row tables
        asrt_path=sum((s.asrt_path for s in segments), ()),
        loc_required_info=sum((s.loc_required_info for s in segments), ()),
        loc_closed_path=sum((s.loc_closed_path for s in segments), ()),
        circ_path=sum((s.circ_path for s in segments), ()),
    )

    # empty-table placeholders, mirroring _TapeBuilder.build(): the
    # executor's gathers need at least one row per table
    if linked["prop_owner"].size == 0:
        linked.update(
            prop_owner=np.full(1, -1, np.int32),
            prop_hash=np.zeros((1, 8), np.uint32),
            prop_child_loc=np.full(1, -2, np.int32),
            prop_required_slot=np.full(1, -1, np.int32),
            psort_hash=np.zeros((1, 8), np.uint32),
            psort_owner=np.full(1, -1, np.int32),
            psort_child_loc=np.full(1, -2, np.int32),
            psort_required_slot=np.full(1, -1, np.int32),
            psort_orig_row=np.zeros(1, np.int32),
            psort_run_len=np.zeros(1, np.int32),
            psort_member=np.zeros(1, np.int32),
        )
    if linked["asrt_owner"].size == 0:
        linked.update(
            asrt_owner=np.full(1, -1, np.int32),
            asrt_op=np.zeros(1, np.int32),
            asrt_group=np.zeros(1, np.int32),
            asrt_f0=np.zeros(1, np.float64),
            asrt_i0=np.zeros(1, np.int32),
            asrt_i1=np.zeros(1, np.int32),
            asrt_u0=np.zeros(1, np.uint32),
            asrt_u1=np.zeros(1, np.uint32),
            asrt_hash=np.zeros((1, 8), np.uint32),
            asrt_circ=np.full(1, -1, np.int32),
            asrt_path=("",),
        )
    if linked["prefix_loc"].size == 0:
        linked["prefix_loc"] = np.full(1, -1, np.int32)

    out = LinkedTape(
        members=tuple(names),
        loc_offsets=loc_off,
        prop_offsets=prop_off,
        asrt_offsets=asrt_off,
        member_n_locations=np.array([s.n_locations for s in segments], np.int32),
        member_unroll_depths=np.array([s.unroll_depth for s in segments], np.int32),
        member_n_frontier=np.array(
            [int(np.count_nonzero(s.loc_frontier)) for s in segments], np.int32
        ),
        member_n_circuits=np.array([s.n_circuits for s in segments], np.int32),
        **linked,
    )
    if os.environ.get("REPRO_LINT_TAPES"):
        # structural-invariant linter (DESIGN.md §15); lazy import --
        # analysis sits above the linker in the layering
        from ..analysis.lint_tape import assert_tape

        assert_tape(out, label="link_tapes")
    return out
