"""Shared example endpoint schemas for the multi-tenant gateway.

One copy serves both the demo (``examples/api_gateway.py``) and the
mixed-traffic benchmark (``benchmarks/registry.py``) so the benchmark
always measures exactly the schemas the demo serves.
"""

from __future__ import annotations

from typing import Any, Dict

__all__ = ["GATEWAY_SCHEMAS"]

GATEWAY_SCHEMAS: Dict[str, Any] = {
    "complete": {
        "type": "object",
        "required": ["prompt"],
        "additionalProperties": False,
        "properties": {
            "prompt": {"type": "string", "minLength": 1, "maxLength": 65536},
            "max_tokens": {"type": "integer", "minimum": 1, "maximum": 4096},
            "temperature": {"type": "number", "minimum": 0, "maximum": 2},
            "stop": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
        },
    },
    "chat": {
        "type": "object",
        "required": ["messages"],
        "additionalProperties": False,
        "properties": {
            "messages": {
                "type": "array",
                "minItems": 1,
                "maxItems": 16,
                "items": {
                    "type": "object",
                    "required": ["role", "content"],
                    "additionalProperties": False,
                    "properties": {
                        "role": {"enum": ["system", "user", "assistant"]},
                        "content": {"type": "string", "minLength": 1},
                    },
                },
            },
            "max_tokens": {"type": "integer", "minimum": 1, "maximum": 4096},
        },
    },
    "embed": {
        "type": "object",
        "required": ["input"],
        "additionalProperties": False,
        "properties": {
            "input": {"type": "string", "minLength": 1, "maxLength": 8192},
            "dimensions": {"type": "integer", "minimum": 8, "maximum": 4096},
        },
    },
    "moderate": {
        "type": "object",
        "required": ["input", "category"],
        "additionalProperties": False,
        "properties": {
            "input": {"type": "string", "minLength": 1},
            "category": {"enum": ["toxicity", "violence", "spam"]},
        },
    },
    # tagged-union endpoint: the most common real-world API-payload shape
    # for logical applicators -- batchable via assertion-group circuits
    # (DESIGN.md §10), previously a guaranteed sequential fallback
    "charge": {
        "type": "object",
        "required": ["amount", "method"],
        "properties": {
            "amount": {"type": "integer", "minimum": 1, "maximum": 10_000_00},
            "currency": {"enum": ["usd", "eur", "gbp"]},
            "method": {
                "type": "object",
                "required": ["kind"],
                "properties": {"kind": {"enum": ["card", "bank", "wallet"]}},
                "oneOf": [
                    {
                        "properties": {
                            "kind": {"const": "card"},
                            "number": {"type": "string", "minLength": 12, "maxLength": 19},
                            "cvv": {"type": "string", "minLength": 3, "maxLength": 4},
                        },
                        "required": ["number", "cvv"],
                    },
                    {
                        "properties": {
                            "kind": {"const": "bank"},
                            "iban": {"type": "string", "minLength": 15, "maxLength": 34},
                        },
                        "required": ["iban"],
                    },
                    {
                        "properties": {
                            "kind": {"const": "wallet"},
                            "wallet_id": {"type": "string", "pattern": "^w-"},
                        },
                        "required": ["wallet_id"],
                    },
                ],
            },
        },
    },
}
