"""Shared example endpoint schemas for the multi-tenant gateway.

One copy serves both the demo (``examples/api_gateway.py``) and the
mixed-traffic benchmark (``benchmarks/registry.py``) so the benchmark
always measures exactly the schemas the demo serves.
"""

from __future__ import annotations

from typing import Any, Dict

__all__ = ["GATEWAY_SCHEMAS"]

GATEWAY_SCHEMAS: Dict[str, Any] = {
    "complete": {
        "type": "object",
        "required": ["prompt"],
        "additionalProperties": False,
        "properties": {
            "prompt": {"type": "string", "minLength": 1, "maxLength": 65536},
            "max_tokens": {"type": "integer", "minimum": 1, "maximum": 4096},
            "temperature": {"type": "number", "minimum": 0, "maximum": 2},
            "stop": {"type": "array", "items": {"type": "string"}, "maxItems": 4},
        },
    },
    "chat": {
        "type": "object",
        "required": ["messages"],
        "additionalProperties": False,
        "properties": {
            "messages": {
                "type": "array",
                "minItems": 1,
                "maxItems": 16,
                "items": {
                    "type": "object",
                    "required": ["role", "content"],
                    "additionalProperties": False,
                    "properties": {
                        "role": {"enum": ["system", "user", "assistant"]},
                        "content": {"type": "string", "minLength": 1},
                    },
                },
            },
            "max_tokens": {"type": "integer", "minimum": 1, "maximum": 4096},
        },
    },
    "embed": {
        "type": "object",
        "required": ["input"],
        "additionalProperties": False,
        "properties": {
            "input": {"type": "string", "minLength": 1, "maxLength": 8192},
            "dimensions": {"type": "integer", "minimum": 8, "maximum": 4096},
        },
    },
    "moderate": {
        "type": "object",
        "required": ["input", "category"],
        "additionalProperties": False,
        "properties": {
            "input": {"type": "string", "minLength": 1},
            "category": {"enum": ["toxicity", "violence", "spam"]},
        },
    },
}
